"""AOT bridge sanity: lowering emits HLO text the rust loader can parse.

We cannot run the rust loader from pytest, but we can assert the
artifact invariants the loader depends on: non-empty HLO text with an
ENTRY computation, a tupled 4-output root, and the expected parameter
shapes baked per bucket.
"""

import json
import os
import subprocess
import sys

import pytest

from compile.aot import BUCKETS, lower_bucket


def test_lower_smallest_bucket_shapes():
    n, h = 2048, 1024  # tiny non-standard bucket keeps the test fast
    text = lower_bucket(n, h)
    assert "ENTRY" in text and "ROOT" in text
    # 5 parameters with the right element counts.
    assert f"f32[{n}]" in text
    assert "s32[%d]" % n in text or f"s32[{n}]" in text
    assert "f32[5]" in text
    # outputs: label[n], hood_energy[h], stats[6], total[1] in root tuple.
    assert f"f32[{h}]" in text
    assert "f32[6]" in text
    assert "f32[1]" in text


def test_bucket_table_is_sane():
    prev = 0
    for n, h in BUCKETS:
        assert n % 1024 == 0, "kernel tile alignment"
        assert h <= n // 2, "every hood has >= 2 member instances"
        assert n > prev, "buckets strictly increasing"
        prev = n


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "model.hlo.txt"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(__file__))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--buckets", "2048:1024"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env,
    )
    assert out.exists()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["buckets"][0]["elems"] == 2048
    assert (tmp_path / man["buckets"][0]["file"]).exists()
