"""L1 correctness: Pallas energy/min kernel vs the pure-jnp oracle.

This is the CORE build-time correctness signal for the kernel that every
AOT artifact embeds. Hypothesis sweeps sizes, parameter ranges, and
degenerate label configurations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.energy import (BLOCK_ELEMS, energy_min,
                                    vmem_bytes_per_tile)
from compile.kernels.ref import energy_min_ref


def _mk_inputs(rng, n, mu=(40.0, 180.0), sigma=(12.0, 30.0), beta=0.5):
    y = rng.uniform(0.0, 255.0, n).astype(np.float32)
    label = rng.integers(0, 2, n).astype(np.float32)
    size_h = rng.integers(2, 40, n).astype(np.float32)
    ones_h = np.minimum(rng.integers(0, 40, n).astype(np.float32), size_h)
    params = np.array([mu[0], mu[1], sigma[0], sigma[1], beta],
                      dtype=np.float32)
    return y, label, ones_h, size_h, params


def _check(n, seed, **kw):
    rng = np.random.default_rng(seed)
    y, label, ones_h, size_h, params = _mk_inputs(rng, n, **kw)
    emin, amin = energy_min(*map(jnp.asarray, (y, label, ones_h, size_h,
                                               params)))
    remin, ramin = energy_min_ref(*map(jnp.asarray,
                                       (y, label, ones_h, size_h, params)))
    np.testing.assert_allclose(np.asarray(emin), np.asarray(remin),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(amin), np.asarray(ramin))


def test_kernel_matches_ref_smallest():
    _check(BLOCK_ELEMS, seed=0)


def test_kernel_matches_ref_multi_tile():
    _check(4 * BLOCK_ELEMS, seed=1)


def test_kernel_rejects_unaligned():
    with pytest.raises(ValueError):
        rng = np.random.default_rng(2)
        y, label, ones_h, size_h, params = _mk_inputs(rng, 100)
        energy_min(*map(jnp.asarray, (y, label, ones_h, size_h, params)))


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mu0=st.floats(min_value=0.0, max_value=255.0),
    mu1=st.floats(min_value=0.0, max_value=255.0),
    sig0=st.floats(min_value=0.5, max_value=100.0),
    sig1=st.floats(min_value=0.5, max_value=100.0),
    beta=st.floats(min_value=0.0, max_value=4.0),
)
def test_kernel_matches_ref_hypothesis(tiles, seed, mu0, mu1, sig0, sig1,
                                       beta):
    _check(tiles * BLOCK_ELEMS, seed=seed, mu=(mu0, mu1),
           sigma=(sig0, sig1), beta=beta)


def test_argmin_ties_prefer_label0():
    # e1 < e0 strict: on exact ties the kernel must pick label 0,
    # matching the rust engines' tie-break.
    n = BLOCK_ELEMS
    y = jnp.full((n,), 100.0, jnp.float32)
    label = jnp.zeros((n,), jnp.float32)
    ones_h = jnp.zeros((n,), jnp.float32)
    size_h = jnp.full((n,), 2.0, jnp.float32)
    # mu0 == mu1, sigma0 == sigma1, beta=0 -> exact tie.
    params = jnp.asarray([100.0, 100.0, 10.0, 10.0, 0.0], jnp.float32)
    _, amin = energy_min(y, label, ones_h, size_h, params)
    assert np.all(np.asarray(amin) == 0.0)


def test_energy_monotone_in_distance():
    # With beta=0 the minimum label must be the closer mean.
    n = BLOCK_ELEMS
    rng = np.random.default_rng(3)
    y = rng.uniform(0, 255, n).astype(np.float32)
    label = np.zeros(n, np.float32)
    ones_h = np.zeros(n, np.float32)
    size_h = np.full(n, 2.0, np.float32)
    params = np.array([50.0, 200.0, 10.0, 10.0, 0.0], np.float32)
    _, amin = energy_min(*map(jnp.asarray, (y, label, ones_h, size_h,
                                            params)))
    expect = (np.abs(y - 200.0) < np.abs(y - 50.0)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(amin), expect)


def test_vmem_budget():
    # DESIGN.md §Perf: one grid step must fit well under 64 KiB of VMEM.
    assert vmem_bytes_per_tile() <= 64 * 1024
