"""em_loop (in-device K-iteration MAP loop) vs an explicit python loop
over the same per-iteration semantics, including the per-vertex
min-energy/tie-break resolution the rust engines implement."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.energy import BLOCK_ELEMS
from compile.kernels.ref import energy_min_ref
from compile.model import em_loop


def np_reference_loop(y, label_v, hood_id, members, valid, vert_elems,
                      vert_seg, k, params, num_hoods, num_verts):
    """Literal numpy restatement of one..k MAP iterations."""
    label_v = label_v.copy()
    he = np.zeros(num_hoods)
    stats = np.zeros(6)
    total = 0.0
    n = y.shape[0]
    size_h = np.zeros(num_hoods)
    for i in range(n):
        size_h[hood_id[i]] += valid[i]
    for _ in range(k):
        lbl_e = label_v[members] * valid
        ones_h = np.zeros(num_hoods)
        for i in range(n):
            ones_h[hood_id[i]] += lbl_e[i]
        emin, amin = energy_min_ref(
            jnp.asarray(y), jnp.asarray(lbl_e),
            jnp.asarray(ones_h[hood_id].astype(np.float32)),
            jnp.asarray(size_h[hood_id].astype(np.float32)),
            jnp.asarray(params))
        emin = np.asarray(emin)
        amin = np.asarray(amin)
        # vertex resolution: min energy, tie -> min label
        new_label = label_v.copy()
        best_e = np.full(num_verts, np.inf)
        for s in range(n):
            v = vert_seg[s]
            best_e[v] = min(best_e[v], emin[vert_elems[s]])
        best_l = np.full(num_verts, 2.0)
        for s in range(n):
            v = vert_seg[s]
            if emin[vert_elems[s]] == best_e[v]:
                best_l[v] = min(best_l[v], amin[vert_elems[s]])
        for v in range(num_verts):
            if np.isfinite(best_e[v]):
                new_label[v] = best_l[v]
        label_v = new_label
        he = np.zeros(num_hoods)
        for i in range(n):
            he[hood_id[i]] += emin[i] * valid[i]
        total = float(np.sum(emin * valid))
        stats = np.zeros(6)
        for i in range(n):
            l = int(amin[i])
            stats[3 * l] += valid[i]
            stats[3 * l + 1] += y[i] * valid[i]
            stats[3 * l + 2] += y[i] * y[i] * valid[i]
    return label_v, he, stats, np.array([total])


def _mk_problem(rng, n, num_hoods, num_verts, pad_frac=0.0):
    y = rng.uniform(0, 255, n).astype(np.float32)
    label_v = rng.integers(0, 2, num_verts).astype(np.float32)
    hood_id = rng.integers(0, max(num_hoods - 1, 1), n).astype(np.int32)
    members = rng.integers(0, max(num_verts - 1, 1), n).astype(np.int32)
    valid = np.ones(n, np.float32)
    n_pad = int(n * pad_frac)
    if n_pad:
        valid[n - n_pad:] = 0.0
        hood_id[n - n_pad:] = num_hoods - 1
    # vertex grouping of the REAL elements; padded slots -> sacrificial
    # vertex num_verts-1
    order = np.argsort(members[: n - n_pad], kind="stable")
    vert_elems = np.concatenate(
        [order, np.zeros(n_pad, dtype=np.int64)]).astype(np.int32)
    vert_seg = np.concatenate([
        members[order],
        np.full(n_pad, num_verts - 1, dtype=np.int32),
    ]).astype(np.int32)
    params = np.array([40.0, 180.0, 12.0, 30.0, 0.5], np.float32)
    return y, label_v, hood_id, members, valid, vert_elems, vert_seg, params


def _run(seed, k, pad_frac=0.0):
    rng = np.random.default_rng(seed)
    n, num_hoods, num_verts = BLOCK_ELEMS, 64, 200
    (y, label_v, hood_id, members, valid, vert_elems, vert_seg,
     params) = _mk_problem(rng, n, num_hoods, num_verts, pad_frac)

    got = em_loop(
        jnp.asarray(y), jnp.asarray(label_v), jnp.asarray(hood_id),
        jnp.asarray(members), jnp.asarray(valid), jnp.asarray(vert_elems),
        jnp.asarray(vert_seg), jnp.asarray([k], dtype=jnp.int32),
        jnp.asarray(params), num_hoods=num_hoods, num_verts=num_verts)
    want = np_reference_loop(y, label_v, hood_id, members, valid,
                             vert_elems, vert_seg, k, params, num_hoods,
                             num_verts)
    gl, ghe, gstats, gtotal = map(np.asarray, got)
    wl, whe, wstats, wtotal = want
    # padded slots may have perturbed the sacrificial vertex; ignore it
    np.testing.assert_array_equal(gl[: num_verts - 1],
                                  wl[: num_verts - 1])
    np.testing.assert_allclose(ghe[: num_hoods - 1],
                               whe[: num_hoods - 1], rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(gstats, wstats, rtol=1e-4, atol=1e-1)
    np.testing.assert_allclose(gtotal, wtotal, rtol=1e-4, atol=1e-1)


def test_single_iteration():
    _run(seed=0, k=1)


def test_multi_iteration():
    _run(seed=1, k=4)


def test_with_padding():
    _run(seed=2, k=3, pad_frac=0.2)


def test_k_zero_returns_initial_labels():
    rng = np.random.default_rng(3)
    n, nh, nv = BLOCK_ELEMS, 32, 100
    (y, label_v, hood_id, members, valid, vert_elems, vert_seg,
     params) = _mk_problem(rng, n, nh, nv)
    got = em_loop(
        jnp.asarray(y), jnp.asarray(label_v), jnp.asarray(hood_id),
        jnp.asarray(members), jnp.asarray(valid), jnp.asarray(vert_elems),
        jnp.asarray(vert_seg), jnp.asarray([0], dtype=jnp.int32),
        jnp.asarray(params), num_hoods=nh, num_verts=nv)
    np.testing.assert_array_equal(np.asarray(got[0]), label_v)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 5),
       pad=st.floats(0.0, 0.4))
def test_em_loop_hypothesis(seed, k, pad):
    _run(seed=seed, k=k, pad_frac=pad)
