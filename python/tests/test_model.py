"""L2 correctness: em_step vs a literal numpy oracle, incl. padding."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.energy import BLOCK_ELEMS
from compile.model import em_step, update_params


def np_oracle(y, label, hood_id, valid, params, num_hoods):
    """Straight-line numpy re-statement of the EM step."""
    mu = [params[0], params[1]]
    sig = [params[2], params[3]]
    beta = params[4]
    n = y.shape[0]
    ones_h = np.zeros(num_hoods)
    size_h = np.zeros(num_hoods)
    for i in range(n):
        ones_h[hood_id[i]] += label[i] * valid[i]
        size_h[hood_id[i]] += valid[i]
    new_label = np.zeros(n, np.float32)
    emin = np.zeros(n, np.float64)
    for i in range(n):
        h = hood_id[i]
        es = []
        for l in (0, 1):
            data = (y[i] - mu[l]) ** 2 / (2 * sig[l] ** 2) + np.log(sig[l])
            if l == 0:
                dis = ones_h[h] - label[i]
            else:
                dis = (size_h[h] - ones_h[h]) - (1 - label[i])
            es.append(data + beta * dis)
        new_label[i] = 1.0 if es[1] < es[0] else 0.0
        emin[i] = min(es)
    hood_energy = np.zeros(num_hoods)
    for i in range(n):
        hood_energy[hood_id[i]] += emin[i] * valid[i]
    stats = np.zeros(6)
    for i in range(n):
        l = int(new_label[i])
        stats[3 * l] += valid[i]
        stats[3 * l + 1] += y[i] * valid[i]
        stats[3 * l + 2] += y[i] * y[i] * valid[i]
    return (new_label, emin, hood_energy, stats,
            np.array([np.sum(emin * valid)]))


def _run_case(seed, n, num_hoods, pad_frac):
    rng = np.random.default_rng(seed)
    y = rng.uniform(0, 255, n).astype(np.float32)
    label = rng.integers(0, 2, n).astype(np.float32)
    hood_id = rng.integers(0, max(num_hoods - 1, 1), n).astype(np.int32)
    valid = np.ones(n, np.float32)
    n_pad = int(n * pad_frac)
    if n_pad:
        valid[n - n_pad:] = 0.0
        hood_id[n - n_pad:] = num_hoods - 1
    params = np.array([40.0, 180.0, 12.0, 30.0, 0.5], np.float32)

    got = em_step(jnp.asarray(y), jnp.asarray(label), jnp.asarray(hood_id),
                  jnp.asarray(valid), jnp.asarray(params),
                  num_hoods=num_hoods)
    want = np_oracle(y, label, hood_id, valid, params, num_hoods)

    nl, emin, he, stats, total = map(np.asarray, got)
    wnl, wemin, whe, wstats, wtotal = want
    real = valid > 0
    np.testing.assert_array_equal(nl[real], wnl[real])
    np.testing.assert_allclose(emin[real], wemin[real], rtol=1e-5,
                               atol=1e-4)
    np.testing.assert_allclose(he, whe, rtol=1e-4, atol=1e-3)
    # stats include padded lanes' labels with valid=0 weight -> exact match
    np.testing.assert_allclose(stats, wstats, rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(total, wtotal, rtol=1e-4, atol=1e-2)


def test_em_step_no_padding():
    _run_case(seed=0, n=BLOCK_ELEMS, num_hoods=128, pad_frac=0.0)


def test_em_step_with_padding():
    _run_case(seed=1, n=BLOCK_ELEMS, num_hoods=128, pad_frac=0.25)


def test_em_step_multi_tile():
    _run_case(seed=2, n=2 * BLOCK_ELEMS, num_hoods=400, pad_frac=0.1)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       hoods=st.integers(2, 512),
       pad=st.floats(0.0, 0.5))
def test_em_step_hypothesis(seed, hoods, pad):
    _run_case(seed=seed, n=BLOCK_ELEMS, num_hoods=hoods, pad_frac=pad)


def test_update_params_matches_closed_form():
    stats = jnp.asarray([4.0, 40.0, 500.0, 2.0, 300.0, 46000.0], jnp.float32)
    out = np.asarray(update_params(stats))
    # label0: mu=10, var=500/4-100=25 -> sigma=5
    np.testing.assert_allclose(out[0], 10.0, rtol=1e-6)
    np.testing.assert_allclose(out[1], 5.0, rtol=1e-6)
    # label1: mu=150, var=23000-22500=500
    np.testing.assert_allclose(out[2], 150.0, rtol=1e-6)
    np.testing.assert_allclose(out[3], np.sqrt(500.0), rtol=1e-5)


def test_update_params_sigma_floor_and_empty_label():
    # Empty label bucket must not divide by zero; sigma floored at 1.0.
    stats = jnp.asarray([0.0, 0.0, 0.0, 3.0, 30.0, 300.0], jnp.float32)
    out = np.asarray(update_params(stats))
    assert np.isfinite(out).all()
    assert out[1] >= 1.0 and out[3] >= 1.0
