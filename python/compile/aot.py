"""AOT bridge: lower the L2 EM step to HLO *text* artifacts.

HLO text — not ``HloModuleProto.serialize()`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids that the
xla_extension 0.5.1 used by the rust `xla` crate rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The EM step is shape-monomorphic, so we emit one artifact per size
*bucket* plus a ``manifest.json`` the rust runtime uses to pick the
smallest bucket that fits a batch (padding the rest):

    artifacts/
      em_step_n<elems>_h<hoods>.hlo.txt
      model.hlo.txt          (alias of the smallest bucket, Makefile dep)
      manifest.json

Usage: ``python -m compile.aot --out ../artifacts/model.hlo.txt``
"""

from __future__ import annotations

import argparse
import json
import os
import shutil

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import em_loop_fn, em_step_fn

# (elements, hoods) buckets. Elements must be multiples of 1024 (kernel
# tile); hoods = elements/2 upper-bounds any real batch (every hood has
# >= 2 member instances). 2x spacing keeps the mean padding waste at
# ~1.5x (§Perf: padded-lane compute dominates XLA-path cost on CPU).
BUCKETS = [
    (4096, 2048),
    (8192, 4096),
    (16384, 8192),
    (32768, 16384),
    (65536, 32768),
    (131072, 65536),
    (262144, 131072),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, num_hoods: int) -> str:
    f32 = jnp.float32
    spec_n = jax.ShapeDtypeStruct((n,), f32)
    spec_i = jax.ShapeDtypeStruct((n,), jnp.int32)
    spec_p = jax.ShapeDtypeStruct((5,), f32)
    lowered = jax.jit(em_step_fn(num_hoods)).lower(
        spec_n, spec_n, spec_i, spec_n, spec_p
    )
    return to_hlo_text(lowered)


def lower_loop_bucket(n: int, num_hoods: int, num_verts: int) -> str:
    """The in-device K-iteration MAP loop (§Perf L2). Vertex capacity
    equals the element capacity (every vertex owns >= 1 element)."""
    f32 = jnp.float32
    spec_n = jax.ShapeDtypeStruct((n,), f32)
    spec_v = jax.ShapeDtypeStruct((num_verts,), f32)
    spec_i = jax.ShapeDtypeStruct((n,), jnp.int32)
    spec_k = jax.ShapeDtypeStruct((1,), jnp.int32)
    spec_p = jax.ShapeDtypeStruct((5,), f32)
    lowered = jax.jit(em_loop_fn(num_hoods, num_verts)).lower(
        spec_n, spec_v, spec_i, spec_i, spec_n, spec_i, spec_i, spec_k,
        spec_p,
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="alias path for the smallest bucket artifact")
    ap.add_argument("--buckets", default=None,
                    help="comma list of n:h overrides, e.g. 4096:2048")
    args = ap.parse_args()

    buckets = BUCKETS
    if args.buckets:
        buckets = [tuple(int(x) for x in b.split(":"))
                   for b in args.buckets.split(",")]

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 2, "entry": "main", "buckets": [],
                "loop_buckets": []}
    first_path = None
    for n, h in buckets:
        text = lower_bucket(n, h)
        name = f"em_step_n{n}_h{h}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append({
            "elems": n,
            "hoods": h,
            "file": name,
            "outputs": ["new_label[n]", "emin[n]", "hood_energy[h]",
                        "stats[6]", "total[1]"],
        })
        if first_path is None:
            first_path = path
        print(f"wrote {path} ({len(text)} chars)")

        v = n  # vertex capacity (see lower_loop_bucket)
        text = lower_loop_bucket(n, h, v)
        name = f"em_loop_n{n}_h{h}_v{v}.hlo.txt"
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["loop_buckets"].append({
            "elems": n,
            "hoods": h,
            "verts": v,
            "file": name,
            "outputs": ["label_v[v]", "hood_energy[h]", "stats[6]",
                        "total[1]"],
        })
        print(f"wrote {path} ({len(text)} chars)")

    shutil.copyfile(first_path, args.out)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out} (alias) and manifest.json")


if __name__ == "__main__":
    main()
