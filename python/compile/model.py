"""L2 — the DPP-PMRF EM/MAP inner step as a single jax computation.

One call of :func:`em_step` performs, for a padded batch of neighborhood
member instances (the paper's replicated ``hoods`` array, §3.2.2):

  1. per-hood label statistics   (ReduceByKey<Add>  -> segment_sum)
  2. gather of hood stats back to elements (Gather  -> take)
  3. fused energy Map + per-vertex two-label Min    (L1 Pallas kernel)
  4. per-hood minimum-energy sums (ReduceByKey<Add> -> segment_sum)
  5. global parameter-update statistics per label   (Reduce<Add>)

The function is shape-monomorphic: ``n`` (element count, multiple of
1024) and ``num_hoods`` are baked into each AOT artifact; the rust
runtime picks the smallest bucket that fits and pads (see
``rust/src/runtime/``). Convergence logic (MAP window, EM window) stays
on the rust side — it is control flow over a handful of scalars.

Inputs
  y        f32[n]  region mean intensity per hood-member instance
  label    f32[n]  current label (0/1) per instance
  hood_id  i32[n]  owning neighborhood id; padding points at num_hoods-1
  valid    f32[n]  1.0 for real elements, 0.0 for padding
  params   f32[5]  (mu0, mu1, sigma0, sigma1, beta)

Outputs (a 5-tuple; lowered with return_tuple=True)
  new_label   f32[n]   argmin-energy label per instance
  emin        f32[n]   per-instance minimum energy (the rust host needs
                       it for the cross-hood per-vertex resolution)
  hood_energy f32[H]   sum of per-instance min energies per hood
  stats       f32[6]   (count0, sum_y0, sum_y2_0, count1, sum_y1, sum_y2_1)
                       over instances, for the host-side mu/sigma update
  total       f32[1]   global energy sum (EM convergence scalar)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import energy as energy_kernel


def em_step(y, label, hood_id, valid, params, *, num_hoods: int):
    """One MAP iteration over a padded element batch. See module docs."""
    lv = label * valid
    ones_h = jax.ops.segment_sum(lv, hood_id, num_segments=num_hoods)
    size_h = jax.ops.segment_sum(valid, hood_id, num_segments=num_hoods)

    # Gather the per-hood stats back to the element lanes.
    ones_e = jnp.take(ones_h, hood_id)
    size_e = jnp.take(size_h, hood_id)

    emin, new_label = energy_kernel.energy_min(y, label, ones_e, size_e,
                                               params)

    emin_v = emin * valid
    hood_energy = jax.ops.segment_sum(emin_v, hood_id,
                                      num_segments=num_hoods)
    total = jnp.sum(emin_v).reshape(1)

    take1 = new_label * valid
    take0 = (1.0 - new_label) * valid
    stats = jnp.stack([
        jnp.sum(take0),
        jnp.sum(y * take0),
        jnp.sum(y * y * take0),
        jnp.sum(take1),
        jnp.sum(y * take1),
        jnp.sum(y * y * take1),
    ])
    return new_label, emin, hood_energy, stats, total


def em_step_fn(num_hoods: int):
    """Monomorphic closure over ``num_hoods`` suitable for jax.jit/lower."""

    def fn(y, label, hood_id, valid, params):
        return em_step(y, label, hood_id, valid, params,
                       num_hoods=num_hoods)

    return fn


def em_loop(y, label_v, hood_id, members, valid, vert_elems, vert_seg, k,
            params, *, num_hoods: int, num_verts: int):
    """K MAP iterations fully in-device (§Perf L2: one dispatch per EM
    iteration instead of one per MAP iteration).

    Extra inputs vs :func:`em_step`:
      label_v     f32[V]  per-VERTEX labels (carried through the loop)
      members     i32[n]  element -> vertex id (label gather)
      vert_elems  i32[n]  element ids grouped by vertex
      vert_seg    i32[n]  vertex id per grouped slot (padding -> V-1)
      k           i32[1]  MAP iteration count (dynamic fori_loop bound)

    Per iteration: gather labels to elements; per-hood stats; fused
    Pallas energy/min; per-vertex resolution via two segment_min passes
    (minimum energy, then minimum label among exact-energy ties — the
    same deterministic rule as the rust engines); labels update
    in-device.

    Returns (label_v f32[V], hood_energy f32[H], stats f32[6],
    total f32[1]) from the final iteration.
    """
    n = y.shape[0]
    size_h = jax.ops.segment_sum(valid, hood_id, num_segments=num_hoods)
    size_e = jnp.take(size_h, hood_id)
    # Slots of padded vertices contribute to the sacrificial segment.
    slot_count = jax.ops.segment_sum(
        jnp.ones((n,), jnp.float32), vert_seg, num_segments=num_verts)
    has_elems = slot_count > 0.0

    def body(_, carry):
        label_v, _he, _stats, _total = carry
        lbl_e = jnp.take(label_v, members) * valid
        ones_h = jax.ops.segment_sum(lbl_e, hood_id,
                                     num_segments=num_hoods)
        ones_e = jnp.take(ones_h, hood_id)
        emin, amin = energy_kernel.energy_min(y, lbl_e, ones_e, size_e,
                                              params)
        # Per-vertex min-energy resolution (ties -> label 0): pass 1
        # finds each vertex's minimum energy; pass 2 takes the minimum
        # label among the slots that attain it exactly.
        emin_by_vert = jnp.take(emin, vert_elems)
        amin_by_vert = jnp.take(amin, vert_elems)
        best_e = jax.ops.segment_min(emin_by_vert, vert_seg,
                                     num_segments=num_verts)
        at_min = emin_by_vert == jnp.take(best_e, vert_seg)
        label_cand = jnp.where(at_min, amin_by_vert, 2.0)
        resolved = jax.ops.segment_min(label_cand, vert_seg,
                                       num_segments=num_verts)
        new_label_v = jnp.where(has_elems, resolved, label_v)

        emin_v = emin * valid
        hood_energy = jax.ops.segment_sum(emin_v, hood_id,
                                          num_segments=num_hoods)
        total = jnp.sum(emin_v).reshape(1)
        take1 = amin * valid
        take0 = (1.0 - amin) * valid
        stats = jnp.stack([
            jnp.sum(take0), jnp.sum(y * take0), jnp.sum(y * y * take0),
            jnp.sum(take1), jnp.sum(y * take1), jnp.sum(y * y * take1),
        ])
        return new_label_v, hood_energy, stats, total

    init = (
        label_v,
        jnp.zeros((num_hoods,), jnp.float32),
        jnp.zeros((6,), jnp.float32),
        jnp.zeros((1,), jnp.float32),
    )
    return jax.lax.fori_loop(0, k[0], body, init)


def em_loop_fn(num_hoods: int, num_verts: int):
    """Monomorphic closure suitable for jax.jit/lower."""

    def fn(y, label_v, hood_id, members, valid, vert_elems, vert_seg, k,
           params):
        return em_loop(y, label_v, hood_id, members, valid, vert_elems,
                       vert_seg, k, params, num_hoods=num_hoods,
                       num_verts=num_verts)

    return fn


def update_params(stats, sigma_floor: float = 1.0):
    """Host-side mu/sigma re-estimation from ``stats`` (mirrors rust).

    Exposed in python for the oracle tests; the production path lives in
    ``rust/src/mrf/params.rs``.
    """
    out = []
    for l in (0, 1):
        cnt, s, s2 = stats[3 * l], stats[3 * l + 1], stats[3 * l + 2]
        cnt = jnp.maximum(cnt, 1.0)
        mu = s / cnt
        var = jnp.maximum(s2 / cnt - mu * mu, 0.0)
        sigma = jnp.maximum(jnp.sqrt(var), sigma_floor)
        out.extend([mu, sigma])
    return jnp.stack(out)  # (mu0, sigma0, mu1, sigma1)
