"""Pure-jnp oracle for the fused energy/min kernel.

This is the correctness reference the Pallas kernel (``energy.py``) and
the rust engines (``rust/src/mrf/energy.rs``) are tested against. Keep
the math literal and boring — no fusion tricks here.
"""

from __future__ import annotations

import jax.numpy as jnp


def energy_both(y, label, ones_h, size_h, params):
    """Energies for both labels; returns (e0 f32[n], e1 f32[n])."""
    mu0, mu1, sig0, sig1, beta = (params[0], params[1], params[2],
                                  params[3], params[4])
    e0 = (y - mu0) ** 2 / (2.0 * sig0 ** 2) + jnp.log(sig0)
    e1 = (y - mu1) ** 2 / (2.0 * sig1 ** 2) + jnp.log(sig1)
    dis0 = ones_h - label
    dis1 = (size_h - ones_h) - (1.0 - label)
    return e0 + beta * dis0, e1 + beta * dis1


def energy_min_ref(y, label, ones_h, size_h, params):
    """Oracle for ``energy.energy_min``: (emin f32[n], argmin f32[n])."""
    e0, e1 = energy_both(y, label, ones_h, size_h, params)
    take1 = e1 < e0
    emin = jnp.where(take1, e1, e0)
    argmin = jnp.where(take1, 1.0, 0.0).astype(jnp.float32)
    return emin, argmin
