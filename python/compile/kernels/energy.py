"""L1 — Pallas kernel for the DPP-PMRF energy hot spot.

The paper's single most compute-heavy DPP is the *Map* that evaluates the
MRF energy function for every replicated neighborhood vertex, immediately
followed by the per-vertex minimum over the two class labels (§3.2.2,
"Compute Energy Function" + "Compute Minimum Vertex and Label Energies").
In the paper those are separate primitives (Map, then SortByKey +
ReduceByKey<Min>); on the accelerator path we *fuse* them: one kernel
computes both label energies in registers and writes only the per-vertex
minimum energy and argmin label. The label pair never round-trips to HBM.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): the replicated
vertex array is reshaped to [rows, 128] (lane-aligned) and tiled in
(8, 128) VMEM blocks over a 1D grid; all per-element operands stream
through VMEM, while the five scalar parameters (mu0, mu1, sigma0,
sigma1, beta) ride in a single small block replicated to every tile.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
bridge ships to the rust runtime.

Energy model (must stay in lockstep with ``rust/src/mrf/energy.rs`` and
``kernels/ref.py``):

    E(v, l) = (y_v - mu_l)^2 / (2 sigma_l^2) + ln(sigma_l)
              + beta * disagree(v, l)

where ``disagree(v, l)`` is the number of *other* members of v's
neighborhood whose current label differs from l:

    disagree(v, 0) = ones_h - label_v
    disagree(v, 1) = (size_h - ones_h) - (1 - label_v)

with ``ones_h`` = count of members of v's hood currently labeled 1 and
``size_h`` = member count of v's hood, both gathered per element by L2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry: TPU-native (sublane, lane) = (8, 128) f32 tile.
BLOCK_ROWS = 8
LANES = 128
BLOCK_ELEMS = BLOCK_ROWS * LANES


def _energy_min_kernel(y_ref, label_ref, ones_ref, size_ref, params_ref,
                       emin_ref, argmin_ref):
    """Fused energy Map + per-vertex two-label Min for one (8,128) tile."""
    y = y_ref[...]
    lbl = label_ref[...]
    ones_h = ones_ref[...]
    size_h = size_ref[...]

    mu0 = params_ref[0, 0]
    mu1 = params_ref[0, 1]
    sig0 = params_ref[0, 2]
    sig1 = params_ref[0, 3]
    beta = params_ref[0, 4]

    # Data term: Gaussian negative log-likelihood per label.
    d0 = y - mu0
    d1 = y - mu1
    e0 = d0 * d0 / (2.0 * sig0 * sig0) + jnp.log(sig0)
    e1 = d1 * d1 / (2.0 * sig1 * sig1) + jnp.log(sig1)

    # Smoothness (Potts over the hood, self-contribution removed).
    dis0 = ones_h - lbl
    dis1 = (size_h - ones_h) - (1.0 - lbl)
    e0 = e0 + beta * dis0
    e1 = e1 + beta * dis1

    take1 = e1 < e0
    emin_ref[...] = jnp.where(take1, e1, e0)
    argmin_ref[...] = jnp.where(take1, jnp.ones_like(y), jnp.zeros_like(y))


@functools.partial(jax.jit, static_argnames=())
def energy_min(y, label, ones_h, size_h, params):
    """Run the fused energy/min kernel over flat f32[n] element arrays.

    Args:
      y:      f32[n]  region mean intensity per hood-member instance.
      label:  f32[n]  current label (0.0 / 1.0) per instance.
      ones_h: f32[n]  per-instance gather of its hood's labeled-1 count.
      size_h: f32[n]  per-instance gather of its hood's member count.
      params: f32[5]  (mu0, mu1, sigma0, sigma1, beta).

    Returns:
      (emin f32[n], argmin f32[n]) — per-vertex minimum energy and the
      label (0.0/1.0) attaining it. ``n`` must be a multiple of 1024.
    """
    n = y.shape[0]
    if n % BLOCK_ELEMS != 0:
        raise ValueError(f"n={n} must be a multiple of {BLOCK_ELEMS}")
    rows = n // LANES
    grid = rows // BLOCK_ROWS

    shape2d = (rows, LANES)
    y2 = y.reshape(shape2d)
    l2 = label.reshape(shape2d)
    o2 = ones_h.reshape(shape2d)
    s2 = size_h.reshape(shape2d)
    p2 = params.reshape(1, 5)

    elem_spec = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    # Whole (tiny) parameter vector visible to every tile.
    param_spec = pl.BlockSpec((1, 5), lambda i: (0, 0))

    emin, argmin = pl.pallas_call(
        _energy_min_kernel,
        grid=(grid,),
        in_specs=[elem_spec, elem_spec, elem_spec, elem_spec, param_spec],
        out_specs=[elem_spec, elem_spec],
        out_shape=[
            jax.ShapeDtypeStruct(shape2d, jnp.float32),
            jax.ShapeDtypeStruct(shape2d, jnp.float32),
        ],
        interpret=True,
    )(y2, l2, o2, s2, p2)
    return emin.reshape(n), argmin.reshape(n)


def vmem_bytes_per_tile() -> int:
    """Static VMEM footprint estimate for one grid step (DESIGN.md §Perf).

    4 f32 input tiles + 2 f32 output tiles of (8,128), plus the 5-float
    parameter block; double-buffered inputs would add another 4 tiles.
    """
    tile = BLOCK_ELEMS * 4
    return 4 * tile + 2 * tile + 5 * 4
