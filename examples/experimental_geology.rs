//! Experimental-dataset scenario (paper §4.1.1): segment a simulated
//! beamline geological stack (strata + fractures + inclusions), compare
//! DPP-PMRF to the reference engine (Fig. 2 protocol — the reference
//! result is the scoring target), and dump the neighborhood
//! demographics the paper uses to explain scaling behaviour (§4.3.3).
//!
//!     cargo run --release --example experimental_geology

use dpp_pmrf::config::{DatasetConfig, DatasetKind, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::eval::{self as metrics, Confusion};

fn main() -> anyhow::Result<()> {
    let dataset_cfg = DatasetConfig {
        kind: DatasetKind::Experimental,
        width: 192,
        height: 192,
        slices: 2,
        ..Default::default()
    };
    let ds = image::generate(&dataset_cfg);

    // Demographics of both datasets: the experimental graph must be
    // denser with a more irregular neighborhood-size distribution.
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let cfg = RunConfig {
            dataset: DatasetConfig { kind, ..dataset_cfg.clone() },
            ..Default::default()
        };
        let d = image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg)?;
        let (seg, model) = coord.build_slice_model(&d.input, 0);
        let hist = model.hoods.size_histogram(4);
        println!(
            "{:<13} regions {:>6}  edges {:>6}  hoods {:>6}  \
             hood-size mean {:>5.1} max {:>4}  irregularity {:.2}",
            kind.name(),
            seg.num_regions,
            model.graph.num_edges(),
            model.hoods.num_hoods(),
            hist.mean(),
            hist.max,
            hist.irregularity()
        );
    }

    // Reference run (the scoring target), then DPP.
    let mut outputs = Vec::new();
    for engine in [EngineKind::Reference, EngineKind::Dpp] {
        let cfg = RunConfig {
            dataset: dataset_cfg.clone(),
            engine,
            ..Default::default()
        };
        let coord = Coordinator::new(cfg)?;
        let report = coord.run(&ds)?;
        println!(
            "{:<10} mean opt {:.3}s  porosity {:.3}",
            report.engine,
            report.mean_opt_secs(),
            report.porosity
        );
        outputs.push(report.output);
    }
    let c = Confusion::from_volumes(&outputs[1], &outputs[0]);
    println!("DPP vs reference: {}", metrics::summary(&c));
    println!("paper (experimental): precision 97.2%  recall 95.2%  \
              accuracy 96.8%");
    Ok(())
}
