//! Mini strong-scaling study (the interactive cousin of
//! `benches/fig4_strong_scaling.rs`): reference vs DPP engine across a
//! thread sweep on one dataset, printed as a speedup table.
//!
//!     cargo run --release --example scaling_study [synthetic|experimental]

use dpp_pmrf::bench_support::{prepare_models, thread_sweep, workload, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, reference::ReferenceEngine,
                    serial::SerialEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::{measure, Timer};

fn main() -> anyhow::Result<()> {
    let kind = match std::env::args().nth(1).as_deref() {
        Some("experimental") => DatasetKind::Experimental,
        _ => DatasetKind::Synthetic,
    };
    let scale = Scale::from_env();
    println!("dataset: {} @ {}x{}x{}", kind.name(), scale.width,
             scale.height, scale.slices);

    let t = Timer::start();
    let (ds, cfg) = workload(kind, scale);
    let models = prepare_models(&ds, &cfg);
    println!("prepared {} slice models in {:.2}s\n", models.len(),
             t.elapsed_secs());

    let serial = measure(1, scale.reps, || {
        for m in &models {
            SerialEngine.run(m, &cfg.mrf);
        }
    });
    println!("serial baseline: {:.3}s", serial.median);
    println!("\n{:>8} {:>14} {:>14} {:>9}", "threads", "reference(s)",
             "dpp(s)", "dpp-gain");
    for threads in thread_sweep() {
        let pool = Pool::new(threads);
        let refeng = ReferenceEngine::new(pool.clone());
        let r = measure(1, scale.reps, || {
            for m in &models {
                refeng.run(m, &cfg.mrf);
            }
        });
        let dppeng = DppEngine::new(if threads == 1 {
            Backend::Serial
        } else {
            Backend::threaded(pool.clone())
        });
        let d = measure(1, scale.reps, || {
            for m in &models {
                dppeng.run(m, &cfg.mrf);
            }
        });
        println!(
            "{:>8} {:>10.3} ({:>4.1}x) {:>6.3} ({:>4.1}x) {:>8.2}x",
            threads,
            r.median,
            serial.median / r.median,
            d.median,
            serial.median / d.median,
            r.median / d.median
        );
    }
    Ok(())
}
