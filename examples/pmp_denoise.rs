//! Particle max-product on a continuous label space: denoise a step
//! image by optimizing a Gaussian-data + truncated-quadratic MRF with
//! per-vertex particle sets (D-PMP), then run the same solver as a
//! drop-in engine through the full segmentation pipeline.
//!
//!     cargo run --release --example pmp_denoise

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::dpp::{PoolDevice, SerialDevice, Workspace};
use dpp_pmrf::image;
use dpp_pmrf::mrf::continuous;
use dpp_pmrf::pmp::{self, PmpConfig};

/// Peak signal-to-noise ratio of a reconstruction vs the clean image,
/// on the 8-bit [0, 255] intensity range.
fn psnr(x: &[f32], clean: &[f32]) -> f64 {
    let mse = x
        .iter()
        .zip(clean)
        .map(|(a, b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / x.len().max(1) as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

fn main() -> anyhow::Result<()> {
    // 1. A noisy step image (plateaus at 60 / 180) as a continuous
    //    MRF: Gaussian data term, truncated-quadratic smoothness.
    let (model, clean) =
        continuous::synthetic_denoise(96, 64, 20.0, 24414);
    println!("instance        : 96x64, sigma 20, {} vertices",
             model.num_vertices());
    println!("noisy input     : energy {:.1}, psnr {:.1} dB",
             model.energy(&model.y), psnr(&model.y, &clean));
    println!("clean image     : energy {:.1}", model.energy(&clean));

    // 2. Solve with D-PMP: per-vertex particle sets, seeded
    //    random-walk proposals, max-product message passing over
    //    particle pairs, select-and-prune each round.
    let cfg = PmpConfig { particles: 6, iters: 10, ..Default::default() };
    let ws = Workspace::new();
    let run = pmp::solve(&SerialDevice, &ws, &model, &cfg, None, false);
    println!("pmp (serial dev): energy {:.1}, psnr {:.1} dB, {} rounds",
             run.energy, psnr(&run.x_map, &clean), run.iters);
    for (r, e) in run.history.iter().enumerate() {
        println!("  round {r}: energy {e:.1}, {} proposals kept",
                 run.accepted[r]);
    }

    // 3. The same solve on a threaded device is bitwise-identical —
    //    the conformance gate (tests/pmp_conformance.rs) enforces it.
    let pool = PoolDevice::new(4, 64);
    let run_pool = pmp::solve(&pool, &ws, &model, &cfg, None, false);
    assert_eq!(run_pool, run, "device independence is bitwise");
    println!("pmp (pool-t4)   : identical bit for bit");

    // 4. As an EM engine (CLI: `dpp-pmrf segment --engine pmp`, tuned
    //    by `--pmp-particles`, `--pmp-iters`, `--pmp-sweeps`,
    //    `--pmp-walk-sigma`): the continuous solver runs inside the
    //    shared EM loop on the full segmentation pipeline, reporting
    //    particle stats beside the usual metrics.
    let rcfg = RunConfig {
        dataset: DatasetConfig {
            width: 64,
            height: 64,
            slices: 2,
            ..Default::default()
        },
        engine: EngineKind::Pmp,
        ..Default::default()
    };
    let dataset = image::generate(&rcfg.dataset);
    let report = Coordinator::new(rcfg)?.run(&dataset)?;
    println!("pmp engine      : {} slices, opt {:.3}s",
             report.slices.len(), report.mean_opt_secs());
    if let (Some(p), Some(a)) =
        (report.pmp_particles(), report.pmp_acceptance())
    {
        println!("particle budget : {p} particles, {:.0}% acceptance",
                 100.0 * a);
    }
    if let Some(c) = &report.confusion {
        println!("verification    : {}", dpp_pmrf::eval::summary(c));
    }
    Ok(())
}
