//! END-TO-END driver: the full system on a real
//! small workload — a 256x256x4 corrupted porous-media stack — run
//! through **all four engines** (serial, reference, dpp, xla), proving
//! every layer composes: image substrate -> oversegmentation -> region
//! graph -> maximal cliques -> neighborhoods -> EM optimization
//! (including the AOT XLA/PJRT path built from the JAX+Pallas layers)
//! -> pixel mapping -> verification metrics.
//!
//!     cargo run --release --example synthetic_porous [WxHxS]

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image::{self, threshold};
use dpp_pmrf::eval::{self as metrics, Confusion};

fn main() -> anyhow::Result<()> {
    let dims: Vec<usize> = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "256x256x4".to_string())
        .split('x')
        .filter_map(|p| p.parse().ok())
        .collect();
    anyhow::ensure!(dims.len() == 3, "usage: synthetic_porous [WxHxS]");

    let dataset_cfg = DatasetConfig {
        width: dims[0],
        height: dims[1],
        slices: dims[2],
        ..Default::default()
    };
    println!(
        "generating synthetic porous stack {}x{}x{} (salt&pepper {}, \
         gaussian sigma {}, ringing {})",
        dims[0], dims[1], dims[2], dataset_cfg.salt_pepper,
        dataset_cfg.gaussian_sigma, dataset_cfg.ringing
    );
    let ds = image::generate(&dataset_cfg);
    let truth = ds.ground_truth.clone().expect("synthetic has truth");

    // Simple-threshold baseline (Fig. 1d).
    let thr = threshold::otsu(&ds.input);
    let thr_c = Confusion::from_volumes(&thr, &truth);
    println!("threshold baseline: {}", metrics::summary(&thr_c));

    println!(
        "\n{:<10} {:>9} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "engine", "precision", "recall", "accuracy", "porosity",
        "init(s)", "opt(s)"
    );
    for engine in [
        EngineKind::Serial,
        EngineKind::Reference,
        EngineKind::Dpp,
        EngineKind::Xla,
    ] {
        let cfg = RunConfig {
            dataset: dataset_cfg.clone(),
            engine,
            ..Default::default()
        };
        let coord = Coordinator::new(cfg)?;
        let report = coord.run(&ds)?;
        let c = report.confusion.unwrap();
        println!(
            "{:<10} {:>8.1}% {:>8.1}% {:>8.1}% {:>9.3} {:>10.3} {:>10.3}",
            report.engine,
            c.precision() * 100.0,
            c.recall() * 100.0,
            c.accuracy() * 100.0,
            report.porosity,
            report.mean_init_secs(),
            report.mean_opt_secs()
        );
        if engine == EngineKind::Dpp {
            let dir = std::path::Path::new("bench_results/e2e");
            coord.save_figure(&ds, &report, 0, dir)?;
        }
    }
    println!(
        "\ntruth porosity {:.3}; figure panels in bench_results/e2e/",
        metrics::porosity(&truth)
    );
    println!("paper reference (synthetic): precision 99.3%  recall 98.3%  \
              accuracy 98.6%");
    Ok(())
}
