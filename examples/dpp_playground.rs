//! DPP playground: shows how the primitive vocabulary composes into a
//! small analysis — the same building blocks the MRF engine is made of
//! (§2.3). Computes, for a random region-graph-like edge list:
//! degree histogram via SortByKey+ReduceByKey, a compacted high-degree
//! vertex list via CopyIf, and a prefix-sum layout via Scan — on both
//! backends, with the per-primitive timing registry on.
//!
//!     cargo run --release --example dpp_playground

use dpp_pmrf::dpp::{self, timing, Backend};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::Pcg32;

fn main() {
    let n_vertices = 1u32 << 16;
    let n_edges = 1 << 20;
    let mut rng = Pcg32::seeded(7);
    let edges: Vec<(u32, u32)> = (0..n_edges)
        .map(|_| (rng.below(n_vertices), rng.below(n_vertices)))
        .collect();

    for (name, bk) in [
        ("serial", Backend::Serial),
        ("threaded", Backend::threaded(Pool::with_default_threads())),
    ] {
        timing::reset();
        timing::set_enabled(true);

        // Map: pack directed edges as sortable pairs.
        let mut keys: Vec<u64> =
            dpp::map(&bk, &edges, |&(a, b)| dpp::pack_pair(a, b));
        // SortByKey groups by source vertex.
        dpp::sort_keys(&bk, &mut keys);
        let srcs: Vec<u32> =
            dpp::map(&bk, &keys, |&k| dpp::unpack_pair(k).0);
        // ReduceByKey<Add>: out-degree per source vertex.
        let ones: Vec<u32> = dpp::map(&bk, &srcs, |_| 1u32);
        let (verts, degs) =
            dpp::reduce_by_key(&bk, &srcs, &ones, 0u32, |a, b| a + b);
        // Reduce: max degree; CopyIf: hubs above half the max.
        let max_deg = dpp::reduce(&bk, &degs, 0u32, |a, b| a.max(b));
        let hubs = dpp::copy_if_indexed(&bk, &verts, |i| {
            degs[i] * 2 > max_deg
        });
        // Scan: CSR-style offsets from the degree sequence.
        let (offsets, total) =
            dpp::scan_exclusive(&bk, &degs, 0u32, |a, b| a + b);

        timing::set_enabled(false);
        println!(
            "[{name}] vertices-with-edges {}  max-degree {max_deg}  \
             hubs {}  csr-total {total} (offsets[1]={})",
            verts.len(),
            hubs.len(),
            offsets.get(1).copied().unwrap_or(0)
        );
        println!("{}", timing::report());
        assert_eq!(total as usize, n_edges);
    }
}
