//! Direct-3D segmentation (the paper's §5 future work): segment a
//! porous volume as ONE 3D region graph and compare against the
//! paper's slice-by-slice protocol. With z-continuity in the model,
//! the 3D mode typically recovers thin pore throats that slice-wise
//! processing fragments.
//!
//!     cargo run --release --example volume_3d [WxHxS]

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::eval as metrics;

fn main() -> anyhow::Result<()> {
    let dims: Vec<usize> = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "96x96x8".to_string())
        .split('x')
        .filter_map(|p| p.parse().ok())
        .collect();
    anyhow::ensure!(dims.len() == 3, "usage: volume_3d [WxHxS]");

    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: dims[0],
            height: dims[1],
            slices: dims[2],
            ..Default::default()
        },
        engine: EngineKind::Dpp,
        ..Default::default()
    };
    let ds = image::generate(&cfg.dataset);
    let coord = Coordinator::new(cfg)?;

    let slicewise = coord.run(&ds)?;
    let direct = coord.run_3d(&ds)?;

    println!("volume {}x{}x{} (synthetic porous, paper corruption)\n",
             dims[0], dims[1], dims[2]);
    for (name, report) in
        [("slice-wise (paper protocol)", &slicewise),
         ("direct 3D (paper §5 ext.)", &direct)]
    {
        let c = report.confusion.as_ref().unwrap();
        println!("{name:<28} {}  porosity {:.3}", metrics::summary(c),
                 report.porosity);
    }
    let s3 = &direct.slices[0];
    println!(
        "\n3D graph: {} regions, {} hoods, {} elements; \
         init {:.3}s, optimization {:.3}s",
        s3.regions, s3.hoods, s3.elements, s3.init_secs, s3.opt_secs
    );
    Ok(())
}
