//! Quickstart: generate a noisy porous volume, segment it with
//! DPP-PMRF, print the verification metrics, peek at the fused
//! plan + pipeline layer the hot loops run on, and serve a two-job
//! batch through the slice scheduler's Service front end.
//!
//!     cargo run --release --example quickstart

use dpp_pmrf::config::{DatasetConfig, EngineKind, MrfConfig, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::dpp::{Backend, SegmentPlan};
use dpp_pmrf::image;
use dpp_pmrf::eval as metrics;
use dpp_pmrf::mrf::dpp::{DppEngine, PairMode};
use dpp_pmrf::mrf::Engine;
use dpp_pmrf::pool::Pool;
use dpp_pmrf::sched::{Job, Service};

fn main() -> anyhow::Result<()> {
    // 1. Describe the run: a 128x128x2 synthetic porous volume with the
    //    paper's corruption stack, segmented by the DPP engine on all
    //    cores.
    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: 128,
            height: 128,
            slices: 2,
            ..Default::default()
        },
        engine: EngineKind::Dpp,
        ..Default::default()
    };

    // 2. Generate the dataset (input + ground truth).
    let dataset = image::generate(&cfg.dataset);

    // 3. Run the pipeline: oversegmentation -> region graph -> maximal
    //    cliques -> neighborhoods -> EM/MAP optimization -> pixel map.
    let coordinator = Coordinator::new(cfg.clone())?;
    let report = coordinator.run(&dataset)?;

    // 4. Inspect the results.
    println!("engine          : {}", report.engine);
    println!("slices          : {}", report.slices.len());
    println!("mean init time  : {:.3}s", report.mean_init_secs());
    println!("mean opt time   : {:.3}s", report.mean_opt_secs());
    if let Some(c) = &report.confusion {
        println!("verification    : {}", metrics::summary(c));
    }
    println!("porosity        : {:.3}", report.porosity);

    // 5. Any engine is a drop-in swap — here loopy belief propagation
    //    with residual message scheduling (CLI: `dpp-pmrf segment
    //    --engine bp`, tuned by `--bp-schedule`, `--bp-damping`,
    //    `--bp-sweeps`, `--bp-tol`, `--bp-frontier`).
    let bp = Coordinator::new(RunConfig {
        engine: EngineKind::Bp,
        ..cfg.clone()
    })?
    .run(&dataset)?;
    println!("bp engine       : opt {:.3}s, {} sweeps",
             bp.mean_opt_secs(), bp.total_map_iters());
    if let Some(c) = &bp.confusion {
        println!("bp verification : {}", metrics::summary(c));
    }

    // 6. The layer underneath (DESIGN.md §7): the iteration hot path
    //    reduces over STATIC keys, so a SegmentPlan pays the paper's
    //    per-iteration SortByKey once and every later reduction runs
    //    sort-free — bitwise-identical to sort + reduce_by_key.
    let bk = Backend::threaded(Pool::with_default_threads());
    let keys: Vec<u64> = (0..1000u64).map(|i| i % 10).collect();
    let plan = SegmentPlan::build(&bk, &keys); // the one sort
    for _iteration in 0..3 {
        let vals: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let sums = plan.reduce_segments(&bk, &vals, 0.0, |a, b| a + b);
        assert_eq!(sums.len(), 10); // one per distinct key, sort-free
    }

    // 7. The planned engine mode drives the whole EM/MAP loop through
    //    that layer: plans built once per run, each MAP iteration one
    //    fused Pipeline region — same labels as every other MAP
    //    engine, bit for bit.
    let seg = dpp_pmrf::overseg::oversegment(
        &bk, &dataset.input.slice(0), &cfg.overseg,
    );
    let model = dpp_pmrf::mrf::build_model(&bk, &seg);
    let planned = DppEngine::with_mode(bk.clone(), PairMode::Planned);
    let res = planned.run(&model, &MrfConfig::default());
    println!("planned engine  : {} -> {} EM / {} MAP iters, energy {:.1}",
             planned.name(), res.em_iters, res.map_iters, res.energy);

    // 8. Throughput mode (DESIGN.md §8): the sched::Service front end
    //    runs many segmentation jobs concurrently — two workers here,
    //    each job sharding its own slices across 2 scheduler lanes
    //    (CLI: `dpp-pmrf segment --lanes 2 --inflight 4`). Reports
    //    come back in submission order, bitwise identical to serial
    //    runs of the same configs.
    let service = Service::new(2, 2);
    let job = |seed: u64| {
        let mut jcfg = RunConfig {
            dataset: DatasetConfig {
                width: 64,
                height: 64,
                slices: 4,
                seed,
                ..Default::default()
            },
            engine: EngineKind::Dpp,
            threads: 1,
            ..Default::default()
        };
        jcfg.sched.lanes = 2;
        Job { dataset: image::generate(&jcfg.dataset), cfg: jcfg }
    };
    for (i, report) in service
        .run_batch(vec![job(101), job(202)])
        .into_iter()
        .enumerate()
    {
        let report = report?;
        println!(
            "service job {i}  : {} slices in {:.3}s ({:.2} slices/s, \
             lane occupancy {:.0}%)",
            report.slices.len(),
            report.total_secs,
            report.slices_per_sec(),
            100.0 * report.lane_occupancy()
        );
    }
    Ok(())
}
