//! Quickstart: generate a noisy porous volume, segment it with
//! DPP-PMRF, print the verification metrics.
//!
//!     cargo run --release --example quickstart

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::metrics;

fn main() -> anyhow::Result<()> {
    // 1. Describe the run: a 128x128x2 synthetic porous volume with the
    //    paper's corruption stack, segmented by the DPP engine on all
    //    cores.
    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: 128,
            height: 128,
            slices: 2,
            ..Default::default()
        },
        engine: EngineKind::Dpp,
        ..Default::default()
    };

    // 2. Generate the dataset (input + ground truth).
    let dataset = image::generate(&cfg.dataset);

    // 3. Run the pipeline: oversegmentation -> region graph -> maximal
    //    cliques -> neighborhoods -> EM/MAP optimization -> pixel map.
    let coordinator = Coordinator::new(cfg.clone())?;
    let report = coordinator.run(&dataset)?;

    // 4. Inspect the results.
    println!("engine          : {}", report.engine);
    println!("slices          : {}", report.slices.len());
    println!("mean init time  : {:.3}s", report.mean_init_secs());
    println!("mean opt time   : {:.3}s", report.mean_opt_secs());
    if let Some(c) = &report.confusion {
        println!("verification    : {}", metrics::summary(c));
    }
    println!("porosity        : {:.3}", report.porosity);

    // 5. Any engine is a drop-in swap — here loopy belief propagation
    //    with residual message scheduling (CLI: `dpp-pmrf segment
    //    --engine bp`, tuned by `--bp-schedule`, `--bp-damping`,
    //    `--bp-sweeps`, `--bp-tol`, `--bp-frontier`).
    let bp = Coordinator::new(RunConfig {
        engine: EngineKind::Bp,
        ..cfg
    })?
    .run(&dataset)?;
    println!("bp engine       : opt {:.3}s, {} sweeps",
             bp.mean_opt_secs(), bp.total_map_iters());
    if let Some(c) = &bp.confusion {
        println!("bp verification : {}", metrics::summary(c));
    }
    Ok(())
}
