#!/usr/bin/env bash
# Hot-loop allocation allowlist (ISSUE 5, DESIGN.md §10).
#
# Modules that opt in with a `deny(hot-loop-alloc)` marker comment
# must justify every allocation-constructor call with an
# `alloc-ok: <reason>` comment on the same line (or the line above).
# This keeps the zero-allocation steady state from rotting: a new
# `vec![...]` / `Vec::with_capacity` / `.collect()` in a marked module
# fails CI until its author states why it is not on the steady-state
# path (once-per-run setup, legacy allocating spelling, ...).
#
# Test modules (`#[cfg(test)]` onward) and doc-comment lines are
# exempt. Runs with no toolchain — plain awk over the sources.

set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
files=$(grep -rl "deny(hot-loop-alloc)" rust/src --include="*.rs" || true)

if [ -z "$files" ]; then
    echo "error: no modules carry the deny(hot-loop-alloc) marker" >&2
    exit 1
fi

for f in $files; do
    hits=$(awk '
        /^#\[cfg\(test\)\]/ { exit }          # test code is exempt
        /alloc-ok:/ { prev_ok = 2 }           # covers this + next line
        {
            line = $0
            sub(/^[ \t]+/, "", line)
            is_doc = (line ~ /^\/\//)         # comments and doc lines
            if (!is_doc && prev_ok == 0 &&
                (line ~ /vec!/ || line ~ /Vec::with_capacity/ ||
                 line ~ /Vec::new\(\)/ || line ~ /\.to_vec\(\)/ ||
                 line ~ /\.collect\(\)/ || line ~ /Box::new/)) {
                printf "%s:%d: %s\n", FILENAME, FNR, $0
            }
            if (prev_ok > 0) { prev_ok -= 1 }
        }
    ' "$f")
    if [ -n "$hits" ]; then
        echo "unjustified allocation(s) in hot-loop module (add"
        echo "  an \`// alloc-ok: <reason>\` comment or move them"
        echo "  off the steady-state path):"
        echo "$hits"
        fail=1
    fi
done

if [ "$fail" -eq 0 ]; then
    echo "hot-loop alloc allowlist: OK ($(echo "$files" | wc -l) modules)"
fi
exit $fail
