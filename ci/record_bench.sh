#!/usr/bin/env bash
# Record the perf trajectory (ISSUE 8): run the bench suite and fold
# every machine-readable result into BENCH_8.json (git sha + bench ->
# metric -> value), the first point on the trajectory ROADMAP.md keeps
# flagging as empty.
#
# Usage: ci/record_bench.sh [bench ...]
#   DPP_PMRF_BENCH_SCALE=smoke|paper|WxHxS   workload size (default smoke)
#   OUT=BENCH_8.json                         output path
#
# Needs: a cargo toolchain + jq. Each bench is a harness=false binary
# that prints a table and writes bench_results/<bench>.json
# (alloc_churn additionally writes BENCH_5.json, folded in too).

set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${OUT:-BENCH_8.json}"
export DPP_PMRF_BENCH_SCALE="${DPP_PMRF_BENCH_SCALE:-smoke}"

# Default suite: one bench per perf surface the repo makes claims
# about — end-to-end throughput, the zero-allocation steady state
# (which now also covers the disarmed obs hooks), certificate
# tightness, and the engine comparison.
benches=("$@")
if [ "${#benches[@]}" -eq 0 ]; then
    benches=(throughput alloc_churn dual_gap bp_vs_map
             bp_schedule_ablation pmp_denoise)
fi

rm -rf bench_results
for b in "${benches[@]}"; do
    echo "== cargo bench --bench $b (scale $DPP_PMRF_BENCH_SCALE) =="
    cargo bench --bench "$b"
done

sha="$(git rev-parse HEAD 2>/dev/null || echo unknown)"

files=()
for f in bench_results/*.json BENCH_5.json; do
    [ -f "$f" ] && files+=("$f")
done
if [ "${#files[@]}" -eq 0 ]; then
    echo "error: no bench wrote a machine-readable result" >&2
    exit 1
fi

# Fold each result file's rows into {bench: {metric: value}}: a
# metric name is the row's string-valued labels joined k=v with '/',
# suffixed with the numeric field's name.
jq -n --arg sha "$sha" --arg scale "$DPP_PMRF_BENCH_SCALE" '
  def metric_rows:
    (.rows // .) | map(
      . as $row |
      ( [ to_entries[]
          | select(.value | type == "string")
          | "\(.key)=\(.value)" ] | join("/") ) as $labels |
      [ $row | to_entries[]
        | select(.value | type == "number")
        | { key: (if $labels == "" then .key
                  else "\($labels)/\(.key)" end),
            value: .value } ]
    ) | add // [] | from_entries;
  { git_sha: $sha,
    scale: $scale,
    benches:
      [ inputs
        | { key: (input_filename
                  | sub(".*/"; "") | sub("\\.json$"; "")),
            value: metric_rows } ]
      | from_entries }
' "${files[@]}" > "$OUT"

echo "wrote $OUT ($(jq '.benches | length' "$OUT") benches, sha $sha)"
