//! Fig. 3 reproduction: DPP-PMRF vs OpenMP-reference runtime ratio at
//! varying concurrency, for both datasets.
//!
//! Each bar of the paper's figure is `T_reference / T_dpp` at one
//! (platform, dataset, thread-count) triple; bars > 1 mean the DPP code
//! wins. Paper shape: DPP wins everywhere, 2–7X.
//!
//! Output: one row per (dataset, threads, engine) plus the derived
//! ratio series, persisted to `bench_results/fig3_runtime_ratio.json`.

use dpp_pmrf::bench_support::{prepare_models, thread_sweep, workload,
                              Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, reference::ReferenceEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("fig3_runtime_ratio");

    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let (ds, cfg) = workload(kind, scale);
        let models = prepare_models(&ds, &cfg);

        for threads in thread_sweep() {
            let pool = Pool::new(threads);
            let engines: Vec<Box<dyn Engine>> = vec![
                Box::new(ReferenceEngine::new(pool.clone())),
                Box::new(DppEngine::new(if threads == 1 {
                    Backend::Serial
                } else {
                    Backend::threaded(pool.clone())
                })),
            ];
            for engine in engines {
                let stats = measure(scale.warmup, scale.reps, || {
                    for m in &models {
                        engine.run(m, &cfg.mrf);
                    }
                });
                report.add(
                    vec![
                        ("dataset", kind.name().to_string()),
                        ("threads", threads.to_string()),
                        ("engine", engine.name().to_string()),
                    ],
                    stats,
                );
            }
        }
    }
    report.finish();

    // Derived Fig. 3 bars: ratio = T_ref / T_dpp.
    println!("Fig. 3 bars (T_reference / T_dpp; >1 means DPP wins):");
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        for threads in thread_sweep() {
            let t = threads.to_string();
            let r = report.median(&[
                ("dataset", kind.name()),
                ("threads", &t),
                ("engine", "reference"),
            ]);
            let d = report.median(&[
                ("dataset", kind.name()),
                ("threads", &t),
                ("engine", "dpp"),
            ]);
            if let (Some(r), Some(d)) = (r, d) {
                println!(
                    "  {:<13} {:>3} threads: {:.2}x",
                    kind.name(),
                    threads,
                    r / d
                );
            }
        }
    }
}
