//! Table 1 reproduction: per-dataset runtimes for the serial CPU
//! baseline, DPP-PMRF on the multicore CPU (max threads), and DPP-PMRF
//! on the accelerator path (XLA/PJRT — the paper's GPU stand-in, see
//! DESIGN.md §Hardware-Adaptation), plus the derived speedup rows.
//!
//! Paper shape: accelerator > threaded CPU > serial, with Speedup-GPU
//! (vs serial) the largest number in the table.

use std::sync::Arc;

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, serial::SerialEngine, xla::XlaEngine,
                    Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::runtime::EmRuntime;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    // Skip-cleanly convention (shared with the runtime/xla tests): a
    // missing or unloadable artifact set is an environment condition,
    // not a bench failure.
    let runtime = match EmRuntime::load(std::path::Path::new("artifacts")) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!(
                "skipping table1_platforms: xla runtime unavailable \
                 ({e}); run `make artifacts` to enable the accelerator \
                 rows"
            );
            return;
        }
    };
    let mut report = Report::new("table1_platforms");
    let max_threads = dpp_pmrf::pool::available_threads();

    let mut table: Vec<(String, f64, f64, f64)> = Vec::new();
    for kind in [DatasetKind::Experimental, DatasetKind::Synthetic] {
        let (ds, cfg) = workload(kind, scale);
        let models = prepare_models(&ds, &cfg);

        let rows: Vec<(&str, Box<dyn Engine>)> = vec![
            ("serial-cpu", Box::new(SerialEngine)),
            (
                "dpp-cpu",
                Box::new(DppEngine::new(Backend::threaded(Pool::new(
                    max_threads,
                )))),
            ),
            ("dpp-xla", Box::new(XlaEngine::new(Arc::clone(&runtime)))),
        ];
        let mut medians = Vec::new();
        for (label, engine) in rows {
            let stats = measure(scale.warmup, scale.reps, || {
                for m in &models {
                    engine.run(m, &cfg.mrf);
                }
            });
            medians.push(stats.median);
            report.add(
                vec![
                    ("dataset", kind.name().to_string()),
                    ("platform", label.to_string()),
                ],
                stats,
            );
        }
        table.push((kind.name().to_string(), medians[0], medians[1],
                    medians[2]));
    }
    report.finish();

    println!("Table 1 (seconds; speedups vs the labeled baseline):");
    println!("{:<22} {:>13} {:>13}", "Platform / Dataset", "Experimental",
             "Synthetic");
    let get = |i: usize, f: fn(&(String, f64, f64, f64)) -> f64| {
        f(&table[i])
    };
    println!("{:<22} {:>13.3} {:>13.3}", "Serial CPU",
             get(0, |r| r.1), get(1, |r| r.1));
    println!("{:<22} {:>13.3} {:>13.3}", "DPP-PMRF CPU",
             get(0, |r| r.2), get(1, |r| r.2));
    println!("{:<22} {:>13.3} {:>13.3}", "DPP-PMRF XLA",
             get(0, |r| r.3), get(1, |r| r.3));
    println!("{:<22} {:>12.1}X {:>12.1}X", "Speedup-CPU (vs serial)",
             get(0, |r| r.1 / r.2), get(1, |r| r.1 / r.2));
    println!("{:<22} {:>12.1}X {:>12.1}X", "Speedup-XLA (vs serial)",
             get(0, |r| r.1 / r.3), get(1, |r| r.1 / r.3));
}
