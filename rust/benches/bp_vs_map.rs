//! BP vs DPP-MAP: convergence wall-clock, inner-iteration counts, and
//! final energy for the same models — the loopy-BP analog of the
//! paper's engine comparisons. Runs the DPP-MAP engine against the BP
//! engine under both message schedules (synchronous and residual), all
//! in convergence mode, so the numbers answer "which optimizer reaches
//! a comparable-energy labeling faster, and in how many inner
//! iterations (MAP iterations vs BP sweeps)?".
//!
//! Output: `bench_results/bp_vs_map.json` — one row per
//! (dataset, engine) with median seconds plus inner-iteration and
//! final-energy labels, and a derived speedup summary per dataset.

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::bp::{BpConfig, BpEngine, BpSchedule};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("bp_vs_map");

    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let (ds, mut cfg) = workload(kind, scale);
        // Convergence race, not fixed-work throughput: let every
        // engine stop at its own convergence point.
        cfg.mrf.fixed_iters = false;
        let models = prepare_models(&ds, &cfg);

        let pool = Pool::with_default_threads();
        let bk = Backend::threaded(pool);
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(DppEngine::new(bk.clone())),
            Box::new(BpEngine::new(
                bk.clone(),
                BpConfig {
                    schedule: BpSchedule::Synchronous,
                    ..Default::default()
                },
            )),
            Box::new(BpEngine::new(
                bk.clone(),
                BpConfig {
                    schedule: BpSchedule::Residual,
                    ..Default::default()
                },
            )),
        ];

        for engine in engines {
            let stats = measure(scale.warmup, scale.reps, || {
                for m in &models {
                    engine.run(m, &cfg.mrf);
                }
            });
            // One scored pass for the quality/effort labels.
            let (mut inner, mut em, mut energy) = (0usize, 0usize, 0.0f64);
            for m in &models {
                let r = engine.run(m, &cfg.mrf);
                inner += r.map_iters;
                em += r.em_iters;
                energy += r.energy;
            }
            report.add(
                vec![
                    ("dataset", kind.name().to_string()),
                    ("engine", engine.name().to_string()),
                    ("em_iters", em.to_string()),
                    ("inner_iters", inner.to_string()),
                    ("final_energy", format!("{energy:.1}")),
                ],
                stats,
            );
        }
    }
    report.finish();

    println!("BP vs DPP-MAP (T_map / T_bp; >1 means BP wins):");
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let map = report.median(&[("dataset", kind.name()),
                                  ("engine", "dpp")]);
        for bp_name in ["bp-sync", "bp"] {
            let bp = report.median(&[("dataset", kind.name()),
                                     ("engine", bp_name)]);
            if let (Some(map), Some(bp)) = (map, bp) {
                println!("  {:<13} {:<8} {:.2}x", kind.name(), bp_name,
                         map / bp);
            }
        }
    }
}
