//! BP frontier-policy ablation (ISSUE 10): convergence wall-clock vs
//! schedule for the whole policy family — synchronous flood, residual
//! frontier, stale-residual (barrier-free), bucketed splash, and
//! randomized subset — across thread counts and frontier parameters.
//! Every configuration runs in convergence mode, so each row answers
//! "how long until this policy's fixed point, over how many sweeps,
//! committing what fraction of messages per sweep?" — the
//! convergence-vs-wall-clock trade the relaxed policies exist to win.
//!
//! Output: `bench_results/bp_schedule_ablation.json` — one row per
//! (policy, threads) with median seconds plus sweep-count,
//! final-energy, and committed-fraction labels, and a printed speedup
//! table normalized to the synchronous schedule at each thread count.

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::bp::{BpConfig, BpEngine, BpSchedule};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::Engine;
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

/// The ablation grid: every policy family, plus a second frontier
/// parameter for the families that take one.
fn policies() -> Vec<(BpSchedule, f32)> {
    vec![
        (BpSchedule::Synchronous, 0.0),
        (BpSchedule::Residual, 0.1),
        (BpSchedule::Residual, 0.5),
        (BpSchedule::StaleResidual, 0.1),
        (BpSchedule::StaleResidual, 0.5),
        (BpSchedule::Bucketed { bins: 4 }, 0.0),
        (BpSchedule::Bucketed { bins: 8 }, 0.0),
        (BpSchedule::RandomizedSubset { p: 0.25, seed: 7 }, 0.0),
        (BpSchedule::RandomizedSubset { p: 0.5, seed: 7 }, 0.0),
    ]
}

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("bp_schedule_ablation");

    let (ds, mut cfg) = workload(DatasetKind::Synthetic, scale);
    // Convergence race: every policy stops at its own fixed point.
    cfg.mrf.fixed_iters = false;
    let models = prepare_models(&ds, &cfg);

    for threads in [1usize, 2, 4] {
        let bk = if threads == 1 {
            Backend::Serial
        } else {
            Backend::threaded(Pool::new(threads))
        };
        for (schedule, frontier) in policies() {
            let bp_cfg = BpConfig {
                schedule,
                frontier,
                ..Default::default()
            };
            let engine = BpEngine::new(bk.clone(), bp_cfg);
            let stats = measure(scale.warmup, scale.reps, || {
                for m in &models {
                    engine.run(m, &cfg.mrf);
                }
            });
            // One scored pass for the quality/effort labels.
            let (mut sweeps, mut energy) = (0usize, 0.0f64);
            let (mut frac_sum, mut frac_n) = (0.0f64, 0usize);
            for m in &models {
                let r = engine.run(m, &cfg.mrf);
                sweeps += r.map_iters;
                energy += r.energy;
                if let Some(b) = r.bp {
                    frac_sum += b.committed_frac;
                    frac_n += 1;
                }
            }
            let frac = frac_sum / frac_n.max(1) as f64;
            report.add(
                vec![
                    ("policy", schedule.spec()),
                    ("frontier", format!("{frontier}")),
                    ("threads", threads.to_string()),
                    ("sweeps", sweeps.to_string()),
                    ("final_energy", format!("{energy:.1}")),
                    ("committed_frac", format!("{frac:.4}")),
                ],
                stats,
            );
        }
    }
    report.finish();

    println!("BP schedule ablation (T_sync / T_policy; >1 means the \
              relaxed frontier wins):");
    for threads in [1usize, 2, 4] {
        let t = threads.to_string();
        let sync =
            report.median(&[("policy", "sync"), ("threads", t.as_str())]);
        for (schedule, frontier) in policies() {
            let spec = schedule.spec();
            let f = format!("{frontier}");
            let row = report.median(&[
                ("policy", spec.as_str()),
                ("frontier", f.as_str()),
                ("threads", t.as_str()),
            ]);
            if let (Some(sync), Some(row)) = (sync, row) {
                println!(
                    "  t{threads} {spec:<14} frontier {frontier:<4} \
                     {:.2}x",
                    sync / row
                );
            }
        }
    }
}
