//! Ablation: DPP chunk ("task") size — §4.1.3's claim that a well
//! chosen blocking factor is key to the DPP engine's advantage.
//!
//! Sweeps the Threaded backend's grain size at max concurrency; the
//! expected shape is a U-curve (tiny grains pay scheduling overhead,
//! huge grains under-parallelize), with a wide flat optimum around the
//! default (4096).

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let (ds, cfg) = workload(DatasetKind::Experimental, scale);
    let models = prepare_models(&ds, &cfg);
    let threads = dpp_pmrf::pool::available_threads();
    let pool = Pool::new(threads);
    let mut report = Report::new("ablation_grain");

    for grain in [64usize, 256, 1024, 4096, 16384, 65536, 1 << 20] {
        let engine = DppEngine::new(Backend::threaded_with_grain(
            pool.clone(),
            grain,
        ));
        let stats = measure(scale.warmup, scale.reps, || {
            for m in &models {
                engine.run(m, &cfg.mrf);
            }
        });
        report.add(
            vec![
                ("threads", threads.to_string()),
                ("grain", grain.to_string()),
            ],
            stats,
        );
    }
    report.finish();
}
