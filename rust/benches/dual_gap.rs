//! Dual-ascent certificates vs the MAP engines: wall-clock to a
//! certified bound, iterations spent, and — the number no other engine
//! can report — the optimality gap the certificate proves for the
//! decoded labeling. Runs the DPP-MAP engine (no certificate) next to
//! the dual engine so the cost of certification is explicit.
//!
//! Output: `bench_results/dual_gap.json` — one row per
//! (dataset, engine) with median seconds plus iteration, energy,
//! lower-bound, and gap labels.

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::dual::{DualConfig, DualEngine};
use dpp_pmrf::mrf::{dpp::DppEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("dual_gap");

    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let (ds, mut cfg) = workload(kind, scale);
        // Convergence race: each engine stops at its own fixpoint /
        // bound stall.
        cfg.mrf.fixed_iters = false;
        let models = prepare_models(&ds, &cfg);

        let pool = Pool::with_default_threads();
        let bk = Backend::threaded(pool);
        let engines: Vec<Box<dyn Engine>> = vec![
            Box::new(DppEngine::new(bk.clone())),
            Box::new(DualEngine::new(bk.clone(), DualConfig::default())),
        ];

        for engine in engines {
            let stats = measure(scale.warmup, scale.reps, || {
                for m in &models {
                    engine.run(m, &cfg.mrf);
                }
            });
            // One scored pass for the quality/certificate labels.
            let (mut inner, mut em, mut energy) = (0usize, 0usize, 0.0f64);
            let mut lower: Option<f64> = Some(0.0);
            for m in &models {
                let r = engine.run(m, &cfg.mrf);
                inner += r.map_iters;
                em += r.em_iters;
                energy += r.energy;
                lower = match (lower, r.lower_bound) {
                    (Some(acc), Some(lb)) => Some(acc + lb),
                    _ => None,
                };
            }
            let (bound_label, gap_label) = match lower {
                Some(lb) => (format!("{lb:.1}"),
                             format!("{:.3e}", (energy - lb).max(0.0))),
                None => ("null".to_string(), "null".to_string()),
            };
            report.add(
                vec![
                    ("dataset", kind.name().to_string()),
                    ("engine", engine.name().to_string()),
                    ("em_iters", em.to_string()),
                    ("inner_iters", inner.to_string()),
                    ("final_energy", format!("{energy:.1}")),
                    ("lower_bound", bound_label),
                    ("optimality_gap", gap_label),
                ],
                stats,
            );
        }
    }
    report.finish();

    println!("certification overhead (T_dual / T_map; 1.0 = free):");
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let map = report.median(&[("dataset", kind.name()),
                                  ("engine", "dpp")]);
        let dual = report.median(&[("dataset", kind.name()),
                                   ("engine", "dual")]);
        if let (Some(map), Some(dual)) = (map, dual) {
            println!("  {:<13} {:.2}x", kind.name(), dual / map);
        }
    }
}
