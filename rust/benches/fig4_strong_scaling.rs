//! Fig. 4 reproduction: strong-scaling speedup curves for the OpenMP
//! reference and DPP-PMRF, on both datasets.
//!
//! Speedup S(p) = T*(1) / T(p) with T*(1) the best serial time
//! (§4.3.1). Paper shape: both sub-linear; the reference scales better
//! on the synthetic dataset (regular neighborhood demographics) than on
//! the experimental one; DPP's limiter is SortByKey/ReduceByKey.

use dpp_pmrf::bench_support::{prepare_models, thread_sweep, workload,
                              Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::mrf::{dpp::DppEngine, reference::ReferenceEngine,
                    serial::SerialEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("fig4_strong_scaling");

    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let (ds, cfg) = workload(kind, scale);
        let models = prepare_models(&ds, &cfg);

        // Best serial baseline T*(n).
        let serial = measure(scale.warmup, scale.reps, || {
            for m in &models {
                SerialEngine.run(m, &cfg.mrf);
            }
        });
        report.add(
            vec![
                ("dataset", kind.name().to_string()),
                ("threads", "1".to_string()),
                ("engine", "serial-baseline".to_string()),
            ],
            serial.clone(),
        );

        for threads in thread_sweep() {
            let pool = Pool::new(threads);
            let engines: Vec<Box<dyn Engine>> = vec![
                Box::new(ReferenceEngine::new(pool.clone())),
                Box::new(DppEngine::new(if threads == 1 {
                    Backend::Serial
                } else {
                    Backend::threaded(pool.clone())
                })),
            ];
            for engine in engines {
                let stats = measure(scale.warmup, scale.reps, || {
                    for m in &models {
                        engine.run(m, &cfg.mrf);
                    }
                });
                report.add(
                    vec![
                        ("dataset", kind.name().to_string()),
                        ("threads", threads.to_string()),
                        ("engine", engine.name().to_string()),
                    ],
                    stats,
                );
            }
        }

        println!("Fig. 4 speedup curves ({}):", kind.name());
        for engine in ["reference", "dpp"] {
            let mut curve = String::new();
            for threads in thread_sweep() {
                let t = threads.to_string();
                if let Some(tp) = report.median(&[
                    ("dataset", kind.name()),
                    ("threads", &t),
                    ("engine", engine),
                ]) {
                    curve.push_str(&format!(
                        " {}→{:.2}x",
                        threads,
                        serial.median / tp
                    ));
                }
            }
            println!("  {engine:<10}{curve}");
        }
    }
    report.finish();
}
