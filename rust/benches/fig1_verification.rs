//! Fig. 1 + §4.2.2 reproduction (synthetic dataset): DPP-PMRF output vs
//! ground truth vs simple threshold, with the paper's verification
//! metrics (precision / recall / accuracy) and porosity.
//!
//! Paper numbers: precision 99.3%, recall 98.3%, accuracy 98.6% — ours
//! are expected in the same high-90s regime at `paper` scale; the
//! required *shape* is MRF > threshold on every metric. PGM figure
//! panels land in `bench_results/fig1/`.

use dpp_pmrf::bench_support::{workload, Scale};
use dpp_pmrf::config::{DatasetKind, EngineKind};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image::threshold;
use dpp_pmrf::eval::{self as metrics, Confusion};

fn main() {
    let scale = Scale::from_env();
    let (ds, mut cfg) = workload(DatasetKind::Synthetic, scale);
    // Verification wants converged results, not fixed bench loops.
    cfg.mrf.fixed_iters = false;
    cfg.mrf.em_iters = 20;
    cfg.mrf.map_iters = 10;
    cfg.engine = EngineKind::Dpp;

    let coord = Coordinator::new(cfg).unwrap();
    let report = coord.run(&ds).unwrap();
    let truth = ds.ground_truth.as_ref().unwrap();

    let mrf = report.confusion.unwrap();
    let thr_vol = threshold::otsu(&ds.input);
    let thr = Confusion::from_volumes(&thr_vol, truth);

    println!("Fig. 1 / §4.2.2 verification (synthetic):");
    println!("  DPP-PMRF : {}", metrics::summary(&mrf));
    println!("  threshold: {}", metrics::summary(&thr));
    println!(
        "  porosity: truth {:.3}  mrf {:.3}  threshold {:.3}",
        metrics::porosity(truth),
        report.porosity,
        metrics::porosity(&thr_vol)
    );
    println!(
        "  paper: precision 99.3%  recall 98.3%  accuracy 98.6%"
    );

    let dir = std::path::Path::new("bench_results/fig1");
    coord.save_figure(&ds, &report, 0, dir).unwrap();
    println!("  wrote panels to {}", dir.display());

    assert!(mrf.accuracy() > thr.accuracy(),
            "shape violated: MRF must beat thresholding");
    assert!(mrf.accuracy() > 0.85);
}
