//! Ablation: fused DPP pipelines + static-key segment caching vs the
//! paper's per-iteration sort (ISSUE 2 tentpole; §4.3.2–4.3.3 names
//! SortByKey + ReduceByKey as the limiters this layer attacks).
//!
//! (a) Primitive level, on identical inputs — the §3.2.2 pairing
//!     pattern (every key appears twice, unsorted). Per "iteration":
//!       * `unfused`: SortByKey(keys, iota) + Gather + ReduceByKey —
//!         exactly what the paper re-runs every MAP iteration;
//!       * `fused`:   `SegmentPlan::reduce_segments` against a plan
//!         built once — the sort amortized out of the loop.
//!     The one-time plan build is reported as its own row so the
//!     amortization claim is checkable: build ≈ one unfused sort.
//!
//! (b) Engine level, on identical models: `PairMode::Paper` (unfused)
//!     vs `PairMode::Planned` (plans cached once per run + the whole
//!     MAP iteration in one `Pipeline` region) vs `PairMode::Fused`
//!     (hand-fused L1 layout). All three are bitwise-identical in
//!     results, so the delta is pure execution structure.

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::{self, Backend, SegmentPlan};
use dpp_pmrf::mrf::dpp::{DppEngine, PairMode};
use dpp_pmrf::mrf::Engine;
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::{measure, Pcg32};

fn main() {
    let scale = Scale::from_env();
    let threads = dpp_pmrf::pool::available_threads();
    let pool = Pool::new(threads);
    let mut report = Report::new("ablation_fusion");

    // ---- (a) primitive level: static keys, fresh values every
    // iteration — the hot-loop shape of every engine.
    let n = 1 << 20;
    let mut rng = Pcg32::seeded(99);
    // Pairing-style keys: element ids replicated twice, unsorted.
    let keys: Vec<u64> = (0..n).map(|i| (i % (n / 2)) as u64).collect();
    let vals: Vec<f32> =
        (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();

    for (name, bk) in [
        ("serial", Backend::Serial),
        ("threaded", Backend::threaded(pool.clone())),
    ] {
        let reps = scale.reps.max(3);

        // Unfused: the per-iteration sort the paper pays.
        let stats = measure(1, reps, || {
            let mut k = keys.clone();
            let mut idx: Vec<u32> = (0..n as u32).collect();
            dpp::sort_by_key(&bk, &mut k, &mut idx);
            let sorted_vals = dpp::gather(&bk, &vals, &idx);
            let (_, sums) =
                dpp::reduce_by_key(&bk, &k, &sorted_vals, 0.0f32,
                                   |a, b| a + b);
            assert_eq!(sums.len(), n / 2);
        });
        report.add(
            vec![
                ("level", "primitive".to_string()),
                ("variant", format!("unfused-{name}")),
                ("threads", bk.threads().to_string()),
            ],
            stats,
        );

        // One-time plan build (the amortized cost).
        let stats = measure(1, reps, || {
            let plan = SegmentPlan::build(&bk, &keys);
            assert_eq!(plan.num_segments(), n / 2);
        });
        report.add(
            vec![
                ("level", "primitive".to_string()),
                ("variant", format!("plan-build-{name}")),
                ("threads", bk.threads().to_string()),
            ],
            stats,
        );

        // Fused: every subsequent iteration is sort-free.
        let plan = SegmentPlan::build(&bk, &keys);
        let stats = measure(1, reps, || {
            let sums =
                plan.reduce_segments(&bk, &vals, 0.0f32, |a, b| a + b);
            assert_eq!(sums.len(), n / 2);
        });
        report.add(
            vec![
                ("level", "primitive".to_string()),
                ("variant", format!("fused-{name}")),
                ("threads", bk.threads().to_string()),
            ],
            stats,
        );
    }

    // ---- (b) engine level: identical models, identical results,
    // different execution structure. Per-iteration time = total /
    // (em_iters * map_iters), fixed by the workload config.
    let (ds, cfg) = workload(DatasetKind::Experimental, scale);
    let models = prepare_models(&ds, &cfg);
    let iters = (cfg.mrf.em_iters * cfg.mrf.map_iters) as f64;
    for mode in [PairMode::Paper, PairMode::Planned, PairMode::Fused] {
        let engine =
            DppEngine::with_mode(Backend::threaded(pool.clone()), mode);
        let stats = measure(scale.warmup, scale.reps, || {
            for m in &models {
                engine.run(m, &cfg.mrf);
            }
        });
        println!(
            "engine {:<12} {:>9.3} ms/run  {:>9.3} ms/map-iter",
            engine.name(),
            stats.mean * 1e3,
            stats.mean * 1e3 / iters
        );
        report.add(
            vec![
                ("level", "engine".to_string()),
                ("variant", engine.name().to_string()),
                ("threads", threads.to_string()),
                ("map_iters", (iters as usize).to_string()),
            ],
            stats,
        );
    }
    report.finish();
}
