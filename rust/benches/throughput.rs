//! Serial vs sharded slice throughput (ISSUE 3 tentpole): slices/sec
//! for the slice scheduler across lane counts {1, 2, 4, 8} and three
//! engines — fused DPP (default), planned DPP (plan-cached pipeline),
//! and loopy BP. Lanes run with `threads = 1` so scaling comes purely
//! from slice-level sharding (the README's "Throughput mode" table).
//!
//! Output: `bench_results/throughput.json` — one row per
//! (engine, lanes) with median seconds, slices/sec, and observed lane
//! occupancy — plus a speedup-vs-1-lane summary on stdout. `lanes=1`
//! is the serial baseline (it takes the literal serial path).

use dpp_pmrf::bench_support::{Report, Scale};
use dpp_pmrf::bp::{BpConfig, BpEngine};
use dpp_pmrf::config::{DatasetConfig, DatasetKind, MrfConfig, RunConfig};
use std::sync::Arc;

use dpp_pmrf::dpp::Device;
use dpp_pmrf::image;
use dpp_pmrf::mrf::dpp::{DppEngine, PairMode};
use dpp_pmrf::mrf::Engine;
use dpp_pmrf::sched;
use dpp_pmrf::util::measure;

const LANES: [usize; 4] = [1, 2, 4, 8];

type Factory =
    Box<dyn Fn(usize, &Arc<dyn Device>) -> Box<dyn Engine> + Sync>;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("throughput");

    // Enough slices that 8 lanes have work; fixed iterations so every
    // configuration does identical work per slice.
    let slices = scale.slices.max(8);
    let base = RunConfig {
        dataset: DatasetConfig {
            kind: DatasetKind::Synthetic,
            width: scale.width,
            height: scale.height,
            slices,
            ..Default::default()
        },
        mrf: MrfConfig {
            em_iters: 5,
            map_iters: 4,
            fixed_iters: true,
            ..Default::default()
        },
        threads: 1,
        ..Default::default()
    };
    let ds = image::generate(&base.dataset);

    let engines: Vec<(&'static str, Factory)> = vec![
        ("dpp", Box::new(|_, dev: &Arc<dyn Device>| {
            Box::new(DppEngine::new(Arc::clone(dev))) as Box<dyn Engine>
        })),
        ("dpp-planned", Box::new(|_, dev: &Arc<dyn Device>| {
            Box::new(DppEngine::with_mode(Arc::clone(dev),
                                          PairMode::Planned))
                as Box<dyn Engine>
        })),
        ("bp", Box::new(|_, dev: &Arc<dyn Device>| {
            Box::new(BpEngine::new(Arc::clone(dev), BpConfig::default()))
                as Box<dyn Engine>
        })),
    ];

    for (name, factory) in &engines {
        let name = *name;
        for lanes in LANES {
            let mut cfg = base.clone();
            cfg.sched.lanes = lanes;
            cfg.sched.inflight = 2 * lanes;
            // Stash the last timed run's report for the occupancy /
            // metric labels — no extra un-timed pass.
            let last = std::cell::RefCell::new(None);
            let stats = measure(scale.warmup, scale.reps, || {
                let r =
                    sched::run_sharded_with(&ds, &cfg, name, |l, dev| {
                        factory(l, dev)
                    })
                .expect("sharded run");
                *last.borrow_mut() = Some(r);
            });
            let r = last.into_inner().expect("at least one rep ran");
            report.add(
                vec![
                    ("engine", name.to_string()),
                    ("lanes", lanes.to_string()),
                    ("slices_per_sec",
                     format!("{:.2}", slices as f64 / stats.median)),
                    ("occupancy",
                     format!("{:.2}", r.lane_occupancy())),
                    ("peak_inflight",
                     r.sched.peak_inflight.to_string()),
                ],
                stats,
            );
        }
    }
    report.finish();

    println!("slice throughput speedup vs lanes=1 (same engine):");
    for (name, _) in &engines {
        let name = *name;
        let t1 = report
            .median(&[("engine", name), ("lanes", "1")])
            .expect("lanes=1 row");
        for lanes in LANES {
            let ls = lanes.to_string();
            let t = report
                .median(&[("engine", name), ("lanes", ls.as_str())])
                .expect("row");
            println!(
                "  {name:<12} lanes {lanes}: {:.2}x ({:.2} slices/s)",
                t1 / t,
                slices as f64 / t
            );
        }
    }
}
