//! Ablation: the per-iteration SortByKey.
//!
//! (a) Engine level — paper mode (replicate energies, SortByKey to pair
//!     label copies, ReduceByKey<Min>; §3.2.2) vs fused mode (the L1
//!     kernel layout: both energies + min in one Map, no sort). This
//!     quantifies how much of DPP-PMRF's runtime the paper's dominant
//!     primitive actually costs — the §Perf optimization headroom.
//! (b) Primitive level — radix SortByKey vs a comparison sort on the
//!     pair keys the paper sorts (§4.3.3 discusses pair-sort overhead).

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::{self, Backend};
use dpp_pmrf::mrf::{dpp::{DppEngine, PairMode}, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::{measure, Pcg32};

fn main() {
    let scale = Scale::from_env();
    let threads = dpp_pmrf::pool::available_threads();
    let pool = Pool::new(threads);
    let mut report = Report::new("ablation_sort");

    // (a) engine level
    let (ds, cfg) = workload(DatasetKind::Experimental, scale);
    let models = prepare_models(&ds, &cfg);
    for mode in [PairMode::Paper, PairMode::Fused] {
        let engine =
            DppEngine::with_mode(Backend::threaded(pool.clone()), mode);
        let stats = measure(scale.warmup, scale.reps, || {
            for m in &models {
                engine.run(m, &cfg.mrf);
            }
        });
        report.add(
            vec![
                ("level", "engine".to_string()),
                ("variant", engine.name().to_string()),
                ("threads", threads.to_string()),
            ],
            stats,
        );
    }

    // (b) primitive level: sort 2^20 (vertexId, cliqueId)-style pairs.
    let n = 1 << 20;
    let mut rng = Pcg32::seeded(1234);
    let keys0: Vec<u64> = (0..n)
        .map(|_| dpp::pack_pair(rng.below(1 << 20), rng.below(1 << 20)))
        .collect();
    let vals0: Vec<u32> = (0..n as u32).collect();

    for (name, bk) in [
        ("radix-serial", Backend::Serial),
        ("radix-threaded", Backend::threaded(pool.clone())),
    ] {
        let stats = measure(1, scale.reps.max(3), || {
            let mut k = keys0.clone();
            let mut v = vals0.clone();
            dpp::sort_by_key(&bk, &mut k, &mut v);
        });
        report.add(
            vec![
                ("level", "primitive".to_string()),
                ("variant", name.to_string()),
                ("threads", bk.threads().to_string()),
            ],
            stats,
        );
    }
    let stats = measure(1, scale.reps.max(3), || {
        let mut k = keys0.clone();
        let mut v = vals0.clone();
        dpp::sort_pairs_comparison(&mut k, &mut v);
    });
    report.add(
        vec![
            ("level", "primitive".to_string()),
            ("variant", "comparison".to_string()),
            ("threads", "1".to_string()),
        ],
        stats,
    );
    report.finish();
}
