//! Allocation churn before/after the workspace layer (ISSUE 5): a
//! counting global allocator measures bytes allocated per EM/MAP
//! iteration for the legacy allocating primitive paths ("before") and
//! the workspace `_into`/`_ws` paths ("after"), plus whole-engine
//! runs for every [`PairMode`].
//!
//! Hard assertions (run on [`SerialDevice`], whose primitive calls
//! have no pool-dispatch allocations):
//!
//! * a warmed workspace iteration allocates **zero** bytes;
//! * a warmed Paper/Fused engine run's allocation volume does not
//!   depend on the MAP-iteration count — i.e. steady-state MAP
//!   iterations are allocation-free. (Planned mode re-boxes its
//!   pipeline stages each iteration — a few hundred bytes, reported
//!   but not asserted; see DESIGN.md §10.)
//! * disabled telemetry spans are free: the EM/MAP loops now open a
//!   span per iteration, so a disarmed `telemetry::span` must neither
//!   allocate nor read the clock (DESIGN.md §11 overhead contract) —
//!   the engine assertions above would catch a regression too, since
//!   every warmed run drops thousands of inert span guards.
//!
//! Output: a table on stdout and machine-readable `BENCH_5.json` at
//! the repo root (the perf-trajectory data point).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use dpp_pmrf::config::{MrfConfig, OversegConfig};
use dpp_pmrf::dpp::{self, SerialDevice, Workspace};
use dpp_pmrf::json::Value;
use dpp_pmrf::mrf::dpp::{DppEngine, PairMode};
use dpp_pmrf::mrf::{self, Engine, MrfModel};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// (allocation calls, bytes) performed by `f`.
fn alloc_delta(f: impl FnOnce()) -> (u64, u64) {
    let c0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let b0 = ALLOC_BYTES.load(Ordering::Relaxed);
    f();
    (
        ALLOC_CALLS.load(Ordering::Relaxed) - c0,
        ALLOC_BYTES.load(Ordering::Relaxed) - b0,
    )
}

/// One §3.2.2-shaped iteration through the legacy allocating
/// primitives — the pre-workspace hot loop ("before").
fn legacy_iteration(n: usize, y: &[f32], idx: &[u32]) {
    let bk = &SerialDevice;
    let lbl: Vec<f32> = dpp::map_indexed(bk, n, |i| (i % 2) as f32);
    let gathered = dpp::gather(bk, y, idx);
    let e_rep: Vec<f32> = dpp::map_indexed(bk, 2 * n, |i| {
        gathered[i % n] + lbl[i % n]
    });
    let mut keys: Vec<u64> = dpp::map_indexed(bk, 2 * n, |i| (i % n) as u64);
    let mut vals: Vec<u32> = dpp::iota(bk, 2 * n);
    dpp::sort_by_key(bk, &mut keys, &mut vals);
    let (_, win) = dpp::reduce_by_key(bk, &keys, &vals, u32::MAX, |a, b| {
        if a == u32::MAX { b } else if b == u32::MAX { a } else { a.min(b) }
    });
    let emin: Vec<f32> = dpp::map(bk, &win, |&i| e_rep[i as usize]);
    std::hint::black_box(emin);
}

/// The same iteration through the workspace paths ("after") — zero
/// allocations once the pool is warm.
fn ws_iteration(ws: &Workspace, n: usize, y: &[f32], idx: &[u32]) {
    let bk = &SerialDevice;
    let mut lbl = ws.take_spare::<f32>(n);
    dpp::map_indexed_into(bk, n, |i| (i % 2) as f32, &mut lbl);
    let mut gathered = ws.take_spare::<f32>(n);
    dpp::gather_into(bk, y, idx, &mut gathered);
    let mut e_rep = ws.take_spare::<f32>(2 * n);
    let g_ref = &gathered;
    let l_ref = &lbl;
    dpp::map_indexed_into(bk, 2 * n, |i| g_ref[i % n] + l_ref[i % n],
                          &mut e_rep);
    let mut keys = ws.take_spare::<u64>(2 * n);
    dpp::map_indexed_into(bk, 2 * n, |i| (i % n) as u64, &mut keys);
    let mut vals = ws.take_spare::<u32>(2 * n);
    dpp::iota_into(bk, 2 * n, &mut vals);
    dpp::sort_by_key_ws(bk, ws, &mut keys, &mut vals);
    let mut win_keys = ws.take_spare::<u64>(n);
    let mut win = ws.take_spare::<u32>(n);
    dpp::reduce_by_key_into(
        bk, ws, &keys[..], &vals[..], u32::MAX,
        |a, b| {
            if a == u32::MAX { b } else if b == u32::MAX { a } else { a.min(b) }
        },
        &mut win_keys, &mut win,
    );
    let mut emin = ws.take_spare::<f32>(n);
    let e_ref = &e_rep;
    dpp::map_into(bk, &win[..], |&i| e_ref[i as usize], &mut emin);
    std::hint::black_box(&emin[..]);
}

fn small_model(seed: u64) -> MrfModel {
    let v = dpp_pmrf::image::synth::porous_ground_truth(96, 96, 1, 0.42,
                                                        seed);
    let mut input = v.clone();
    dpp_pmrf::image::noise::additive_gaussian(&mut input, 60.0, seed);
    let seg = dpp_pmrf::overseg::oversegment(
        &SerialDevice,
        &input.slice(0),
        &OversegConfig { scale: 64.0, min_region: 4 },
    );
    mrf::build_model_serial(&seg)
}

fn mode_name(mode: PairMode) -> &'static str {
    match mode {
        PairMode::Paper => "paper",
        PairMode::Planned => "planned",
        PairMode::Fused => "fused",
    }
}

fn main() {
    let mut rows: Vec<Value> = Vec::new();

    // ---- primitive-level before/after ----
    let n = 50_000usize;
    let y: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 17.0).collect();
    let idx: Vec<u32> = (0..n as u32).rev().collect();

    let (legacy_calls, legacy_bytes) =
        alloc_delta(|| legacy_iteration(n, &y, &idx));

    let ws = Workspace::new();
    ws_iteration(&ws, n, &y, &idx); // warm-up pass (pool misses)
    ws_iteration(&ws, n, &y, &idx); // growth-convergence pass
    let (ws_calls, ws_bytes) =
        alloc_delta(|| ws_iteration(&ws, n, &y, &idx));
    assert_eq!(
        (ws_calls, ws_bytes),
        (0, 0),
        "steady-state workspace iteration must not allocate"
    );
    println!(
        "primitive iteration (n={n}): legacy {legacy_bytes} B in \
         {legacy_calls} allocs -> workspace {ws_bytes} B in {ws_calls} \
         allocs (steady state)"
    );
    rows.push(Value::object(vec![
        ("level", Value::str("primitives")),
        ("n", n.into()),
        ("legacy_bytes_per_iter", (legacy_bytes as usize).into()),
        ("legacy_allocs_per_iter", (legacy_calls as usize).into()),
        ("workspace_bytes_per_iter", (ws_bytes as usize).into()),
        ("workspace_allocs_per_iter", (ws_calls as usize).into()),
    ]));

    // ---- telemetry off: inert spans allocate nothing ----
    assert!(!dpp_pmrf::telemetry::tracing());
    let (span_calls, span_bytes) = alloc_delta(|| {
        for i in 0..1000u64 {
            let _s = dpp_pmrf::telemetry::span("prim", "Map");
            let _a = dpp_pmrf::telemetry::span_arg("map", "map_iter",
                                                   "iter", i);
            dpp_pmrf::telemetry::name_thread(format_args!("lane-{i}"));
        }
    });
    assert_eq!(
        (span_calls, span_bytes),
        (0, 0),
        "disarmed spans must not allocate"
    );
    println!("telemetry off: 1000 span/span_arg/name_thread triples -> \
              {span_bytes} B in {span_calls} allocs");
    rows.push(Value::object(vec![
        ("level", Value::str("telemetry_off")),
        ("span_bytes_per_1000", (span_bytes as usize).into()),
        ("span_allocs_per_1000", (span_calls as usize).into()),
    ]));

    // ---- observability off: disarmed hooks allocate nothing ----
    {
        let _g = dpp_pmrf::obs::obs_test_lock();
        assert!(!dpp_pmrf::obs::live(), "nothing armed in this bench");
        let (obs_calls, obs_bytes) = alloc_delta(|| {
            for i in 0..1000u64 {
                dpp_pmrf::obs::tick();
                dpp_pmrf::obs::map_sample(0, i as usize, 0.0, 0);
                dpp_pmrf::obs::bp_sample(0, i as usize, 0.0, 0.5, 0,
                                         "residual", 0.0);
                dpp_pmrf::obs::dual_sample(0, i as usize, 0.0, 0.0, 0.0);
            }
        });
        assert_eq!(
            (obs_calls, obs_bytes),
            (0, 0),
            "disarmed obs hooks must not allocate"
        );
        println!("obs off: 1000 tick/map/bp/dual hook quads -> \
                  {obs_bytes} B in {obs_calls} allocs");
        rows.push(Value::object(vec![
            ("level", Value::str("obs_off")),
            ("hook_bytes_per_1000", (obs_bytes as usize).into()),
            ("hook_allocs_per_1000", (obs_calls as usize).into()),
        ]));
    }

    // ---- engine-level: marginal bytes per extra MAP iteration ----
    let model = small_model(5);
    let cfg_short = MrfConfig { fixed_iters: true, em_iters: 2,
                                map_iters: 2, ..Default::default() };
    let cfg_long = MrfConfig { fixed_iters: true, em_iters: 2,
                               map_iters: 8, ..Default::default() };

    for mode in [PairMode::Paper, PairMode::Planned, PairMode::Fused] {
        let engine = DppEngine::with_mode(SerialDevice, mode);
        let (_, cold_bytes) =
            alloc_delta(|| { engine.run(&model, &cfg_long); });
        // Converge the pool fully before the warm measurements.
        engine.run(&model, &cfg_long);
        let (_, warm_short) =
            alloc_delta(|| { engine.run(&model, &cfg_short); });
        let (_, warm_long) =
            alloc_delta(|| { engine.run(&model, &cfg_long); });
        let extra_iters = (cfg_long.map_iters - cfg_short.map_iters)
            * cfg_long.em_iters;
        let per_iter = warm_long.saturating_sub(warm_short) as f64
            / extra_iters as f64;
        if matches!(mode, PairMode::Paper | PairMode::Fused) {
            assert_eq!(
                warm_long, warm_short,
                "{:?}: steady-state MAP iterations must not allocate",
                mode
            );
        }
        println!(
            "engine {:<8} cold run {cold_bytes:>12} B | warm runs: \
             {warm_short} B ({}x{} iters) vs {warm_long} B ({}x{} \
             iters) -> {per_iter:.1} B per extra MAP iteration",
            mode_name(mode),
            cfg_short.em_iters, cfg_short.map_iters,
            cfg_long.em_iters, cfg_long.map_iters,
        );
        let stats = engine.workspace_stats();
        rows.push(Value::object(vec![
            ("level", Value::str("engine")),
            ("mode", Value::str(mode_name(mode))),
            ("cold_run_bytes", (cold_bytes as usize).into()),
            ("warm_run_bytes_short", (warm_short as usize).into()),
            ("warm_run_bytes_long", (warm_long as usize).into()),
            ("bytes_per_extra_map_iter", per_iter.into()),
            ("workspace_hit_rate", stats.hit_rate().into()),
            ("workspace_high_water_bytes",
             stats.high_water_bytes.into()),
        ]));
    }

    let doc = Value::object(vec![
        ("bench", Value::str("alloc_churn")),
        ("issue", 5usize.into()),
        ("rows", Value::Array(rows)),
    ]);
    std::fs::write("BENCH_5.json", doc.to_pretty())
        .expect("write BENCH_5.json");
    println!("wrote BENCH_5.json");
}
