//! Particle max-product on the synthetic denoising workload: solver
//! wall-clock across devices and particle budgets, with the decoded
//! continuous energy, round count, and proposal acceptance as quality
//! labels — the continuous-label analog of `dual_gap.rs`.
//!
//! The serial oracle (`pmp::serial`) runs beside every DPP device at
//! the smallest particle budget, making the data-parallel overhead
//! (or win) on particle-sized work explicit. All rows decode the
//! same energies bitwise — the conformance gate
//! (`tests/pmp_conformance.rs`) enforces it; this bench prices it.
//!
//! Output: `bench_results/pmp_denoise.json` — one row per
//! (device, particles) with median seconds plus quality labels.

use dpp_pmrf::bench_support::{Report, Scale};
use dpp_pmrf::dpp::{Device, PoolDevice, SerialDevice, Workspace};
use dpp_pmrf::mrf::continuous;
use dpp_pmrf::pmp::{self, PmpConfig};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let mut report = Report::new("pmp_denoise");

    // One noisy step image per bench run; every row solves the same
    // instance so seconds are comparable across devices and budgets.
    let (model, truth) = continuous::synthetic_denoise(
        scale.width, scale.height, 20.0, 24414,
    );

    let devices: Vec<(&str, Box<dyn Device>)> = vec![
        ("serial", Box::new(SerialDevice)),
        ("pool-t2", Box::new(PoolDevice::new(2, 64))),
        ("pool-max",
         Box::new(PoolDevice::from_pool(Pool::with_default_threads(),
                                        64))),
    ];

    for particles in [2usize, 4, 8] {
        let cfg = PmpConfig {
            particles,
            iters: 8,
            ..Default::default()
        };

        // The serial oracle prices the plain-loop baseline once per
        // particle budget.
        let stats = measure(scale.warmup, scale.reps, || {
            pmp::serial::solve(&model, &cfg, None, false);
        });
        let run = pmp::serial::solve(&model, &cfg, None, false);
        report.add(
            vec![
                ("device", "oracle".to_string()),
                ("particles", particles.to_string()),
                ("rounds", run.iters.to_string()),
                ("energy", format!("{:.1}", run.energy)),
                ("noise_energy", format!("{:.1}", model.energy(&model.y))),
                ("truth_energy", format!("{:.1}", model.energy(&truth))),
            ],
            stats,
        );

        for (tag, dev) in &devices {
            let ws = Workspace::new();
            let stats = measure(scale.warmup, scale.reps, || {
                pmp::solve(&**dev, &ws, &model, &cfg, None, false);
            });
            let run = pmp::solve(&**dev, &ws, &model, &cfg, None, false);
            let denom =
                (run.iters * model.num_vertices() * particles) as f64;
            let acceptance =
                run.accepted.iter().sum::<u64>() as f64 / denom.max(1.0);
            report.add(
                vec![
                    ("device", tag.to_string()),
                    ("particles", particles.to_string()),
                    ("rounds", run.iters.to_string()),
                    ("energy", format!("{:.1}", run.energy)),
                    ("acceptance", format!("{acceptance:.3}")),
                ],
                stats,
            );
        }
    }
    report.finish();

    println!("particle-parallel speedup (T_oracle / T_device):");
    for particles in ["2", "4", "8"] {
        let oracle = report.median(&[("device", "oracle"),
                                     ("particles", particles)]);
        let pool = report.median(&[("device", "pool-max"),
                                   ("particles", particles)]);
        if let (Some(o), Some(p)) = (oracle, pool) {
            println!("  K={particles:<3} {:.2}x", o / p);
        }
    }
}
