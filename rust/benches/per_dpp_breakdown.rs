//! §4.3.2–4.3.3 analysis reproduction: per-primitive runtime breakdown
//! of DPP-PMRF (paper mode) at 1 thread vs max threads.
//!
//! The paper's finding: SortByKey and ReduceByKey dominate the runtime
//! and are the scalability limiters (≈5X at 24 cores / ≈11X at 64 on
//! their machines while the Maps scale near-linearly). This bench
//! prints the same breakdown for our engine.

use dpp_pmrf::bench_support::{prepare_models, workload, Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::dpp::{timing, Backend};
use dpp_pmrf::mrf::{dpp::{DppEngine, PairMode}, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::Stats;

fn main() {
    let scale = Scale::from_env();
    let (ds, cfg) = workload(DatasetKind::Experimental, scale);
    let models = prepare_models(&ds, &cfg);
    let max_threads = dpp_pmrf::pool::available_threads();
    let mut report = Report::new("per_dpp_breakdown");

    let mut snaps = Vec::new();
    for threads in [1usize, max_threads] {
        let backend = if threads == 1 {
            Backend::Serial
        } else {
            Backend::threaded(Pool::new(threads))
        };
        let engine = DppEngine::with_mode(backend, PairMode::Paper);
        timing::reset();
        timing::set_enabled(true);
        for m in &models {
            engine.run(m, &cfg.mrf);
        }
        timing::set_enabled(false);
        let snap = timing::snapshot();
        println!("--- per-DPP breakdown @ {threads} thread(s) ---");
        println!("{}", timing::report());
        for (name, st) in &snap {
            report.add(
                vec![
                    ("threads", threads.to_string()),
                    ("primitive", name.to_string()),
                ],
                Stats::from_samples(&[st.nanos as f64 / 1e9]),
            );
        }
        snaps.push((threads, snap));
        timing::reset();
    }
    report.finish();

    // Per-primitive scaling factor (the paper's SortByKey/ReduceByKey
    // observation).
    let (_, ref serial) = snaps[0];
    let (t, ref par) = snaps[1];
    println!("per-primitive speedup 1 -> {t} threads:");
    for (name, s) in serial {
        if let Some(p) = par.get(name) {
            if p.nanos > 0 {
                println!(
                    "  {:<16} {:>6.2}x",
                    name,
                    s.nanos as f64 / p.nanos as f64
                );
            }
        }
    }
}
