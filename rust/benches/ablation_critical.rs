//! Ablation: the reference implementation's critical section.
//!
//! §4.3.3 blames the OpenMP code's sub-linear scaling partly on a
//! serialized output write-back. Our reference engine reproduces that
//! mutex faithfully; this bench measures how much it actually costs by
//! toggling it at increasing concurrency.

use dpp_pmrf::bench_support::{prepare_models, thread_sweep, workload,
                              Report, Scale};
use dpp_pmrf::config::DatasetKind;
use dpp_pmrf::mrf::{reference::ReferenceEngine, Engine};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::measure;

fn main() {
    let scale = Scale::from_env();
    let (ds, cfg) = workload(DatasetKind::Experimental, scale);
    let models = prepare_models(&ds, &cfg);
    let mut report = Report::new("ablation_critical");

    for threads in thread_sweep() {
        let pool = Pool::new(threads);
        for (variant, engine) in [
            ("with-critical", ReferenceEngine::new(pool.clone())),
            (
                "no-critical",
                ReferenceEngine::without_critical_section(pool.clone()),
            ),
        ] {
            let stats = measure(scale.warmup, scale.reps, || {
                for m in &models {
                    engine.run(m, &cfg.mrf);
                }
            });
            report.add(
                vec![
                    ("threads", threads.to_string()),
                    ("variant", variant.to_string()),
                ],
                stats,
            );
        }
    }
    report.finish();
}
