//! Fig. 2 + §4.2.2 reproduction (experimental dataset): DPP-PMRF vs the
//! reference implementation's result — there is no ground truth for the
//! beamline data, so the paper scores DPP-PMRF *against the reference
//! output* (precision 97.2%, recall 95.2%, accuracy 96.8%).
//!
//! Required shape: near-total agreement between the two engines, with
//! residual differences confined to small regions (label ties), and
//! both clearly different from naive thresholding.

use dpp_pmrf::bench_support::{workload, Scale};
use dpp_pmrf::config::{DatasetKind, EngineKind};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image::threshold;
use dpp_pmrf::eval::{self as metrics, Confusion};

fn main() {
    let scale = Scale::from_env();
    let (ds, mut base) = workload(DatasetKind::Experimental, scale);
    base.mrf.fixed_iters = false;
    base.mrf.em_iters = 20;
    base.mrf.map_iters = 10;

    let mut outputs = Vec::new();
    for engine in [EngineKind::Reference, EngineKind::Dpp] {
        let mut cfg = base.clone();
        cfg.engine = engine;
        let coord = Coordinator::new(cfg).unwrap();
        let report = coord.run(&ds).unwrap();
        if engine == EngineKind::Dpp {
            let dir = std::path::Path::new("bench_results/fig2");
            coord.save_figure(&ds, &report, 0, dir).unwrap();
            println!("wrote panels to {}", dir.display());
        }
        outputs.push(report.output);
    }
    let reference = &outputs[0];
    let dpp = &outputs[1];

    // Score DPP against the reference result (the paper's protocol).
    let c = Confusion::from_volumes(dpp, reference);
    println!("Fig. 2 / §4.2.2 verification (experimental):");
    println!("  DPP vs reference: {}", metrics::summary(&c));
    println!("  paper:            precision 97.2%  recall 95.2%  \
              accuracy 96.8%");

    let thr = threshold::otsu(&ds.input);
    let t = Confusion::from_volumes(&thr, reference);
    println!("  threshold vs ref: {}", metrics::summary(&t));
    println!(
        "  porosity: ref {:.3}  dpp {:.3}  threshold {:.3}",
        metrics::porosity(reference),
        metrics::porosity(dpp),
        metrics::porosity(&thr)
    );

    assert!(c.accuracy() > 0.95,
            "engines must agree closely: {}", c.accuracy());
    assert!(c.accuracy() > t.accuracy(),
            "DPP must match the reference better than thresholding does");
}
