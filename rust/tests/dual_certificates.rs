//! Weak-duality property suite (ISSUE 7): the dual ascent's bound must
//! be monotone non-decreasing across iterations, sit at or below the
//! exhaustive optimum on tiny grids (and be tight there against its
//! own f64 pairwise objective — binary Potts with non-negative edge
//! weights is submodular, where MPLP closes the gap), lower-bound
//! every engine's primal energy on production-sized models, and come
//! out bitwise identical across devices and scheduler lane counts.

mod common;

use dpp_pmrf::config::{DatasetConfig, EngineKind, MrfConfig, RunConfig};
use dpp_pmrf::coordinator::{Coordinator, RunReport};
use dpp_pmrf::dpp::{PoolDevice, SerialDevice};
use dpp_pmrf::dual::{self, DualConfig, PairGraph};
use dpp_pmrf::image;
use dpp_pmrf::mrf::{self, EngineResources};
use dpp_pmrf::pool::Pool;

const GRIDS: [(usize, usize); 3] = [(2, 3), (3, 3), (3, 4)];

#[test]
fn bound_is_monotone_and_tight_on_tiny_grids() {
    let prm = common::fixed_params();
    // Generous budget: tightness needs convergence, not just ascent.
    let cfg = DualConfig { iters: 200, ..Default::default() };
    for (w, h) in GRIDS {
        for seed in [31u64, 32, 33] {
            let model = common::grid_model(w, h, seed);
            let run = dual::solve(&SerialDevice, &model, &prm, &cfg);

            // Monotone non-decreasing across iterations (up to f64
            // accumulation noise).
            for (i, pair) in run.history.windows(2).enumerate() {
                assert!(
                    pair[1] >= pair[0] - 1e-9 * pair[0].abs().max(1.0),
                    "{w}x{h} seed {seed}: bound fell at iter {}: \
                     {} -> {}",
                    i + 1,
                    pair[0],
                    pair[1]
                );
            }

            // Weak duality + tightness against the dual's own f64
            // pairwise objective: bound <= optimum always, and equal
            // at convergence on these submodular instances.
            let g = PairGraph::build(&SerialDevice, &model, prm.beta);
            let unary = dual::unaries(&SerialDevice, &model, &g, &prm);
            let pair_opt = common::brute_force_pair(&g, &unary);
            let scale = pair_opt.abs().max(1.0);
            assert!(
                run.bound <= pair_opt + 1e-9 * scale,
                "{w}x{h} seed {seed}: bound {} above optimum {pair_opt}",
                run.bound
            );
            assert!(
                run.bound >= pair_opt - 1e-9 * scale,
                "{w}x{h} seed {seed}: bound {} not tight vs {pair_opt}",
                run.bound
            );

            // The reported certificate (bound minus scorer slack) never
            // exceeds the exhaustive optimum of the f32-scored hood
            // energy — the acceptance inequality at its tightest.
            let (_, opt) = common::brute_force_config(&model, &prm);
            let lower = run.bound - dual::scorer_slack(&model, &prm);
            assert!(
                lower <= opt,
                "{w}x{h} seed {seed}: certificate {lower} beat the \
                 exhaustive optimum {opt}"
            );
        }
    }
}

#[test]
fn certificate_lower_bounds_every_engine_primal() {
    let prm = common::fixed_params();
    let model = common::porous_model(41);
    let run =
        dual::solve(&SerialDevice, &model, &prm, &DualConfig::default());
    let lower = run.bound - dual::scorer_slack(&model, &prm);
    assert!(lower.is_finite());

    // The dual's own primal decode first...
    let (_, own) = mrf::config_energy(&model, &run.labels, &prm);
    assert!(lower <= own, "certificate {lower} above own decode {own}");

    // ...then every engine's final labels, scored under the same
    // fixed parameters the bound was computed for (weak duality holds
    // for EVERY labeling of that objective).
    let res = EngineResources::new(Pool::serial(), SerialDevice);
    for kind in [EngineKind::Serial, EngineKind::Reference,
                 EngineKind::Dpp, EngineKind::Bp, EngineKind::Dual] {
        let engine = mrf::make_engine(kind, &res).unwrap();
        let out = engine.run(&model, &MrfConfig::default());
        let (_, e) = mrf::config_energy(&model, &out.labels, &prm);
        assert!(
            lower <= e,
            "{}: certificate {lower} exceeds primal {e}",
            engine.name()
        );
    }
}

#[test]
fn solve_is_device_independent_bitwise() {
    let prm = common::fixed_params();
    let cfg = DualConfig::default();
    for seed in [61u64, 62] {
        let model = common::porous_model(seed);
        let want = dual::solve(&SerialDevice, &model, &prm, &cfg);
        for threads in [1usize, 2, 4] {
            let dev = PoolDevice::new(threads, 64);
            let got = dual::solve(&dev, &model, &prm, &cfg);
            assert_eq!(
                got.bound.to_bits(),
                want.bound.to_bits(),
                "seed {seed} t{threads}: bound drifted"
            );
            assert_eq!(got, want, "seed {seed} t{threads}");
        }
    }
}

#[test]
fn coordinator_dual_runs_certify_across_lanes() {
    let mut cfg = RunConfig {
        dataset: DatasetConfig {
            width: 64,
            height: 64,
            slices: 4,
            ..Default::default()
        },
        engine: EngineKind::Dual,
        threads: 2,
        ..Default::default()
    };
    let ds = image::generate(&cfg.dataset);
    let mut baseline: Option<RunReport> = None;
    for lanes in [1usize, 2, 4] {
        cfg.sched.lanes = lanes;
        let report =
            Coordinator::new(cfg.clone()).unwrap().run(&ds).unwrap();
        assert_eq!(report.engine, "dual");

        // Every slice certifies: finite bound, gap >= 0, bound below
        // the slice's own final energy.
        for s in &report.slices {
            let lb = s.lower_bound.expect("dual engine certifies");
            assert!(lb.is_finite(), "lanes {lanes} slice {}", s.z);
            assert!(lb <= s.final_energy,
                    "lanes {lanes} slice {}: {lb} > {}",
                    s.z, s.final_energy);
            let gap = s.optimality_gap.expect("gap present");
            assert!(gap >= 0.0, "lanes {lanes} slice {}: gap {gap}", s.z);
        }
        let lb = report.lower_bound().expect("run-level bound");
        assert!(lb.is_finite());
        assert!(report.optimality_gap().unwrap() >= 0.0);

        // Bitwise parity across lane counts — outputs, energies, AND
        // certificates.
        match &baseline {
            None => baseline = Some(report),
            Some(b) => {
                assert_eq!(report.output.data, b.output.data,
                           "lanes {lanes}: output drifted");
                for (a, s) in report.slices.iter().zip(&b.slices) {
                    assert_eq!(a.final_energy.to_bits(),
                               s.final_energy.to_bits(),
                               "lanes {lanes} slice {}", a.z);
                    assert_eq!(a.lower_bound.unwrap().to_bits(),
                               s.lower_bound.unwrap().to_bits(),
                               "lanes {lanes} slice {}", a.z);
                }
            }
        }
    }
}
