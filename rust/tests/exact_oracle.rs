//! Brute-force exact-MAP gate (ISSUE 7): on tiny grids the `2^nv`
//! optimum is enumerable, so every engine's primal energy — scored
//! under one shared parameter set — must come out at or above it. A
//! heuristic "beating" the exhaustive optimum means the oracle and the
//! engines disagree about the objective, which is exactly the bug this
//! suite exists to catch.

mod common;

use dpp_pmrf::config::{EngineKind, MrfConfig};
use dpp_pmrf::dpp::SerialDevice;
use dpp_pmrf::mrf::{self, EngineResources};
use dpp_pmrf::pool::Pool;

const GRIDS: [(usize, usize); 3] = [(2, 3), (3, 3), (3, 4)];
const SEEDS: [u64; 3] = [11, 12, 13];

/// Every engine that can run without accelerator artifacts.
const ENGINES: [EngineKind; 6] = [
    EngineKind::Serial,
    EngineKind::Reference,
    EngineKind::Dpp,
    EngineKind::Bp,
    EngineKind::Dual,
    EngineKind::Pmp,
];

#[test]
fn oracle_optimum_is_consistent_and_locally_minimal() {
    let prm = common::fixed_params();
    let model = common::grid_model(3, 3, 21);
    let (labels, opt) = common::brute_force_config(&model, &prm);
    // The reported optimum is the energy of the reported labeling...
    let (_, check) = mrf::config_energy(&model, &labels, &prm);
    assert_eq!(check, opt);
    // ...and no single-vertex flip improves on it (necessary condition
    // for a global optimum; catches enumeration/scoring mismatches).
    for v in 0..labels.len() {
        let mut flipped = labels.clone();
        flipped[v] ^= 1;
        let (_, e) = mrf::config_energy(&model, &flipped, &prm);
        assert!(e >= opt, "flip {v}: {e} < {opt}");
    }
}

#[test]
fn every_engine_respects_the_exact_optimum() {
    let prm = common::fixed_params();
    let res = EngineResources::new(Pool::serial(), SerialDevice);
    let cfg = MrfConfig::default();
    for (w, h) in GRIDS {
        for seed in SEEDS {
            let model = common::grid_model(w, h, seed);
            let (_, opt) = common::brute_force_config(&model, &prm);
            for kind in ENGINES {
                let engine = mrf::make_engine(kind, &res).unwrap();
                let out = engine.run(&model, &cfg);
                // Score the engine's labels under the shared fixed
                // parameters: the oracle enumerated every labeling, so
                // this holds with NO tolerance.
                let (_, e) = mrf::config_energy(&model, &out.labels, &prm);
                assert!(
                    e >= opt,
                    "{} beat the exhaustive optimum on {w}x{h} seed \
                     {seed}: {e} < {opt}",
                    engine.name()
                );
            }
        }
    }
}

#[test]
fn every_bp_policy_is_exact_on_trees() {
    // Chains are trees: max-product BP converges to the exact MAP, so
    // every frontier policy (ISSUE 10) must decode the brute-force
    // optimum labeling — not just match its energy. Decisive
    // observations (common::chain_model) make the optimum unique in
    // practice, so label equality is the stronger, fair check.
    use dpp_pmrf::bp::{self, BpConfig, BpSchedule};
    use dpp_pmrf::dpp::Backend;
    let prm = common::fixed_params();
    let policies = [
        BpSchedule::Synchronous,
        BpSchedule::Residual,
        BpSchedule::StaleResidual,
        BpSchedule::Bucketed { bins: 8 },
        BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
    ];
    for n in [6usize, 10, 12] {
        for seed in SEEDS {
            let model = common::chain_model(n, seed);
            let (want, opt) = common::brute_force_config(&model, &prm);
            for schedule in policies {
                let cfg = BpConfig {
                    schedule,
                    max_sweeps: 400,
                    tol: 1e-6,
                    ..Default::default()
                };
                let (labels, run) =
                    bp::solve(&Backend::Serial, &model, &prm, &cfg);
                assert!(run.converged,
                        "chain {n} seed {seed} {schedule:?} converged");
                assert_eq!(labels, want,
                           "chain {n} seed {seed} {schedule:?}");
                let (_, e) = mrf::config_energy(&model, &labels, &prm);
                assert_eq!(e, opt,
                           "chain {n} seed {seed} {schedule:?} energy");
            }
        }
    }
}

#[test]
fn xla_engine_without_artifacts_fails_cleanly() {
    // The sweep above skips the XLA engine (no AOT artifacts in the
    // test environment); pin that the factory refuses it with a clear
    // error instead of panicking.
    let res = EngineResources::new(Pool::serial(), SerialDevice);
    let err = mrf::make_engine(EngineKind::Xla, &res).unwrap_err();
    assert!(err.to_string().contains("artifacts"), "{err}");
}
