//! Report-contract regression (ISSUE 7): `RunReport::to_json` is the
//! surface benches, the CI smoke checks, and downstream dashboards
//! scrape — pin its key set at both the run and slice level, and pin
//! that the certificate fields (`lower_bound`, `optimality_gap`) are
//! present-but-null for non-certifying engines and finite/ordered for
//! the dual engine.

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::json::Value;

fn report_json(engine: EngineKind) -> Value {
    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: 64,
            height: 64,
            slices: 2,
            ..Default::default()
        },
        engine,
        threads: 2,
        ..Default::default()
    };
    let ds = image::generate(&cfg.dataset);
    Coordinator::new(cfg).unwrap().run(&ds).unwrap().to_json()
}

fn keys(v: &Value) -> Vec<&str> {
    v.as_object()
        .expect("JSON object")
        .keys()
        .map(String::as_str)
        .collect()
}

/// The run-level contract on a synthetic dataset (ground truth
/// present, so the confusion metrics appear).
const RUN_KEYS: [&str; 33] = [
    "accuracy", "bp_committed_frac", "bp_schedule", "convergence",
    "device", "device_fused_regions", "device_offload",
    "device_threaded", "em_iters", "engine", "exec", "inflight_cap",
    "job_latency", "lane_occupancy", "lane_timeline", "lanes",
    "lower_bound", "map_iters", "mean_init_secs", "mean_opt_secs",
    "optimality_gap", "peak_inflight", "pmp_acceptance",
    "pmp_max_marginal_energy", "pmp_particles", "porosity",
    "precision", "queue_wait", "recall", "slice_reports", "slices",
    "slices_per_sec", "total_secs",
];

/// The per-slice row contract.
const SLICE_KEYS: [&str; 18] = [
    "bp_committed_frac", "bp_schedule", "elements", "em_iters",
    "final_energy", "hoods", "init_secs", "lane", "lower_bound",
    "map_iters", "opt_secs", "optimality_gap", "pmp_acceptance",
    "pmp_max_marginal_energy", "pmp_particles", "queue_wait_secs",
    "regions", "z",
];

fn assert_schema(j: &Value) {
    let mut want: Vec<&str> = RUN_KEYS.to_vec();
    want.sort_unstable();
    assert_eq!(keys(j), want, "run-level key set changed");
    let rows = j.get("slice_reports").and_then(Value::as_array).unwrap();
    assert!(!rows.is_empty());
    let mut want: Vec<&str> = SLICE_KEYS.to_vec();
    want.sort_unstable();
    for row in rows {
        assert_eq!(keys(row), want, "slice-row key set changed");
    }
}

#[test]
fn non_certifying_engine_reports_null_certificates() {
    let j = report_json(EngineKind::Serial);
    assert_schema(&j);
    // Present-but-null: consumers probe one stable schema and need
    // not special-case engines without certificates.
    assert_eq!(j.get("lower_bound"), Some(&Value::Null));
    assert_eq!(j.get("optimality_gap"), Some(&Value::Null));
    // Flight recorder off by default: the key is pinned, the value
    // null (ISSUE 8).
    assert_eq!(j.get("convergence"), Some(&Value::Null));
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        assert_eq!(row.get("lower_bound"), Some(&Value::Null));
        assert_eq!(row.get("optimality_gap"), Some(&Value::Null));
    }
    // Particle fields follow the same contract: pinned keys, null
    // values for every engine but pmp (ISSUE 9).
    for key in
        ["pmp_particles", "pmp_acceptance", "pmp_max_marginal_energy"]
    {
        assert_eq!(j.get(key), Some(&Value::Null), "{key}");
    }
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        assert_eq!(row.get("pmp_particles"), Some(&Value::Null));
        assert_eq!(row.get("pmp_acceptance"), Some(&Value::Null));
        assert_eq!(row.get("pmp_max_marginal_energy"),
                   Some(&Value::Null));
    }
    // BP frontier fields too: pinned keys, null for non-BP engines
    // (ISSUE 10).
    assert_eq!(j.get("bp_schedule"), Some(&Value::Null));
    assert_eq!(j.get("bp_committed_frac"), Some(&Value::Null));
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        assert_eq!(row.get("bp_schedule"), Some(&Value::Null));
        assert_eq!(row.get("bp_committed_frac"), Some(&Value::Null));
    }
}

#[test]
fn bp_engine_reports_schedule_and_committed_fraction() {
    let j = report_json(EngineKind::Bp);
    assert_schema(&j);
    // Default frontier policy, named by its spec string at the run
    // level (all slices agree) and per slice.
    assert_eq!(
        j.get("bp_schedule").and_then(Value::as_str),
        Some("residual")
    );
    let frac = j
        .get("bp_committed_frac")
        .and_then(Value::as_f64)
        .expect("bp run carries a committed fraction");
    assert!((0.0..=1.0).contains(&frac), "committed fraction {frac}");
    assert!(frac > 0.0, "some messages must commit");
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        assert_eq!(
            row.get("bp_schedule").and_then(Value::as_str),
            Some("residual")
        );
        let f = row
            .get("bp_committed_frac")
            .and_then(Value::as_f64)
            .expect("per-slice committed fraction");
        assert!((0.0..=1.0).contains(&f), "slice fraction {f}");
    }
}

#[test]
fn pmp_engine_reports_numeric_particle_stats() {
    let j = report_json(EngineKind::Pmp);
    assert_schema(&j);
    // The certificate stays null (pmp does not certify) while the
    // particle deliverables go numeric — both contracts at once.
    assert_eq!(j.get("lower_bound"), Some(&Value::Null));
    assert_eq!(j.get("optimality_gap"), Some(&Value::Null));
    let particles = j
        .get("pmp_particles")
        .and_then(Value::as_f64)
        .expect("pmp run carries a particle count");
    assert!(particles >= 1.0);
    let acc = j
        .get("pmp_acceptance")
        .and_then(Value::as_f64)
        .expect("pmp run carries an acceptance rate");
    assert!((0.0..=1.0).contains(&acc), "acceptance {acc}");
    assert!(j
        .get("pmp_max_marginal_energy")
        .and_then(Value::as_f64)
        .expect("pmp run carries a continuous energy")
        .is_finite());
    let mut sum = 0.0f64;
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        let p = row
            .get("pmp_particles")
            .and_then(Value::as_f64)
            .expect("per-slice particle count");
        assert!(p >= 1.0);
        sum += p;
        assert!(row
            .get("pmp_acceptance")
            .and_then(Value::as_f64)
            .is_some());
        assert!(row
            .get("pmp_max_marginal_energy")
            .and_then(Value::as_f64)
            .is_some());
    }
    // Run-level particle count is the per-slice sum.
    assert_eq!(particles, sum, "run particles vs slice sum");
}

#[test]
fn dual_engine_reports_finite_ordered_certificates() {
    let j = report_json(EngineKind::Dual);
    assert_schema(&j);
    let lb = j
        .get("lower_bound")
        .and_then(Value::as_f64)
        .expect("dual run carries a numeric lower bound");
    assert!(lb.is_finite());
    let gap = j
        .get("optimality_gap")
        .and_then(Value::as_f64)
        .expect("dual run carries a numeric gap");
    assert!(gap >= 0.0, "gap {gap}");
    let mut sum = 0.0f64;
    for row in j.get("slice_reports").and_then(Value::as_array).unwrap() {
        let slb = row
            .get("lower_bound")
            .and_then(Value::as_f64)
            .expect("per-slice bound");
        assert!(slb.is_finite());
        let sgap = row
            .get("optimality_gap")
            .and_then(Value::as_f64)
            .expect("per-slice gap");
        assert!(sgap >= 0.0, "slice gap {sgap}");
        let energy =
            row.get("final_energy").and_then(Value::as_f64).unwrap();
        assert!(slb <= energy, "slice bound {slb} above energy {energy}");
        sum += slb;
    }
    // Run-level bound is the per-slice sum (energies are additive).
    assert!((lb - sum).abs() <= 1e-9 * sum.abs().max(1.0),
            "run bound {lb} vs slice sum {sum}");
}

/// Empty-percentile semantics (ISSUE 8): zero completed jobs must
/// serialize as `null` percentile objects — "no traffic" is
/// distinguishable from "instant jobs" — at every surface a report
/// consumer scrapes.
#[test]
fn zero_jobs_emit_null_percentile_objects() {
    // The report path's exact-percentile summarizer.
    let j = dpp_pmrf::telemetry::percentiles(&[]).to_json();
    for q in ["p50", "p90", "p99"] {
        assert_eq!(j.get(q), Some(&Value::Null), "percentiles.{q}");
    }
    // The serving path: a fresh service has completed nothing.
    let svc = dpp_pmrf::sched::Service::new(1, 1);
    let lat = svc.latency();
    assert_eq!(lat.jobs, 0);
    for (name, s) in [("wait", lat.wait), ("exec", lat.exec)] {
        assert_eq!(s.samples, 0, "{name}");
        let j = s.to_json();
        for q in ["p50", "p90", "p99"] {
            assert_eq!(j.get(q), Some(&Value::Null), "{name}.{q}");
        }
    }
}
