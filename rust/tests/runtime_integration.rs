//! Runtime-layer integration: AOT artifacts loaded through PJRT must
//! agree with the rust energy math across randomized shapes and
//! parameter settings, bucket selection must pad correctly, and the
//! XLA engine must agree with the serial engine through the
//! coordinator. (Requires `make artifacts`.)

use std::path::Path;
use std::sync::Arc;

use dpp_pmrf::config::{DatasetConfig, EngineKind, MrfConfig, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::mrf::energy::{self, Params};
use dpp_pmrf::runtime::EmRuntime;
use dpp_pmrf::util::Pcg32;

/// `None` (skip) when the PJRT runtime / AOT artifacts are
/// unavailable — offline builds carry only the stub binding in
/// `rust/src/runtime/xla.rs`; run `make artifacts` on a full toolchain
/// to exercise these tests.
fn runtime() -> Option<Arc<EmRuntime>> {
    match EmRuntime::load(Path::new("artifacts")) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping xla runtime test: {e}");
            None
        }
    }
}

#[test]
fn randomized_batches_match_rust_oracle() {
    let Some(rt) = runtime() else { return };
    for seed in 0..8u64 {
        let mut rng = Pcg32::seeded(seed);
        let nh = 1 + rng.below(40) as usize;
        let n = nh + rng.below(900) as usize;
        let prm = Params {
            mu: [rng.f32() * 255.0, rng.f32() * 255.0],
            sigma: [1.0 + rng.f32() * 60.0, 1.0 + rng.f32() * 60.0],
            beta: rng.f32() * 2.0,
        };
        let y: Vec<f32> = (0..n).map(|_| rng.f32() * 255.0).collect();
        let label: Vec<f32> =
            (0..n).map(|_| (rng.next_u32() & 1) as f32).collect();
        // every hood gets at least one element
        let hood_id: Vec<u32> = (0..n)
            .map(|i| if i < nh { i as u32 } else { rng.below(nh as u32) })
            .collect();
        let out = rt.em_step(&y, &label, &hood_id, nh, &prm).unwrap();

        // oracle
        let mut ones = vec![0.0f32; nh];
        let mut size = vec![0.0f32; nh];
        for i in 0..n {
            ones[hood_id[i] as usize] += label[i];
            size[hood_id[i] as usize] += 1.0;
        }
        let mut he = vec![0.0f32; nh];
        for i in 0..n {
            let h = hood_id[i] as usize;
            let (em, am) =
                energy::energy_min(y[i], label[i], ones[h], size[h], &prm);
            assert!(
                (out.emin[i] - em).abs() < 1e-3 * em.abs().max(1.0),
                "seed {seed} emin[{i}] {} vs {em}",
                out.emin[i]
            );
            assert_eq!(out.new_label[i], am as f32,
                       "seed {seed} label[{i}]");
            he[h] += em;
        }
        for h in 0..nh {
            assert!(
                (out.hood_energy[h] - he[h]).abs()
                    < 1e-2 * he[h].abs().max(1.0),
                "seed {seed} hood {h}: {} vs {}",
                out.hood_energy[h],
                he[h]
            );
        }
        assert_eq!((out.stats[0] + out.stats[3]) as usize, n,
                   "seed {seed} stats count");
    }
}

#[test]
fn bucket_boundaries_are_exact() {
    let Some(rt) = runtime() else { return };
    // exactly at the smallest bucket
    let b = rt.pick_bucket(4096, 2048).unwrap();
    assert_eq!(b.elems, 4096);
    // one element over -> next bucket
    let b = rt.pick_bucket(4097, 10).unwrap();
    assert_eq!(b.elems, 8192);
    // hood-bound (elems fit, hoods don't)
    let b = rt.pick_bucket(100, 4000).unwrap();
    assert_eq!(b.elems, 8192);
}

#[test]
fn full_coordinator_run_with_xla_engine() {
    if runtime().is_none() {
        return;
    }
    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: 64,
            height: 64,
            slices: 1,
            ..Default::default()
        },
        engine: EngineKind::Xla,
        mrf: MrfConfig { em_iters: 6, ..Default::default() },
        ..Default::default()
    };
    let ds = image::generate(&cfg.dataset);
    let coord = Coordinator::new(cfg).unwrap();
    let report = coord.run(&ds).unwrap();
    assert_eq!(report.engine, "xla");
    let acc = report.confusion.unwrap().accuracy();
    assert!(acc > 0.8, "accuracy {acc}");
}

#[test]
fn xla_vs_serial_label_agreement_via_coordinator() {
    if runtime().is_none() {
        return;
    }
    let mk = |engine| RunConfig {
        dataset: DatasetConfig {
            width: 64,
            height: 64,
            slices: 1,
            ..Default::default()
        },
        engine,
        mrf: MrfConfig {
            fixed_iters: true,
            em_iters: 3,
            map_iters: 3,
            ..Default::default()
        },
        ..Default::default()
    };
    let ds = image::generate(&mk(EngineKind::Serial).dataset);
    let a = Coordinator::new(mk(EngineKind::Serial))
        .unwrap()
        .run(&ds)
        .unwrap();
    let b = Coordinator::new(mk(EngineKind::Xla))
        .unwrap()
        .run(&ds)
        .unwrap();
    let n = a.output.voxels() as f64;
    let agree = a
        .output
        .data
        .iter()
        .zip(&b.output.data)
        .filter(|(x, y)| x == y)
        .count() as f64;
    assert!(agree / n > 0.99, "agreement {}", agree / n);
}

#[test]
fn runtime_reusable_across_coordinators() {
    let Some(rt) = runtime() else { return };
    for seed in [1u64, 2] {
        let cfg = RunConfig {
            dataset: DatasetConfig {
                width: 48,
                height: 48,
                slices: 1,
                seed,
                ..Default::default()
            },
            engine: EngineKind::Xla,
            mrf: MrfConfig { em_iters: 2, ..Default::default() },
            ..Default::default()
        };
        let ds = image::generate(&cfg.dataset);
        let coord = Coordinator::with_runtime(cfg, Arc::clone(&rt));
        let report = coord.run(&ds).unwrap();
        assert_eq!(report.slices.len(), 1);
    }
}
