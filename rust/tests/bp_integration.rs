//! BP subsystem integration tests (ISSUE 1 acceptance): serial-vs-DPP
//! sweep parity on small synthetic graphs, determinism under the
//! residual schedule, and the energy-quality property — BP final
//! energy within tolerance of `SerialEngine` on the same fixtures the
//! pipeline integration tests use.

use dpp_pmrf::bp::{self, serial::run_serial, BpConfig, BpEngine, BpGraph,
                   BpSchedule};
use dpp_pmrf::config::{DatasetConfig, DatasetKind, EngineKind, MrfConfig,
                       RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::image;
use dpp_pmrf::mrf::{self, Engine, MrfModel, Params};
use dpp_pmrf::overseg::oversegment;
use dpp_pmrf::pool::Pool;

mod common;

/// Every frontier policy the scheduler family exposes (ISSUE 10),
/// with fixed parameters so runs are reproducible.
const ALL_POLICIES: [BpSchedule; 5] = [
    BpSchedule::Synchronous,
    BpSchedule::Residual,
    BpSchedule::StaleResidual,
    BpSchedule::Bucketed { bins: 8 },
    BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
];

fn small_cfg(kind: DatasetKind, engine: EngineKind) -> RunConfig {
    RunConfig {
        dataset: DatasetConfig {
            kind,
            width: 64,
            height: 64,
            slices: 2,
            ..Default::default()
        },
        engine,
        threads: 3,
        ..Default::default()
    }
}

/// First-slice model of the standard integration fixture.
fn fixture_model(kind: DatasetKind) -> MrfModel {
    let cfg = small_cfg(kind, EngineKind::Serial);
    let ds = image::generate(&cfg.dataset);
    let seg = oversegment(&Backend::Serial, &ds.input.slice(0),
                          &cfg.overseg);
    mrf::build_model_serial(&seg)
}

#[test]
fn sweep_parity_serial_oracle_vs_dpp_backends() {
    let model = fixture_model(DatasetKind::Synthetic);
    let prm = Params { mu: [50.0, 190.0], sigma: [30.0, 30.0], beta: 0.5 };
    for schedule in ALL_POLICIES {
        let cfg = BpConfig { schedule, ..Default::default() };
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let (want_msg, want_labels, _) =
            run_serial(&model, &g, &prm, &cfg, false);
        for bk in [
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 128),
        ] {
            let (labels, _) = bp::solve(&bk, &model, &prm, &cfg);
            assert_eq!(labels, want_labels, "{schedule:?} labels {bk:?}");
            // and the raw message state agrees bitwise
            let unary = bp::sweep::unaries(&bk, &model, &prm);
            let mut st =
                bp::BpState::new(g.num_edges(), model.num_vertices());
            bp::sweep::run(
                &bk, &model, &g, &unary, &mut st, &cfg, false, 0,
            );
            assert_eq!(st.msg, want_msg, "{schedule:?} messages {bk:?}");
        }
    }
}

#[test]
fn residual_schedule_is_deterministic() {
    let model = fixture_model(DatasetKind::Experimental);
    let cfg = MrfConfig::default();
    let bp_cfg = BpConfig { schedule: BpSchedule::Residual,
                            ..Default::default() };
    let a = BpEngine::new(Backend::Serial, bp_cfg).run(&model, &cfg);
    let b = BpEngine::new(Backend::Serial, bp_cfg).run(&model, &cfg);
    assert_eq!(a, b, "same backend, same result");
    let c = BpEngine::new(
        Backend::threaded_with_grain(Pool::new(4), 64),
        bp_cfg,
    )
    .run(&model, &cfg);
    assert_eq!(a, c, "thread count does not change the result");
}

#[test]
fn bp_energy_within_tolerance_of_serial_engine_on_fixtures() {
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let model = fixture_model(kind);
        let cfg = MrfConfig::default();
        let map = mrf::serial::SerialEngine.run(&model, &cfg);
        for schedule in ALL_POLICIES {
            let bp_cfg = BpConfig { schedule, ..Default::default() };
            let bp_res =
                BpEngine::new(Backend::Serial, bp_cfg).run(&model, &cfg);
            let rel = (bp_res.energy - map.energy).abs()
                / map.energy.abs().max(1.0);
            assert!(rel < 0.05,
                    "{kind:?}/{schedule:?}: bp {} vs serial {} (rel {rel})",
                    bp_res.energy, map.energy);
        }
    }
}

#[test]
fn bp_engine_through_coordinator_on_synthetic() {
    // `--engine bp` end to end: full pipeline, ground-truth scoring.
    let cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Bp);
    let ds = image::generate(&cfg.dataset);
    let report = Coordinator::new(cfg).unwrap().run(&ds).unwrap();
    assert_eq!(report.engine, "bp");
    let acc = report.confusion.expect("synthetic has truth").accuracy();
    assert!(acc > 0.85, "bp accuracy {acc}");
    for s in &report.slices {
        assert!(s.map_iters >= 1, "sweeps recorded per slice");
    }
    // the per-slice iteration counts survive into the JSON report
    let j = report.to_json();
    assert!(j.get("map_iters").and_then(|v| v.as_f64()).unwrap() >= 1.0);
}

#[test]
fn bp_config_round_trips_through_json() {
    for schedule in ALL_POLICIES {
        let mut cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Bp);
        cfg.bp = BpConfig {
            damping: 0.25,
            max_sweeps: 17,
            tol: 1e-2,
            schedule,
            frontier: 0.75,
        };
        let back = RunConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg, "{schedule:?}");
    }
}

#[test]
fn every_policy_decodes_the_synchronous_labels_on_chains() {
    // Chains are trees, so max-product BP is exact: whatever subset
    // of messages a relaxed frontier defers, at convergence every
    // policy must decode the same labeling the synchronous flood
    // does. Decisive observations (common::chain_model) rule out
    // near-tie flips.
    let prm = common::fixed_params();
    for seed in [3, 17, 99] {
        let model = common::chain_model(40, seed);
        let base_cfg = BpConfig {
            max_sweeps: 400,
            tol: 1e-6,
            schedule: BpSchedule::Synchronous,
            ..Default::default()
        };
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let (want, sync_run) =
            bp::solve(&Backend::Serial, &model, &prm, &base_cfg);
        assert!(sync_run.converged, "seed {seed}: sync must converge");
        for schedule in ALL_POLICIES {
            let cfg = BpConfig { schedule, ..base_cfg };
            let (labels, run) =
                bp::solve(&Backend::Serial, &model, &prm, &cfg);
            assert!(run.converged,
                    "seed {seed}/{schedule:?}: converged in {} sweeps",
                    run.sweeps);
            assert_eq!(labels, want, "seed {seed}/{schedule:?}");
            // and the serial oracle agrees for the same policy
            let (_, oracle_labels, oracle_sweeps) =
                run_serial(&model, &g, &prm, &cfg, false);
            assert!(oracle_sweeps <= cfg.max_sweeps,
                    "seed {seed}/{schedule:?}");
            assert_eq!(oracle_labels, want,
                       "seed {seed}/{schedule:?} oracle");
        }
    }
}

#[test]
fn relaxed_policies_are_bitwise_stable_across_scheduler_lanes() {
    // Acceptance criterion (ISSUE 10): `--lanes` must not perturb any
    // frontier policy — lane sharding changes which thread runs a
    // slice, never what the slice computes.
    for schedule in ALL_POLICIES {
        let mut outputs = Vec::new();
        for lanes in [1usize, 2, 4] {
            let mut cfg =
                small_cfg(DatasetKind::Synthetic, EngineKind::Bp);
            cfg.bp.schedule = schedule;
            cfg.sched.lanes = lanes;
            let ds = image::generate(&cfg.dataset);
            let report = Coordinator::new(cfg).unwrap().run(&ds).unwrap();
            assert_eq!(
                report.bp_schedule(),
                Some(schedule.spec().as_str()),
                "{schedule:?} lanes {lanes}: report names the policy"
            );
            outputs.push(report.output.data);
        }
        assert_eq!(outputs[0], outputs[1], "{schedule:?}: 1 vs 2 lanes");
        assert_eq!(outputs[0], outputs[2], "{schedule:?}: 1 vs 4 lanes");
    }
}
