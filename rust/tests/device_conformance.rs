//! Device conformance suite (ISSUE 4 acceptance; DESIGN.md §9): the
//! contract any [`Device`] implementation — including any future real
//! GPU backend — must pass before it may sit behind the primitive API.
//!
//! For EVERY primitive, every registered device must produce
//! **bitwise-identical** results to [`SerialDevice`] across empty /
//! single-element / odd-length / large inputs and thread counts
//! {1, 2, 4} (plus an odd grain). Exact ops (integers, min/max) are
//! checked on all primitives; floating-point outputs are compared by
//! bit pattern wherever the contract demands bitwise equality — maps,
//! gathers, scatters, sorts, and every *segmented* reduction (a
//! [`SegmentPlan`] reduces each segment serially in cached stable
//! order, so floats must match exactly). The one sanctioned exemption
//! is the association order of global float `reduce`/`scan`, which is
//! chunk-ordered per device configuration — those are exercised here
//! with exact integer ops only.

mod common;

use std::path::Path;
use std::sync::Arc;

use dpp_pmrf::dpp::{self, Backend, Device, DeviceKind, IntoDevice,
                    OfflineAcceleratorDevice, Pipeline, PoolDevice,
                    SegmentPlan, SerialDevice, SharedSlice, Workspace};
use dpp_pmrf::dual;
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::Pcg32;

/// The device registry under test: the serial oracle's peers. Every
/// entry must match [`SerialDevice`] bitwise on the whole battery.
fn devices() -> Vec<(String, Arc<dyn Device>)> {
    let mut out: Vec<(String, Arc<dyn Device>)> = Vec::new();
    for threads in [1, 2, 4] {
        out.push((
            format!("pool-t{threads}-g64"),
            Arc::new(PoolDevice::new(threads, 64)),
        ));
    }
    // Odd grain: chunk boundaries land mid-everything.
    out.push(("pool-t4-g1021".into(), Arc::new(PoolDevice::new(4, 1021))));
    // The legacy enum bridged through IntoDevice must behave as the
    // pool device it wraps.
    out.push((
        "legacy-backend-t2-g64".into(),
        Backend::threaded_with_grain(Pool::new(2), 64).into_device(),
    ));
    // The accelerator seat without artifacts: host-serial execution.
    out.push((
        "accel-no-artifacts".into(),
        Arc::new(OfflineAcceleratorDevice::load(Path::new(
            "no/such/artifacts",
        ))),
    ));
    out
}

/// Input shapes the contract names: empty, single, odd-length, large.
const SIZES: [usize; 5] = [0, 1, 7, 1_000, 10_000];

fn rand_u32(n: usize, seed: u64, modulo: u32) -> Vec<u32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.next_u64() as u32) % modulo.max(1)).collect()
}

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.next_u64() % 10_000) as f32 * 0.37 - 1850.0)
        .collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn registry_names_and_caps_are_sane() {
    let serial = SerialDevice;
    assert_eq!(serial.name(), "serial");
    assert!(!serial.caps().threaded);
    for (tag, dev) in devices() {
        assert!(!dev.name().is_empty(), "{tag}");
        assert!(dev.threads() >= 1, "{tag}");
        if dev.caps().threaded {
            assert!(dev.pool().is_some(), "{tag}: threaded needs a pool");
        }
        // No registered device claims offload in the offline build.
        assert!(!dev.caps().offload, "{tag}");
    }
    assert_eq!(DeviceKind::all().len(), 4);
}

#[test]
fn map_family_matches_serial_bitwise() {
    for n in SIZES {
        let xs = rand_u32(n, 0xA0 + n as u64, u32::MAX);
        let fs = rand_f32(n, 0xB0 + n as u64);
        let want_map = dpp::map(&SerialDevice, &xs, |x| x.wrapping_mul(3));
        let want_mapf = dpp::map(&SerialDevice, &fs, |x| x * 1.5 + 0.25);
        let want_idx =
            dpp::map_indexed(&SerialDevice, n, |i| (i as u32) ^ 0x5a5a);
        let want_zip =
            dpp::zip_map(&SerialDevice, &xs, &fs, |a, b| *a as f32 + b);
        let want_iota = dpp::iota(&SerialDevice, n);
        let mut want_inplace = xs.clone();
        dpp::map_in_place(&SerialDevice, &mut want_inplace, |i, x| {
            x.wrapping_add(i as u32)
        });
        for (tag, dev) in devices() {
            let dev = &*dev;
            assert_eq!(
                dpp::map(dev, &xs, |x| x.wrapping_mul(3)),
                want_map,
                "{tag} map n={n}"
            );
            assert_eq!(
                bits(&dpp::map(dev, &fs, |x| x * 1.5 + 0.25)),
                bits(&want_mapf),
                "{tag} map(f32) n={n}"
            );
            assert_eq!(
                dpp::map_indexed(dev, n, |i| (i as u32) ^ 0x5a5a),
                want_idx,
                "{tag} map_indexed n={n}"
            );
            assert_eq!(
                bits(&dpp::zip_map(dev, &xs, &fs, |a, b| *a as f32 + b)),
                bits(&want_zip),
                "{tag} zip_map n={n}"
            );
            assert_eq!(dpp::iota(dev, n), want_iota, "{tag} iota n={n}");
            let mut got = xs.clone();
            dpp::map_in_place(dev, &mut got, |i, x| {
                x.wrapping_add(i as u32)
            });
            assert_eq!(got, want_inplace, "{tag} map_in_place n={n}");
        }
    }
}

#[test]
fn exact_reduce_and_scan_match_serial_bitwise() {
    for n in SIZES {
        let xs = rand_u32(n, 0xC0 + n as u64, 1 << 20);
        let xs64: Vec<u64> = xs.iter().map(|&x| x as u64).collect();
        let want_sum =
            dpp::reduce(&SerialDevice, &xs64, 0u64, |a, b| a + b);
        let want_min =
            dpp::reduce(&SerialDevice, &xs64, u64::MAX, |a, b| a.min(b));
        let (want_ex, want_total) =
            dpp::scan_exclusive(&SerialDevice, &xs, 0u32, |a, b| {
                a.wrapping_add(b)
            });
        let want_inc =
            dpp::scan_inclusive(&SerialDevice, &xs, 0u32, |a, b| {
                a.wrapping_add(b)
            });
        for (tag, dev) in devices() {
            let dev = &*dev;
            assert_eq!(
                dpp::reduce(dev, &xs64, 0u64, |a, b| a + b),
                want_sum,
                "{tag} reduce<add> n={n}"
            );
            assert_eq!(
                dpp::reduce(dev, &xs64, u64::MAX, |a, b| a.min(b)),
                want_min,
                "{tag} reduce<min> n={n}"
            );
            let (ex, total) = dpp::scan_exclusive(dev, &xs, 0u32, |a, b| {
                a.wrapping_add(b)
            });
            assert_eq!(ex, want_ex, "{tag} scan_exclusive n={n}");
            assert_eq!(total, want_total, "{tag} scan total n={n}");
            assert_eq!(
                dpp::scan_inclusive(dev, &xs, 0u32, |a, b| {
                    a.wrapping_add(b)
                }),
                want_inc,
                "{tag} scan_inclusive n={n}"
            );
        }
    }
}

#[test]
fn gather_scatter_match_serial_bitwise() {
    for n in SIZES {
        let src = rand_f32(n, 0xD0 + n as u64);
        // A permutation gather/scatter plus a with-repeats gather.
        let perm: Vec<u32> = (0..n as u32).rev().collect();
        let repeats: Vec<u32> = if n == 0 {
            Vec::new()
        } else {
            rand_u32(2 * n + 1, 0xD7 + n as u64, n as u32)
        };
        let want_g = dpp::gather(&SerialDevice, &src, &perm);
        let want_r = dpp::gather(&SerialDevice, &src, &repeats);
        let mut want_s = vec![0.0f32; n];
        dpp::scatter(&SerialDevice, &src, &perm, &mut want_s);
        for (tag, dev) in devices() {
            let dev = &*dev;
            assert_eq!(
                bits(&dpp::gather(dev, &src, &perm)),
                bits(&want_g),
                "{tag} gather(perm) n={n}"
            );
            assert_eq!(
                bits(&dpp::gather(dev, &src, &repeats)),
                bits(&want_r),
                "{tag} gather(repeats) n={n}"
            );
            let mut out = vec![0.0f32; n];
            dpp::scatter(dev, &src, &perm, &mut out);
            assert_eq!(bits(&out), bits(&want_s), "{tag} scatter n={n}");
        }
    }
}

#[test]
fn compaction_family_matches_serial() {
    for n in SIZES {
        let xs = rand_u32(n, 0xE0 + n as u64, 97);
        let keep = |i: usize| xs[i] % 3 == 0;
        let want_copy = dpp::copy_if_indexed(&SerialDevice, &xs, keep);
        let want_sel = dpp::select_indices(&SerialDevice, n, keep);
        let want_uniq = dpp::unique(&SerialDevice, &xs);
        for (tag, dev) in devices() {
            let dev = &*dev;
            assert_eq!(
                dpp::copy_if_indexed(dev, &xs, keep),
                want_copy,
                "{tag} copy_if n={n}"
            );
            assert_eq!(
                dpp::select_indices(dev, n, keep),
                want_sel,
                "{tag} select_indices n={n}"
            );
            assert_eq!(
                dpp::unique(dev, &xs),
                want_uniq,
                "{tag} unique n={n}"
            );
        }
    }
}

#[test]
fn sort_by_key_matches_serial_at_every_key_width() {
    for n in SIZES {
        for key_bits in [4u32, 16, 40, 64] {
            let mut rng = Pcg32::seeded(0xF0 + n as u64 + key_bits as u64);
            let mask = if key_bits >= 64 {
                u64::MAX
            } else {
                (1u64 << key_bits) - 1
            };
            let keys: Vec<u64> =
                (0..n).map(|_| rng.next_u64() & mask).collect();
            let vals: Vec<u32> = (0..n as u32).collect();
            let (mut wk, mut wv) = (keys.clone(), vals.clone());
            dpp::sort_by_key(&SerialDevice, &mut wk, &mut wv);
            for (tag, dev) in devices() {
                let dev = &*dev;
                let (mut gk, mut gv) = (keys.clone(), vals.clone());
                dpp::sort_by_key(dev, &mut gk, &mut gv);
                assert_eq!(gk, wk, "{tag} keys n={n} bits={key_bits}");
                assert_eq!(gv, wv, "{tag} vals n={n} bits={key_bits}");
            }
        }
    }
}

#[test]
fn reduce_by_key_matches_serial_bitwise_floats() {
    for n in SIZES {
        // Grouped keys (the ReduceByKey contract) with float payloads:
        // each segment reduces serially, so floats must match bitwise.
        let mut keys = rand_u32(n, 0x1F0 + n as u64, 37);
        keys.sort_unstable();
        let vals = rand_f32(n, 0x1F7 + n as u64);
        let (wk, wv) = dpp::reduce_by_key(&SerialDevice, &keys, &vals,
                                          0.0f32, |a, b| a + b);
        for (tag, dev) in devices() {
            let dev = &*dev;
            let (gk, gv) =
                dpp::reduce_by_key(dev, &keys, &vals, 0.0f32, |a, b| a + b);
            assert_eq!(gk, wk, "{tag} rbk keys n={n}");
            assert_eq!(bits(&gv), bits(&wv), "{tag} rbk vals n={n}");
        }
    }
}

#[test]
fn segment_plans_identical_and_reduce_bitwise() {
    for n in SIZES {
        let keys64: Vec<u64> = rand_u32(n, 0x2F0 + n as u64, 53)
            .into_iter()
            .map(u64::from)
            .collect();
        let keys32: Vec<u32> =
            keys64.iter().map(|&k| k as u32).collect();
        let vals = rand_f32(n, 0x2F7 + n as u64);
        let want_plan = SegmentPlan::build(&SerialDevice, &keys64);
        let want_sums = want_plan.reduce_segments(&SerialDevice, &vals,
                                                  0.0f32, |a, b| a + b);
        for (tag, dev) in devices() {
            let dev = &*dev;
            // The plan itself — permutation, segment keys, offsets —
            // must be identical on every device...
            let plan = SegmentPlan::build(dev, &keys64);
            assert_eq!(plan, want_plan, "{tag} plan n={n}");
            assert_eq!(
                SegmentPlan::build_u32(dev, &keys32),
                want_plan,
                "{tag} plan(u32) n={n}"
            );
            // ...and every segmented float reduction bitwise so.
            let sums =
                plan.reduce_segments(dev, &vals, 0.0f32, |a, b| a + b);
            assert_eq!(bits(&sums), bits(&want_sums),
                       "{tag} seg-reduce n={n}");
        }
    }
    // CSR-offset plans (the empty-segment constructor) reduce the
    // same everywhere too.
    let plan = SegmentPlan::from_csr_offsets(&[0, 0, 2, 2, 5, 5]);
    let vals = [1.5f32, -2.25, 4.0, 0.5, 8.0];
    let want = plan.reduce_segments(&SerialDevice, &vals, 0.0f32,
                                    |a, b| a + b);
    for (tag, dev) in devices() {
        let got = plan.reduce_segments(&*dev, &vals, 0.0f32, |a, b| a + b);
        assert_eq!(bits(&got), bits(&want), "{tag} csr seg-reduce");
    }
}

#[test]
fn workspace_paths_match_legacy_allocating_paths_on_every_device() {
    // ISSUE 5 acceptance: the `_into`/`_ws` spellings are part of the
    // device contract — on every registered device they must equal
    // the legacy allocating paths bitwise (and `chunk_bounds_into`
    // must equal `chunk_bounds`, since every float association order
    // hangs off it).
    for n in SIZES {
        let xs = rand_u32(n, 0x4F0 + n as u64, 1 << 16);
        let fs = rand_f32(n, 0x4F7 + n as u64);
        let mut grouped = rand_u32(n, 0x4FA + n as u64, 29);
        grouped.sort_unstable();
        for (tag, dev) in devices() {
            let dev = &*dev;
            let ws = Workspace::new();

            let mut bounds = Vec::new();
            dev.chunk_bounds_into(n, &mut bounds);
            assert_eq!(bounds, dev.chunk_bounds(n), "{tag} bounds n={n}");

            let mut m = Vec::new();
            dpp::map_into(dev, &fs, |x| x * 2.0 - 0.5, &mut m);
            assert_eq!(bits(&m),
                       bits(&dpp::map(dev, &fs, |x| x * 2.0 - 0.5)),
                       "{tag} map_into n={n}");

            let idx: Vec<u32> = (0..n as u32).rev().collect();
            let mut g = Vec::new();
            dpp::gather_into(dev, &fs, &idx, &mut g);
            assert_eq!(bits(&g), bits(&dpp::gather(dev, &fs, &idx)),
                       "{tag} gather_into n={n}");

            let mut ex = Vec::new();
            let total = dpp::scan_exclusive_into(
                dev, &ws, &xs, 0u32, |a, b| a.wrapping_add(b), &mut ex);
            let (wex, wtotal) = dpp::scan_exclusive(
                dev, &xs, 0u32, |a, b| a.wrapping_add(b));
            assert_eq!((ex, total), (wex, wtotal),
                       "{tag} scan_into n={n}");

            let (mut rk, mut rv) = (Vec::new(), Vec::new());
            dpp::reduce_by_key_into(dev, &ws, &grouped, &fs, 0.0f32,
                                    |a, b| a + b, &mut rk, &mut rv);
            let (wk, wv) = dpp::reduce_by_key(dev, &grouped, &fs, 0.0f32,
                                              |a, b| a + b);
            assert_eq!(rk, wk, "{tag} rbk_into keys n={n}");
            assert_eq!(bits(&rv), bits(&wv), "{tag} rbk_into vals n={n}");

            let keys: Vec<u64> = xs.iter().map(|&k| k as u64).collect();
            let vals: Vec<u32> = (0..n as u32).collect();
            let (mut sk, mut sv) = (keys.clone(), vals.clone());
            dpp::sort_by_key_ws(dev, &ws, &mut sk, &mut sv);
            let (mut lk, mut lv) = (keys.clone(), vals);
            dpp::sort_by_key(dev, &mut lk, &mut lv);
            assert_eq!((sk, sv), (lk, lv), "{tag} sort_ws n={n}");

            let mut ko = keys.clone();
            dpp::sort_keys_ws(dev, &ws, &mut ko);
            let mut lo = keys;
            dpp::sort_keys(dev, &mut lo);
            assert_eq!(ko, lo, "{tag} sort_keys_ws n={n}");
        }
    }
}

#[test]
fn pipelines_match_serial_bitwise() {
    for n in SIZES {
        let xs = rand_f32(n, 0x3F0 + n as u64);
        let run_on = |dev: &dyn Device| -> (Vec<u32>, u64) {
            let mut doubled = vec![0.0f32; n];
            let mut flags = vec![0u8; n];
            let mut total = vec![0u64; 1];
            {
                let wd = SharedSlice::new(&mut doubled);
                let wf = SharedSlice::new(&mut flags);
                let wt = SharedSlice::new(&mut total);
                let xs_ref = &xs;
                Pipeline::new()
                    // Stage 1 (Map): arithmetic on the raw input.
                    .stage("Map", n, |s, e| {
                        for i in s..e {
                            unsafe { wd.write(i, xs_ref[i] * 2.0 + 1.0) };
                        }
                    })
                    // Stage 2 (Map): reads stage 1 through the barrier.
                    .stage("Map", n, |s, e| {
                        for i in s..e {
                            let v = unsafe { wd.read(i) };
                            unsafe { wf.write(i, u8::from(v > 0.0)) };
                        }
                    })
                    // Stage 3 (Reduce, serial tail): exact fold.
                    .serial_stage("Reduce", || {
                        let mut acc = 0u64;
                        for i in 0..n {
                            acc += u64::from(unsafe { wf.read(i) });
                        }
                        unsafe { wt.write(0, acc) };
                    })
                    .run(dev);
            }
            (bits(&doubled), total[0])
        };
        let (want_bits, want_total) = run_on(&SerialDevice);
        for (tag, dev) in devices() {
            let (got_bits, got_total) = run_on(&*dev);
            assert_eq!(got_bits, want_bits, "{tag} pipeline stage n={n}");
            assert_eq!(got_total, want_total, "{tag} pipeline total n={n}");
        }
    }
}

#[test]
fn bp_frontier_policies_match_serial_device_bitwise() {
    // ISSUE 10 acceptance: every frontier policy — including the
    // fold-free relaxed ones — is part of the device contract. For
    // each policy, every registered device must reproduce the
    // SerialDevice run exactly: message state by bit pattern, decoded
    // labels, and the run counters (sweeps / updated_total), because
    // relaxed commit rules are pure functions of (position, sweep)
    // and may not see chunking.
    use dpp_pmrf::bp::{self, BpConfig, BpGraph, BpSchedule, BpState};
    let prm = common::fixed_params();
    let policies = [
        BpSchedule::Synchronous,
        BpSchedule::Residual,
        BpSchedule::StaleResidual,
        BpSchedule::Bucketed { bins: 8 },
        BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
    ];
    let model = common::porous_model(23);
    for schedule in policies {
        let cfg = BpConfig { schedule, ..Default::default() };
        let run_on = |dev: &dyn Device| {
            let g = BpGraph::build(dev, &model, prm.beta);
            let unary = bp::sweep::unaries(dev, &model, &prm);
            let mut st =
                BpState::new(g.num_edges(), model.num_vertices());
            let run = bp::sweep::run(
                dev, &model, &g, &unary, &mut st, &cfg, false, 0,
            );
            let (labels, _) = bp::solve(dev, &model, &prm, &cfg);
            (bits(&st.msg), labels, run)
        };
        let (want_bits, want_labels, want_run) = run_on(&SerialDevice);
        for (tag, dev) in devices() {
            let (got_bits, got_labels, got_run) = run_on(&*dev);
            assert_eq!(got_bits, want_bits,
                       "{tag} {schedule:?}: message bits drifted");
            assert_eq!(got_labels, want_labels, "{tag} {schedule:?}");
            assert_eq!(got_run, want_run,
                       "{tag} {schedule:?}: run counters drifted");
        }
    }
}

#[test]
fn dual_ascent_matches_its_serial_oracle_bitwise() {
    // ISSUE 7 acceptance: the dual engine's DPP path — graph build,
    // belief refresh, colored edge updates, bound fold, decode — must
    // match the plain-loop serial oracle ([`dual::serial::solve`])
    // bitwise on every registered device, labels, bound, AND history.
    let prm = common::fixed_params();
    let cfg = dual::DualConfig::default();
    for seed in [17u64, 18] {
        let model = common::porous_model(seed);
        let want = dual::serial::solve(&model, &prm, &cfg);
        let on_serial = dual::solve(&SerialDevice, &model, &prm, &cfg);
        assert_eq!(on_serial, want, "seed {seed}: SerialDevice");
        for (tag, dev) in devices() {
            let got = dual::solve(&*dev, &model, &prm, &cfg);
            assert_eq!(got.bound.to_bits(), want.bound.to_bits(),
                       "{tag} seed {seed}: bound drifted");
            assert_eq!(got, want, "{tag} seed {seed}");
        }
    }
}
