//! Scheduler determinism suite (ISSUE 3 acceptance): sharding the
//! slice stack across lanes must change *throughput only*. For every
//! lane count the output volume and every per-slice final energy must
//! be bitwise identical to the serial `Coordinator::run` path, on both
//! the DPP-MAP and BP engines; and the init→optimize hand-off queue
//! must never hold more than the configured in-flight cap.

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::{Coordinator, RunReport};
use dpp_pmrf::image::{self, Dataset};

fn cfg(engine: EngineKind, lanes: usize, slices: usize) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: DatasetConfig {
            width: 48,
            height: 48,
            slices,
            ..Default::default()
        },
        engine,
        // threads > 1 so the determinism claim covers the threaded
        // backend (chunk bounds depend on the thread count — every
        // lane must reproduce them exactly).
        threads: 2,
        ..Default::default()
    };
    cfg.sched.lanes = lanes;
    cfg
}

fn run(c: RunConfig, ds: &Dataset) -> RunReport {
    Coordinator::new(c).unwrap().run(ds).unwrap()
}

/// Bitwise comparison of everything the scheduler must not perturb.
fn assert_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.output.data, b.output.data, "{tag}: output volume");
    assert_eq!(a.slices.len(), b.slices.len(), "{tag}: slice count");
    for (x, y) in a.slices.iter().zip(&b.slices) {
        assert_eq!(x.z, y.z, "{tag}: slice order");
        assert_eq!(
            x.final_energy.to_bits(),
            y.final_energy.to_bits(),
            "{tag}: slice {} energy {} vs {}",
            x.z, x.final_energy, y.final_energy
        );
        assert_eq!(x.em_iters, y.em_iters, "{tag}: slice {}", x.z);
        assert_eq!(x.map_iters, y.map_iters, "{tag}: slice {}", x.z);
        assert_eq!(x.regions, y.regions, "{tag}: slice {}", x.z);
        assert_eq!(x.hoods, y.hoods, "{tag}: slice {}", x.z);
    }
    assert_eq!(a.porosity.to_bits(), b.porosity.to_bits(), "{tag}");
}

#[test]
fn lanes_1_matches_manual_serial_loop() {
    // The scheduler's serial path must reproduce the literal pre-PR
    // loop: build model, run engine, paint — in ascending slice order
    // on the coordinator's own backend.
    for engine in [EngineKind::Dpp, EngineKind::Bp] {
        let c = cfg(engine, 1, 3);
        let ds = image::generate(&c.dataset);
        let coord = Coordinator::new(c.clone()).unwrap();
        let report = coord.run(&ds).unwrap();

        let eng = coord.engine();
        let mut manual =
            dpp_pmrf::image::Volume::new(48, 48, c.dataset.slices);
        for z in 0..c.dataset.slices {
            let (seg, model) = coord.build_slice_model(&ds.input, z);
            let res = eng.run(&model, &c.mrf);
            assert_eq!(
                res.energy.to_bits(),
                report.slices[z].final_energy.to_bits(),
                "{engine:?} slice {z}"
            );
            let bright = u8::from(res.params.mu[1] > res.params.mu[0]);
            let px = manual.slice_mut(z);
            for (p, &region) in seg.labels.iter().enumerate() {
                px[p] = if res.labels[region as usize] == bright {
                    255
                } else {
                    0
                };
            }
        }
        assert_eq!(manual.data, report.output.data, "{engine:?}");
    }
}

#[test]
fn sharded_lanes_bitwise_match_serial_dpp() {
    let ds = image::generate(&cfg(EngineKind::Dpp, 1, 6).dataset);
    let serial = run(cfg(EngineKind::Dpp, 1, 6), &ds);
    assert_eq!(serial.sched.lanes, 1);
    for lanes in [2, 4] {
        let sharded = run(cfg(EngineKind::Dpp, lanes, 6), &ds);
        assert_eq!(sharded.sched.lanes, lanes);
        assert_identical(&sharded, &serial, &format!("dpp lanes={lanes}"));
    }
}

#[test]
fn sharded_lanes_bitwise_match_serial_bp() {
    let ds = image::generate(&cfg(EngineKind::Bp, 1, 6).dataset);
    let serial = run(cfg(EngineKind::Bp, 1, 6), &ds);
    for lanes in [2, 4] {
        let sharded = run(cfg(EngineKind::Bp, lanes, 6), &ds);
        assert_identical(&sharded, &serial, &format!("bp lanes={lanes}"));
    }
}

#[test]
fn single_threaded_lanes_also_match() {
    // threads = 1 switches every worker to Backend::Serial — the
    // lane-parallel throughput configuration must hold the same
    // bitwise contract.
    let mut base = cfg(EngineKind::Dpp, 1, 5);
    base.threads = 1;
    let ds = image::generate(&base.dataset);
    let serial = run(base.clone(), &ds);
    let mut sharded_cfg = base;
    sharded_cfg.sched.lanes = 4;
    let sharded = run(sharded_cfg, &ds);
    assert_identical(&sharded, &serial, "dpp threads=1 lanes=4");
}

#[test]
fn inflight_cap_is_never_exceeded() {
    // Property sweep over caps and lane counts: the queue's observed
    // high-water mark must respect the configured cap, and at least
    // one slice must have flowed through the queue.
    let ds = image::generate(&cfg(EngineKind::Dpp, 1, 8).dataset);
    for cap in [1, 2, 3] {
        for lanes in [2, 4] {
            let mut c = cfg(EngineKind::Dpp, lanes, 8);
            c.sched.inflight = cap;
            let report = run(c, &ds);
            assert!(
                report.sched.peak_inflight <= cap,
                "cap {cap} lanes {lanes}: peak {}",
                report.sched.peak_inflight
            );
            assert!(report.sched.peak_inflight >= 1,
                    "cap {cap} lanes {lanes}: queue never used");
            assert_eq!(report.sched.inflight_cap, cap);
            assert_eq!(report.slices.len(), 8);
        }
    }
}

#[test]
fn throughput_metrics_are_consistent() {
    let mut c = cfg(EngineKind::Dpp, 2, 4);
    c.threads = 1;
    let ds = image::generate(&c.dataset);
    let report = run(c, &ds);
    assert!(report.total_secs > 0.0);
    let expect = report.slices.len() as f64 / report.total_secs;
    assert!((report.slices_per_sec() - expect).abs() < 1e-12);
    let occ = report.lane_occupancy();
    assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    assert_eq!(report.sched.lane_busy_secs.len(), 2);
    assert_eq!(report.sched.init_busy_secs.len(), 2);
}
