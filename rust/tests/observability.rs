//! Observability integration contract (ISSUE 8): the convergence
//! flight recorder journals monotone dual bounds into the run report,
//! serving SLOs mark and count violating jobs, the Prometheus
//! exposition parses line by line, and — the other half of the
//! contract — arming none of it leaves run output bitwise identical.
//!
//! Every test serializes on `obs_test_lock`: the recorder is
//! process-global, and even the SLO tests run engines whose iteration
//! hooks would journal into a concurrently-armed ring.

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image;
use dpp_pmrf::json::Value;
use dpp_pmrf::obs::{self, ConvPoint, SloConfig};
use dpp_pmrf::sched::{Service, ServiceOptions};

fn dual_cfg(slices: usize) -> RunConfig {
    RunConfig {
        dataset: DatasetConfig {
            width: 48,
            height: 48,
            slices,
            ..Default::default()
        },
        engine: EngineKind::Dual,
        threads: 1,
        ..Default::default()
    }
}

fn run(cfg: &RunConfig) -> dpp_pmrf::coordinator::RunReport {
    let ds = image::generate(&cfg.dataset);
    Coordinator::new(cfg.clone()).unwrap().run(&ds).unwrap()
}

// ---- Prometheus text-format line validator -------------------------

fn is_name_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_' || c == ':'
}

fn is_name_char(c: char) -> bool {
    is_name_start(c) || c.is_ascii_digit()
}

/// Split a leading metric/label name off `s`; `None` when `s` does not
/// start with a valid name.
fn split_name(s: &str) -> Option<(&str, &str)> {
    let mut end = 0;
    for (i, c) in s.char_indices() {
        if i == 0 && !is_name_start(c) {
            return None;
        }
        if i > 0 && !is_name_char(c) {
            break;
        }
        end = i + c.len_utf8();
    }
    if end == 0 {
        None
    } else {
        Some((&s[..end], &s[end..]))
    }
}

/// Validate one non-comment exposition line: `name[{labels}] value`.
/// `families` holds every name declared by a preceding `# TYPE` line;
/// histogram series may append `_bucket`/`_sum`/`_count`.
fn check_sample(
    line: &str,
    families: &std::collections::HashSet<String>,
) -> Result<(), String> {
    let (name, mut rest) =
        split_name(line).ok_or_else(|| format!("bad name: {line}"))?;
    let declared = families.contains(name)
        || ["_bucket", "_sum", "_count"].iter().any(|suf| {
            name.strip_suffix(suf)
                .is_some_and(|base| families.contains(base))
        });
    if !declared {
        return Err(format!("sample `{name}` has no preceding # TYPE"));
    }
    if let Some(mut r) = rest.strip_prefix('{') {
        loop {
            let (_label, r2) = split_name(r)
                .ok_or_else(|| format!("bad label name in: {line}"))?;
            let r2 = r2
                .strip_prefix("=\"")
                .ok_or_else(|| format!("label missing =\" in: {line}"))?;
            // Scan to the closing quote, honoring backslash escapes.
            let mut close = None;
            let mut it = r2.char_indices();
            while let Some((i, c)) = it.next() {
                match c {
                    '\\' => {
                        it.next();
                    }
                    '"' => {
                        close = Some(i);
                        break;
                    }
                    _ => {}
                }
            }
            let close = close
                .ok_or_else(|| format!("unterminated label in: {line}"))?;
            let after = &r2[close + 1..];
            if let Some(a) = after.strip_prefix(',') {
                r = a;
            } else if let Some(a) = after.strip_prefix('}') {
                rest = a;
                break;
            } else {
                return Err(format!("expected , or }} in: {line}"));
            }
        }
    }
    let value = rest
        .strip_prefix(' ')
        .ok_or_else(|| format!("missing space before value: {line}"))?;
    value
        .parse::<f64>()
        .map_err(|_| format!("unparseable value `{value}` in: {line}"))?;
    Ok(())
}

/// Full-page validator: every line is a well-formed `# HELP`, `# TYPE`,
/// or sample line, and every sample belongs to a declared family.
/// Returns the number of sample lines.
fn validate_exposition(text: &str) -> usize {
    let mut families = std::collections::HashSet::new();
    let mut samples = 0;
    for line in text.lines() {
        assert!(!line.is_empty(), "blank line in exposition");
        if let Some(c) = line.strip_prefix("# ") {
            if let Some(h) = c.strip_prefix("HELP ") {
                let (_, rest) = split_name(h).expect("HELP name");
                assert!(rest.starts_with(' '), "HELP without text: {line}");
            } else if let Some(t) = c.strip_prefix("TYPE ") {
                let (name, rest) = split_name(t).expect("TYPE name");
                let kind = rest.trim_start();
                assert!(
                    ["counter", "gauge", "histogram"].contains(&kind),
                    "unknown TYPE `{kind}`: {line}"
                );
                families.insert(name.to_string());
            } else {
                panic!("unknown comment form: {line}");
            }
        } else {
            check_sample(line, &families).unwrap();
            samples += 1;
        }
    }
    samples
}

// ---- tests ---------------------------------------------------------

#[test]
fn metrics_text_round_trips_the_line_format_validator() {
    let _g = obs::obs_test_lock();
    let cfg = dual_cfg(1);
    let ds = image::generate(&cfg.dataset);
    let service = Service::new(1, 1);
    let reports = service
        .run_batch(vec![dpp_pmrf::sched::Job { dataset: ds, cfg }]);
    assert!(reports[0].is_ok());
    let text = service.metrics_text();
    let samples = validate_exposition(&text);
    assert!(samples > 0, "exposition has sample lines");
    // Histogram translation: cumulative buckets end at +Inf carrying
    // the series count (DESIGN.md §13).
    assert!(
        text.contains("dpp_job_exec_seconds_bucket{le=\"+Inf\"} 1\n"),
        "{text}"
    );
    assert!(text.contains("dpp_job_exec_seconds_count 1\n"));
}

#[test]
fn forced_gap_slo_marks_the_job_and_shows_in_health() {
    let _g = obs::obs_test_lock();
    // max_gap = 0 is unsatisfiable for the dual engine: its certified
    // gap includes the (strictly positive) scorer slack, so the SLO
    // must trip deterministically.
    let opts = ServiceOptions {
        slo: SloConfig { max_gap: Some(0.0), ..Default::default() },
        ..Default::default()
    };
    let service = Service::with_options(1, 1, opts);
    let cfg = dual_cfg(1);
    let ds = image::generate(&cfg.dataset);
    let (res, stats) = service
        .submit(dpp_pmrf::sched::Job { dataset: ds, cfg })
        .wait_stats();
    let report = res.unwrap();
    assert!(report.optimality_gap().unwrap() > 0.0);
    assert!(stats.slo.gap, "0-gap SLO must flag a certified dual run");
    assert!(!stats.slo.job_latency, "no latency threshold configured");
    let h = service.health();
    assert_eq!(h.slo_gap_violations, 1);
    assert_eq!(h.slo_violations(), 1);
    // And the violation reaches the exposition.
    assert!(service
        .metrics_text()
        .contains("dpp_slo_violations_total{slo=\"gap\"} 1\n"));
}

#[test]
fn armed_dual_run_journals_monotone_bounds_into_the_report() {
    let _g = obs::obs_test_lock();
    obs::arm(obs::DEFAULT_CAPACITY);
    let report = run(&dual_cfg(1));
    obs::disarm();
    let log = report
        .convergence
        .as_ref()
        .expect("armed run embeds its journal");
    assert!(!log.samples.is_empty());
    assert_eq!(log.dropped, 0, "default capacity holds a small run");
    // Every sample from a dual run is a dual point, and within one EM
    // iteration the journaled lower bound is the running best of the
    // ascent — non-decreasing by construction, with gap >= 0 and
    // bound <= primal throughout.
    let mut prev: Option<(u32, f64)> = None;
    for s in &log.samples {
        let ConvPoint::Dual { lower_bound, primal, gap } = s.point
        else {
            panic!("non-dual sample {:?}", s.point);
        };
        assert!(lower_bound.is_finite());
        assert!(gap >= 0.0, "gap {gap}");
        assert!(lower_bound <= primal + 1e-9 * primal.abs().max(1.0));
        if let Some((em, lb)) = prev {
            if em == s.em {
                assert!(
                    lower_bound >= lb,
                    "bound regressed within em {em}: {lb} -> \
                     {lower_bound}"
                );
            }
        }
        prev = Some((s.em, lower_bound));
    }
    // Report section: <= 256 points with the exact first and last
    // samples retained.
    let section = report.to_json();
    let conv = section.get("convergence").expect("convergence key");
    assert_eq!(
        conv.get("samples").and_then(Value::as_usize),
        Some(log.samples.len())
    );
    let points = conv.get("points").and_then(Value::as_array).unwrap();
    assert!(points.len() <= 256, "{} points", points.len());
    let first = &log.samples[0];
    let last = &log.samples[log.samples.len() - 1];
    assert_eq!(
        points[0].get("t_nanos").and_then(Value::as_usize),
        Some(first.t_nanos as usize)
    );
    assert_eq!(
        points[points.len() - 1]
            .get("t_nanos")
            .and_then(Value::as_usize),
        Some(last.t_nanos as usize)
    );
}

#[test]
fn armed_run_is_bitwise_identical_to_a_disarmed_run() {
    let _g = obs::obs_test_lock();
    let cfg = dual_cfg(2);
    let off = run(&cfg);
    assert!(off.convergence.is_none(), "disarmed run embeds nothing");
    obs::arm(obs::DEFAULT_CAPACITY);
    let on = run(&cfg);
    obs::disarm();
    assert!(on.convergence.is_some());
    // The recorder only reads engine state — labels, energies, and
    // certificates must match bit for bit.
    assert_eq!(off.output.data, on.output.data);
    assert_eq!(off.porosity, on.porosity);
    assert_eq!(off.lower_bound(), on.lower_bound());
    assert_eq!(off.optimality_gap(), on.optimality_gap());
}
