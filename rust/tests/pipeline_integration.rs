//! Cross-module integration tests: the full pipeline composed end to
//! end, engine agreement at the coordinator level, config round trips,
//! and dataset demographics invariants (paper §4.3.3).

use dpp_pmrf::config::{DatasetConfig, DatasetKind, EngineKind, MrfConfig,
                       RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::image;
use dpp_pmrf::eval::Confusion;
use dpp_pmrf::mrf::{self, Engine};
use dpp_pmrf::overseg::oversegment;
use dpp_pmrf::pool::Pool;

fn small_cfg(kind: DatasetKind, engine: EngineKind) -> RunConfig {
    RunConfig {
        dataset: DatasetConfig {
            kind,
            width: 64,
            height: 64,
            slices: 2,
            ..Default::default()
        },
        engine,
        threads: 3,
        ..Default::default()
    }
}

#[test]
fn full_pipeline_synthetic_all_engines_agree() {
    let base = small_cfg(DatasetKind::Synthetic, EngineKind::Serial);
    let ds = image::generate(&base.dataset);
    let mut outputs = Vec::new();
    for engine in [
        EngineKind::Serial,
        EngineKind::Reference,
        EngineKind::Dpp,
        EngineKind::Xla,
    ] {
        let coord =
            Coordinator::new(small_cfg(DatasetKind::Synthetic, engine))
                .unwrap();
        outputs.push((engine, coord.run(&ds).unwrap().output));
    }
    let (_, ref baseline) = outputs[0];
    let n = baseline.voxels() as f64;
    for (engine, o) in &outputs[1..] {
        let agree = o
            .data
            .iter()
            .zip(&baseline.data)
            .filter(|(a, b)| a == b)
            .count() as f64;
        assert!(agree / n > 0.99, "{engine:?} agreement {}", agree / n);
    }
}

#[test]
fn model_builders_agree_on_both_datasets() {
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let cfg = small_cfg(kind, EngineKind::Serial);
        let ds = image::generate(&cfg.dataset);
        let seg = oversegment(&Backend::Serial, &ds.input.slice(0),
                              &cfg.overseg);
        let serial = mrf::build_model_serial(&seg);
        let dpp = mrf::build_model(
            &Backend::threaded_with_grain(Pool::new(4), 128),
            &seg,
        );
        assert_eq!(serial.graph, dpp.graph, "{kind:?} graph");
        assert_eq!(serial.hoods, dpp.hoods, "{kind:?} hoods");
        assert_eq!(serial.y, dpp.y, "{kind:?} observations");
    }
}

#[test]
fn experimental_graph_denser_and_more_irregular_than_synthetic() {
    // The paper's §4.3.3 demographics claim, as a structural test.
    let mut stats = Vec::new();
    for kind in [DatasetKind::Synthetic, DatasetKind::Experimental] {
        let cfg = small_cfg(kind, EngineKind::Serial);
        let ds = image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg).unwrap();
        let (_, model) = coord.build_slice_model(&ds.input, 0);
        let hist = model.hoods.size_histogram(4);
        stats.push((
            model.hoods.num_hoods(),
            hist.mean(),
            model.graph.num_edges() as f64
                / model.graph.num_vertices() as f64,
        ));
    }
    let (syn, exp) = (stats[0], stats[1]);
    assert!(exp.1 > syn.1,
            "experimental hoods more complex: {} vs {}", exp.1, syn.1);
    assert!(exp.2 > syn.2,
            "experimental graph denser: {} vs {}", exp.2, syn.2);
}

#[test]
fn config_file_round_trip_drives_coordinator() {
    let dir = std::env::temp_dir().join("dpp_pmrf_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    let mut cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Reference);
    cfg.mrf = MrfConfig { em_iters: 2, map_iters: 2, ..Default::default() };
    cfg.save_json(&path).unwrap();
    let loaded = RunConfig::from_json_file(&path).unwrap();
    assert_eq!(loaded, cfg);
    let ds = image::generate(&loaded.dataset);
    let report = Coordinator::new(loaded).unwrap().run(&ds).unwrap();
    assert_eq!(report.engine, "reference");
}

#[test]
fn fixed_iters_engine_equivalence_through_coordinator() {
    // With fixed iteration counts, serial / reference / dpp-serial are
    // bit-identical through the full pipeline.
    let ds = image::generate(
        &small_cfg(DatasetKind::Experimental, EngineKind::Serial).dataset,
    );
    let mrf_cfg = MrfConfig {
        fixed_iters: true,
        em_iters: 3,
        map_iters: 3,
        ..Default::default()
    };
    let mut outs: Vec<Vec<u8>> = Vec::new();
    for engine in [EngineKind::Serial, EngineKind::Reference,
                   EngineKind::Dpp] {
        let mut cfg = small_cfg(DatasetKind::Experimental, engine);
        cfg.mrf = mrf_cfg.clone();
        cfg.threads = 1; // serial backend everywhere -> exact equality
        let coord = Coordinator::new(cfg).unwrap();
        outs.push(coord.run(&ds).unwrap().output.data);
    }
    assert_eq!(outs[0], outs[1], "reference == serial");
    assert_eq!(outs[0], outs[2], "dpp == serial");
}

#[test]
fn segmentation_beats_threshold_under_paper_corruption() {
    let cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Dpp);
    let ds = image::generate(&cfg.dataset);
    let truth = ds.ground_truth.clone().unwrap();
    let report = Coordinator::new(cfg).unwrap().run(&ds).unwrap();
    let mrf_acc = report.confusion.unwrap().accuracy();
    let thr = image::threshold::otsu(&ds.input);
    let thr_acc = Confusion::from_volumes(&thr, &truth).accuracy();
    assert!(mrf_acc > thr_acc, "mrf {mrf_acc} vs threshold {thr_acc}");
}

#[test]
fn volume_io_survives_pipeline() {
    let dir = std::env::temp_dir().join("dpp_pmrf_io_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Serial);
    let ds = image::generate(&cfg.dataset);
    let raw = dir.join("input.raw");
    ds.input.write_raw(&raw).unwrap();
    let loaded = image::Volume::read_raw(&raw).unwrap();
    assert_eq!(loaded, ds.input);

    // Segment the loaded copy; result must match segmenting the
    // original.
    let coord = Coordinator::new(cfg).unwrap();
    let ds2 = image::Dataset {
        input: loaded,
        ground_truth: ds.ground_truth.clone(),
        name: "loaded",
    };
    let a = coord.run(&ds).unwrap();
    let b = coord.run(&ds2).unwrap();
    assert_eq!(a.output, b.output);
}

#[test]
fn engine_trait_objects_are_interchangeable() {
    let cfg = small_cfg(DatasetKind::Synthetic, EngineKind::Serial);
    let ds = image::generate(&cfg.dataset);
    let coord = Coordinator::new(cfg.clone()).unwrap();
    let (_, model) = coord.build_slice_model(&ds.input, 0);
    let engines: Vec<Box<dyn Engine>> = vec![
        Box::new(mrf::serial::SerialEngine),
        Box::new(mrf::reference::ReferenceEngine::new(Pool::new(2))),
        Box::new(mrf::dpp::DppEngine::new(Backend::Serial)),
    ];
    let mrf_cfg = MrfConfig {
        fixed_iters: true,
        em_iters: 2,
        map_iters: 2,
        ..Default::default()
    };
    let results: Vec<_> =
        engines.iter().map(|e| e.run(&model, &mrf_cfg)).collect();
    for r in &results[1..] {
        assert_eq!(r.labels, results[0].labels);
    }
}
