//! PMP conformance gate (ISSUE 9 acceptance): the particle
//! max-product solver must be **bitwise-identical** between the
//! serial oracle ([`pmp::serial`]) and the DPP path on every
//! registered device, and across scheduler lanes {1, 2, 4}; and on a
//! particle set quantized to the discrete Potts label grid, its
//! converged energy must match the exhaustive oracle on tree
//! instances of ≤ 12 vertices (where synchronous min-sum is exact).

use std::path::Path;
use std::sync::Arc;

use dpp_pmrf::config::{DatasetConfig, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::dpp::{Backend, Device, IntoDevice,
                    OfflineAcceleratorDevice, PoolDevice, SerialDevice,
                    Workspace};
use dpp_pmrf::image;
use dpp_pmrf::mrf::continuous::{self, ContinuousModel};
use dpp_pmrf::pmp::{self, PmpConfig};
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::Pcg32;

/// The device registry under test — the same roster the primitive
/// conformance suite sweeps (`tests/device_conformance.rs`), so a
/// future backend lands in both gates by construction.
fn devices() -> Vec<(String, Arc<dyn Device>)> {
    let mut out: Vec<(String, Arc<dyn Device>)> = Vec::new();
    out.push(("serial".into(), Arc::new(SerialDevice)));
    for threads in [1, 2, 4] {
        out.push((
            format!("pool-t{threads}-g64"),
            Arc::new(PoolDevice::new(threads, 64)),
        ));
    }
    // Odd grain: chunk boundaries land mid-particle-tensor.
    out.push(("pool-t4-g1021".into(), Arc::new(PoolDevice::new(4, 1021))));
    out.push((
        "legacy-backend-t2-g64".into(),
        Backend::threaded_with_grain(Pool::new(2), 64).into_device(),
    ));
    out.push((
        "accel-no-artifacts".into(),
        Arc::new(OfflineAcceleratorDevice::load(Path::new(
            "no/such/artifacts",
        ))),
    ));
    out
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn solve_matches_serial_oracle_bitwise_on_every_device() {
    // Cold start and warm start, convergence-gated and fixed-round.
    for (seed, fixed) in [(21u64, false), (22, true)] {
        let (model, _) = continuous::synthetic_denoise(9, 6, 9.0, seed);
        let cfg = PmpConfig { iters: 5, ..Default::default() };
        let want = pmp::serial::solve(&model, &cfg, None, fixed);
        let warm_want = pmp::serial::solve(
            &model, &cfg, Some(&want.particles), fixed,
        );
        for (tag, dev) in devices() {
            let ws = Workspace::new();
            let got = pmp::solve(&*dev, &ws, &model, &cfg, None, fixed);
            assert_eq!(
                bits(&got.x_map),
                bits(&want.x_map),
                "{tag}: x_map bits (seed {seed}, fixed {fixed})"
            );
            assert_eq!(
                got.energy.to_bits(),
                want.energy.to_bits(),
                "{tag}: energy bits"
            );
            assert_eq!(
                bits(&got.particles),
                bits(&want.particles),
                "{tag}: surviving particle tensor bits"
            );
            assert_eq!(got, want, "{tag}: full run equality");
            // Warm start resumes bitwise too: the pruned tensor of
            // one run is a valid init for the next.
            let got_warm = pmp::solve(
                &*dev, &ws, &model, &cfg, Some(&got.particles), fixed,
            );
            assert_eq!(got_warm, warm_want, "{tag}: warm-start run");
        }
    }
}

#[test]
fn sched_lanes_produce_bitwise_identical_pmp_runs() {
    let cfg = RunConfig {
        dataset: DatasetConfig {
            width: 48,
            height: 48,
            slices: 4,
            ..Default::default()
        },
        engine: EngineKind::Pmp,
        threads: 2,
        ..Default::default()
    };
    let ds = image::generate(&cfg.dataset);
    let mut baseline = None;
    for lanes in [1usize, 2, 4] {
        let mut c = cfg.clone();
        c.sched.lanes = lanes;
        let report = Coordinator::new(c).unwrap().run(&ds).unwrap();
        assert_eq!(report.sched.lanes, lanes);
        assert_eq!(report.engine, "pmp");
        let Some(base) = &baseline else {
            baseline = Some(report);
            continue;
        };
        let base: &dpp_pmrf::coordinator::RunReport = base;
        assert_eq!(report.output.data, base.output.data,
                   "{lanes} lanes: output voxels");
        for (a, b) in report.slices.iter().zip(&base.slices) {
            assert_eq!(a.z, b.z);
            assert_eq!(a.final_energy.to_bits(), b.final_energy.to_bits(),
                       "{lanes} lanes: slice {} energy", a.z);
            assert_eq!(a.pmp_particles, b.pmp_particles);
            assert_eq!(
                a.pmp_acceptance.map(f64::to_bits),
                b.pmp_acceptance.map(f64::to_bits),
                "{lanes} lanes: slice {} acceptance", a.z
            );
            assert_eq!(
                a.pmp_max_marginal_energy.map(f64::to_bits),
                b.pmp_max_marginal_energy.map(f64::to_bits),
                "{lanes} lanes: slice {} max-marginal", a.z
            );
        }
    }
}

/// Potts-quantized model on a `w x h` grid: random observations, the
/// two fixed class levels as the only admissible labels.
const LEVELS: [f32; 2] = [60.0, 180.0];

fn quantized_model(w: usize, h: usize, seed: u64) -> ContinuousModel {
    let nv = w * h;
    let mut rng = Pcg32::seeded(seed);
    let y: Vec<f32> =
        (0..nv).map(|_| (rng.next_u32() % 256) as f32).collect();
    ContinuousModel::new(continuous::grid_graph(w, h), y, 25.0, 0.5, 4.0)
}

/// Exhaustive optimum over the quantized label grid `LEVELS^nv`
/// under the continuous energy — the pmp analog of
/// `common::brute_force_config` (tests/exact_oracle.rs).
fn brute_force_quantized(model: &ContinuousModel) -> f64 {
    let nv = model.num_vertices();
    assert!(nv <= 12, "exhaustive oracle is for tiny instances");
    let mut best = f64::INFINITY;
    for mask in 0u32..(1u32 << nv) {
        let x: Vec<f32> = (0..nv)
            .map(|v| LEVELS[((mask >> v) & 1) as usize])
            .collect();
        best = best.min(model.energy(&x));
    }
    best
}

/// Zero-walk config: proposals duplicate their base particle, so a
/// `LEVELS`-quantized init stays on the discrete grid for the whole
/// solve and decode searches exactly the oracle's space.
fn quantized_cfg() -> PmpConfig {
    PmpConfig {
        particles: LEVELS.len(),
        iters: 1,
        // Path instances below have diameter ≤ 11; synchronous
        // min-sum is exact after ≥ diameter sweeps on a tree.
        sweeps: 16,
        walk_sigma: 0.0,
        tol: 0.0,
        seed: 99,
    }
}

fn quantized_init(nv: usize) -> Vec<f32> {
    (0..nv).flat_map(|_| LEVELS).collect()
}

#[test]
fn quantized_particles_match_exhaustive_oracle_on_trees() {
    // Path graphs (h = 1) are trees: min-sum is exact, so the decoded
    // energy must equal the enumerated optimum.
    for (w, h) in [(2usize, 1usize), (6, 1), (12, 1)] {
        for seed in [11u64, 12, 13] {
            let model = quantized_model(w, h, seed);
            let best = brute_force_quantized(&model);
            let cfg = quantized_cfg();
            let init = quantized_init(w * h);
            let run =
                pmp::serial::solve(&model, &cfg, Some(&init), true);
            // Decoded labels live on the quantized grid, so the
            // energy can never beat the enumeration...
            assert!(
                run.energy >= best,
                "{w}x{h} seed {seed}: pmp {} beat the oracle {best}",
                run.energy
            );
            // ...and exact min-sum on a tree must attain it.
            assert!(
                (run.energy - best).abs()
                    <= 1e-9 * best.abs().max(1.0),
                "{w}x{h} seed {seed}: pmp {} != oracle {best}",
                run.energy
            );
            // The DPP path agrees bitwise on the same instance.
            let ws = Workspace::new();
            let dpp_run = pmp::solve(
                &PoolDevice::new(4, 64), &ws, &model, &cfg,
                Some(&init), true,
            );
            assert_eq!(dpp_run, run, "{w}x{h} seed {seed}: dpp path");
        }
    }
}

#[test]
fn quantized_particles_respect_the_oracle_on_loopy_grids() {
    // 3x3 has cycles: min-sum is a heuristic there, so only the
    // one-sided bound holds — decoded energy at or above the optimum.
    for seed in [11u64, 12, 13] {
        let model = quantized_model(3, 3, seed);
        let best = brute_force_quantized(&model);
        let run = pmp::serial::solve(
            &model, &quantized_cfg(), Some(&quantized_init(9)), true,
        );
        assert!(
            run.energy >= best,
            "3x3 seed {seed}: pmp {} beat the oracle {best}",
            run.energy
        );
    }
}
