//! Shared fixtures for the oracle/certificate integration tests:
//! tiny grid models small enough for exhaustive enumeration, a
//! production-shaped porous model built through the public pipeline,
//! and the brute-force optima the dual certificates are gated against.
#![allow(dead_code)] // each test binary uses a subset

use dpp_pmrf::config::OversegConfig;
use dpp_pmrf::dpp::Backend;
use dpp_pmrf::dual::{self, PairGraph};
use dpp_pmrf::graph::Csr;
use dpp_pmrf::image::{noise, synth};
use dpp_pmrf::mce;
use dpp_pmrf::mrf::{self, hoods, MrfModel, Params};
use dpp_pmrf::overseg::oversegment;
use dpp_pmrf::util::Pcg32;

/// 4-connected `w x h` grid in CSR form, vertices row-major. Neighbor
/// lists come out sorted (up < left < right < down in linear ids).
pub fn grid_csr(w: usize, h: usize) -> Csr {
    let nv = w * h;
    let mut offsets = vec![0u32; nv + 1];
    let mut neighbors = Vec::new();
    for y in 0..h {
        for x in 0..w {
            let v = y * w + x;
            let before = neighbors.len();
            if y > 0 {
                neighbors.push((v - w) as u32);
            }
            if x > 0 {
                neighbors.push((v - 1) as u32);
            }
            if x + 1 < w {
                neighbors.push((v + 1) as u32);
            }
            if y + 1 < h {
                neighbors.push((v + w) as u32);
            }
            offsets[v + 1] =
                offsets[v] + (neighbors.len() - before) as u32;
        }
    }
    Csr { offsets, neighbors }
}

/// Tiny random Potts model on a 4-connected grid: observations drawn
/// uniformly from 0..256, neighborhoods built through the real
/// MCE + hoods pipeline so engines see production structure.
pub fn grid_model(w: usize, h: usize, seed: u64) -> MrfModel {
    let graph = grid_csr(w, h);
    let cliques = mce::enumerate_serial(&graph);
    let hoods = hoods::build_serial(&graph, &cliques, w * h);
    let mut rng = Pcg32::seeded(seed);
    let y: Vec<f32> =
        (0..w * h).map(|_| (rng.next_u32() % 256) as f32).collect();
    MrfModel { graph, y, hoods }
}

/// Chain (path) model on `grid_csr(1, n)` — a tree, so max-product BP
/// is exact and every frontier policy must land on the same optimum.
/// Observations are drawn from widely separated clusters around the
/// two class means so the optimum is decisive: no near-ties that
/// could flip a label under f32 reassociation or schedule changes.
pub fn chain_model(n: usize, seed: u64) -> MrfModel {
    let graph = grid_csr(1, n);
    let cliques = mce::enumerate_serial(&graph);
    let hoods = hoods::build_serial(&graph, &cliques, n);
    let mut rng = Pcg32::seeded(seed);
    const LEVELS: [f32; 4] = [50.0, 70.0, 170.0, 190.0];
    let y: Vec<f32> = (0..n)
        .map(|_| LEVELS[(rng.next_u32() % 4) as usize])
        .collect();
    MrfModel { graph, y, hoods }
}

/// Fixed scoring parameters for cross-engine comparisons: engines
/// estimate their own (mu, sigma) per run, so quality gates score
/// every engine's final labels under one shared parameter set.
pub fn fixed_params() -> Params {
    Params { mu: [60.0, 180.0], sigma: [25.0, 25.0], beta: 0.5 }
}

/// Production-shaped model through the public pipeline (the crate's
/// unit tests use `bp::test_model`, which is `pub(crate)`-only).
pub fn porous_model(seed: u64) -> MrfModel {
    let v = synth::porous_ground_truth(48, 48, 1, 0.42, seed);
    let mut input = v.clone();
    noise::additive_gaussian(&mut input, 60.0, seed);
    let seg = oversegment(
        &Backend::Serial,
        &input.slice(0),
        &OversegConfig { scale: 64.0, min_region: 4 },
    );
    mrf::build_model_serial(&seg)
}

/// Exhaustive MAP under the shared hood energy
/// ([`mrf::config_energy`]): the exact optimum every engine's primal
/// energy is gated against. Enumerates all `2^nv` labelings, so the
/// model must stay at 12 vertices or fewer.
pub fn brute_force_config(model: &MrfModel, prm: &Params)
    -> (Vec<u8>, f64) {
    let nv = model.num_vertices();
    assert!(nv <= 12, "exhaustive oracle is for tiny grids (nv = {nv})");
    let mut best = f64::INFINITY;
    let mut best_labels = vec![0u8; nv];
    for mask in 0u32..(1u32 << nv) {
        let labels: Vec<u8> =
            (0..nv).map(|v| ((mask >> v) & 1) as u8).collect();
        let (_, e) = mrf::config_energy(model, &labels, prm);
        if e < best {
            best = e;
            best_labels = labels;
        }
    }
    (best_labels, best)
}

/// Exhaustive optimum of the dual engine's own pairwise objective
/// ([`dual::pair_energy`]) — the f64 quantity its bound certifies,
/// free of the per-instance f32 rounding `config_energy` carries
/// (the two differ by at most [`dual::scorer_slack`]).
pub fn brute_force_pair(g: &PairGraph, unary: &[f64]) -> f64 {
    let nv = g.num_vertices;
    assert!(nv <= 12, "exhaustive oracle is for tiny grids (nv = {nv})");
    let mut best = f64::INFINITY;
    for mask in 0u32..(1u32 << nv) {
        let labels: Vec<u8> =
            (0..nv).map(|v| ((mask >> v) & 1) as u8).collect();
        best = best.min(dual::pair_energy(g, unary, &labels));
    }
    best
}
