//! ISSUE 5 acceptance: the workspace layer is a pure perf refactor.
//!
//! * Every `_into`/`_ws` primitive produces **bitwise-identical**
//!   output to its legacy allocating spelling, on every device and
//!   thread count {1, 2, 4} — warm or cold pool.
//! * Engine-level determinism: a warm workspace never changes
//!   results (second run of one engine == first run, bitwise).
//! * The reuse-hit property: after the first EM iteration warms the
//!   pool, the engine's workspace hit rate is 100% — further
//!   iterations (and further same-shape runs) add **zero** misses,
//!   i.e. the steady state performs no allocations through the pool
//!   (`benches/alloc_churn.rs` asserts the same via a counting global
//!   allocator).

use std::sync::Arc;

use dpp_pmrf::config::{MrfConfig, OversegConfig};
use dpp_pmrf::dpp::{self, Device, PoolDevice, SerialDevice, Workspace};
use dpp_pmrf::mrf::dpp::{DppEngine, PairMode};
use dpp_pmrf::mrf::{self, Engine, MrfModel};
use dpp_pmrf::overseg::{oversegment, oversegment_ws};
use dpp_pmrf::util::Pcg32;

/// Devices the contract names: serial oracle + pools at 1/2/4 threads
/// (plus an odd grain so chunk boundaries land mid-everything).
fn devices() -> Vec<(String, Arc<dyn Device>)> {
    let mut out: Vec<(String, Arc<dyn Device>)> =
        vec![("serial".into(), Arc::new(SerialDevice))];
    for threads in [1, 2, 4] {
        out.push((
            format!("pool-t{threads}-g64"),
            Arc::new(PoolDevice::new(threads, 64)),
        ));
    }
    out.push(("pool-t4-g1021".into(), Arc::new(PoolDevice::new(4, 1021))));
    out
}

fn rand_u32(n: usize, seed: u64, modulo: u32) -> Vec<u32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.next_u64() as u32) % modulo.max(1)).collect()
}

fn rand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (rng.next_u64() % 10_000) as f32 * 0.37 - 1850.0)
        .collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn small_model(seed: u64) -> MrfModel {
    let v = dpp_pmrf::image::synth::porous_ground_truth(48, 48, 1, 0.42,
                                                        seed);
    let mut input = v.clone();
    dpp_pmrf::image::noise::additive_gaussian(&mut input, 60.0, seed);
    let seg = oversegment(
        &SerialDevice,
        &input.slice(0),
        &OversegConfig { scale: 64.0, min_region: 4 },
    );
    mrf::build_model_serial(&seg)
}

#[test]
fn workspace_primitives_bitwise_match_allocating_paths() {
    for n in [0usize, 1, 7, 1_000, 10_000] {
        let xs = rand_u32(n, 0x50 + n as u64, 1 << 20);
        let fs = rand_f32(n, 0x60 + n as u64);
        let idx: Vec<u32> = (0..n as u32).rev().collect();
        let mut grouped = rand_u32(n, 0x70 + n as u64, 37);
        grouped.sort_unstable();
        for (tag, dev) in devices() {
            let dev = &*dev;
            let ws = Workspace::new();
            // Two rounds: cold pool, then warm pool — identical both
            // times.
            for round in 0..2 {
                let t = format!("{tag} n={n} round={round}");

                let mut m = Vec::new();
                dpp::map_into(dev, &fs, |x| x * 1.5 + 0.25, &mut m);
                assert_eq!(bits(&m),
                           bits(&dpp::map(dev, &fs, |x| x * 1.5 + 0.25)),
                           "{t} map");

                let mut g = Vec::new();
                dpp::gather_into(dev, &fs, &idx, &mut g);
                assert_eq!(bits(&g), bits(&dpp::gather(dev, &fs, &idx)),
                           "{t} gather");

                let mut ex = Vec::new();
                let total = dpp::scan_exclusive_into(
                    dev, &ws, &xs, 0u32, |a, b| a.wrapping_add(b),
                    &mut ex);
                let (wex, wtotal) = dpp::scan_exclusive(
                    dev, &xs, 0u32, |a, b| a.wrapping_add(b));
                assert_eq!((ex, total), (wex, wtotal), "{t} scan");

                assert_eq!(
                    dpp::reduce_ws(dev, &ws, &xs, 0u32,
                                   |a, b| a.wrapping_add(b)),
                    dpp::reduce(dev, &xs, 0u32,
                                |a, b| a.wrapping_add(b)),
                    "{t} reduce"
                );

                let mut sel = Vec::new();
                dpp::select_indices_into(dev, &ws, n, |i| xs[i] % 3 == 0,
                                         &mut sel);
                assert_eq!(sel,
                           dpp::select_indices(dev, n, |i| xs[i] % 3 == 0),
                           "{t} select");

                let mut uniq = Vec::new();
                dpp::unique_into(dev, &ws, &grouped, &mut uniq);
                assert_eq!(uniq, dpp::unique(dev, &grouped), "{t} unique");

                let (mut rk, mut rv) = (Vec::new(), Vec::new());
                dpp::reduce_by_key_into(dev, &ws, &grouped, &fs, 0.0f32,
                                        |a, b| a + b, &mut rk, &mut rv);
                let (wk, wv) = dpp::reduce_by_key(dev, &grouped, &fs,
                                                  0.0f32, |a, b| a + b);
                assert_eq!(rk, wk, "{t} rbk keys");
                assert_eq!(bits(&rv), bits(&wv), "{t} rbk vals (float)");

                let keys64: Vec<u64> =
                    xs.iter().map(|&k| k as u64).collect();
                let (mut sk, mut sv) =
                    (keys64.clone(), idx.clone());
                dpp::sort_by_key_ws(dev, &ws, &mut sk, &mut sv);
                let (mut lk, mut lv) = (keys64.clone(), idx.clone());
                dpp::sort_by_key(dev, &mut lk, &mut lv);
                assert_eq!((sk, sv), (lk, lv), "{t} sort_by_key");

                let mut ko = keys64.clone();
                dpp::sort_keys_ws(dev, &ws, &mut ko);
                let mut lo = keys64;
                dpp::sort_keys(dev, &mut lo);
                assert_eq!(ko, lo, "{t} sort_keys");
            }
        }
    }
}

#[test]
fn overseg_ws_matches_plain_oversegment_across_slices() {
    let cfg = OversegConfig { scale: 64.0, min_region: 4 };
    for (tag, dev) in devices() {
        let ws = Workspace::new();
        for seed in 0..3u64 {
            let v = dpp_pmrf::image::synth::porous_ground_truth(
                40, 40, 1, 0.42, seed);
            let a = oversegment_ws(&*dev, &ws, &v.slice(0), &cfg);
            let b = oversegment(&*dev, &v.slice(0), &cfg);
            assert_eq!(a.labels, b.labels, "{tag} seed={seed}");
            assert_eq!(a.mean, b.mean, "{tag} seed={seed}");
            assert_eq!(a.size, b.size, "{tag} seed={seed}");
        }
        // Cross-slice reuse: re-segmenting a slice the pool has seen
        // adds no misses (same shapes -> pure hits).
        let v = dpp_pmrf::image::synth::porous_ground_truth(
            40, 40, 1, 0.42, 2);
        oversegment_ws(&*dev, &ws, &v.slice(0), &cfg);
        let warm = ws.stats().misses;
        oversegment_ws(&*dev, &ws, &v.slice(0), &cfg);
        assert_eq!(ws.stats().misses, warm, "{tag} overseg steady state");
    }
}

#[test]
fn engine_results_identical_with_warm_and_cold_workspace() {
    let model = small_model(77);
    let cfg = MrfConfig { fixed_iters: true, em_iters: 3, map_iters: 3,
                          ..Default::default() };
    for (tag, dev) in devices() {
        for mode in [PairMode::Paper, PairMode::Planned, PairMode::Fused] {
            let engine = DppEngine::with_mode(Arc::clone(&dev), mode);
            let cold = engine.run(&model, &cfg); // warms the pool
            let warm = engine.run(&model, &cfg); // runs entirely warm
            assert_eq!(cold, warm, "{tag} {mode:?}");
            // A fresh engine (fresh pool) agrees too.
            let fresh = DppEngine::with_mode(Arc::clone(&dev), mode)
                .run(&model, &cfg);
            assert_eq!(cold, fresh, "{tag} {mode:?} fresh engine");
        }
    }
}

#[test]
fn paper_mode_hit_rate_is_total_after_first_em_iteration() {
    let model = small_model(78);
    let engine = DppEngine::with_mode(SerialDevice, PairMode::Paper);
    // Warm-up: exactly one EM iteration of one MAP iteration.
    let warm_cfg = MrfConfig { fixed_iters: true, em_iters: 1,
                               map_iters: 1, ..Default::default() };
    engine.run(&model, &warm_cfg);
    let warm = engine.workspace_stats();
    assert!(warm.misses > 0, "paper mode draws from the pool");
    // Steady state: a 4x3-iteration run on the same model adds many
    // hits and ZERO misses — the 100%-reuse property.
    let long_cfg = MrfConfig { fixed_iters: true, em_iters: 4,
                               map_iters: 3, ..Default::default() };
    engine.run(&model, &long_cfg);
    let after = engine.workspace_stats();
    assert_eq!(after.misses, warm.misses,
               "no allocations after the first EM iteration");
    assert!(after.hits > warm.hits, "steady state served from the pool");
    assert_eq!(after.outstanding_bytes, 0,
               "every guard returned its storage");
    // The pool's footprint is bounded by what one iteration needs:
    // once converged, more iterating never moves the high-water mark.
    engine.run(&model, &long_cfg);
    let again = engine.workspace_stats();
    assert_eq!(again.misses, after.misses);
    assert_eq!(again.high_water_bytes, after.high_water_bytes,
               "iterating does not grow the pool");
}

#[test]
fn bp_engine_workspace_reuses_across_em_iterations() {
    let model = small_model(79);
    let engine = dpp_pmrf::bp::BpEngine::new(
        SerialDevice, dpp_pmrf::bp::BpConfig::default());
    let warm_cfg = MrfConfig { fixed_iters: true, em_iters: 1,
                               ..Default::default() };
    engine.run(&model, &warm_cfg);
    let warm = engine.workspace_stats();
    let long_cfg = MrfConfig { fixed_iters: true, em_iters: 4,
                               ..Default::default() };
    engine.run(&model, &long_cfg);
    let after = engine.workspace_stats();
    assert_eq!(after.misses, warm.misses,
               "bp steady state allocates nothing through the pool");
    assert!(after.hits > warm.hits);
}
