//! Telemetry acceptance suite (DESIGN.md §11):
//!
//! * scoped recorders attribute primitive time per run without the
//!   global registry (and capture the migrated workspace counters);
//! * profiling + tracing must not perturb results — telemetry-on runs
//!   are bitwise-identical to telemetry-off runs across devices and
//!   lane counts;
//! * a 2-lane sharded traced run exports Chrome trace-event JSON with
//!   per-lane span attribution (`opt-lane-N` thread names own the
//!   slice spans);
//! * `RunReport::to_json` carries `p50/p90/p99` job latency and the
//!   lane-occupancy timeline, profiling on or off.

use dpp_pmrf::config::{DatasetConfig, DeviceKind, EngineKind, RunConfig};
use dpp_pmrf::coordinator::{Coordinator, RunReport};
use dpp_pmrf::dpp::timing;
use dpp_pmrf::image::{self, Dataset};
use dpp_pmrf::json::Value;
use dpp_pmrf::telemetry::{self, Recorder, Tracer};

fn cfg(device: DeviceKind, lanes: usize, slices: usize) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: DatasetConfig {
            width: 48,
            height: 48,
            slices,
            ..Default::default()
        },
        engine: EngineKind::Dpp,
        device,
        threads: 2,
        ..Default::default()
    };
    cfg.sched.lanes = lanes;
    cfg
}

fn run(c: RunConfig, ds: &Dataset) -> RunReport {
    Coordinator::new(c).unwrap().run(ds).unwrap()
}

fn assert_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.output.data, b.output.data, "{tag}: output volume");
    assert_eq!(a.slices.len(), b.slices.len(), "{tag}: slice count");
    for (x, y) in a.slices.iter().zip(&b.slices) {
        assert_eq!(x.z, y.z, "{tag}: slice order");
        assert_eq!(
            x.final_energy.to_bits(),
            y.final_energy.to_bits(),
            "{tag}: slice {} energy",
            x.z
        );
        assert_eq!(x.em_iters, y.em_iters, "{tag}: slice {}", x.z);
        assert_eq!(x.map_iters, y.map_iters, "{tag}: slice {}", x.z);
    }
}

#[test]
fn scoped_recorder_attributes_a_full_run() {
    // The recorder itself is thread-scoped and needs no lock; the
    // trace lock only keeps this run's spans out of a tracer armed by
    // a concurrently running test in this binary.
    let _sg = telemetry::trace_test_lock();
    let c = cfg(DeviceKind::Auto, 1, 2);
    let ds = image::generate(&c.dataset);
    let coord = Coordinator::new(c).unwrap();
    let rec = Recorder::new();
    let report = {
        let _scope = rec.install();
        coord.run(&ds).unwrap()
    };
    assert_eq!(report.slices.len(), 2);
    let snap = rec.snapshot();
    // Primitive rows from both pipeline phases land in the scope.
    for name in ["Map", "ReduceByKey", "Gather", "SortByKey"] {
        assert!(
            snap.time_rows.get(name).is_some_and(|r| r.calls > 0),
            "missing primitive row {name}: {:?}",
            snap.time_rows.keys().collect::<Vec<_>>()
        );
    }
    // Stage-level rows from the scheduler.
    assert!(snap.time_rows.contains_key("Sched::init"));
    assert!(snap.time_rows.contains_key("Sched::opt"));
    // Workspace counters migrated off COUNTER_PREFIX timing rows:
    // first-class counters/gauges, never time rows.
    assert!(snap.counters.contains_key("Workspace::miss"));
    assert!(snap.gauges.contains_key("Workspace::high_water_bytes"));
    assert!(snap.gauges.contains_key("Workspace::resident_bytes"));
    assert!(
        !snap.time_rows.keys().any(|k| k.starts_with("Workspace::")),
        "counters must not appear as time rows"
    );
    assert!(snap.total_nanos() > 0);
}

#[test]
fn telemetry_on_is_bitwise_identical_to_off() {
    // The acceptance bar: enabling the global registry AND an armed
    // tracer must change nothing about the computation, on every
    // device x lane shape. Held for the whole test (off runs included)
    // so concurrent tests never observe our armed tracer and we never
    // pollute theirs.
    let _sg = telemetry::trace_test_lock();
    let _tg = timing::test_lock();
    for device in [DeviceKind::Serial, DeviceKind::Pool] {
        let base = cfg(device, 1, 4);
        let ds = image::generate(&base.dataset);
        for lanes in [1, 2, 4] {
            let mut c = base.clone();
            c.sched.lanes = lanes;
            let off = run(c.clone(), &ds);
            let on = {
                timing::set_enabled(true);
                let tracer = Tracer::start();
                let r = run(c, &ds);
                let trace = tracer.finish();
                timing::set_enabled(false);
                timing::reset();
                assert!(trace.num_events() > 0, "tracer captured spans");
                r
            };
            assert_identical(
                &on,
                &off,
                &format!("{} lanes={lanes}", device.name()),
            );
        }
    }
}

#[test]
fn traced_two_lane_run_attributes_spans_per_lane() {
    let _sg = telemetry::trace_test_lock();
    let c = cfg(DeviceKind::Auto, 2, 6);
    let ds = image::generate(&c.dataset);
    let tracer = Tracer::start();
    let report = run(c, &ds);
    let trace = tracer.finish();

    let j = trace.to_chrome_json();
    let events = j
        .get("traceEvents")
        .and_then(Value::as_array)
        .expect("traceEvents array");
    assert!(!events.is_empty());

    // Both optimize lanes registered a thread-name metadata record;
    // remember which tids they own.
    let mut lane_tids = Vec::new();
    let mut lane_names = Vec::new();
    for e in events {
        if e.get("ph").and_then(Value::as_str) != Some("M") {
            continue;
        }
        let name = e
            .get("args")
            .and_then(|a| a.get("name"))
            .and_then(Value::as_str)
            .unwrap()
            .to_string();
        if name.starts_with("opt-lane-") {
            lane_tids.push(e.get("tid").and_then(Value::as_f64).unwrap());
            lane_names.push(name);
        }
    }
    lane_names.sort();
    assert_eq!(lane_names, ["opt-lane-0", "opt-lane-1"]);

    // Every X event is well-formed, and per-lane attribution holds:
    // each of the 6 slice-optimize spans sits on a thread named
    // opt-lane-N.
    let mut opt_spans = 0usize;
    let mut zs = Vec::new();
    let mut cats = std::collections::BTreeSet::new();
    for e in events {
        if e.get("ph").and_then(Value::as_str) != Some("X") {
            continue;
        }
        assert!(e.get("ts").and_then(Value::as_f64).unwrap() >= 0.0);
        assert!(e.get("dur").and_then(Value::as_f64).unwrap() >= 0.0);
        let cat = e.get("cat").and_then(Value::as_str).unwrap();
        cats.insert(cat.to_string());
        let name = e.get("name").and_then(Value::as_str).unwrap();
        if cat == "slice" && name == "opt" {
            opt_spans += 1;
            let tid = e.get("tid").and_then(Value::as_f64).unwrap();
            assert!(
                lane_tids.contains(&tid),
                "slice/opt span on unnamed thread tid={tid}"
            );
            zs.push(
                e.get("args")
                    .and_then(|a| a.get("z"))
                    .and_then(Value::as_f64)
                    .unwrap() as usize,
            );
        }
    }
    assert_eq!(opt_spans, 6, "one optimize span per slice");
    zs.sort_unstable();
    assert_eq!(zs, [0, 1, 2, 3, 4, 5]);
    // The full hierarchy is present: run + slice roots, the EM/MAP
    // iteration levels, and leaf primitive/pipeline-stage spans.
    for want in ["run", "slice", "em", "map", "prim", "stage"] {
        assert!(cats.contains(want), "missing span category {want}: {cats:?}");
    }

    // Report side of the telemetry bar (profiling was OFF here): the
    // JSON still carries job latency percentiles and the lane timeline.
    let rj = report.to_json();
    for q in ["p50", "p90", "p99"] {
        let v = rj
            .get("job_latency")
            .and_then(|l| l.get(q))
            .and_then(Value::as_f64)
            .unwrap();
        assert!(v > 0.0, "job_latency.{q}");
    }
    match rj.get("lane_timeline") {
        Some(Value::Array(lanes)) => {
            assert_eq!(lanes.len(), 2, "one timeline per optimize lane");
            let spans: usize = lanes
                .iter()
                .map(|l| l.as_array().unwrap().len())
                .sum();
            assert_eq!(spans, 6, "every slice on some lane's timeline");
        }
        other => panic!("lane_timeline missing/not array: {other:?}"),
    }
    for s in &report.slices {
        assert!(s.lane < 2, "slice {} lane {}", s.z, s.lane);
        assert!(s.queue_wait_secs >= 0.0);
    }
}
