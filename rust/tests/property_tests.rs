//! Randomized property tests (proptest is unavailable offline, so this
//! is a small hand-rolled harness: seeded generators + a fixed trial
//! budget; failures print the seed for replay).
//!
//! Invariants covered: DPP primitives vs serial oracles, radix sort vs
//! std sort, scan/reduce algebra, MCE vs Bron–Kerbosch, neighborhood
//! structure, energy packing order, and convergence-window behaviour.

use dpp_pmrf::dpp::{self, Backend};
use dpp_pmrf::graph::Csr;
use dpp_pmrf::mce;
use dpp_pmrf::mrf::energy;
use dpp_pmrf::pool::Pool;
use dpp_pmrf::util::Pcg32;

const TRIALS: u64 = 24;

fn backends() -> Vec<Backend> {
    vec![
        Backend::Serial,
        Backend::threaded_with_grain(Pool::new(4), 64),
        Backend::threaded_with_grain(Pool::new(3), 1021), // odd grain
    ]
}

fn random_csr(rng: &mut Pcg32, max_n: u32) -> Csr {
    let n = 2 + rng.below(max_n) as usize;
    let m = rng.below((n * 3) as u32) as usize;
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for _ in 0..m {
        let a = rng.below(n as u32);
        let b = rng.below(n as u32);
        if a != b {
            adj[a as usize].push(b);
            adj[b as usize].push(a);
        }
    }
    let mut offsets = vec![0u32];
    let mut neighbors = Vec::new();
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
        neighbors.extend_from_slice(l);
        offsets.push(neighbors.len() as u32);
    }
    Csr { offsets, neighbors }
}

#[test]
fn prop_sort_by_key_matches_std() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed);
        let n = 1 + rng.below(5000) as usize;
        let bits = 1 + rng.below(64);
        let mask = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let keys0: Vec<u64> =
            (0..n).map(|_| rng.next_u64() & mask).collect();
        let mut expect: Vec<u64> = keys0.clone();
        expect.sort_unstable();
        for bk in backends() {
            let mut keys = keys0.clone();
            let mut vals: Vec<u32> = (0..n as u32).collect();
            dpp::sort_by_key(&bk, &mut keys, &mut vals);
            assert_eq!(keys, expect, "seed {seed} bits {bits}");
            // payload is a permutation that maps back to the input
            for (k, v) in keys.iter().zip(&vals) {
                assert_eq!(keys0[*v as usize], *k, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_scan_reduce_algebra() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xABCD);
        let n = rng.below(10_000) as usize;
        let xs: Vec<u64> =
            (0..n).map(|_| rng.below(1000) as u64).collect();
        let total: u64 = xs.iter().sum();
        for bk in backends() {
            // Reduce = sum
            assert_eq!(dpp::reduce(&bk, &xs, 0, |a, b| a + b), total,
                       "seed {seed}");
            // exclusive[i] + x[i] == inclusive[i]; last inclusive == total
            let (ex, t) = dpp::scan_exclusive(&bk, &xs, 0, |a, b| a + b);
            let inc = dpp::scan_inclusive(&bk, &xs, 0, |a, b| a + b);
            assert_eq!(t, total);
            for i in 0..n {
                assert_eq!(ex[i] + xs[i], inc[i], "seed {seed} @{i}");
            }
            if n > 0 {
                assert_eq!(inc[n - 1], total);
            }
        }
    }
}

#[test]
fn prop_gather_scatter_inverse() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0x5CA7);
        let n = 1 + rng.below(4000) as usize;
        let src: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        // random permutation
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        for bk in backends() {
            let g = dpp::gather(&bk, &src, &perm);
            let mut back = vec![0u32; n];
            dpp::scatter(&bk, &g, &perm, &mut back);
            assert_eq!(back, src, "seed {seed}");
        }
    }
}

#[test]
fn prop_unique_and_reduce_by_key_consistent() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0x0F0F);
        let n = 1 + rng.below(3000) as usize;
        let mut keys: Vec<u32> =
            (0..n).map(|_| rng.below(50)).collect();
        keys.sort_unstable();
        let vals: Vec<u64> = (0..n).map(|_| rng.below(100) as u64).collect();
        // serial oracle
        let mut want_keys = Vec::new();
        let mut want_sums: Vec<u64> = Vec::new();
        for i in 0..n {
            if i == 0 || keys[i] != keys[i - 1] {
                want_keys.push(keys[i]);
                want_sums.push(0);
            }
            *want_sums.last_mut().unwrap() += vals[i];
        }
        for bk in backends() {
            assert_eq!(dpp::unique(&bk, &keys), want_keys, "seed {seed}");
            let (k, v) =
                dpp::reduce_by_key(&bk, &keys, &vals, 0, |a, b| a + b);
            assert_eq!(k, want_keys, "seed {seed}");
            assert_eq!(v, want_sums, "seed {seed}");
        }
    }
}

#[test]
fn prop_segment_plan_bitwise_matches_sort_reduce() {
    // The SegmentPlan contract: reduce_segments on a plan built once
    // is BITWISE identical (f32, no tolerance) to the unfused
    // SortByKey + ReduceByKey pair on the same input, on every
    // backend and thread count.
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0x5E97);
        let n = rng.below(4000) as usize; // 0 => empty-input edge
        let nkeys = 1 + rng.below(60);
        let keys: Vec<u64> =
            (0..n).map(|_| rng.below(nkeys) as u64).collect();
        let vals: Vec<f32> =
            (0..n).map(|_| rng.f32() * 100.0 - 50.0).collect();
        for bk in backends() {
            // Unfused reference.
            let mut k = keys.clone();
            let mut idx: Vec<u32> = (0..n as u32).collect();
            dpp::sort_by_key(&bk, &mut k, &mut idx);
            let sorted_vals = dpp::gather(&bk, &vals, &idx);
            let (want_k, want_v) = dpp::reduce_by_key(
                &bk, &k, &sorted_vals, 0.0f32, |a, b| a + b,
            );
            // Fused: plan built once, reductions sort-free.
            let plan = dpp::SegmentPlan::build(&bk, &keys);
            assert!(plan.matches(&keys), "seed {seed}");
            let got =
                plan.reduce_segments(&bk, &vals, 0.0f32, |a, b| a + b);
            assert_eq!(plan.segment_keys(), &want_k[..], "seed {seed}");
            assert_eq!(got, want_v, "seed {seed}: bitwise mismatch");
            // Allocation-free variant agrees.
            let mut out = vec![0.0f32; plan.num_segments()];
            plan.reduce_segments_into(&bk, &vals, 0.0, |a, b| a + b,
                                      &mut out);
            assert_eq!(out, got, "seed {seed}");
        }
    }
}

#[test]
fn prop_segment_plan_single_segment_and_empty() {
    for bk in backends() {
        // Single segment: every key identical.
        let keys = vec![7u64; 513];
        let vals: Vec<f32> = (0..513).map(|i| i as f32 * 0.25).collect();
        let plan = dpp::SegmentPlan::build(&bk, &keys);
        assert_eq!(plan.num_segments(), 1);
        let got = plan.reduce_segments(&bk, &vals, 0.0f32, |a, b| a + b);
        // Serial left-to-right sum — the reduce_by_key order.
        let mut want = 0.0f32;
        for &v in &vals {
            want += v;
        }
        assert_eq!(got, vec![want]);
        // Empty input.
        let empty = dpp::SegmentPlan::build(&bk, &[]);
        assert_eq!(
            empty.reduce_segments(&bk, &[] as &[f32], 0.0, |a, b| a + b),
            Vec::<f32>::new()
        );
    }
}

#[test]
fn prop_segment_plan_csr_offsets_with_empty_segments() {
    // from_csr_offsets is the only constructor that can express empty
    // segments; they must reduce to the identity on every backend.
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xC5A0);
        let nseg = 1 + rng.below(40) as usize;
        let mut offsets = vec![0u32];
        for _ in 0..nseg {
            let len =
                if rng.below(3) == 0 { 0 } else { rng.below(20) };
            offsets.push(offsets.last().unwrap() + len);
        }
        let n = *offsets.last().unwrap() as usize;
        let vals: Vec<u64> =
            (0..n).map(|_| rng.below(1000) as u64).collect();
        let plan = dpp::SegmentPlan::from_csr_offsets(&offsets);
        assert_eq!(plan.num_segments(), nseg);
        assert_eq!(plan.len(), n);
        for bk in backends() {
            let got = plan.reduce_segments(&bk, &vals, 0, |a, b| a + b);
            for j in 0..nseg {
                let (s, e) =
                    (offsets[j] as usize, offsets[j + 1] as usize);
                let want: u64 = vals[s..e].iter().sum();
                assert_eq!(got[j], want, "seed {seed} seg {j}");
            }
        }
    }
}

#[test]
fn prop_mce_matches_bron_kerbosch() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xC11C);
        let g = random_csr(&mut rng, 40);
        let want = mce::enumerate_serial(&g).normalized();
        for bk in backends() {
            let got = mce::enumerate_dpp(&bk, &g).normalized();
            assert_eq!(got, want, "seed {seed}");
        }
    }
}

#[test]
fn prop_maximal_cliques_are_cliques_and_maximal() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xFACE);
        let g = random_csr(&mut rng, 30);
        let cs = mce::enumerate_serial(&g);
        for i in 0..cs.num_cliques() {
            let c = cs.clique(i);
            // pairwise adjacency
            for (ai, &a) in c.iter().enumerate() {
                for &b in &c[ai + 1..] {
                    assert!(g.adjacent(a, b), "seed {seed}: not a clique");
                }
            }
            // maximality: no vertex extends it
            for w in 0..g.num_vertices() as u32 {
                if c.contains(&w) {
                    continue;
                }
                assert!(
                    !c.iter().all(|&u| g.adjacent(w, u)),
                    "seed {seed}: clique {c:?} extendable by {w}"
                );
            }
        }
    }
}

#[test]
fn prop_hoods_contain_clique_and_one_hop_only() {
    use dpp_pmrf::mrf::hoods;
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0x400D);
        let g = random_csr(&mut rng, 30);
        let cliques = mce::enumerate_serial(&g);
        let h = hoods::build_serial(&g, &cliques, g.num_vertices());
        assert_eq!(h.num_hoods(), cliques.num_cliques());
        for c in 0..cliques.num_cliques() {
            let clique = cliques.clique(c);
            let members = h.hood_members(c);
            // clique ⊆ hood
            for v in clique {
                assert!(members.contains(v), "seed {seed}");
            }
            // every member is in the clique or adjacent to a clique
            // vertex
            for &m in members {
                let ok = clique.contains(&m)
                    || clique.iter().any(|&v| g.adjacent(v, m));
                assert!(ok, "seed {seed}: member {m} not within 1 hop");
            }
            // sorted, deduplicated
            assert!(members.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
        }
    }
}

#[test]
fn prop_energy_packing_total_order() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xEEEE);
        for _ in 0..200 {
            let e1 = (rng.f32() - 0.3) * 1000.0;
            let e2 = (rng.f32() - 0.3) * 1000.0;
            let l1 = (rng.next_u32() & 1) as u8;
            let l2 = (rng.next_u32() & 1) as u8;
            let p1 = energy::pack_energy_label(e1, l1);
            let p2 = energy::pack_energy_label(e2, l2);
            if e1 < e2 {
                assert!(p1 < p2, "seed {seed}: {e1} {e2}");
            }
            if e1 == e2 && l1 < l2 {
                assert!(p1 < p2);
            }
            assert_eq!(energy::unpack_label(p1), l1);
            assert_eq!(energy::unpack_energy(p1), e1);
        }
    }
}

#[test]
fn prop_argmin_consistent_with_pair() {
    let prm = energy::Params {
        mu: [60.0, 190.0],
        sigma: [15.0, 25.0],
        beta: 0.7,
    };
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xA191);
        for _ in 0..500 {
            let y = rng.f32() * 255.0;
            let lbl = (rng.next_u32() & 1) as f32;
            let size = 2.0 + rng.below(30) as f32;
            let ones = rng.below(size as u32 + 1) as f32;
            let (e0, e1) = energy::energy_pair(y, lbl, ones, size, &prm);
            let (em, am) = energy::energy_min(y, lbl, ones, size, &prm);
            assert_eq!(em, e0.min(e1));
            assert_eq!(am == 1, e1 < e0, "strict-less tie break");
        }
    }
}

#[test]
fn prop_copy_if_partition() {
    for seed in 0..TRIALS {
        let mut rng = Pcg32::seeded(seed ^ 0xF1F1);
        let n = rng.below(5000) as usize;
        let xs: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        for bk in backends() {
            let evens = dpp::copy_if_indexed(&bk, &xs, |i| xs[i] % 2 == 0);
            let odds = dpp::copy_if_indexed(&bk, &xs, |i| xs[i] % 2 == 1);
            assert_eq!(evens.len() + odds.len(), n, "seed {seed}");
            assert!(evens.iter().all(|x| x % 2 == 0));
            assert!(odds.iter().all(|x| x % 2 == 1));
        }
    }
}
