//! Edge-message layout: the static index structure BP sweeps over.
//!
//! Messages are stored edge-major in one flat `Vec<f32>` with two
//! entries (label 0, label 1) per *directed* edge, where directed edge
//! `e` is position `e` of the CSR `neighbors` array — `src[e] ->
//! neighbors[e]`. The reverse-edge index `rev` pairs the two directions
//! of every undirected edge; it is what turns "sum the messages *into*
//! a vertex" into a Gather through `rev` followed by a segmented reduce
//! over the vertex's own CSR row.
//!
//! Potts weights are calibrated to the hood energy (DESIGN.md §5): the
//! hood Potts term charges `beta` once per ordered disagreeing pair per
//! shared hood, so an undirected edge (u, v) carries
//! `2 * beta * |hoods(u) ∩ hoods(v)|`. BP over these weights optimizes
//! the same objective the MAP engines report, up to the (rare)
//! same-hood pairs that are not graph-adjacent.

use crate::dpp::{self, Device, SegmentPlan};
use crate::mrf::{Hoods, MrfModel};

/// Static per-directed-edge structure for BP over a [`MrfModel`].
#[derive(Debug, Clone, PartialEq)]
pub struct BpGraph {
    /// Directed edge -> source vertex (CSR row expansion).
    pub src: Vec<u32>,
    /// Directed edge -> the opposite-direction edge's index.
    pub rev: Vec<u32>,
    /// Directed edge -> Potts disagreement weight (symmetric).
    pub weight: Vec<f32>,
    /// Per-vertex edge segments, cached once from the CSR offsets
    /// ("segments for free": the adjacency rows *are* the sorted
    /// segmentation, empty rows included). The belief sweep's
    /// Gather + segmented reduce runs over this plan every sweep with
    /// no sort and no key compare.
    pub plan: SegmentPlan,
}

impl BpGraph {
    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// Build the reverse index and hood-calibrated Potts weights, all
    /// via Map over the directed-edge domain.
    pub fn build(bk: &dyn Device, model: &MrfModel, beta: f32)
        -> BpGraph {
        let g = &model.graph;
        let ne = g.neighbors.len();
        let offsets = &g.offsets;
        let neighbors = &g.neighbors;

        // Map: source vertex of edge e = the row whose offset range
        // contains e (offsets are sorted, so a binary search).
        let src: Vec<u32> = dpp::map_indexed(bk, ne, |e| {
            offsets.partition_point(|&o| o as usize <= e) as u32 - 1
        });

        // Map: position of the (v -> u) twin inside v's sorted row.
        let src_ref = &src;
        let rev: Vec<u32> = dpp::map_indexed(bk, ne, |e| {
            let u = src_ref[e];
            let v = neighbors[e] as usize;
            let row =
                &neighbors[offsets[v] as usize..offsets[v + 1] as usize];
            let p = row
                .binary_search(&u)
                .expect("CSR stores both directions of every edge");
            offsets[v] + p as u32
        });

        // Map: Potts weight from hood co-occurrence.
        let h = &model.hoods;
        let weight: Vec<f32> = dpp::map_indexed(bk, ne, |e| {
            2.0 * beta * co_occurrence(h, src_ref[e], neighbors[e]) as f32
        });

        BpGraph {
            src,
            rev,
            weight,
            plan: SegmentPlan::from_csr_offsets(offsets),
        }
    }
}

/// Number of hoods containing both `u` and `v`: merge-intersection of
/// the two sorted hood-id lists (each vertex appears at most once per
/// hood, and `vert_elems` walks hoods in ascending order).
fn co_occurrence(h: &Hoods, u: u32, v: u32) -> u32 {
    let mut i = h.vert_offsets[u as usize] as usize;
    let iu = h.vert_offsets[u as usize + 1] as usize;
    let mut j = h.vert_offsets[v as usize] as usize;
    let jv = h.vert_offsets[v as usize + 1] as usize;
    let mut count = 0u32;
    while i < iu && j < jv {
        let hu = h.hood_id[h.vert_elems[i] as usize];
        let hv = h.hood_id[h.vert_elems[j] as usize];
        if hu == hv {
            count += 1;
            i += 1;
            j += 1;
        } else if hu < hv {
            i += 1;
        } else {
            j += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn reverse_index_is_an_involution() {
        let model = small_model(11);
        let g = BpGraph::build(&Backend::Serial, &model, 0.5);
        assert_eq!(g.num_edges(), model.graph.neighbors.len());
        for e in 0..g.num_edges() {
            let r = g.rev[e] as usize;
            assert_eq!(g.rev[r] as usize, e, "rev twice = identity");
            assert_eq!(g.src[r], model.graph.neighbors[e],
                       "twin starts where e ends");
            assert_eq!(model.graph.neighbors[r], g.src[e],
                       "twin ends where e starts");
        }
    }

    #[test]
    fn src_matches_csr_rows() {
        let model = small_model(12);
        let g = BpGraph::build(&Backend::Serial, &model, 0.5);
        let offs = &model.graph.offsets;
        for v in 0..model.graph.num_vertices() {
            for e in offs[v] as usize..offs[v + 1] as usize {
                assert_eq!(g.src[e] as usize, v);
            }
        }
    }

    #[test]
    fn plan_segments_are_the_csr_rows() {
        let model = small_model(14);
        let g = BpGraph::build(&Backend::Serial, &model, 0.5);
        assert_eq!(g.plan.offsets(), &model.graph.offsets[..]);
        assert_eq!(g.plan.num_segments(), model.graph.num_vertices());
        assert_eq!(g.plan.len(), g.num_edges());
        assert_eq!(g.plan.permutation(), None, "CSR rows: identity");
    }

    #[test]
    fn weights_symmetric_positive_and_backend_independent() {
        let model = small_model(13);
        let a = BpGraph::build(&Backend::Serial, &model, 0.5);
        let b = BpGraph::build(
            &Backend::threaded_with_grain(Pool::new(4), 64),
            &model,
            0.5,
        );
        assert_eq!(a, b, "build is deterministic across backends");
        for e in 0..a.num_edges() {
            assert_eq!(a.weight[e], a.weight[a.rev[e] as usize]);
            // every RAG edge lies in at least one maximal clique, hence
            // in at least one shared hood
            assert!(a.weight[e] >= 2.0 * 0.5, "edge {e} weight");
        }
    }
}
