//! BP sweeps on a [`Backend`]: beliefs (gather + segmented reduce over
//! the cached [`crate::dpp::SegmentPlan`] in [`BpGraph`]), candidate
//! messages (map), residual max (exact reduce), and the frontier
//! commit (map) — see the module docs of [`crate::bp`].
//!
//! One sweep executes as **one** [`Pipeline`] region: the four passes
//! are stages separated by phase barriers instead of four pool
//! fork-joins, with the serial residual fold as a one-invocation stage
//! between them. Per-stage time still lands in [`crate::dpp::timing`].
//!
//! Deterministic by construction: per-vertex and per-edge loops run in
//! index order inside each chunk, chunks write disjoint slots, and the
//! only cross-chunk reduction is `max` (exact, association-free). The
//! serial oracle in [`super::serial`] reproduces every pass bitwise.

//! Allocation discipline — deny(hot-loop-alloc): a steady-state sweep
//! allocates nothing. Every per-sweep tensor (candidates, residuals,
//! chunk partials, the fold scalars) lives in [`BpState`], allocated
//! once per run and resized within capacity thereafter; remaining
//! allocations are annotated `alloc-ok` and checked by
//! `ci/check_hot_loop_allocs.sh`. (The `Pipeline` stage boxing is the
//! one known per-sweep residue — a few hundred bytes, see DESIGN.md
//! §10.)

use crate::dpp::core::SharedSlice;
use crate::dpp::{Device, DeviceExt, Pipeline};
use crate::mrf::{energy, MrfModel, Params};

use super::messages::BpGraph;
use super::{BpConfig, BpSchedule};

/// Message buffers plus per-sweep scratch, reused across sweeps and
/// EM iterations. `msg` holds two f32 per directed edge: `[2e]` =
/// label 0, `[2e+1]` = label 1, normalized so the smaller entry is 0.
/// The chunk-partial and scalar buffers the sweep's reduction stages
/// write are part of the state too, so a steady-state sweep performs
/// zero heap allocations (DESIGN.md §10).
#[derive(Debug, Clone)]
pub struct BpState {
    pub msg: Vec<f32>,
    cand: Vec<f32>,
    resid: Vec<f32>,
    belief: Vec<f32>,
    /// Per-chunk residual maxima of stage 2 (one slot per grain-sized
    /// chunk; sized lazily per sweep, within capacity once warm).
    partial_max: Vec<f32>,
    /// Per-chunk commit counts of stage 4.
    partial_cnt: Vec<usize>,
    /// `[max_residual, tau]`, published by the serial fold stage.
    scalars: Vec<f32>,
}

impl BpState {
    pub fn new(num_edges: usize, num_vertices: usize) -> BpState {
        BpState {
            msg: vec![0.0; 2 * num_edges],      // alloc-ok: once per run
            cand: vec![0.0; 2 * num_edges],     // alloc-ok: once per run
            resid: vec![0.0; num_edges],        // alloc-ok: once per run
            belief: vec![0.0; 2 * num_vertices], // alloc-ok: once per run
            partial_max: Vec::new(), // alloc-ok: empty, sized on use
            partial_cnt: Vec::new(), // alloc-ok: empty, sized on use
            scalars: Vec::new(),     // alloc-ok: empty, sized on use
        }
    }

    /// Zero all messages (cold start).
    pub fn reset(&mut self) {
        self.msg.fill(0.0);
    }
}

/// Result of one sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepStats {
    /// Max candidate residual across all messages (pre-commit).
    pub max_residual: f32,
    /// Messages actually committed this round.
    pub updated: usize,
}

/// Result of a full BP run (one E-step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpRun {
    pub sweeps: usize,
    pub max_residual: f32,
    pub converged: bool,
}

/// Unary energies, two per vertex: the Gaussian data term weighted by
/// the vertex's hood multiplicity, so the BP objective matches the
/// hood energy's data term (each element instance counts once).
pub fn unaries(bk: &dyn Device, model: &MrfModel, prm: &Params)
    -> Vec<f32> {
    let mut out = Vec::new(); // alloc-ok: legacy allocating spelling
    unaries_into(bk, model, prm, &mut out);
    out
}

/// Allocation-free [`unaries`]: writes the `2 * num_vertices` unary
/// energies into `out` (cleared and resized, within capacity once the
/// engine's buffer is warm) — the BP engine reuses one buffer across
/// all EM iterations.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::bp::sweep;
/// use dpp_pmrf::config::OversegConfig;
/// use dpp_pmrf::dpp::SerialDevice;
/// use dpp_pmrf::image::synth;
/// use dpp_pmrf::mrf::{self, Params};
/// let v = synth::porous_ground_truth(16, 16, 1, 0.4, 1);
/// let seg = dpp_pmrf::overseg::oversegment(
///     &SerialDevice, &v.slice(0),
///     &OversegConfig { scale: 64.0, min_region: 2 });
/// let model = mrf::build_model_serial(&seg);
/// let prm = Params { mu: [60.0, 180.0], sigma: [25.0, 25.0],
///                    beta: 0.5 };
/// let mut out = Vec::new();
/// sweep::unaries_into(&SerialDevice, &model, &prm, &mut out);
/// assert_eq!(out, sweep::unaries(&SerialDevice, &model, &prm));
/// ```
pub fn unaries_into(
    bk: &dyn Device,
    model: &MrfModel,
    prm: &Params,
    out: &mut Vec<f32>,
) {
    let pp = energy::Prepared::from_params(prm);
    let h = &model.hoods;
    let y = &model.y;
    let nv = model.num_vertices();
    out.clear();
    out.resize(2 * nv, 0.0);
    {
        let win = SharedSlice::new(out);
        bk.for_chunks(nv, |s, e| {
            for v in s..e {
                // Vertices outside every hood still get their plain
                // data term so BP labels them sensibly.
                let k = (h.vert_offsets[v + 1] - h.vert_offsets[v])
                    .max(1) as f32;
                let d0 = y[v] - pp.mu[0];
                let d1 = y[v] - pp.mu[1];
                unsafe {
                    win.write(2 * v, k * (d0 * d0 * pp.inv2s[0] + pp.lns[0]));
                    win.write(
                        2 * v + 1,
                        k * (d1 * d1 * pp.inv2s[1] + pp.lns[1]),
                    );
                }
            }
        });
    }
}

/// Beliefs stage body over vertices `s..e`: unary + sum of incoming
/// messages — a Gather through `rev` reduced over the static vertex
/// segments cached in `g.plan` (empty segment = isolated vertex =
/// plain unary). Reads `msg` and writes `belief` through windows so
/// sweep and decode can share it inside a [`Pipeline`].
fn beliefs_chunk(
    g: &BpGraph,
    unary: &[f32],
    msg: &SharedSlice<f32>,
    belief: &SharedSlice<f32>,
    s: usize,
    e: usize,
) {
    for v in s..e {
        let (rs, re) = g.plan.segment_bounds(v);
        let mut b0 = unary[2 * v];
        let mut b1 = unary[2 * v + 1];
        for ed in rs..re {
            let r = g.rev[ed] as usize;
            b0 += unsafe { msg.read(2 * r) };
            b1 += unsafe { msg.read(2 * r + 1) };
        }
        unsafe {
            belief.write(2 * v, b0);
            belief.write(2 * v + 1, b1);
        }
    }
}

/// Chunk grain for the edge-domain stages. Chunk starts are multiples
/// of the grain, so `start / grain` indexes the per-chunk partial
/// arrays no matter which worker claims the chunk (under Serial the
/// single full-range chunk lands in slot 0).
fn edge_grain(bk: &dyn Device, ne: usize) -> usize {
    // Serial-execution devices report `usize::MAX`: one chunk covers
    // the whole edge domain and its partial lands in slot 0, exactly
    // as the old per-variant match arranged.
    bk.grain().min(ne.max(1)).max(1)
}

/// One BP round under the configured schedule, executed as a single
/// fused pipeline region: beliefs -> candidates (+ per-chunk residual
/// maxima) -> serial residual fold + frontier threshold -> commit.
pub fn sweep(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
) -> SweepStats {
    let nv = model.num_vertices();
    let ne = g.num_edges();
    let grain = edge_grain(bk, ne);
    let slots = ne.div_ceil(grain).max(1);
    // Per-sweep scratch lives in the state: resized within capacity
    // after the first sweep, so the steady state allocates nothing.
    st.partial_max.clear();
    st.partial_max.resize(slots, 0.0);
    st.partial_cnt.clear();
    st.partial_cnt.resize(slots, 0);
    st.scalars.clear();
    st.scalars.resize(2, 0.0);
    {
        let w_msg = SharedSlice::new(&mut st.msg);
        let w_cand = SharedSlice::new(&mut st.cand);
        let w_resid = SharedSlice::new(&mut st.resid);
        let w_belief = SharedSlice::new(&mut st.belief);
        let w_pmax = SharedSlice::new(&mut st.partial_max);
        let w_pcnt = SharedSlice::new(&mut st.partial_cnt);
        let w_scal = SharedSlice::new(&mut st.scalars);
        let damping = cfg.damping;
        let schedule = cfg.schedule;
        let frontier = cfg.frontier;
        Pipeline::new()
            // (1) Beliefs: Gather(rev) + segmented reduce per vertex.
            .stage("Gather", nv, |s, e| {
                beliefs_chunk(g, unary, &w_msg, &w_belief, s, e);
            })
            // (2) Candidates: min-sum Potts update, normalization,
            // damping, per-message residuals + per-chunk max.
            .stage_with_grain("Map", ne, grain, |s, e| {
                let mut mx = 0.0f32;
                for ed in s..e {
                    let u = g.src[ed] as usize;
                    let r = g.rev[ed] as usize;
                    let (m0, m1) = unsafe {
                        (w_msg.read(2 * ed), w_msg.read(2 * ed + 1))
                    };
                    let h0 = unsafe { w_belief.read(2 * u) }
                        - unsafe { w_msg.read(2 * r) };
                    let h1 = unsafe { w_belief.read(2 * u + 1) }
                        - unsafe { w_msg.read(2 * r + 1) };
                    let w = g.weight[ed];
                    let mut c0 = h0.min(h1 + w);
                    let mut c1 = h1.min(h0 + w);
                    let norm = c0.min(c1);
                    c0 -= norm;
                    c1 -= norm;
                    let n0 = damping * m0 + (1.0 - damping) * c0;
                    let n1 = damping * m1 + (1.0 - damping) * c1;
                    let rr = (n0 - m0).abs().max((n1 - m1).abs());
                    unsafe {
                        w_cand.write(2 * ed, n0);
                        w_cand.write(2 * ed + 1, n1);
                        w_resid.write(ed, rr);
                    }
                    mx = mx.max(rr);
                }
                let slot = s / grain;
                let old = unsafe { w_pmax.read(slot) };
                unsafe { w_pmax.write(slot, old.max(mx)) };
            })
            // (3) Exact Reduce<Max> over the chunk maxima + the
            // frontier threshold, on one worker between barriers.
            .serial_stage("Reduce", || {
                let mut mx = 0.0f32;
                for i in 0..slots {
                    mx = mx.max(unsafe { w_pmax.read(i) });
                }
                let tau = match schedule {
                    BpSchedule::Synchronous => 0.0,
                    BpSchedule::Residual => frontier * mx,
                };
                unsafe {
                    w_scal.write(0, mx);
                    w_scal.write(1, tau);
                }
            })
            // (4) Commit the frontier (residual >= tau).
            .stage_with_grain("Scatter", ne, grain, |s, e| {
                let tau = unsafe { w_scal.read(1) };
                let mut cnt = 0usize;
                for ed in s..e {
                    if unsafe { w_resid.read(ed) } >= tau {
                        unsafe {
                            w_msg.write(2 * ed, w_cand.read(2 * ed));
                            w_msg
                                .write(2 * ed + 1, w_cand.read(2 * ed + 1));
                        }
                        cnt += 1;
                    }
                }
                let slot = s / grain;
                let old = unsafe { w_pcnt.read(slot) };
                unsafe { w_pcnt.write(slot, old + cnt) };
            })
            .run(bk);
    }
    SweepStats {
        max_residual: st.scalars[0],
        updated: st.partial_cnt.iter().sum(),
    }
}

/// Sweep until the max residual drops below `cfg.tol` (or
/// `cfg.max_sweeps`; with `fixed` every run does the full count).
/// `em` is the caller's EM iteration index, stamped onto the flight
/// recorder's per-sweep samples (pass 0 outside an EM loop).
#[allow(clippy::too_many_arguments)]
pub fn run(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
    fixed: bool,
    em: usize,
) -> BpRun {
    let max_sweeps = cfg.max_sweeps.max(1);
    let mut last = 0.0f32;
    for s in 0..max_sweeps {
        // Sweep-level trace span (the BP analogue of a MAP iteration);
        // inert — no clock read, no allocation — unless a tracer is
        // armed, so the hot loop's zero-alloc contract holds.
        let _sweep_span = crate::telemetry::span_arg(
            "map", "bp_sweep", "sweep", s as u64,
        );
        let stats = sweep(bk, model, g, unary, st, cfg);
        last = stats.max_residual;
        // Flight-recorder hook (DESIGN.md §13): one relaxed load when
        // off; sample fields are already computed by the sweep.
        if crate::obs::live() {
            crate::obs::bp_sample(
                em,
                s,
                stats.max_residual as f64,
                cfg.damping as f64,
                stats.updated as u64,
            );
        }
        if last < cfg.tol && !fixed {
            return BpRun { sweeps: s + 1, max_residual: last,
                           converged: true };
        }
    }
    BpRun { sweeps: max_sweeps, max_residual: last,
            converged: last < cfg.tol }
}

/// Decode labels from the current messages: recompute beliefs, take
/// the per-vertex argmin with the engines' tie-break (ties -> 0) —
/// two pipeline stages in one region.
pub fn decode(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    labels: &mut [u8],
) {
    let nv = model.num_vertices();
    let w_msg = SharedSlice::new(&mut st.msg);
    let w_belief = SharedSlice::new(&mut st.belief);
    let w_lab = SharedSlice::new(labels);
    Pipeline::new()
        .stage("Gather", nv, |s, e| {
            beliefs_chunk(g, unary, &w_msg, &w_belief, s, e);
        })
        .stage("Map", nv, |s, e| {
            for v in s..e {
                let (b0, b1) = unsafe {
                    (w_belief.read(2 * v), w_belief.read(2 * v + 1))
                };
                unsafe { w_lab.write(v, u8::from(b1 < b0)) };
            }
        })
        .run(bk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    fn test_params() -> Params {
        Params { mu: [60.0, 180.0], sigma: [25.0, 25.0], beta: 0.5 }
    }

    #[test]
    fn synchronous_sweeps_converge_and_decode_binary() {
        let model = small_model(31);
        let prm = test_params();
        let cfg = BpConfig {
            schedule: BpSchedule::Synchronous,
            ..Default::default()
        };
        let (labels, run) = crate::bp::solve(&Backend::Serial, &model,
                                             &prm, &cfg);
        assert!(run.converged, "residual {}", run.max_residual);
        assert!(run.sweeps <= cfg.max_sweeps);
        assert!(labels.iter().all(|&l| l <= 1));
        assert_eq!(labels.len(), model.num_vertices());
    }

    #[test]
    fn residual_schedule_updates_fewer_messages_per_round() {
        let model = small_model(32);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());

        let sync = BpConfig { schedule: BpSchedule::Synchronous,
                              ..Default::default() };
        let s1 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &sync);
        assert_eq!(s1.updated, g.num_edges(), "sync commits everything");

        let res = BpConfig { schedule: BpSchedule::Residual,
                             frontier: 0.5, ..Default::default() };
        let s2 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &res);
        assert!(s2.updated <= g.num_edges());
        assert!(s2.updated > 0, "frontier is never empty while r_max > 0");
    }

    #[test]
    fn backends_produce_bitwise_identical_messages() {
        let model = small_model(33);
        let prm = test_params();
        for schedule in [BpSchedule::Synchronous, BpSchedule::Residual] {
            let cfg = BpConfig { schedule, ..Default::default() };
            let mut runs = Vec::new();
            for bk in [
                Backend::Serial,
                Backend::threaded_with_grain(Pool::new(4), 32),
            ] {
                let g = BpGraph::build(&bk, &model, prm.beta);
                let unary = unaries(&bk, &model, &prm);
                let mut st = BpState::new(g.num_edges(),
                                          model.num_vertices());
                let r = run(&bk, &model, &g, &unary, &mut st, &cfg, false);
                runs.push((st.msg.clone(), r));
            }
            assert_eq!(runs[0].0, runs[1].0, "{schedule:?} messages");
            assert_eq!(runs[0].1, runs[1].1, "{schedule:?} run stats");
        }
    }

    #[test]
    fn fixed_mode_runs_exact_sweep_count() {
        let model = small_model(34);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());
        let cfg = BpConfig { max_sweeps: 7, ..Default::default() };
        let r = run(&Backend::Serial, &model, &g, &unary, &mut st, &cfg,
                    true);
        assert_eq!(r.sweeps, 7);
    }
}
