//! BP sweeps on a [`Backend`]: beliefs (gather + segmented reduce),
//! candidate messages (map), residual max (exact reduce), and the
//! frontier commit (map) — see the module docs of [`crate::bp`].
//!
//! Deterministic by construction: per-vertex and per-edge loops run in
//! index order inside each chunk, chunks write disjoint slots, and the
//! only cross-chunk reduction is `max` (exact, association-free). The
//! serial oracle in [`super::serial`] reproduces every pass bitwise.

use crate::dpp::core::SharedSlice;
use crate::dpp::Backend;
use crate::mrf::{energy, MrfModel, Params};

use super::messages::BpGraph;
use super::{BpConfig, BpSchedule};

/// Message buffers, reused across sweeps and EM iterations.
/// `msg` holds two f32 per directed edge: `[2e]` = label 0, `[2e+1]` =
/// label 1, normalized so the smaller entry is 0.
#[derive(Debug, Clone)]
pub struct BpState {
    pub msg: Vec<f32>,
    cand: Vec<f32>,
    resid: Vec<f32>,
    belief: Vec<f32>,
}

impl BpState {
    pub fn new(num_edges: usize, num_vertices: usize) -> BpState {
        BpState {
            msg: vec![0.0; 2 * num_edges],
            cand: vec![0.0; 2 * num_edges],
            resid: vec![0.0; num_edges],
            belief: vec![0.0; 2 * num_vertices],
        }
    }

    /// Zero all messages (cold start).
    pub fn reset(&mut self) {
        self.msg.fill(0.0);
    }
}

/// Result of one sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepStats {
    /// Max candidate residual across all messages (pre-commit).
    pub max_residual: f32,
    /// Messages actually committed this round.
    pub updated: usize,
}

/// Result of a full BP run (one E-step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpRun {
    pub sweeps: usize,
    pub max_residual: f32,
    pub converged: bool,
}

/// Unary energies, two per vertex: the Gaussian data term weighted by
/// the vertex's hood multiplicity, so the BP objective matches the
/// hood energy's data term (each element instance counts once).
pub fn unaries(bk: &Backend, model: &MrfModel, prm: &Params) -> Vec<f32> {
    let pp = energy::Prepared::from_params(prm);
    let h = &model.hoods;
    let y = &model.y;
    let nv = model.num_vertices();
    let mut out = vec![0.0f32; 2 * nv];
    {
        let win = SharedSlice::new(&mut out);
        bk.for_chunks(nv, |s, e| {
            for v in s..e {
                // Vertices outside every hood still get their plain
                // data term so BP labels them sensibly.
                let k = (h.vert_offsets[v + 1] - h.vert_offsets[v])
                    .max(1) as f32;
                let d0 = y[v] - pp.mu[0];
                let d1 = y[v] - pp.mu[1];
                unsafe {
                    win.write(2 * v, k * (d0 * d0 * pp.inv2s[0] + pp.lns[0]));
                    win.write(
                        2 * v + 1,
                        k * (d1 * d1 * pp.inv2s[1] + pp.lns[1]),
                    );
                }
            }
        });
    }
    out
}

/// Beliefs: per vertex, unary + sum of incoming messages (the messages
/// at the reverse of the vertex's own CSR row — a Gather through `rev`
/// reduced over the static vertex segments).
fn beliefs(
    bk: &Backend,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    msg: &[f32],
    belief: &mut [f32],
) {
    let offsets = &model.graph.offsets;
    let nv = model.num_vertices();
    let win = SharedSlice::new(belief);
    let rev = &g.rev;
    bk.for_chunks(nv, |s, e| {
        for v in s..e {
            let (rs, re) = (offsets[v] as usize, offsets[v + 1] as usize);
            let mut b0 = unary[2 * v];
            let mut b1 = unary[2 * v + 1];
            for ed in rs..re {
                let r = rev[ed] as usize;
                b0 += msg[2 * r];
                b1 += msg[2 * r + 1];
            }
            unsafe {
                win.write(2 * v, b0);
                win.write(2 * v + 1, b1);
            }
        }
    });
}

/// Candidate messages for every directed edge: min-sum Potts update
/// from the source belief minus the reverse message, normalized,
/// damped; fills `cand`/`resid` and returns the exact max residual.
fn candidates(
    bk: &Backend,
    g: &BpGraph,
    belief: &[f32],
    msg: &[f32],
    damping: f32,
    cand: &mut [f32],
    resid: &mut [f32],
) -> f32 {
    let ne = g.num_edges();
    let bounds = bk.chunk_bounds(ne);
    let mut partial_max = vec![0.0f32; bounds.len()];
    {
        let wc = SharedSlice::new(cand);
        let wr = SharedSlice::new(resid);
        let wm = SharedSlice::new(&mut partial_max);
        let bounds_ref = &bounds;
        bk.for_chunk_ids(bounds_ref.len(), |c| {
            let (s, e) = bounds_ref[c];
            let mut mx = 0.0f32;
            for ed in s..e {
                let u = g.src[ed] as usize;
                let r = g.rev[ed] as usize;
                let h0 = belief[2 * u] - msg[2 * r];
                let h1 = belief[2 * u + 1] - msg[2 * r + 1];
                let w = g.weight[ed];
                let mut c0 = h0.min(h1 + w);
                let mut c1 = h1.min(h0 + w);
                let norm = c0.min(c1);
                c0 -= norm;
                c1 -= norm;
                let n0 = damping * msg[2 * ed] + (1.0 - damping) * c0;
                let n1 = damping * msg[2 * ed + 1] + (1.0 - damping) * c1;
                let rr = (n0 - msg[2 * ed])
                    .abs()
                    .max((n1 - msg[2 * ed + 1]).abs());
                unsafe {
                    wc.write(2 * ed, n0);
                    wc.write(2 * ed + 1, n1);
                    wr.write(ed, rr);
                }
                mx = mx.max(rr);
            }
            unsafe { wm.write(c, mx) };
        });
    }
    partial_max.into_iter().fold(0.0f32, f32::max)
}

/// Commit candidates whose residual reaches `tau`; returns how many.
fn commit(
    bk: &Backend,
    msg: &mut [f32],
    cand: &[f32],
    resid: &[f32],
    tau: f32,
) -> usize {
    let ne = resid.len();
    let bounds = bk.chunk_bounds(ne);
    let mut partial = vec![0usize; bounds.len()];
    {
        let wm = SharedSlice::new(msg);
        let wp = SharedSlice::new(&mut partial);
        let bounds_ref = &bounds;
        bk.for_chunk_ids(bounds_ref.len(), |c| {
            let (s, e) = bounds_ref[c];
            let mut cnt = 0usize;
            for ed in s..e {
                if resid[ed] >= tau {
                    unsafe {
                        wm.write(2 * ed, cand[2 * ed]);
                        wm.write(2 * ed + 1, cand[2 * ed + 1]);
                    }
                    cnt += 1;
                }
            }
            unsafe { wp.write(c, cnt) };
        });
    }
    partial.iter().sum()
}

/// One BP round under the configured schedule.
pub fn sweep(
    bk: &Backend,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
) -> SweepStats {
    beliefs(bk, model, g, unary, &st.msg, &mut st.belief);
    let max_residual = candidates(
        bk, g, &st.belief, &st.msg, cfg.damping, &mut st.cand,
        &mut st.resid,
    );
    let tau = match cfg.schedule {
        BpSchedule::Synchronous => 0.0,
        BpSchedule::Residual => cfg.frontier * max_residual,
    };
    let updated = commit(bk, &mut st.msg, &st.cand, &st.resid, tau);
    SweepStats { max_residual, updated }
}

/// Sweep until the max residual drops below `cfg.tol` (or
/// `cfg.max_sweeps`; with `fixed` every run does the full count).
pub fn run(
    bk: &Backend,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
    fixed: bool,
) -> BpRun {
    let max_sweeps = cfg.max_sweeps.max(1);
    let mut last = 0.0f32;
    for s in 0..max_sweeps {
        let stats = sweep(bk, model, g, unary, st, cfg);
        last = stats.max_residual;
        if last < cfg.tol && !fixed {
            return BpRun { sweeps: s + 1, max_residual: last,
                           converged: true };
        }
    }
    BpRun { sweeps: max_sweeps, max_residual: last,
            converged: last < cfg.tol }
}

/// Decode labels from the current messages: recompute beliefs, take
/// the per-vertex argmin with the engines' tie-break (ties -> 0).
pub fn decode(
    bk: &Backend,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    labels: &mut [u8],
) {
    beliefs(bk, model, g, unary, &st.msg, &mut st.belief);
    let win = SharedSlice::new(labels);
    let belief = &st.belief;
    bk.for_chunks(model.num_vertices(), |s, e| {
        for v in s..e {
            unsafe {
                win.write(v, u8::from(belief[2 * v + 1] < belief[2 * v]));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::pool::Pool;

    fn test_params() -> Params {
        Params { mu: [60.0, 180.0], sigma: [25.0, 25.0], beta: 0.5 }
    }

    #[test]
    fn synchronous_sweeps_converge_and_decode_binary() {
        let model = small_model(31);
        let prm = test_params();
        let cfg = BpConfig {
            schedule: BpSchedule::Synchronous,
            ..Default::default()
        };
        let (labels, run) = crate::bp::solve(&Backend::Serial, &model,
                                             &prm, &cfg);
        assert!(run.converged, "residual {}", run.max_residual);
        assert!(run.sweeps <= cfg.max_sweeps);
        assert!(labels.iter().all(|&l| l <= 1));
        assert_eq!(labels.len(), model.num_vertices());
    }

    #[test]
    fn residual_schedule_updates_fewer_messages_per_round() {
        let model = small_model(32);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());

        let sync = BpConfig { schedule: BpSchedule::Synchronous,
                              ..Default::default() };
        let s1 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &sync);
        assert_eq!(s1.updated, g.num_edges(), "sync commits everything");

        let res = BpConfig { schedule: BpSchedule::Residual,
                             frontier: 0.5, ..Default::default() };
        let s2 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &res);
        assert!(s2.updated <= g.num_edges());
        assert!(s2.updated > 0, "frontier is never empty while r_max > 0");
    }

    #[test]
    fn backends_produce_bitwise_identical_messages() {
        let model = small_model(33);
        let prm = test_params();
        for schedule in [BpSchedule::Synchronous, BpSchedule::Residual] {
            let cfg = BpConfig { schedule, ..Default::default() };
            let mut runs = Vec::new();
            for bk in [
                Backend::Serial,
                Backend::threaded_with_grain(Pool::new(4), 32),
            ] {
                let g = BpGraph::build(&bk, &model, prm.beta);
                let unary = unaries(&bk, &model, &prm);
                let mut st = BpState::new(g.num_edges(),
                                          model.num_vertices());
                let r = run(&bk, &model, &g, &unary, &mut st, &cfg, false);
                runs.push((st.msg.clone(), r));
            }
            assert_eq!(runs[0].0, runs[1].0, "{schedule:?} messages");
            assert_eq!(runs[0].1, runs[1].1, "{schedule:?} run stats");
        }
    }

    #[test]
    fn fixed_mode_runs_exact_sweep_count() {
        let model = small_model(34);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());
        let cfg = BpConfig { max_sweeps: 7, ..Default::default() };
        let r = run(&Backend::Serial, &model, &g, &unary, &mut st, &cfg,
                    true);
        assert_eq!(r.sweeps, 7);
    }
}
