//! BP sweeps on a [`Backend`]: beliefs (gather + segmented reduce over
//! the cached [`crate::dpp::SegmentPlan`] in [`BpGraph`]), candidate
//! messages (map), and the schedule-dispatched frontier commit — see
//! the module docs of [`crate::bp`] and DESIGN.md §15.
//!
//! One sweep executes as **one** [`Pipeline`] region with phase
//! barriers between stages instead of pool fork-joins. How many stages
//! the region has is the whole point of the frontier-policy family:
//!
//! * `Residual` and `Bucketed` need *this* sweep's residuals to pick
//!   the frontier, so they keep the serial one-invocation fold stage
//!   between the candidate map and the commit — four stages, three
//!   barriers.
//! * `Synchronous`, `StaleResidual`, and `RandomizedSubset` know their
//!   commit rule before the sweep starts (commit everything, threshold
//!   against the previous sweep's max, position-keyed coin flips), so
//!   the fold stage is **gone**: three stages, two barriers, and the
//!   exact residual max folds on the host after the region returns —
//!   off the barrier-to-barrier critical path (Van der Merwe et al.
//!   2019). The stage list under `--profile` shows the difference.
//!
//! Deterministic by construction: per-vertex and per-edge loops run in
//! index order inside each chunk, chunks write disjoint slots, the
//! only cross-chunk reductions are `max` and bitmask-`or` (exact,
//! association-free), and every relaxed commit rule is a pure function
//! of (position, sweep index). The serial oracle in [`super::serial`]
//! reproduces every pass bitwise.

//! Allocation discipline — deny(hot-loop-alloc): a steady-state sweep
//! allocates nothing. Every per-sweep tensor (candidates, residuals,
//! chunk partials, bin masks, the fold scalars) lives in [`BpState`],
//! allocated once per run and resized within capacity thereafter;
//! remaining allocations are annotated `alloc-ok` and checked by
//! `ci/check_hot_loop_allocs.sh`. (The `Pipeline` stage boxing is the
//! one known per-sweep residue — a few hundred bytes, see DESIGN.md
//! §10.)

use crate::dpp::core::SharedSlice;
use crate::dpp::{Device, DeviceExt, Pipeline};
use crate::mrf::{energy, MrfModel, Params};
use crate::util::{splitmix64, Pcg32};

use super::messages::BpGraph;
use super::{BpConfig, BpSchedule};

/// Message buffers plus per-sweep scratch, reused across sweeps and
/// EM iterations. `msg` holds two f32 per directed edge: `[2e]` =
/// label 0, `[2e+1]` = label 1, normalized so the smaller entry is 0.
/// The chunk-partial and scalar buffers the sweep's reduction stages
/// write are part of the state too, so a steady-state sweep performs
/// zero heap allocations (DESIGN.md §10).
#[derive(Debug, Clone)]
pub struct BpState {
    pub msg: Vec<f32>,
    cand: Vec<f32>,
    resid: Vec<f32>,
    belief: Vec<f32>,
    /// Per-chunk residual maxima of the candidate map (one slot per
    /// grain-sized chunk; sized lazily per sweep, within capacity once
    /// warm).
    partial_max: Vec<f32>,
    /// Per-chunk commit counts of the frontier commit stage.
    partial_cnt: Vec<usize>,
    /// Per-chunk log2-bin occupancy bitmasks (`Bucketed` only: bit b
    /// set when some residual in the chunk lands in bin b).
    partial_bins: Vec<u64>,
    /// `[max_residual, commit gate]` — written by the serial fold
    /// stage for the fold-keeping schedules, by the host epilogue for
    /// the fold-free ones.
    scalars: Vec<f32>,
    /// Previous sweep's exact max residual (`StaleResidual` only):
    /// `None` before the first sweep, which therefore commits
    /// everything — the pinned first-sweep semantics.
    stale_max: Option<f32>,
    /// Sweeps executed on this state since construction/reset — the
    /// `RandomizedSubset` coin-flip round coordinate. Advances
    /// identically everywhere because sweep counts are deterministic.
    round: u64,
}

impl BpState {
    pub fn new(num_edges: usize, num_vertices: usize) -> BpState {
        BpState {
            msg: vec![0.0; 2 * num_edges],      // alloc-ok: once per run
            cand: vec![0.0; 2 * num_edges],     // alloc-ok: once per run
            resid: vec![0.0; num_edges],        // alloc-ok: once per run
            belief: vec![0.0; 2 * num_vertices], // alloc-ok: once per run
            partial_max: Vec::new(),  // alloc-ok: empty, sized on use
            partial_cnt: Vec::new(),  // alloc-ok: empty, sized on use
            partial_bins: Vec::new(), // alloc-ok: empty, sized on use
            scalars: Vec::new(),      // alloc-ok: empty, sized on use
            stale_max: None,
            round: 0,
        }
    }

    /// Zero all messages and restart the schedule clocks (cold start):
    /// the stale threshold forgets its previous max and the randomized
    /// coin-flip stream rewinds to round 0.
    pub fn reset(&mut self) {
        self.msg.fill(0.0);
        self.stale_max = None;
        self.round = 0;
    }
}

/// Result of one sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepStats {
    /// Max candidate residual across all messages (pre-commit).
    pub max_residual: f32,
    /// Messages actually committed this round.
    pub updated: usize,
}

/// Result of a full BP run (one E-step).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpRun {
    pub sweeps: usize,
    pub max_residual: f32,
    pub converged: bool,
    /// Total messages committed across all sweeps — the numerator of
    /// the committed fraction the run report carries.
    pub updated_total: usize,
}

impl BpRun {
    /// Mean fraction of directed messages committed per sweep (1.0
    /// under the synchronous schedule by construction).
    pub fn committed_frac(&self, num_edges: usize) -> f64 {
        self.updated_total as f64
            / (self.sweeps.max(1) * num_edges.max(1)) as f64
    }
}

/// Log2 bucket of a residual relative to `tol`, clamped to `bins`
/// buckets: bucket b covers `[tol * 2^b, tol * 2^(b+1))` and the top
/// bucket absorbs everything larger; residuals below `tol` (already
/// converged — committing them cannot change the fixed point) occupy
/// no bucket. Pure exponent arithmetic on the f32 bit pattern: no
/// libm, bitwise identical on every device.
#[inline]
pub(super) fn residual_bin(rr: f32, tol: f32, bins: u32) -> Option<u32> {
    if !(rr >= tol) {
        return None; // below tol, or NaN-poisoned: never prioritized
    }
    let e = (((rr / tol).to_bits() >> 23) & 0xff) as i32 - 127;
    Some((e.max(0) as u32).min(bins - 1))
}

/// `RandomizedSubset` coin flip for message `ed` on sweep `round`: a
/// pure function of (seed, round, position) in the PR 9
/// proposal-stream style, so the kept subset never depends on
/// execution order, chunking, device, or lane count — the schedule
/// stays bitwise identical everywhere.
#[inline]
pub(super) fn subset_keeps(
    seed: u64,
    round: u64,
    ed: usize,
    p: f32,
) -> bool {
    let mut rng = Pcg32::new(
        splitmix64(seed ^ round.wrapping_mul(0x9E37_79B9)),
        ed as u64,
    );
    rng.f32() < p
}

/// Commit gate known *before* the sweep runs, for the schedules whose
/// rule does not depend on this sweep's residuals — exactly the
/// schedules whose pipeline region carries no serial fold stage.
/// `None` means the schedule folds mid-pipeline (`Residual`,
/// `Bucketed`).
#[inline]
fn static_gate(cfg: &BpConfig, stale_max: Option<f32>) -> Option<f32> {
    match cfg.schedule {
        BpSchedule::Synchronous => Some(0.0),
        // First sweep: no previous max, threshold 0, commit all.
        BpSchedule::StaleResidual => {
            Some(stale_max.map_or(0.0, |m| cfg.frontier * m))
        }
        // Coins gate the commit; the residual threshold is unused.
        BpSchedule::RandomizedSubset { .. } => Some(0.0),
        BpSchedule::Residual | BpSchedule::Bucketed { .. } => None,
    }
}

/// Unary energies, two per vertex: the Gaussian data term weighted by
/// the vertex's hood multiplicity, so the BP objective matches the
/// hood energy's data term (each element instance counts once).
pub fn unaries(bk: &dyn Device, model: &MrfModel, prm: &Params)
    -> Vec<f32> {
    let mut out = Vec::new(); // alloc-ok: legacy allocating spelling
    unaries_into(bk, model, prm, &mut out);
    out
}

/// Allocation-free [`unaries`]: writes the `2 * num_vertices` unary
/// energies into `out` (cleared and resized, within capacity once the
/// engine's buffer is warm) — the BP engine reuses one buffer across
/// all EM iterations.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::bp::sweep;
/// use dpp_pmrf::config::OversegConfig;
/// use dpp_pmrf::dpp::SerialDevice;
/// use dpp_pmrf::image::synth;
/// use dpp_pmrf::mrf::{self, Params};
/// let v = synth::porous_ground_truth(16, 16, 1, 0.4, 1);
/// let seg = dpp_pmrf::overseg::oversegment(
///     &SerialDevice, &v.slice(0),
///     &OversegConfig { scale: 64.0, min_region: 2 });
/// let model = mrf::build_model_serial(&seg);
/// let prm = Params { mu: [60.0, 180.0], sigma: [25.0, 25.0],
///                    beta: 0.5 };
/// let mut out = Vec::new();
/// sweep::unaries_into(&SerialDevice, &model, &prm, &mut out);
/// assert_eq!(out, sweep::unaries(&SerialDevice, &model, &prm));
/// ```
pub fn unaries_into(
    bk: &dyn Device,
    model: &MrfModel,
    prm: &Params,
    out: &mut Vec<f32>,
) {
    let pp = energy::Prepared::from_params(prm);
    let h = &model.hoods;
    let y = &model.y;
    let nv = model.num_vertices();
    out.clear();
    out.resize(2 * nv, 0.0);
    {
        let win = SharedSlice::new(out);
        bk.for_chunks(nv, |s, e| {
            for v in s..e {
                // Vertices outside every hood still get their plain
                // data term so BP labels them sensibly.
                let k = (h.vert_offsets[v + 1] - h.vert_offsets[v])
                    .max(1) as f32;
                let d0 = y[v] - pp.mu[0];
                let d1 = y[v] - pp.mu[1];
                unsafe {
                    win.write(2 * v, k * (d0 * d0 * pp.inv2s[0] + pp.lns[0]));
                    win.write(
                        2 * v + 1,
                        k * (d1 * d1 * pp.inv2s[1] + pp.lns[1]),
                    );
                }
            }
        });
    }
}

/// Beliefs stage body over vertices `s..e`: unary + sum of incoming
/// messages — a Gather through `rev` reduced over the static vertex
/// segments cached in `g.plan` (empty segment = isolated vertex =
/// plain unary). Reads `msg` and writes `belief` through windows so
/// sweep and decode can share it inside a [`Pipeline`].
fn beliefs_chunk(
    g: &BpGraph,
    unary: &[f32],
    msg: &SharedSlice<f32>,
    belief: &SharedSlice<f32>,
    s: usize,
    e: usize,
) {
    for v in s..e {
        let (rs, re) = g.plan.segment_bounds(v);
        let mut b0 = unary[2 * v];
        let mut b1 = unary[2 * v + 1];
        for ed in rs..re {
            let r = g.rev[ed] as usize;
            b0 += unsafe { msg.read(2 * r) };
            b1 += unsafe { msg.read(2 * r + 1) };
        }
        unsafe {
            belief.write(2 * v, b0);
            belief.write(2 * v + 1, b1);
        }
    }
}

/// Chunk grain for the edge-domain stages. Chunk starts are multiples
/// of the grain, so `start / grain` indexes the per-chunk partial
/// arrays no matter which worker claims the chunk (under Serial the
/// single full-range chunk lands in slot 0).
fn edge_grain(bk: &dyn Device, ne: usize) -> usize {
    // Serial-execution devices report `usize::MAX`: one chunk covers
    // the whole edge domain and its partial lands in slot 0, exactly
    // as the old per-variant match arranged.
    bk.grain().min(ne.max(1)).max(1)
}

/// One BP round under the configured frontier policy, executed as a
/// single fused pipeline region: beliefs -> candidates (+ per-chunk
/// residual maxima and, for `Bucketed`, bin masks) -> [serial fold,
/// only when the policy needs this sweep's residuals] -> commit.
pub fn sweep(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
) -> SweepStats {
    let nv = model.num_vertices();
    let ne = g.num_edges();
    let grain = edge_grain(bk, ne);
    let slots = ne.div_ceil(grain).max(1);
    // Per-sweep scratch lives in the state: resized within capacity
    // after the first sweep, so the steady state allocates nothing.
    st.partial_max.clear();
    st.partial_max.resize(slots, 0.0);
    st.partial_cnt.clear();
    st.partial_cnt.resize(slots, 0);
    st.partial_bins.clear();
    st.partial_bins.resize(slots, 0);
    st.scalars.clear();
    st.scalars.resize(2, 0.0);
    let round = st.round;
    let gate = static_gate(cfg, st.stale_max);
    {
        let w_msg = SharedSlice::new(&mut st.msg);
        let w_cand = SharedSlice::new(&mut st.cand);
        let w_resid = SharedSlice::new(&mut st.resid);
        let w_belief = SharedSlice::new(&mut st.belief);
        let w_pmax = SharedSlice::new(&mut st.partial_max);
        let w_pcnt = SharedSlice::new(&mut st.partial_cnt);
        let w_pbin = SharedSlice::new(&mut st.partial_bins);
        let w_scal = SharedSlice::new(&mut st.scalars);
        let damping = cfg.damping;
        let schedule = cfg.schedule;
        let frontier = cfg.frontier;
        let tol = cfg.tol;
        // Policy parameters hoisted to block scope so the stage
        // closures can borrow them for the pipeline's lifetime.
        let bucket_bins = match schedule {
            BpSchedule::Bucketed { bins } => bins,
            _ => 0,
        };
        let (keep_p, keep_seed) = match schedule {
            BpSchedule::RandomizedSubset { p, seed } => (p, seed),
            _ => (1.0, 0),
        };
        let p = Pipeline::new()
            // (1) Beliefs: Gather(rev) + segmented reduce per vertex.
            .stage("Gather", nv, |s, e| {
                beliefs_chunk(g, unary, &w_msg, &w_belief, s, e);
            })
            // (2) Candidates: min-sum Potts update, normalization,
            // damping, per-message residuals + per-chunk max (and
            // per-chunk bin-occupancy masks under Bucketed).
            .stage_with_grain("Map", ne, grain, |s, e| {
                let mut mx = 0.0f32;
                let mut mask = 0u64;
                for ed in s..e {
                    let u = g.src[ed] as usize;
                    let r = g.rev[ed] as usize;
                    let (m0, m1) = unsafe {
                        (w_msg.read(2 * ed), w_msg.read(2 * ed + 1))
                    };
                    let h0 = unsafe { w_belief.read(2 * u) }
                        - unsafe { w_msg.read(2 * r) };
                    let h1 = unsafe { w_belief.read(2 * u + 1) }
                        - unsafe { w_msg.read(2 * r + 1) };
                    let w = g.weight[ed];
                    let mut c0 = h0.min(h1 + w);
                    let mut c1 = h1.min(h0 + w);
                    let norm = c0.min(c1);
                    c0 -= norm;
                    c1 -= norm;
                    let n0 = damping * m0 + (1.0 - damping) * c0;
                    let n1 = damping * m1 + (1.0 - damping) * c1;
                    let rr = (n0 - m0).abs().max((n1 - m1).abs());
                    unsafe {
                        w_cand.write(2 * ed, n0);
                        w_cand.write(2 * ed + 1, n1);
                        w_resid.write(ed, rr);
                    }
                    if bucket_bins > 0 {
                        if let Some(b) = residual_bin(rr, tol, bucket_bins)
                        {
                            mask |= 1 << b;
                        }
                    }
                    mx = mx.max(rr);
                }
                let slot = s / grain;
                let old = unsafe { w_pmax.read(slot) };
                unsafe { w_pmax.write(slot, old.max(mx)) };
                if bucket_bins > 0 {
                    let old = unsafe { w_pbin.read(slot) };
                    unsafe { w_pbin.write(slot, old | mask) };
                }
            });
        // (3) The mid-pipeline serial fold — ONLY for the schedules
        // whose commit rule depends on this sweep's residuals. The
        // fold-free schedules skip the stage (and its barrier)
        // entirely: this conditional is the headline perf change of
        // the frontier-policy family (DESIGN.md §15).
        let p = if gate.is_none() {
            p.serial_stage("Reduce", || {
                let mut mx = 0.0f32;
                for i in 0..slots {
                    mx = mx.max(unsafe { w_pmax.read(i) });
                }
                let published = match schedule {
                    // Exact frontier: a residual threshold.
                    BpSchedule::Residual => frontier * mx,
                    // Splash approximation: the top non-empty bucket
                    // index (commit-all sentinel -1 when every
                    // residual is already below tol).
                    BpSchedule::Bucketed { .. } => {
                        let mut bins = 0u64;
                        for i in 0..slots {
                            bins |= unsafe { w_pbin.read(i) };
                        }
                        if bins == 0 {
                            -1.0
                        } else {
                            (63 - bins.leading_zeros()) as f32
                        }
                    }
                    // Fold-free schedules never build this stage.
                    _ => 0.0,
                };
                unsafe {
                    w_scal.write(0, mx);
                    w_scal.write(1, published);
                }
            })
        } else {
            p
        };
        // (4) Commit the frontier. A separate post-barrier stage for
        // every policy: fusing it into the candidate map would let a
        // chunk read messages a neighbor chunk already overwrote —
        // Gauss-Seidel races that break bitwise determinism.
        let p = match schedule {
            BpSchedule::RandomizedSubset { .. } => {
                p.stage_with_grain("Scatter", ne, grain, |s, e| {
                    let mut cnt = 0usize;
                    for ed in s..e {
                        if subset_keeps(keep_seed, round, ed, keep_p) {
                            unsafe {
                                w_msg.write(2 * ed, w_cand.read(2 * ed));
                                w_msg.write(
                                    2 * ed + 1,
                                    w_cand.read(2 * ed + 1),
                                );
                            }
                            cnt += 1;
                        }
                    }
                    let slot = s / grain;
                    let old = unsafe { w_pcnt.read(slot) };
                    unsafe { w_pcnt.write(slot, old + cnt) };
                })
            }
            BpSchedule::Bucketed { .. } => {
                p.stage_with_grain("Scatter", ne, grain, |s, e| {
                    // Re-derive each residual's bucket and compare to
                    // the published top — exactly consistent with the
                    // fold's occupancy mask, so the commit set is
                    // never empty while any residual reaches tol.
                    let top = unsafe { w_scal.read(1) };
                    let mut cnt = 0usize;
                    for ed in s..e {
                        let keep = if top < 0.0 {
                            true
                        } else {
                            residual_bin(
                                unsafe { w_resid.read(ed) },
                                tol,
                                bucket_bins,
                            )
                            .is_some_and(|b| b >= top as u32)
                        };
                        if keep {
                            unsafe {
                                w_msg.write(2 * ed, w_cand.read(2 * ed));
                                w_msg.write(
                                    2 * ed + 1,
                                    w_cand.read(2 * ed + 1),
                                );
                            }
                            cnt += 1;
                        }
                    }
                    let slot = s / grain;
                    let old = unsafe { w_pcnt.read(slot) };
                    unsafe { w_pcnt.write(slot, old + cnt) };
                })
            }
            // Threshold schedules: tau is either the static gate
            // (Synchronous, StaleResidual) or the fold's output
            // (Residual).
            _ => p.stage_with_grain("Scatter", ne, grain, |s, e| {
                let tau = match gate {
                    Some(t) => t,
                    None => unsafe { w_scal.read(1) },
                };
                let mut cnt = 0usize;
                for ed in s..e {
                    if unsafe { w_resid.read(ed) } >= tau {
                        unsafe {
                            w_msg.write(2 * ed, w_cand.read(2 * ed));
                            w_msg
                                .write(2 * ed + 1, w_cand.read(2 * ed + 1));
                        }
                        cnt += 1;
                    }
                }
                let slot = s / grain;
                let old = unsafe { w_pcnt.read(slot) };
                unsafe { w_pcnt.write(slot, old + cnt) };
            }),
        };
        p.run(bk);
    }
    // Host epilogue for the fold-free schedules: the exact max over
    // the handful of chunk partials, off the barrier critical path.
    // Bitwise equal to the in-pipeline fold — identical loop over
    // identical slots — so `max_residual` (and therefore convergence)
    // is schedule-placement-independent.
    if gate.is_some() {
        let mut mx = 0.0f32;
        for &v in &st.partial_max {
            mx = mx.max(v);
        }
        st.scalars[0] = mx;
    }
    if matches!(cfg.schedule, BpSchedule::StaleResidual) {
        st.stale_max = Some(st.scalars[0]);
    }
    st.round += 1;
    SweepStats {
        max_residual: st.scalars[0],
        updated: st.partial_cnt.iter().sum(),
    }
}

/// Sweep until the max residual drops below `cfg.tol` (or
/// `cfg.max_sweeps`; with `fixed` every run does the full count).
/// `em` is the caller's EM iteration index, stamped onto the flight
/// recorder's per-sweep samples (pass 0 outside an EM loop).
#[allow(clippy::too_many_arguments)]
pub fn run(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    cfg: &BpConfig,
    fixed: bool,
    em: usize,
) -> BpRun {
    let max_sweeps = cfg.max_sweeps.max(1);
    let ne = g.num_edges();
    let mut last = 0.0f32;
    let mut updated_total = 0usize;
    for s in 0..max_sweeps {
        // Sweep-level trace span (the BP analogue of a MAP iteration);
        // inert — no clock read, no allocation — unless a tracer is
        // armed, so the hot loop's zero-alloc contract holds.
        let _sweep_span = crate::telemetry::span_arg(
            "map", "bp_sweep", "sweep", s as u64,
        );
        let stats = sweep(bk, model, g, unary, st, cfg);
        last = stats.max_residual;
        updated_total += stats.updated;
        // Flight-recorder hook (DESIGN.md §13): one relaxed load when
        // off; sample fields are already computed by the sweep.
        if crate::obs::live() {
            crate::obs::bp_sample(
                em,
                s,
                stats.max_residual as f64,
                cfg.damping as f64,
                stats.updated as u64,
                cfg.schedule.name(),
                stats.updated as f64 / ne.max(1) as f64,
            );
        }
        if last < cfg.tol && !fixed {
            return BpRun { sweeps: s + 1, max_residual: last,
                           converged: true, updated_total };
        }
    }
    BpRun { sweeps: max_sweeps, max_residual: last,
            converged: last < cfg.tol, updated_total }
}

/// Decode labels from the current messages: recompute beliefs, take
/// the per-vertex argmin with the engines' tie-break (ties -> 0) —
/// two pipeline stages in one region.
pub fn decode(
    bk: &dyn Device,
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    st: &mut BpState,
    labels: &mut [u8],
) {
    let nv = model.num_vertices();
    let w_msg = SharedSlice::new(&mut st.msg);
    let w_belief = SharedSlice::new(&mut st.belief);
    let w_lab = SharedSlice::new(labels);
    Pipeline::new()
        .stage("Gather", nv, |s, e| {
            beliefs_chunk(g, unary, &w_msg, &w_belief, s, e);
        })
        .stage("Map", nv, |s, e| {
            for v in s..e {
                let (b0, b1) = unsafe {
                    (w_belief.read(2 * v), w_belief.read(2 * v + 1))
                };
                unsafe { w_lab.write(v, u8::from(b1 < b0)) };
            }
        })
        .run(bk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::bp::ALL_SCHEDULES;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    fn test_params() -> Params {
        Params { mu: [60.0, 180.0], sigma: [25.0, 25.0], beta: 0.5 }
    }

    #[test]
    fn synchronous_sweeps_converge_and_decode_binary() {
        let model = small_model(31);
        let prm = test_params();
        let cfg = BpConfig {
            schedule: BpSchedule::Synchronous,
            ..Default::default()
        };
        let (labels, run) = crate::bp::solve(&Backend::Serial, &model,
                                             &prm, &cfg);
        assert!(run.converged, "residual {}", run.max_residual);
        assert!(run.sweeps <= cfg.max_sweeps);
        assert!(labels.iter().all(|&l| l <= 1));
        assert_eq!(labels.len(), model.num_vertices());
    }

    #[test]
    fn relaxed_schedules_update_fewer_messages_per_round() {
        let model = small_model(32);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());

        let sync = BpConfig { schedule: BpSchedule::Synchronous,
                              ..Default::default() };
        let s1 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &sync);
        assert_eq!(s1.updated, g.num_edges(), "sync commits everything");

        for schedule in [
            BpSchedule::Residual,
            BpSchedule::Bucketed { bins: 8 },
            BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
        ] {
            let cfg = BpConfig { schedule, frontier: 0.5,
                                 ..Default::default() };
            let s = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                          &cfg);
            assert!(s.updated <= g.num_edges(), "{schedule:?}");
            assert!(s.updated > 0,
                    "{schedule:?}: frontier never empty while r_max > 0");
        }
    }

    #[test]
    fn stale_residual_first_sweep_commits_everything() {
        // The pinned edge case (DESIGN.md §15): no previous max means
        // threshold 0, so sweep 1 is synchronous; later sweeps relax.
        let model = small_model(36);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());
        let cfg = BpConfig { schedule: BpSchedule::StaleResidual,
                             ..Default::default() };
        let s1 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &cfg);
        assert_eq!(s1.updated, g.num_edges(),
                   "no previous max => commit everything");
        let s2 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &cfg);
        assert!(s2.updated < g.num_edges(),
                "second sweep thresholds against sweep 1's max");
        // Reset restores the commit-everything first-sweep semantics.
        st.reset();
        let s3 = sweep(&Backend::Serial, &model, &g, &unary, &mut st,
                       &cfg);
        assert_eq!(s3.updated, g.num_edges(), "reset forgets the max");
        assert_eq!(s3.max_residual.to_bits(), s1.max_residual.to_bits(),
                   "reset reproduces sweep 1 bitwise");
    }

    #[test]
    fn fold_free_schedules_have_no_reduce_stage() {
        // The acceptance criterion of ISSUE 10 made mechanical: under
        // the timing profiler, a Residual/Bucketed sweep records a
        // serial "Reduce" stage and the fold-free schedules do not —
        // one fewer stage, one fewer barrier.
        let model = small_model(37);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let _guard = crate::dpp::timing::test_lock();
        for (schedule, folds) in [
            (BpSchedule::Residual, true),
            (BpSchedule::Bucketed { bins: 8 }, true),
            (BpSchedule::Synchronous, false),
            (BpSchedule::StaleResidual, false),
            (BpSchedule::RandomizedSubset { p: 0.5, seed: 7 }, false),
        ] {
            let cfg = BpConfig { schedule, ..Default::default() };
            let mut st =
                BpState::new(g.num_edges(), model.num_vertices());
            crate::dpp::timing::set_enabled(true);
            crate::dpp::timing::reset();
            // Two sweeps: the steady state, not just the first round.
            sweep(&Backend::Serial, &model, &g, &unary, &mut st, &cfg);
            sweep(&Backend::Serial, &model, &g, &unary, &mut st, &cfg);
            let snap = crate::dpp::timing::snapshot();
            crate::dpp::timing::set_enabled(false);
            assert_eq!(snap.contains_key("Reduce"), folds,
                       "{schedule:?} stage list: {:?}",
                       snap.keys().collect::<Vec<_>>());
            assert!(snap.contains_key("Scatter"), "{schedule:?}");
        }
    }

    #[test]
    fn residual_bin_is_exact_log2_of_the_ratio() {
        let tol = 1e-3f32;
        assert_eq!(residual_bin(0.0, tol, 8), None);
        assert_eq!(residual_bin(tol * 0.999, tol, 8), None);
        assert_eq!(residual_bin(tol, tol, 8), Some(0));
        assert_eq!(residual_bin(tol * 1.999, tol, 8), Some(0));
        assert_eq!(residual_bin(tol * 2.0, tol, 8), Some(1));
        assert_eq!(residual_bin(tol * 4.0, tol, 8), Some(2));
        // The top bin absorbs everything larger.
        assert_eq!(residual_bin(tol * 1e9, tol, 8), Some(7));
        assert_eq!(residual_bin(f32::NAN, tol, 8), None);
    }

    #[test]
    fn subset_coin_flips_are_position_keyed_and_seeded() {
        // Pure function of (seed, round, position): recomputing gives
        // the same answer, and both round and seed decorrelate.
        let a: Vec<bool> =
            (0..256).map(|ed| subset_keeps(9, 3, ed, 0.5)).collect();
        let b: Vec<bool> =
            (0..256).map(|ed| subset_keeps(9, 3, ed, 0.5)).collect();
        assert_eq!(a, b);
        let other_round: Vec<bool> =
            (0..256).map(|ed| subset_keeps(9, 4, ed, 0.5)).collect();
        assert_ne!(a, other_round);
        let other_seed: Vec<bool> =
            (0..256).map(|ed| subset_keeps(8, 3, ed, 0.5)).collect();
        assert_ne!(a, other_seed);
        // p = 1 keeps everything.
        assert!((0..256).all(|ed| subset_keeps(9, 3, ed, 1.0)));
        let kept = a.iter().filter(|&&k| k).count();
        assert!((64..=192).contains(&kept), "p=0.5 kept {kept}/256");
    }

    #[test]
    fn backends_produce_bitwise_identical_messages() {
        let model = small_model(33);
        let prm = test_params();
        for schedule in ALL_SCHEDULES {
            let cfg = BpConfig { schedule, ..Default::default() };
            let mut runs = Vec::new();
            for bk in [
                Backend::Serial,
                Backend::threaded_with_grain(Pool::new(4), 32),
            ] {
                let g = BpGraph::build(&bk, &model, prm.beta);
                let unary = unaries(&bk, &model, &prm);
                let mut st = BpState::new(g.num_edges(),
                                          model.num_vertices());
                let r = run(&bk, &model, &g, &unary, &mut st, &cfg,
                            false, 0);
                runs.push((st.msg.clone(), r));
            }
            assert_eq!(runs[0].0, runs[1].0, "{schedule:?} messages");
            assert_eq!(runs[0].1, runs[1].1, "{schedule:?} run stats");
        }
    }

    #[test]
    fn fixed_mode_runs_exact_sweep_count() {
        let model = small_model(34);
        let prm = test_params();
        let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
        let unary = unaries(&Backend::Serial, &model, &prm);
        let mut st = BpState::new(g.num_edges(), model.num_vertices());
        let cfg = BpConfig { max_sweeps: 7, ..Default::default() };
        let r = run(&Backend::Serial, &model, &g, &unary, &mut st, &cfg,
                    true, 0);
        assert_eq!(r.sweeps, 7);
        assert!(r.updated_total <= 7 * g.num_edges());
        assert!(r.committed_frac(g.num_edges()) <= 1.0);
    }
}
