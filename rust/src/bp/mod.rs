//! Max-product loopy belief propagation over the region graph
//! (DESIGN.md §6, §15) — a second optimizer for [`crate::mrf::MrfModel`]
//! beside the EM/MAP engines, expressed entirely in the DPP vocabulary
//! of [`crate::dpp`].
//!
//! The pairwise reformulation of the hood energy (DESIGN.md §5): unary
//! energies are the Gaussian data term of [`crate::mrf::energy`]
//! weighted by each vertex's hood multiplicity, and the Potts coupling
//! between adjacent regions is weighted by how many hoods contain both
//! endpoints ([`messages::BpGraph`]). Min-sum messages (max-product in
//! the log domain) live in one flat edge-major `Vec<f32>` indexed by
//! the CSR adjacency; one sweep is
//!
//! 1. **Gather** reverse-edge messages + **segmented reduce** per
//!    vertex -> beliefs,
//! 2. **Map** over directed edges -> damped candidate messages and
//!    per-message residuals,
//! 3. a schedule-dependent commit rule (the **frontier policy**) that
//!    picks which candidates replace their messages this round.
//!
//! The frontier policies are the [`BpSchedule`] family (DESIGN.md §15,
//! after Van der Merwe et al. 2019, *Message Scheduling for
//! Performant, Many-Core Belief Propagation*): the exact residual
//! frontier keeps a serial `Reduce<Max>` fold between barriers every
//! sweep, while the relaxed policies (stale threshold, log2 residual
//! buckets, randomized subsets) either move that fold off the critical
//! path or drop it entirely — same fixed points, less serialization.
//!
//! All of it fused: the vertex segments come from the
//! [`crate::dpp::SegmentPlan`] cached in [`messages::BpGraph`] (CSR
//! rows — no per-sweep sort or key compare), and one sweep runs as a
//! single [`crate::dpp::Pipeline`] region — phase barriers between the
//! passes instead of one pool fork-join per pass.
//!
//! Modules: [`messages`] (edge layout + reverse index + Potts weights),
//! [`sweep`] (schedule-dispatched sweeps on a [`crate::dpp::Device`]),
//! [`serial`] (plain-loop oracle for tests), [`engine`] ([`BpEngine`],
//! an [`crate::mrf::Engine`] running BP as the E-step inside the
//! shared EM outer loop).
//!
//! Every pass is deterministic across backends and thread counts: the
//! only floating-point reduction is an exact `max`, per-vertex /
//! per-edge arithmetic runs in a fixed order, and every relaxed commit
//! rule is a pure function of (position, sweep index) — never of
//! execution order. BP with any schedule and any backend is therefore
//! bitwise-reproducible — stronger than the MAP engines'
//! chunk-order-dependent parameter reductions.

pub mod engine;
pub mod messages;
pub mod serial;
pub mod sweep;

pub use engine::BpEngine;
pub use messages::BpGraph;
pub use sweep::{BpRun, BpState, SweepStats};

use anyhow::{bail, Result};

/// Bucket count when `--bp-schedule bucketed` gives none.
pub const DEFAULT_BUCKET_BINS: u32 = 8;
/// Keep probability when `--bp-schedule random` gives none.
pub const DEFAULT_SUBSET_P: f32 = 0.5;
/// Coin-flip stream seed when `--bp-schedule random` gives none.
pub const DEFAULT_SUBSET_SEED: u64 = 0x5EED;
/// Bin masks are one `u64` per chunk, so at most 63 usable bins.
pub const MAX_BUCKET_BINS: u32 = 63;

/// Message-commit schedule for one BP round — the frontier policy
/// family (DESIGN.md §15). Every policy computes the same candidates;
/// they differ only in which candidates commit each sweep, and in how
/// much cross-worker coordination that decision costs.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum BpSchedule {
    /// Jacobi: every message recomputed and committed each round. No
    /// fold stage — the commit rule is known before the sweep starts.
    Synchronous,
    /// Exact residual frontier: only messages whose residual reaches
    /// `frontier * max_residual` commit, with the max taken over
    /// *this* sweep's residuals — which costs a serial `Reduce<Max>`
    /// fold on one worker between barriers every sweep.
    #[default]
    Residual,
    /// Relaxed residual frontier: threshold against the *previous*
    /// sweep's max residual instead of this one's. The stale bound is
    /// known before the sweep starts, so the steady-state region has
    /// no serial fold stage and one fewer barrier than `Residual`;
    /// the first sweep (no previous max) commits everything.
    StaleResidual,
    /// Splash-style priority approximation: residuals land in `bins`
    /// log2 buckets relative to `tol` (bucket b covers
    /// `[tol * 2^b, tol * 2^(b+1))`, the top bucket absorbs larger),
    /// and only the highest non-empty bucket commits — a priority
    /// queue to within 2x, with an O(bins) bitmask fold instead of a
    /// global sort.
    Bucketed {
        /// Number of log2 residual buckets, in `[2, MAX_BUCKET_BINS]`.
        bins: u32,
    },
    /// Relaxed randomized schedule: each directed message commits this
    /// sweep with probability `p`, decided by a Pcg32 draw that is a
    /// pure function of (seed, sweep index, message index) — the PR 9
    /// proposal-stream construction — so the subset never depends on
    /// execution order, chunking, device, or lane count. No fold
    /// stage at all.
    RandomizedSubset {
        /// Per-(sweep, message) keep probability, in `(0, 1]`.
        p: f32,
        /// Stream seed; same seed = same subsets everywhere.
        seed: u64,
    },
}

impl BpSchedule {
    /// Parse a schedule spec: `sync`, `residual`, `stale`,
    /// `bucketed[:BINS]`, `random[:P[:SEED]]`. Parameterized specs
    /// round-trip through [`BpSchedule::spec`].
    pub fn parse(s: &str) -> Result<Self> {
        let mut it = s.split(':');
        let head = it.next().unwrap_or("");
        let args: Vec<&str> = it.collect();
        let at_most = |n: usize| -> Result<()> {
            if args.len() > n {
                bail!(
                    "schedule `{head}` takes at most {n} parameter(s), \
                     got `{s}`"
                );
            }
            Ok(())
        };
        let out = match head {
            "sync" | "synchronous" => {
                at_most(0)?;
                BpSchedule::Synchronous
            }
            "residual" => {
                at_most(0)?;
                BpSchedule::Residual
            }
            "stale" | "stale-residual" => {
                at_most(0)?;
                BpSchedule::StaleResidual
            }
            "bucketed" => {
                at_most(1)?;
                let bins = match args.first() {
                    Some(b) => b.parse::<u32>().map_err(|_| {
                        anyhow::anyhow!(
                            "bucketed bin count `{b}` is not an integer"
                        )
                    })?,
                    None => DEFAULT_BUCKET_BINS,
                };
                BpSchedule::Bucketed { bins }
            }
            "random" | "randomized" => {
                at_most(2)?;
                let p = match args.first() {
                    Some(p) => p.parse::<f32>().map_err(|_| {
                        anyhow::anyhow!(
                            "randomized keep probability `{p}` is not \
                             a number"
                        )
                    })?,
                    None => DEFAULT_SUBSET_P,
                };
                let seed = match args.get(1) {
                    Some(s) => s.parse::<u64>().map_err(|_| {
                        anyhow::anyhow!(
                            "randomized seed `{s}` is not an integer"
                        )
                    })?,
                    None => DEFAULT_SUBSET_SEED,
                };
                BpSchedule::RandomizedSubset { p, seed }
            }
            _ => bail!(
                "unknown bp schedule `{s}` \
                 (sync|residual|stale|bucketed[:bins]|random[:p[:seed]])"
            ),
        };
        out.validate()?;
        Ok(out)
    }

    /// Parameter bounds, shared by the CLI parse path and
    /// `RunConfig::validate` (programmatic construction).
    pub fn validate(&self) -> Result<()> {
        match *self {
            BpSchedule::Bucketed { bins } => {
                if !(2..=MAX_BUCKET_BINS).contains(&bins) {
                    bail!(
                        "bucketed bin count must be in \
                         [2, {MAX_BUCKET_BINS}], got {bins}: one bin \
                         degenerates to the synchronous schedule"
                    );
                }
            }
            BpSchedule::RandomizedSubset { p, .. } => {
                if !(p > 0.0 && p <= 1.0) {
                    bail!(
                        "randomized keep probability must be in \
                         (0, 1], got {p}: 0 never commits anything"
                    );
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Policy family name (parameter-free): engine names and
    /// flight-recorder samples.
    pub fn name(&self) -> &'static str {
        match self {
            BpSchedule::Synchronous => "sync",
            BpSchedule::Residual => "residual",
            BpSchedule::StaleResidual => "stale",
            BpSchedule::Bucketed { .. } => "bucketed",
            BpSchedule::RandomizedSubset { .. } => "random",
        }
    }

    /// Canonical spelling, parameters included: `parse(spec()) ==
    /// *self`. This is what the JSON config and the run report carry.
    pub fn spec(&self) -> String {
        match *self {
            BpSchedule::Bucketed { bins } => format!("bucketed:{bins}"),
            BpSchedule::RandomizedSubset { p, seed } => {
                format!("random:{p}:{seed}")
            }
            other => other.name().to_string(),
        }
    }
}

/// Scheduling statistics of one BP engine run, surfaced through
/// `EmResult` into the run report (present-but-null for every other
/// engine family — see `tests/report_schema.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpStats {
    /// The frontier policy that produced the run.
    pub schedule: BpSchedule,
    /// Mean fraction of directed messages committed per sweep across
    /// the run — 1.0 under `Synchronous` by construction, strictly
    /// below 1.0 when a relaxed policy actually relaxes.
    pub committed_frac: f64,
}

/// Belief-propagation hyperparameters (CLI: `--bp-*`; JSON: `"bp"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpConfig {
    /// Fraction of the old message kept per update (0 = no damping).
    pub damping: f32,
    /// Maximum message sweeps per EM iteration.
    pub max_sweeps: usize,
    /// Convergence: stop sweeping when the max residual drops below.
    pub tol: f32,
    pub schedule: BpSchedule,
    /// `Residual`/`StaleResidual` only: commit messages with
    /// `residual >= frontier * max_residual` (exact or stale max
    /// respectively). 0 commits everything (synchronous), 1 commits
    /// only the maximal-residual messages.
    pub frontier: f32,
}

impl Default for BpConfig {
    fn default() -> Self {
        BpConfig {
            damping: 0.5,
            max_sweeps: 50,
            tol: 1e-3,
            schedule: BpSchedule::default(),
            frontier: 0.5,
        }
    }
}

/// One-shot solve for tests and playgrounds: build the edge structure,
/// run BP to convergence under `prm`, decode labels.
pub fn solve(
    bk: &dyn crate::dpp::Device,
    model: &crate::mrf::MrfModel,
    prm: &crate::mrf::Params,
    cfg: &BpConfig,
) -> (Vec<u8>, BpRun) {
    let g = BpGraph::build(bk, model, prm.beta);
    let unary = sweep::unaries(bk, model, prm);
    let mut st = BpState::new(g.num_edges(), model.num_vertices());
    let run = sweep::run(bk, model, &g, &unary, &mut st, cfg, false, 0);
    let mut labels = vec![0u8; model.num_vertices()];
    sweep::decode(bk, model, &g, &unary, &mut st, &mut labels);
    (labels, run)
}

/// The whole frontier-policy family with representative parameters —
/// one list for the per-policy test batteries instead of per-file
/// copies.
#[cfg(test)]
pub(crate) const ALL_SCHEDULES: [BpSchedule; 5] = [
    BpSchedule::Synchronous,
    BpSchedule::Residual,
    BpSchedule::StaleResidual,
    BpSchedule::Bucketed { bins: 8 },
    BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
];

/// Shared small test fixture: a noisy porous slice, oversegmented and
/// model-built serially. One definition for every bp submodule test
/// (and `mrf`'s `config_energy` test) instead of per-file copies.
#[cfg(test)]
pub(crate) fn test_model(seed: u64) -> crate::mrf::MrfModel {
    let v =
        crate::image::synth::porous_ground_truth(48, 48, 1, 0.42, seed);
    let mut input = v.clone();
    crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
    let seg = crate::overseg::oversegment(
        &crate::dpp::Backend::Serial,
        &input.slice(0),
        &crate::config::OversegConfig { scale: 64.0, min_region: 4 },
    );
    crate::mrf::build_model_serial(&seg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_round_trip() {
        for s in ["sync", "residual", "stale"] {
            assert_eq!(BpSchedule::parse(s).unwrap().name(), s);
        }
        assert_eq!(BpSchedule::parse("synchronous").unwrap(),
                   BpSchedule::Synchronous);
        assert_eq!(BpSchedule::parse("stale-residual").unwrap(),
                   BpSchedule::StaleResidual);
        assert!(BpSchedule::parse("chaotic").is_err());
    }

    #[test]
    fn parameterized_specs_round_trip() {
        for s in ["sync", "residual", "stale", "bucketed:4",
                  "bucketed:63", "random:0.25:9", "random:1:0"] {
            let sched = BpSchedule::parse(s).unwrap();
            assert_eq!(BpSchedule::parse(&sched.spec()).unwrap(), sched,
                       "spec {s}");
        }
        // Defaults fill omitted parameters.
        assert_eq!(
            BpSchedule::parse("bucketed").unwrap(),
            BpSchedule::Bucketed { bins: DEFAULT_BUCKET_BINS }
        );
        assert_eq!(
            BpSchedule::parse("random").unwrap(),
            BpSchedule::RandomizedSubset {
                p: DEFAULT_SUBSET_P,
                seed: DEFAULT_SUBSET_SEED,
            }
        );
        assert_eq!(
            BpSchedule::parse("random:0.75").unwrap(),
            BpSchedule::RandomizedSubset {
                p: 0.75,
                seed: DEFAULT_SUBSET_SEED,
            }
        );
    }

    #[test]
    fn invalid_schedule_parameters_are_rejected() {
        for bad in ["bucketed:1", "bucketed:0", "bucketed:64",
                    "bucketed:x", "random:0", "random:-0.5", "random:1.5",
                    "random:nope", "random:0.5:notanint",
                    "sync:extra", "stale:extra", "random:0.5:1:extra"] {
            assert!(BpSchedule::parse(bad).is_err(), "should reject {bad}");
        }
        assert!(BpSchedule::Bucketed { bins: 1 }.validate().is_err());
        assert!(BpSchedule::RandomizedSubset { p: 0.0, seed: 1 }
            .validate()
            .is_err());
        assert!(BpSchedule::RandomizedSubset { p: f32::NAN, seed: 1 }
            .validate()
            .is_err());
    }

    #[test]
    fn default_config_is_sane() {
        let c = BpConfig::default();
        assert!(c.damping >= 0.0 && c.damping < 1.0);
        assert!(c.frontier >= 0.0 && c.frontier <= 1.0);
        assert!(c.max_sweeps >= 1);
        assert!(c.tol > 0.0);
        for sched in ALL_SCHEDULES {
            sched.validate().unwrap();
        }
    }
}
