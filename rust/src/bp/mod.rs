//! Max-product loopy belief propagation over the region graph
//! (DESIGN.md §6) — a second optimizer for [`crate::mrf::MrfModel`]
//! beside the EM/MAP engines, expressed entirely in the DPP vocabulary
//! of [`crate::dpp`].
//!
//! The pairwise reformulation of the hood energy (DESIGN.md §5): unary
//! energies are the Gaussian data term of [`crate::mrf::energy`]
//! weighted by each vertex's hood multiplicity, and the Potts coupling
//! between adjacent regions is weighted by how many hoods contain both
//! endpoints ([`messages::BpGraph`]). Min-sum messages (max-product in
//! the log domain) live in one flat edge-major `Vec<f32>` indexed by
//! the CSR adjacency; one sweep is
//!
//! 1. **Gather** reverse-edge messages + **segmented reduce** per
//!    vertex -> beliefs,
//! 2. **Map** over directed edges -> damped candidate messages and
//!    per-message residuals,
//! 3. **Reduce⟨Max⟩** over residuals, then a **Map** commit of the
//!    residual frontier (Van der Merwe et al. 2019: updating only the
//!    high-residual messages each round converges in far fewer message
//!    updates than the synchronous schedule).
//!
//! All of it fused: the vertex segments come from the
//! [`crate::dpp::SegmentPlan`] cached in [`messages::BpGraph`] (CSR
//! rows — no per-sweep sort or key compare), and one sweep runs as a
//! single [`crate::dpp::Pipeline`] region — phase barriers between the
//! passes instead of one pool fork-join per pass.
//!
//! Modules: [`messages`] (edge layout + reverse index + Potts weights),
//! [`sweep`] (synchronous and residual-scheduled sweeps on a
//! [`crate::dpp::Device`]), [`serial`] (plain-loop oracle for tests),
//! [`engine`] ([`BpEngine`], an [`crate::mrf::Engine`] running BP as
//! the E-step inside the shared EM outer loop).
//!
//! Every pass is deterministic across backends and thread counts: the
//! only floating-point reduction is an exact `max`, and per-vertex /
//! per-edge arithmetic runs in a fixed order. BP with any backend is
//! therefore bitwise-reproducible — stronger than the MAP engines'
//! chunk-order-dependent parameter reductions.

pub mod engine;
pub mod messages;
pub mod serial;
pub mod sweep;

pub use engine::BpEngine;
pub use messages::BpGraph;
pub use sweep::{BpRun, BpState, SweepStats};

use anyhow::{bail, Result};

/// Message-update schedule for one BP round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BpSchedule {
    /// Jacobi: every message recomputed and committed each round.
    Synchronous,
    /// Residual frontier: every candidate is computed, but only
    /// messages whose residual reaches `frontier * max_residual`
    /// commit this round (the top of the residual distribution).
    #[default]
    Residual,
}

impl BpSchedule {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sync" | "synchronous" => Ok(BpSchedule::Synchronous),
            "residual" => Ok(BpSchedule::Residual),
            _ => bail!("unknown bp schedule `{s}` (sync|residual)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BpSchedule::Synchronous => "sync",
            BpSchedule::Residual => "residual",
        }
    }
}

/// Belief-propagation hyperparameters (CLI: `--bp-*`; JSON: `"bp"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BpConfig {
    /// Fraction of the old message kept per update (0 = no damping).
    pub damping: f32,
    /// Maximum message sweeps per EM iteration.
    pub max_sweeps: usize,
    /// Convergence: stop sweeping when the max residual drops below.
    pub tol: f32,
    pub schedule: BpSchedule,
    /// Residual schedule only: commit messages with
    /// `residual >= frontier * max_residual`. 0 commits everything
    /// (synchronous), 1 commits only the maximal-residual messages.
    pub frontier: f32,
}

impl Default for BpConfig {
    fn default() -> Self {
        BpConfig {
            damping: 0.5,
            max_sweeps: 50,
            tol: 1e-3,
            schedule: BpSchedule::default(),
            frontier: 0.5,
        }
    }
}

/// One-shot solve for tests and playgrounds: build the edge structure,
/// run BP to convergence under `prm`, decode labels.
pub fn solve(
    bk: &dyn crate::dpp::Device,
    model: &crate::mrf::MrfModel,
    prm: &crate::mrf::Params,
    cfg: &BpConfig,
) -> (Vec<u8>, BpRun) {
    let g = BpGraph::build(bk, model, prm.beta);
    let unary = sweep::unaries(bk, model, prm);
    let mut st = BpState::new(g.num_edges(), model.num_vertices());
    let run = sweep::run(bk, model, &g, &unary, &mut st, cfg, false, 0);
    let mut labels = vec![0u8; model.num_vertices()];
    sweep::decode(bk, model, &g, &unary, &mut st, &mut labels);
    (labels, run)
}

/// Shared small test fixture: a noisy porous slice, oversegmented and
/// model-built serially. One definition for every bp submodule test
/// (and `mrf`'s `config_energy` test) instead of per-file copies.
#[cfg(test)]
pub(crate) fn test_model(seed: u64) -> crate::mrf::MrfModel {
    let v =
        crate::image::synth::porous_ground_truth(48, 48, 1, 0.42, seed);
    let mut input = v.clone();
    crate::image::noise::additive_gaussian(&mut input, 60.0, seed);
    let seg = crate::overseg::oversegment(
        &crate::dpp::Backend::Serial,
        &input.slice(0),
        &crate::config::OversegConfig { scale: 64.0, min_region: 4 },
    );
    crate::mrf::build_model_serial(&seg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_parse_round_trip() {
        for s in ["sync", "residual"] {
            assert_eq!(BpSchedule::parse(s).unwrap().name(), s);
        }
        assert_eq!(BpSchedule::parse("synchronous").unwrap(),
                   BpSchedule::Synchronous);
        assert!(BpSchedule::parse("chaotic").is_err());
    }

    #[test]
    fn default_config_is_sane() {
        let c = BpConfig::default();
        assert!(c.damping >= 0.0 && c.damping < 1.0);
        assert!(c.frontier >= 0.0 && c.frontier <= 1.0);
        assert!(c.max_sweeps >= 1);
        assert!(c.tol > 0.0);
    }
}
