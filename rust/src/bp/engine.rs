//! [`BpEngine`] — loopy BP as the E-step of the shared EM outer loop,
//! a drop-in [`Engine`] beside the MAP engines (DESIGN.md §3, §6).
//!
//! Per EM iteration: refresh the unaries from the current (mu, sigma),
//! run message sweeps to convergence (messages warm-start from the
//! previous EM iteration), decode per-vertex labels from the beliefs,
//! score the labeling with the shared hood energy
//! ([`crate::mrf::config_energy`]) for the convergence window, and
//! re-estimate (mu, sigma) from the hood-member instances exactly as
//! the MAP engines do. `EmResult::map_iters` reports total BP sweeps,
//! making iteration counts comparable in `benches/bp_vs_map.rs`.

use std::sync::Arc;

use crate::config::MrfConfig;
use crate::dpp::{Device, DeviceExt, IntoDevice, Workspace,
                 WorkspaceStats};
use crate::mrf::{self, params, ConvergenceWindow, Engine, EmResult,
                 MrfModel};

use super::messages::BpGraph;
use super::sweep::{self, BpState};
use super::{BpConfig, BpSchedule, BpStats};

pub struct BpEngine {
    device: Arc<dyn Device>,
    pub bp: BpConfig,
    /// Scratch pool for per-EM-iteration tensors (unaries, scoring
    /// buffers); one per engine, so each scheduler lane's BP engine
    /// amortizes buffers across its slices (DESIGN.md §10).
    ws: Workspace,
}

impl BpEngine {
    /// Engine on any device — accepts a concrete device, an
    /// `Arc<dyn Device>`, or the deprecated `Backend` spelling.
    pub fn new(device: impl IntoDevice, bp: BpConfig) -> Self {
        BpEngine { device: device.into_device(), bp,
                   ws: Workspace::new() }
    }

    /// The device every sweep of this engine executes on.
    pub fn device(&self) -> &Arc<dyn Device> {
        &self.device
    }

    /// Counters of the engine-held scratch pool (see
    /// [`crate::dpp::Workspace::stats`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::bp::{BpConfig, BpEngine};
    /// use dpp_pmrf::dpp::SerialDevice;
    /// let engine = BpEngine::new(SerialDevice, BpConfig::default());
    /// assert_eq!(engine.workspace_stats().misses, 0);
    /// ```
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.stats()
    }
}

impl Engine for BpEngine {
    fn name(&self) -> &'static str {
        match self.bp.schedule {
            BpSchedule::Synchronous => "bp-sync",
            BpSchedule::Residual => "bp",
            BpSchedule::StaleResidual => "bp-stale",
            BpSchedule::Bucketed { .. } => "bp-bucketed",
            BpSchedule::RandomizedSubset { .. } => "bp-random",
        }
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let bk: &dyn Device = &*self.device;
        let nv = model.num_vertices();
        let g = BpGraph::build(bk, model, cfg.beta as f32);
        let y_elem = model.y_elems();

        // Same seeded init as every other engine; BP ignores the
        // initial labels (messages start at zero) but shares the
        // initial parameters, so class polarity matches.
        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);
        let mut st = BpState::new(g.num_edges(), nv);

        let mut em_window = ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_sweeps = 0usize;
        let mut total_updated = 0usize;
        let mut em_iters = 0usize;
        // One unary buffer for the whole run: refreshed in place per
        // EM iteration (allocation-free after the first).
        let mut unary = self.ws.take_spare::<f32>(2 * nv);

        for _em in 0..cfg.em_iters {
            // Inert unless a tracer is armed (see telemetry::span).
            let _em_span = crate::telemetry::span_arg(
                "em", "em_iter", "iter", em_iters as u64,
            );
            em_iters += 1;

            sweep::unaries_into(bk, model, &prm, &mut unary);
            let bp_run = sweep::run(
                bk, model, &g, &unary, &mut st, &self.bp, cfg.fixed_iters,
                em_iters - 1,
            );
            total_sweeps += bp_run.sweeps;
            total_updated += bp_run.updated_total;
            sweep::decode(bk, model, &g, &unary, &mut st, &mut labels);

            // Score with the shared hood energy (histories directly
            // comparable to the MAP engines') and collect the M-step
            // statistics, both in one parallel pass over workspace
            // scratch.
            let (total, stats) = score_and_stats(
                bk, &self.ws, model, &labels, &prm, &y_elem,
            );
            prm = params::update(&stats, cfg.beta as f32);

            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }
        self.ws.publish_timing();

        // Mean committed fraction across the whole run: how much the
        // frontier policy actually relaxed (1.0 for Synchronous).
        let committed_frac = total_updated as f64
            / (total_sweeps.max(1) * g.num_edges().max(1)) as f64;

        EmResult {
            labels,
            em_iters,
            map_iters: total_sweeps,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: None,
            bp: Some(BpStats {
                schedule: self.bp.schedule,
                committed_frac,
            }),
        }
    }
}

/// Fused scoring pass over the static hood segments: the hood energy
/// of the labeling (bitwise-equal to [`mrf::config_energy`]) plus the
/// per-label parameter statistics, one parallel sweep instead of three
/// serial ones. Deterministic across backends and thread counts: each
/// hood accumulates sequentially inside one chunk iteration, and the
/// cross-hood merges run serially in hood order.
fn score_and_stats(
    bk: &dyn Device,
    ws: &Workspace,
    model: &MrfModel,
    labels: &[u8],
    prm: &mrf::Params,
    y_elem: &[f32],
) -> (f64, params::Stats) {
    use crate::dpp::core::SharedSlice;

    let h = &model.hoods;
    let nh = h.num_hoods();
    let n = h.num_elements();
    let pp = mrf::energy::Prepared::from_params(prm);
    // Hood-unit grain scaled from the element grain (as in mrf::dpp).
    let hood_grain = (bk.grain() / (n / nh.max(1)).max(1)).max(1);

    let mut hood_energy = ws.take::<f64>(nh);
    let mut hood_stats = ws.take::<params::Stats>(nh);
    {
        let we = SharedSlice::new(&mut hood_energy[..]);
        let wst = SharedSlice::new(&mut hood_stats[..]);
        bk.for_chunks_with(nh, hood_grain, |hs, he| {
            for hd in hs..he {
                let (s, e) =
                    (h.offsets[hd] as usize, h.offsets[hd + 1] as usize);
                let sum = mrf::hood_label_energy(
                    &h.members[s..e], &model.y, labels, &pp,
                );
                let mut st = params::Stats::default();
                for el in s..e {
                    st.add(labels[h.members[el] as usize], y_elem[el]);
                }
                unsafe {
                    we.write(hd, sum);
                    wst.write(hd, st);
                }
            }
        });
    }
    let total = hood_energy.iter().sum();
    let mut stats = params::Stats::default();
    for st in hood_stats.iter() {
        stats.merge(st);
    }
    (total, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn bp_engine_deterministic_across_backends_and_runs() {
        let model = small_model(51);
        let cfg = MrfConfig::default();
        for schedule in crate::bp::ALL_SCHEDULES {
            let bp = BpConfig { schedule, ..Default::default() };
            let a = BpEngine::new(Backend::Serial, bp).run(&model, &cfg);
            let b = BpEngine::new(Backend::Serial, bp).run(&model, &cfg);
            assert_eq!(a, b, "{schedule:?}: rerun identical");
            let c = BpEngine::new(
                Backend::threaded_with_grain(Pool::new(4), 64),
                bp,
            )
            .run(&model, &cfg);
            assert_eq!(a, c, "{schedule:?}: backend independent");
        }
    }

    #[test]
    fn bp_energy_close_to_serial_map_engine() {
        let model = small_model(52);
        let cfg = MrfConfig::default();
        let map = crate::mrf::serial::SerialEngine.run(&model, &cfg);
        let bp = BpEngine::new(Backend::Serial, BpConfig::default())
            .run(&model, &cfg);
        assert!(bp.labels.iter().all(|&l| l <= 1));
        let rel = (bp.energy - map.energy).abs() / map.energy.abs().max(1.0);
        assert!(rel < 0.05, "bp {} vs map {} (rel {rel})",
                bp.energy, map.energy);
        let agree = bp
            .labels
            .iter()
            .zip(&map.labels)
            .filter(|(a, b)| a == b)
            .count() as f64
            / map.labels.len() as f64;
        assert!(agree > 0.9, "label agreement {agree}");
    }

    #[test]
    fn fixed_iters_runs_exact_em_and_sweep_counts() {
        let model = small_model(53);
        let cfg = MrfConfig {
            em_iters: 3,
            fixed_iters: true,
            ..Default::default()
        };
        let bp = BpConfig { max_sweeps: 5, ..Default::default() };
        let res = BpEngine::new(Backend::Serial, bp).run(&model, &cfg);
        assert_eq!(res.em_iters, 3);
        assert_eq!(res.map_iters, 15, "3 EM x 5 sweeps");
    }

    #[test]
    fn score_matches_config_energy_bitwise() {
        let model = small_model(55);
        let prm = crate::mrf::Params {
            mu: [60.0, 180.0],
            sigma: [25.0, 25.0],
            beta: 0.5,
        };
        let labels: Vec<u8> =
            (0..model.num_vertices()).map(|v| (v % 2) as u8).collect();
        let y_elem = model.y_elems();
        let (_, want) = mrf::config_energy(&model, &labels, &prm);
        let ws = Workspace::new();
        for bk in [
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 64),
        ] {
            let (total, stats) =
                score_and_stats(&bk, &ws, &model, &labels, &prm, &y_elem);
            assert_eq!(total, want, "bitwise-equal energy ({bk:?})");
            let n: f64 = stats.acc[0][0] + stats.acc[1][0];
            assert_eq!(n, model.hoods.num_elements() as f64);
        }
    }

    #[test]
    fn engine_reports_schedule_and_committed_fraction() {
        let model = small_model(56);
        let cfg = MrfConfig::default();
        let sync = BpEngine::new(
            Backend::Serial,
            BpConfig { schedule: BpSchedule::Synchronous,
                       ..Default::default() },
        )
        .run(&model, &cfg);
        let stats = sync.bp.expect("bp engine always reports BpStats");
        assert_eq!(stats.schedule, BpSchedule::Synchronous);
        assert_eq!(stats.committed_frac, 1.0,
                   "synchronous commits everything by construction");
        for schedule in [
            BpSchedule::Residual,
            BpSchedule::StaleResidual,
            BpSchedule::Bucketed { bins: 8 },
            BpSchedule::RandomizedSubset { p: 0.5, seed: 7 },
        ] {
            let res = BpEngine::new(
                Backend::Serial,
                BpConfig { schedule, ..Default::default() },
            )
            .run(&model, &cfg);
            let stats = res.bp.expect("BpStats present");
            assert_eq!(stats.schedule, schedule);
            assert!(stats.committed_frac > 0.0
                        && stats.committed_frac < 1.0,
                    "{schedule:?} relaxes: {}", stats.committed_frac);
        }
    }

    #[test]
    fn engine_names_distinguish_every_policy_family() {
        let mut names = std::collections::BTreeSet::new();
        for schedule in crate::bp::ALL_SCHEDULES {
            let e = BpEngine::new(
                Backend::Serial,
                BpConfig { schedule, ..Default::default() },
            );
            names.insert(e.name());
        }
        assert_eq!(names.len(), crate::bp::ALL_SCHEDULES.len());
    }

    #[test]
    fn residual_schedule_needs_no_more_sweeps_budget() {
        // Smoke check on the Van der Merwe claim at our scale: the
        // residual schedule converges within the same sweep budget
        // while committing fewer message updates per round.
        let model = small_model(54);
        let cfg = MrfConfig::default();
        let sync = BpEngine::new(
            Backend::Serial,
            BpConfig { schedule: BpSchedule::Synchronous,
                       ..Default::default() },
        )
        .run(&model, &cfg);
        let res = BpEngine::new(
            Backend::Serial,
            BpConfig { schedule: BpSchedule::Residual,
                       ..Default::default() },
        )
        .run(&model, &cfg);
        let rel = (sync.energy - res.energy).abs()
            / sync.energy.abs().max(1.0);
        assert!(rel < 0.05, "schedules agree on energy (rel {rel})");
    }
}
