//! Serial BP oracle — straight loops, no primitives, no chunking.
//!
//! Implements exactly the math of [`super::sweep`] (same per-edge
//! update, same normalization, damping, frontier policies and
//! tie-breaks) so tests can require *bitwise* equality against the DPP
//! sweeps on any backend: the only cross-chunk reductions in the DPP
//! path are an exact `max` and a bitmask `or`, and every relaxed
//! commit rule ([`BpSchedule::StaleResidual`]'s previous-sweep
//! threshold, [`BpSchedule::Bucketed`]'s log2 bucket compare,
//! [`BpSchedule::RandomizedSubset`]'s position-keyed coin flips — the
//! very same [`super::sweep`] helpers) is a pure function of
//! (position, sweep index), so no floating-point slack is needed for
//! any policy.

use crate::mrf::{energy, MrfModel, Params};

use super::messages::BpGraph;
use super::sweep::{residual_bin, subset_keeps};
use super::{BpConfig, BpSchedule};

/// Full serial BP run: returns (messages, labels, sweeps executed).
pub fn run_serial(
    model: &MrfModel,
    g: &BpGraph,
    prm: &Params,
    cfg: &BpConfig,
    fixed: bool,
) -> (Vec<f32>, Vec<u8>, usize) {
    let nv = model.num_vertices();
    let ne = g.num_edges();
    let unary = unaries_serial(model, prm);
    let mut msg = vec![0.0f32; 2 * ne];
    let mut belief = vec![0.0f32; 2 * nv];
    let mut cand = vec![0.0f32; 2 * ne];
    let mut resid = vec![0.0f32; ne];

    let max_sweeps = cfg.max_sweeps.max(1);
    let mut sweeps = 0usize;
    // Schedule clocks, mirroring a fresh `BpState`: the stale
    // threshold starts with no previous max (sweep 1 commits
    // everything) and the randomized coin stream starts at round 0.
    let mut stale_max: Option<f32> = None;
    let mut round = 0u64;
    for _ in 0..max_sweeps {
        sweeps += 1;
        beliefs_serial(model, g, &unary, &msg, &mut belief);
        let mut r_max = 0.0f32;
        for ed in 0..ne {
            let u = g.src[ed] as usize;
            let r = g.rev[ed] as usize;
            let h0 = belief[2 * u] - msg[2 * r];
            let h1 = belief[2 * u + 1] - msg[2 * r + 1];
            let w = g.weight[ed];
            let mut c0 = h0.min(h1 + w);
            let mut c1 = h1.min(h0 + w);
            let norm = c0.min(c1);
            c0 -= norm;
            c1 -= norm;
            let n0 = cfg.damping * msg[2 * ed] + (1.0 - cfg.damping) * c0;
            let n1 =
                cfg.damping * msg[2 * ed + 1] + (1.0 - cfg.damping) * c1;
            let rr = (n0 - msg[2 * ed])
                .abs()
                .max((n1 - msg[2 * ed + 1]).abs());
            cand[2 * ed] = n0;
            cand[2 * ed + 1] = n1;
            resid[ed] = rr;
            r_max = r_max.max(rr);
        }
        // The frontier policy, in plain loops (DESIGN.md §15).
        match cfg.schedule {
            BpSchedule::Synchronous => {
                for ed in 0..ne {
                    msg[2 * ed] = cand[2 * ed];
                    msg[2 * ed + 1] = cand[2 * ed + 1];
                }
            }
            BpSchedule::Residual => {
                let tau = cfg.frontier * r_max;
                commit_threshold(&mut msg, &cand, &resid, tau);
            }
            BpSchedule::StaleResidual => {
                let tau = stale_max.map_or(0.0, |m| cfg.frontier * m);
                commit_threshold(&mut msg, &cand, &resid, tau);
            }
            BpSchedule::Bucketed { bins } => {
                let top = resid
                    .iter()
                    .filter_map(|&rr| residual_bin(rr, cfg.tol, bins))
                    .max();
                for ed in 0..ne {
                    let keep = match top {
                        // Everything below tol: commit all, exactly
                        // like the DPP path's empty-mask sentinel.
                        None => true,
                        Some(t) => residual_bin(resid[ed], cfg.tol, bins)
                            .is_some_and(|b| b >= t),
                    };
                    if keep {
                        msg[2 * ed] = cand[2 * ed];
                        msg[2 * ed + 1] = cand[2 * ed + 1];
                    }
                }
            }
            BpSchedule::RandomizedSubset { p, seed } => {
                for ed in 0..ne {
                    if subset_keeps(seed, round, ed, p) {
                        msg[2 * ed] = cand[2 * ed];
                        msg[2 * ed + 1] = cand[2 * ed + 1];
                    }
                }
            }
        }
        stale_max = Some(r_max);
        round += 1;
        if r_max < cfg.tol && !fixed {
            break;
        }
    }

    beliefs_serial(model, g, &unary, &msg, &mut belief);
    let labels: Vec<u8> = (0..nv)
        .map(|v| u8::from(belief[2 * v + 1] < belief[2 * v]))
        .collect();
    (msg, labels, sweeps)
}

fn commit_threshold(
    msg: &mut [f32],
    cand: &[f32],
    resid: &[f32],
    tau: f32,
) {
    for (ed, &rr) in resid.iter().enumerate() {
        if rr >= tau {
            msg[2 * ed] = cand[2 * ed];
            msg[2 * ed + 1] = cand[2 * ed + 1];
        }
    }
}

fn unaries_serial(model: &MrfModel, prm: &Params) -> Vec<f32> {
    let pp = energy::Prepared::from_params(prm);
    let h = &model.hoods;
    let nv = model.num_vertices();
    let mut out = vec![0.0f32; 2 * nv];
    for v in 0..nv {
        let k = (h.vert_offsets[v + 1] - h.vert_offsets[v]).max(1) as f32;
        let d0 = model.y[v] - pp.mu[0];
        let d1 = model.y[v] - pp.mu[1];
        out[2 * v] = k * (d0 * d0 * pp.inv2s[0] + pp.lns[0]);
        out[2 * v + 1] = k * (d1 * d1 * pp.inv2s[1] + pp.lns[1]);
    }
    out
}

fn beliefs_serial(
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    msg: &[f32],
    belief: &mut [f32],
) {
    let offsets = &model.graph.offsets;
    for v in 0..model.num_vertices() {
        let mut b0 = unary[2 * v];
        let mut b1 = unary[2 * v + 1];
        for ed in offsets[v] as usize..offsets[v + 1] as usize {
            let r = g.rev[ed] as usize;
            b0 += msg[2 * r];
            b1 += msg[2 * r + 1];
        }
        belief[2 * v] = b0;
        belief[2 * v + 1] = b1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::bp::ALL_SCHEDULES;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn oracle_matches_dpp_sweeps_bitwise_on_both_backends() {
        let model = small_model(41);
        let prm = Params { mu: [60.0, 180.0], sigma: [25.0, 25.0],
                           beta: 0.5 };
        for schedule in ALL_SCHEDULES {
            let cfg = BpConfig { schedule, ..Default::default() };
            let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
            let (want_msg, want_labels, want_sweeps) =
                run_serial(&model, &g, &prm, &cfg, false);
            for bk in [
                Backend::Serial,
                Backend::threaded_with_grain(Pool::new(4), 64),
            ] {
                let unary = super::super::sweep::unaries(&bk, &model, &prm);
                let mut st = super::super::sweep::BpState::new(
                    g.num_edges(),
                    model.num_vertices(),
                );
                let run = super::super::sweep::run(
                    &bk, &model, &g, &unary, &mut st, &cfg, false, 0,
                );
                let mut labels = vec![0u8; model.num_vertices()];
                super::super::sweep::decode(
                    &bk, &model, &g, &unary, &mut st, &mut labels,
                );
                assert_eq!(st.msg, want_msg, "{schedule:?} messages {bk:?}");
                assert_eq!(labels, want_labels, "{schedule:?} labels");
                assert_eq!(run.sweeps, want_sweeps, "{schedule:?} sweeps");
            }
        }
    }
}
