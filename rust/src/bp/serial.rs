//! Serial BP oracle — straight loops, no primitives, no chunking.
//!
//! Implements exactly the math of [`super::sweep`] (same per-edge
//! update, same normalization, damping, frontier rule and tie-breaks)
//! so tests can require *bitwise* equality against the DPP sweeps on
//! any backend: the only cross-chunk reduction in the DPP path is an
//! exact `max`, so no floating-point slack is needed.

use crate::mrf::{energy, MrfModel, Params};

use super::messages::BpGraph;
use super::{BpConfig, BpSchedule};

/// Full serial BP run: returns (messages, labels, sweeps executed).
pub fn run_serial(
    model: &MrfModel,
    g: &BpGraph,
    prm: &Params,
    cfg: &BpConfig,
    fixed: bool,
) -> (Vec<f32>, Vec<u8>, usize) {
    let nv = model.num_vertices();
    let ne = g.num_edges();
    let unary = unaries_serial(model, prm);
    let mut msg = vec![0.0f32; 2 * ne];
    let mut belief = vec![0.0f32; 2 * nv];
    let mut cand = vec![0.0f32; 2 * ne];
    let mut resid = vec![0.0f32; ne];

    let max_sweeps = cfg.max_sweeps.max(1);
    let mut sweeps = 0usize;
    for _ in 0..max_sweeps {
        sweeps += 1;
        beliefs_serial(model, g, &unary, &msg, &mut belief);
        let mut r_max = 0.0f32;
        for ed in 0..ne {
            let u = g.src[ed] as usize;
            let r = g.rev[ed] as usize;
            let h0 = belief[2 * u] - msg[2 * r];
            let h1 = belief[2 * u + 1] - msg[2 * r + 1];
            let w = g.weight[ed];
            let mut c0 = h0.min(h1 + w);
            let mut c1 = h1.min(h0 + w);
            let norm = c0.min(c1);
            c0 -= norm;
            c1 -= norm;
            let n0 = cfg.damping * msg[2 * ed] + (1.0 - cfg.damping) * c0;
            let n1 =
                cfg.damping * msg[2 * ed + 1] + (1.0 - cfg.damping) * c1;
            let rr = (n0 - msg[2 * ed])
                .abs()
                .max((n1 - msg[2 * ed + 1]).abs());
            cand[2 * ed] = n0;
            cand[2 * ed + 1] = n1;
            resid[ed] = rr;
            r_max = r_max.max(rr);
        }
        let tau = match cfg.schedule {
            BpSchedule::Synchronous => 0.0,
            BpSchedule::Residual => cfg.frontier * r_max,
        };
        for ed in 0..ne {
            if resid[ed] >= tau {
                msg[2 * ed] = cand[2 * ed];
                msg[2 * ed + 1] = cand[2 * ed + 1];
            }
        }
        if r_max < cfg.tol && !fixed {
            break;
        }
    }

    beliefs_serial(model, g, &unary, &msg, &mut belief);
    let labels: Vec<u8> = (0..nv)
        .map(|v| u8::from(belief[2 * v + 1] < belief[2 * v]))
        .collect();
    (msg, labels, sweeps)
}

fn unaries_serial(model: &MrfModel, prm: &Params) -> Vec<f32> {
    let pp = energy::Prepared::from_params(prm);
    let h = &model.hoods;
    let nv = model.num_vertices();
    let mut out = vec![0.0f32; 2 * nv];
    for v in 0..nv {
        let k = (h.vert_offsets[v + 1] - h.vert_offsets[v]).max(1) as f32;
        let d0 = model.y[v] - pp.mu[0];
        let d1 = model.y[v] - pp.mu[1];
        out[2 * v] = k * (d0 * d0 * pp.inv2s[0] + pp.lns[0]);
        out[2 * v + 1] = k * (d1 * d1 * pp.inv2s[1] + pp.lns[1]);
    }
    out
}

fn beliefs_serial(
    model: &MrfModel,
    g: &BpGraph,
    unary: &[f32],
    msg: &[f32],
    belief: &mut [f32],
) {
    let offsets = &model.graph.offsets;
    for v in 0..model.num_vertices() {
        let mut b0 = unary[2 * v];
        let mut b1 = unary[2 * v + 1];
        for ed in offsets[v] as usize..offsets[v + 1] as usize {
            let r = g.rev[ed] as usize;
            b0 += msg[2 * r];
            b1 += msg[2 * r + 1];
        }
        belief[2 * v] = b0;
        belief[2 * v + 1] = b1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::test_model as small_model;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn oracle_matches_dpp_sweeps_bitwise_on_both_backends() {
        let model = small_model(41);
        let prm = Params { mu: [60.0, 180.0], sigma: [25.0, 25.0],
                           beta: 0.5 };
        for schedule in [BpSchedule::Synchronous, BpSchedule::Residual] {
            let cfg = BpConfig { schedule, ..Default::default() };
            let g = BpGraph::build(&Backend::Serial, &model, prm.beta);
            let (want_msg, want_labels, want_sweeps) =
                run_serial(&model, &g, &prm, &cfg, false);
            for bk in [
                Backend::Serial,
                Backend::threaded_with_grain(Pool::new(4), 64),
            ] {
                let unary = super::super::sweep::unaries(&bk, &model, &prm);
                let mut st = super::super::sweep::BpState::new(
                    g.num_edges(),
                    model.num_vertices(),
                );
                let run = super::super::sweep::run(
                    &bk, &model, &g, &unary, &mut st, &cfg, false, 0,
                );
                let mut labels = vec![0u8; model.num_vertices()];
                super::super::sweep::decode(
                    &bk, &model, &g, &unary, &mut st, &mut labels,
                );
                assert_eq!(st.msg, want_msg, "{schedule:?} messages {bk:?}");
                assert_eq!(labels, want_labels, "{schedule:?} labels");
                assert_eq!(run.sweeps, want_sweeps, "{schedule:?} sweeps");
            }
        }
    }
}
