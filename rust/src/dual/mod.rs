//! Dual decomposition engine — MPLP-style block-coordinate ascent
//! with **certified optimality gaps** (DESIGN.md §12).
//!
//! Every other engine reports a primal energy with no statement of
//! how far from optimal it is. This module optimizes the *dual* of
//! the pairwise relaxation instead: the hood energy decomposes
//! exactly into a binary Potts model ([`graph`]), whose LP dual is
//! ascended by per-edge reparameterization updates ([`ascent`]). By
//! weak duality the bound after ANY number of iterations — at ANY
//! message values — is a true lower bound on every labeling's
//! energy, so the engine can report `lower_bound` alongside the
//! usual primal energy and the coordinator can derive a certified
//! `optimality_gap` per slice ([`crate::coordinator::SliceReport`]).
//!
//! Layout mirrors the BP engine: [`DualEngine`] is generic over
//! `&dyn Device`, draws every per-iteration tensor from its
//! [`crate::dpp::Workspace`], and must match the plain-loop oracle
//! ([`serial`]) bitwise on every device at any thread count.
//!
//! The reported bound is `best dual bound - scorer_slack`: the dual
//! operates in f64 on the exact pairwise decomposition, while
//! [`crate::mrf::config_energy`] rounds per-instance in f32, so a
//! per-instance rounding allowance ([`scorer_slack`]) is subtracted
//! once to keep `lower_bound <= config_energy(x)` for every labeling
//! `x`. The slack is labeling-independent and ~1e-6 relative — far
//! below any energy difference the engines care about.

pub mod ascent;
pub mod graph;
pub mod serial;

mod engine;

pub use engine::DualEngine;
pub use graph::PairGraph;

use crate::dpp::{Device, Workspace};
use crate::mrf::energy::Prepared;
use crate::mrf::{MrfModel, Params};

/// Dual-ascent parameters (`--dual-iters`, `--dual-tol`; JSON
/// section `"dual"`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DualConfig {
    /// Max ascent iterations per EM iteration.
    pub iters: usize,
    /// Early stop when one iteration improves the bound by less than
    /// `tol * max(1, |bound|)` (relative). 0 stops at exact stall.
    pub tol: f64,
}

impl Default for DualConfig {
    fn default() -> Self {
        DualConfig { iters: 100, tol: 1e-9 }
    }
}

/// Outcome of one dual solve under fixed parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DualRun {
    /// Primal decode: per-vertex argmin of the final beliefs.
    pub labels: Vec<u8>,
    /// Best dual bound reached — a lower bound on the pairwise
    /// objective ([`pair_energy`]) of EVERY labeling.
    pub bound: f64,
    /// Bound after each iteration (monotone non-decreasing up to f64
    /// noise).
    pub history: Vec<f64>,
    /// Iterations actually executed.
    pub iters: usize,
}

/// One-shot dual solve on any device. The serial oracle
/// ([`serial::solve`]) must match this bitwise — pinned by
/// `tests/device_conformance.rs`.
pub fn solve(
    bk: &dyn Device,
    model: &MrfModel,
    prm: &Params,
    cfg: &DualConfig,
) -> DualRun {
    let ws = Workspace::new();
    let g = PairGraph::build(bk, model, prm.beta);
    let nv = g.num_vertices;
    let mut unary = vec![0.0f64; 2 * nv];
    ascent::unaries_into(bk, model, &g, prm, &mut unary);
    let mut msg = vec![0.0f64; 2 * g.num_slots()];
    let mut bel = vec![0.0f64; 2 * nv];
    let run =
        ascent::run(bk, &ws, &g, &unary, &mut msg, &mut bel, cfg, false);
    let mut labels = vec![0u8; nv];
    ascent::decode(bk, &bel, &mut labels);
    DualRun {
        labels,
        bound: run.best,
        history: run.history,
        iters: run.iters,
    }
}

/// Dual unaries for a model under `prm` (the `mult_v * data_v` terms
/// of the pairwise decomposition), for callers that evaluate
/// [`pair_energy`] directly (tests, benches).
pub fn unaries(
    bk: &dyn Device,
    model: &MrfModel,
    g: &PairGraph,
    prm: &Params,
) -> Vec<f64> {
    let mut out = vec![0.0f64; 2 * g.num_vertices];
    ascent::unaries_into(bk, model, g, prm, &mut out);
    out
}

/// The pairwise objective the dual bounds: unaries at the assigned
/// labels plus `2 beta cooc` per disagreeing canonical edge, folded
/// serially in index order. Equals the hood energy
/// ([`crate::mrf::config_energy`]) in exact arithmetic; the two
/// computed values differ by at most [`scorer_slack`].
pub fn pair_energy(g: &PairGraph, unary: &[f64], labels: &[u8]) -> f64 {
    let mut e = 0.0f64;
    for (v, &l) in labels.iter().enumerate() {
        e += unary[2 * v + l as usize];
    }
    for k in 0..g.num_edges() {
        if labels[g.eu[k] as usize] != labels[g.ev[k] as usize] {
            e += g.ew[k];
        }
    }
    e
}

/// Labeling-independent allowance for the f32 rounding inside
/// [`crate::mrf::config_energy`]: per hood-member instance, the
/// scorer computes `fl(fl(data) + fl(beta * disagree))` in f32, so
/// its value can sit below the exact pairwise term by a few ulps.
/// Budgeting `1e-6 * (|e0| + |e1| + 2 beta size_h)` per instance
/// (1e-6 > several f32 ulps of each addend, for either label) makes
/// `bound - scorer_slack <= config_energy(x)` hold for every
/// labeling `x`, which is the contract `lower_bound` ships with.
pub fn scorer_slack(model: &MrfModel, prm: &Params) -> f64 {
    const EPS: f64 = 1e-6;
    let pp = Prepared::from_params(prm);
    let beta = prm.beta as f64;
    let h = &model.hoods;
    let mut slack = 0.0f64;
    for hd in 0..h.num_hoods() {
        let size = h.hood_size(hd) as f64;
        for &v in h.hood_members(hd) {
            let y = model.y[v as usize];
            let d0 = y - pp.mu[0];
            let d1 = y - pp.mu[1];
            let e0 = (d0 * d0 * pp.inv2s[0] + pp.lns[0]) as f64;
            let e1 = (d1 * d1 * pp.inv2s[1] + pp.lns[1]) as f64;
            slack += EPS * (e0.abs() + e1.abs() + 2.0 * beta * size);
        }
    }
    slack
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::SerialDevice;
    use crate::util::Pcg32;

    fn fixed_params() -> Params {
        Params { mu: [60.0, 180.0], sigma: [25.0, 25.0], beta: 0.5 }
    }

    #[test]
    fn pair_energy_matches_hood_energy_within_slack() {
        let model = crate::bp::test_model(71);
        let prm = fixed_params();
        let g = PairGraph::build(&SerialDevice, &model, prm.beta);
        let un = unaries(&SerialDevice, &model, &g, &prm);
        let slack = scorer_slack(&model, &prm);
        assert!(slack > 0.0 && slack.is_finite());
        let mut rng = Pcg32::seeded(9);
        for _ in 0..8 {
            let labels: Vec<u8> = (0..model.num_vertices())
                .map(|_| (rng.next_u32() & 1) as u8)
                .collect();
            let pair = pair_energy(&g, &un, &labels);
            let (_, hood) =
                crate::mrf::config_energy(&model, &labels, &prm);
            assert!(
                (pair - hood).abs() <= slack,
                "pair {pair} vs hood {hood} (slack {slack})"
            );
        }
    }

    #[test]
    fn bound_monotone_and_below_decoded_primal() {
        let model = crate::bp::test_model(72);
        let prm = fixed_params();
        let cfg = DualConfig::default();
        let run = solve(&SerialDevice, &model, &prm, &cfg);
        assert!(run.iters >= 1 && run.iters <= cfg.iters);
        for w in run.history.windows(2) {
            assert!(
                w[1] >= w[0] - 1e-9 * w[0].abs().max(1.0),
                "bound not monotone: {} -> {}",
                w[0],
                w[1]
            );
        }
        let g = PairGraph::build(&SerialDevice, &model, prm.beta);
        let un = unaries(&SerialDevice, &model, &g, &prm);
        let primal = pair_energy(&g, &un, &run.labels);
        assert!(
            run.bound <= primal + 1e-9 * primal.abs().max(1.0),
            "weak duality: bound {} vs primal {primal}",
            run.bound
        );
    }

    #[test]
    fn serial_oracle_is_bitwise_identical() {
        let model = crate::bp::test_model(73);
        let prm = fixed_params();
        let cfg = DualConfig { iters: 40, ..Default::default() };
        let dpp = solve(&SerialDevice, &model, &prm, &cfg);
        let oracle = serial::solve(&model, &prm, &cfg);
        assert_eq!(dpp, oracle);
    }

    #[test]
    fn default_config_sane() {
        let cfg = DualConfig::default();
        assert!(cfg.iters >= 1);
        assert!(cfg.tol >= 0.0 && cfg.tol.is_finite());
    }
}
