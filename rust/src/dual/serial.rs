//! Serial oracle for the dual ascent — plain nested loops, no device,
//! no workspace.
//!
//! Every per-item formula is the shared `#[inline]` function from
//! [`super::ascent`] (`refresh_one`, `edge_apply`, `edge_slack`,
//! `fold_bound`, `stop`), and the loops visit vertices, color
//! classes, class edges, and bound terms in exactly the order the DPP
//! path does — so DPP/serial bitwise equality at any thread count is
//! structural (the conformance contract of DESIGN.md §9, pinned by
//! `tests/device_conformance.rs`).

use crate::dpp::SerialDevice;
use crate::mrf::{MrfModel, Params};

use super::ascent;
use super::graph::PairGraph;
use super::{DualConfig, DualRun};

/// One-shot dual solve with straight loops. The graph build itself is
/// device-independent, so sharing [`PairGraph::build`] keeps the
/// structure identical by construction.
pub fn solve(model: &MrfModel, prm: &Params, cfg: &DualConfig)
    -> DualRun {
    let g = PairGraph::build(&SerialDevice, model, prm.beta);
    let nv = g.num_vertices;

    let mut unary = vec![0.0f64; 2 * nv];
    {
        let pp = crate::mrf::energy::Prepared::from_params(prm);
        for (v, u) in unary.chunks_exact_mut(2).enumerate() {
            let y = model.y[v];
            let d0 = y - pp.mu[0];
            let d1 = y - pp.mu[1];
            let e0 = d0 * d0 * pp.inv2s[0] + pp.lns[0];
            let e1 = d1 * d1 * pp.inv2s[1] + pp.lns[1];
            let m = g.mult[v] as f64;
            u[0] = m * e0 as f64;
            u[1] = m * e1 as f64;
        }
    }

    let mut msg = vec![0.0f64; 2 * g.num_slots()];
    let mut bel = vec![0.0f64; 2 * nv];
    let ne = g.num_edges();
    let mut vmin = vec![0.0f64; nv];
    let mut eslack = vec![0.0f64; ne];
    let mut history = Vec::with_capacity(cfg.iters);
    let mut best = f64::NEG_INFINITY;
    let mut iters = 0usize;

    for it in 0..cfg.iters {
        iters = it + 1;
        // 1. Belief refresh.
        for v in 0..nv {
            let b = ascent::refresh_one(&g, &unary, &msg, v);
            bel[2 * v] = b[0];
            bel[2 * v + 1] = b[1];
        }
        // 2. Edge-colored Gauss-Seidel, class order then edge order.
        for c in 0..g.num_colors() {
            let (cs, ce) = (
                g.color_offsets[c] as usize,
                g.color_offsets[c + 1] as usize,
            );
            for &k in &g.color_edges[cs..ce] {
                let k = k as usize;
                let u = g.eu[k] as usize;
                let v = g.ev[k] as usize;
                let su = g.epos_u[k] as usize;
                let sv = g.epos_v[k] as usize;
                let bu = [bel[2 * u], bel[2 * u + 1]];
                let bv = [bel[2 * v], bel[2 * v + 1]];
                let mu = [msg[2 * su], msg[2 * su + 1]];
                let mv = [msg[2 * sv], msg[2 * sv + 1]];
                let (nbu, nbv, nu, nvv) =
                    ascent::edge_apply(bu, bv, mu, mv, g.ew[k]);
                bel[2 * u] = nbu[0];
                bel[2 * u + 1] = nbu[1];
                bel[2 * v] = nbv[0];
                bel[2 * v + 1] = nbv[1];
                msg[2 * su] = nu[0];
                msg[2 * su + 1] = nu[1];
                msg[2 * sv] = nvv[0];
                msg[2 * sv + 1] = nvv[1];
            }
        }
        // 3. Bound terms + the shared index-order fold.
        for (v, out) in vmin.iter_mut().enumerate() {
            *out = bel[2 * v].min(bel[2 * v + 1]);
        }
        for (k, out) in eslack.iter_mut().enumerate() {
            let su = g.epos_u[k] as usize;
            let sv = g.epos_v[k] as usize;
            let mu = [msg[2 * su], msg[2 * su + 1]];
            let mv = [msg[2 * sv], msg[2 * sv + 1]];
            *out = ascent::edge_slack(mu, mv, g.ew[k]);
        }
        let b = ascent::fold_bound(&vmin, &eslack);
        let prev = history.last().copied();
        history.push(b);
        if b > best {
            best = b;
        }
        if let Some(prev) = prev {
            if ascent::stop(prev, b, cfg.tol) {
                break;
            }
        }
    }

    let labels: Vec<u8> = (0..nv)
        .map(|v| u8::from(bel[2 * v + 1] < bel[2 * v]))
        .collect();
    DualRun { labels, bound: best, history, iters }
}
