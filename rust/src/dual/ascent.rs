//! MPLP-style block-coordinate ascent over the pairwise dual.
//!
//! One ascent iteration (all f64):
//!
//! 1. **Belief refresh** — `bel[v] = unary[v] + sum of messages into
//!    v`, a map over vertices whose per-vertex segment comes from the
//!    cached CSR [`crate::dpp::SegmentPlan`] in [`PairGraph`].
//! 2. **Edge-colored Gauss-Seidel** — color classes run sequentially;
//!    within a class every edge updates both of its messages and
//!    incrementally patches both endpoint beliefs. Classes are
//!    node-disjoint ([`PairGraph`]), so the parallel sweep touches
//!    disjoint memory and is exactly the sequential update.
//! 3. **Bound** — per-vertex min-belief and per-edge slack terms are
//!    materialized by parallel maps into workspace scratch, then
//!    folded serially in index order, so the f64 association order is
//!    fixed for every device and thread count.
//!
//! Every per-item formula lives in a shared `#[inline]` function that
//! the serial oracle ([`super::serial`]) calls too — the bitwise
//! DPP/serial contract is structural, not coincidental (the same rule
//! BP's sweeps follow, DESIGN.md §9/§12).
//!
//! Why the bound is a lower bound (weak duality): for any messages,
//! regrouping terms gives `E(x) = sum_v bel_v(x_v) + sum_e
//! (w_e [x_u != x_v] - m_u(x_u) - m_v(x_v))` for every labeling `x`,
//! so minimizing each vertex term and each edge term independently
//! can only decrease the value. The update is the standard MPLP
//! half-split reparameterization, which never decreases the bound.

use crate::dpp::{Device, DeviceExt, SharedSlice, Workspace};
use crate::mrf::energy::Prepared;
use crate::mrf::{MrfModel, Params};

use super::graph::PairGraph;
use super::DualConfig;

/// Dual unaries: `mult_v * data_v(label)`. The f32 data term is
/// computed with exactly the operations of
/// [`crate::mrf::energy::energy_pair_p`] (same bits), then promoted
/// to f64 and scaled by the hood-instance multiplicity.
pub(crate) fn unaries_into(
    bk: &dyn Device,
    model: &MrfModel,
    g: &PairGraph,
    prm: &Params,
    out: &mut [f64],
) {
    let pp = Prepared::from_params(prm);
    let win = SharedSlice::new(out);
    bk.for_chunks(g.num_vertices, |s, e| {
        for v in s..e {
            let y = model.y[v];
            let d0 = y - pp.mu[0];
            let d1 = y - pp.mu[1];
            let e0 = d0 * d0 * pp.inv2s[0] + pp.lns[0];
            let e1 = d1 * d1 * pp.inv2s[1] + pp.lns[1];
            let m = g.mult[v] as f64;
            unsafe {
                win.write(2 * v, m * e0 as f64);
                win.write(2 * v + 1, m * e1 as f64);
            }
        }
    });
}

/// Belief of one vertex: unary plus the slot-ordered sum of messages
/// into it (the vertex's segment of the cached plan).
#[inline]
pub(crate) fn refresh_one(
    g: &PairGraph,
    unary: &[f64],
    msg: &[f64],
    v: usize,
) -> [f64; 2] {
    let (s, e) = g.plan.segment_bounds(v);
    let mut b0 = unary[2 * v];
    let mut b1 = unary[2 * v + 1];
    for slot in s..e {
        b0 += msg[2 * slot];
        b1 += msg[2 * slot + 1];
    }
    [b0, b1]
}

/// One MPLP edge update on plain values: given both endpoint beliefs
/// and current messages, return `(new bel_u, new bel_v, new msg into
/// u, new msg into v)`. `A = bel - msg` is the belief with this
/// edge's contribution removed; each new message gives the endpoint
/// half of the edge-restricted min-marginal.
#[inline]
pub(crate) fn edge_apply(
    bu: [f64; 2],
    bv: [f64; 2],
    mu: [f64; 2],
    mv: [f64; 2],
    w: f64,
) -> ([f64; 2], [f64; 2], [f64; 2], [f64; 2]) {
    let au = [bu[0] - mu[0], bu[1] - mu[1]];
    let av = [bv[0] - mv[0], bv[1] - mv[1]];
    let nu = [
        0.5 * (av[0].min(av[1] + w) - au[0]),
        0.5 * (av[1].min(av[0] + w) - au[1]),
    ];
    let nv = [
        0.5 * (au[0].min(au[1] + w) - av[0]),
        0.5 * (au[1].min(au[0] + w) - av[1]),
    ];
    (
        [bu[0] + (nu[0] - mu[0]), bu[1] + (nu[1] - mu[1])],
        [bv[0] + (nv[0] - mv[0]), bv[1] + (nv[1] - mv[1])],
        nu,
        nv,
    )
}

/// The edge term of the dual bound: min over the four label pairs of
/// the reparameterized pairwise energy.
#[inline]
pub(crate) fn edge_slack(mu: [f64; 2], mv: [f64; 2], w: f64) -> f64 {
    (-mu[0] - mv[0])
        .min(w - mu[0] - mv[1])
        .min(w - mu[1] - mv[0])
        .min(-mu[1] - mv[1])
}

/// Serial index-order fold of the materialized bound terms — the ONE
/// association order both the DPP path and the serial oracle use.
#[inline]
pub(crate) fn fold_bound(vmin: &[f64], eslack: &[f64]) -> f64 {
    let mut b = 0.0f64;
    for &x in vmin {
        b += x;
    }
    for &x in eslack {
        b += x;
    }
    b
}

/// Relative-improvement early stop shared by both paths.
#[inline]
pub(crate) fn stop(prev: f64, cur: f64, tol: f64) -> bool {
    (cur - prev) <= tol * prev.abs().max(1.0)
}

/// Outcome of one ascent run.
pub(crate) struct AscentRun {
    /// Iterations actually executed.
    pub iters: usize,
    /// Best (maximum) bound reached — the certified lower bound on
    /// the pairwise objective.
    pub best: f64,
    /// Bound after each iteration.
    pub history: Vec<f64>,
}

/// Run block-coordinate ascent on this device. `msg` carries the dual
/// state (2 entries per directed slot) and may be warm-started from a
/// previous run — the bound is valid at ANY messages, so reusing them
/// across EM iterations is sound and saves iterations. `bel` is
/// overwritten. `fixed` disables the early stop (the crate-wide
/// `fixed_iters` contract: exact iteration counts for tests).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run(
    bk: &dyn Device,
    ws: &Workspace,
    g: &PairGraph,
    unary: &[f64],
    msg: &mut [f64],
    bel: &mut [f64],
    cfg: &DualConfig,
    fixed: bool,
) -> AscentRun {
    let nv = g.num_vertices;
    let ne = g.num_edges();
    debug_assert_eq!(msg.len(), 2 * g.num_slots());
    debug_assert_eq!(bel.len(), 2 * nv);

    let mut vmin = ws.take::<f64>(nv);
    let mut eslack = ws.take::<f64>(ne);
    let mut history = Vec::with_capacity(cfg.iters);
    let mut best = f64::NEG_INFINITY;
    let mut iters = 0usize;

    for it in 0..cfg.iters {
        // Inert unless a tracer is armed (telemetry span taxonomy:
        // one `dual_iter` level between `em` and `prim`).
        let _span = crate::telemetry::span_arg(
            "map", "dual_iter", "iter", it as u64,
        );
        iters = it + 1;

        // 1. Belief refresh (map over the plan's vertex segments).
        {
            let wb = SharedSlice::new(&mut bel[..]);
            let msg_r: &[f64] = msg;
            bk.for_chunks(nv, |s, e| {
                for v in s..e {
                    let b = refresh_one(g, unary, msg_r, v);
                    unsafe {
                        wb.write(2 * v, b[0]);
                        wb.write(2 * v + 1, b[1]);
                    }
                }
            });
        }

        // 2. Edge-colored Gauss-Seidel: classes sequential, edges
        // within a class parallel (node-disjoint, so every chunk
        // touches disjoint bel/msg entries).
        for c in 0..g.num_colors() {
            let (cs, ce) = (
                g.color_offsets[c] as usize,
                g.color_offsets[c + 1] as usize,
            );
            let wb = SharedSlice::new(&mut bel[..]);
            let wm = SharedSlice::new(&mut msg[..]);
            bk.for_chunks(ce - cs, |s, e| {
                for i in s..e {
                    let k = g.color_edges[cs + i] as usize;
                    let u = g.eu[k] as usize;
                    let v = g.ev[k] as usize;
                    let su = g.epos_u[k] as usize;
                    let sv = g.epos_v[k] as usize;
                    unsafe {
                        let bu = [wb.read(2 * u), wb.read(2 * u + 1)];
                        let bv = [wb.read(2 * v), wb.read(2 * v + 1)];
                        let mu = [wm.read(2 * su), wm.read(2 * su + 1)];
                        let mv = [wm.read(2 * sv), wm.read(2 * sv + 1)];
                        let (nbu, nbv, nu, nvv) =
                            edge_apply(bu, bv, mu, mv, g.ew[k]);
                        wb.write(2 * u, nbu[0]);
                        wb.write(2 * u + 1, nbu[1]);
                        wb.write(2 * v, nbv[0]);
                        wb.write(2 * v + 1, nbv[1]);
                        wm.write(2 * su, nu[0]);
                        wm.write(2 * su + 1, nu[1]);
                        wm.write(2 * sv, nvv[0]);
                        wm.write(2 * sv + 1, nvv[1]);
                    }
                }
            });
        }

        // 3. Bound: materialize per-item terms in parallel, fold
        // serially in index order (fixed association).
        {
            let wv = SharedSlice::new(&mut vmin[..]);
            let bel_r: &[f64] = bel;
            bk.for_chunks(nv, |s, e| {
                for v in s..e {
                    let b = bel_r[2 * v].min(bel_r[2 * v + 1]);
                    unsafe { wv.write(v, b) };
                }
            });
            let we = SharedSlice::new(&mut eslack[..]);
            let msg_r: &[f64] = msg;
            bk.for_chunks(ne, |s, e| {
                for k in s..e {
                    let su = g.epos_u[k] as usize;
                    let sv = g.epos_v[k] as usize;
                    let mu = [msg_r[2 * su], msg_r[2 * su + 1]];
                    let mv = [msg_r[2 * sv], msg_r[2 * sv + 1]];
                    unsafe {
                        we.write(k, edge_slack(mu, mv, g.ew[k]))
                    };
                }
            });
        }
        let b = fold_bound(&vmin, &eslack);
        let prev = history.last().copied();
        history.push(b);
        if b > best {
            best = b;
        }
        if let Some(prev) = prev {
            if !fixed && stop(prev, b, cfg.tol) {
                break;
            }
        }
    }

    AscentRun { iters, best, history }
}

/// Primal decode: per-vertex argmin of the final beliefs (strict `<`,
/// ties -> label 0 — the crate-wide tie rule).
pub(crate) fn decode(bk: &dyn Device, bel: &[f64], labels: &mut [u8]) {
    let nv = labels.len();
    let win = SharedSlice::new(labels);
    bk.for_chunks(nv, |s, e| {
        for v in s..e {
            let l = u8::from(bel[2 * v + 1] < bel[2 * v]);
            unsafe { win.write(v, l) };
        }
    });
}
