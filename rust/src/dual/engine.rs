//! [`DualEngine`] — the dual ascent as a drop-in [`Engine`] in the
//! shared EM outer loop, mirroring the BP engine's shape.
//!
//! Per EM iteration: refresh the dual unaries from the current
//! (mu, sigma), ascend the dual (messages warm-start from the
//! previous EM iteration — the bound is valid at any messages, so
//! this is sound), decode per-vertex labels from the beliefs, score
//! the labeling with the shared hood energy
//! ([`crate::mrf::config_energy`]) so histories are directly
//! comparable to the MAP/BP engines, and re-estimate (mu, sigma)
//! from the hood-member instances exactly as they do.
//!
//! The extra deliverable over every other engine:
//! `EmResult::lower_bound` = the final EM iteration's best dual
//! bound minus [`super::scorer_slack`] under the SAME parameters the
//! reported energy was scored with — so `energy - lower_bound` is a
//! certified non-negative optimality gap.

use std::sync::Arc;

use crate::config::MrfConfig;
use crate::dpp::{Device, IntoDevice, Workspace, WorkspaceStats};
use crate::mrf::{self, params, ConvergenceWindow, Engine, EmResult,
                 MrfModel};

use super::graph::PairGraph;
use super::{ascent, scorer_slack, DualConfig};

pub struct DualEngine {
    device: Arc<dyn Device>,
    pub dual: DualConfig,
    /// Scratch pool for per-iteration tensors (messages, beliefs,
    /// unaries, bound terms); one per engine, so each scheduler
    /// lane's dual engine amortizes buffers across its slices
    /// (DESIGN.md §10).
    ws: Workspace,
}

impl DualEngine {
    /// Engine on any device — accepts a concrete device, an
    /// `Arc<dyn Device>`, or the deprecated `Backend` spelling.
    pub fn new(device: impl IntoDevice, dual: DualConfig) -> Self {
        DualEngine { device: device.into_device(), dual,
                     ws: Workspace::new() }
    }

    /// The device every ascent sweep of this engine executes on.
    pub fn device(&self) -> &Arc<dyn Device> {
        &self.device
    }

    /// Counters of the engine-held scratch pool (see
    /// [`crate::dpp::Workspace::stats`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dual::{DualConfig, DualEngine};
    /// use dpp_pmrf::dpp::SerialDevice;
    /// let engine = DualEngine::new(SerialDevice, DualConfig::default());
    /// assert_eq!(engine.workspace_stats().misses, 0);
    /// ```
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.stats()
    }
}

impl Engine for DualEngine {
    fn name(&self) -> &'static str {
        "dual"
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let bk: &dyn Device = &*self.device;
        let nv = model.num_vertices();
        let g = PairGraph::build(bk, model, cfg.beta as f32);
        let y_elem = model.y_elems();

        // Same seeded init as every other engine; the dual ignores
        // the initial labels (messages start at zero) but shares the
        // initial parameters, so class polarity matches.
        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        let mut em_window =
            ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut total_iters = 0usize;
        let mut em_iters = 0usize;
        let mut lower = f64::NEG_INFINITY;

        // Persistent per-run buffers: messages carry the dual state
        // across EM iterations (warm start), beliefs and unaries are
        // overwritten each iteration.
        let mut msg = self.ws.take::<f64>(2 * g.num_slots());
        msg.fill(0.0);
        let mut bel = self.ws.take::<f64>(2 * nv);
        let mut unary = self.ws.take::<f64>(2 * nv);

        for _em in 0..cfg.em_iters {
            // Inert unless a tracer is armed (see telemetry::span).
            let _em_span = crate::telemetry::span_arg(
                "em", "em_iter", "iter", em_iters as u64,
            );
            em_iters += 1;

            ascent::unaries_into(bk, model, &g, &prm, &mut unary);
            let run = ascent::run(
                bk, &self.ws, &g, &unary, &mut msg, &mut bel,
                &self.dual, cfg.fixed_iters,
            );
            total_iters += run.iters;
            ascent::decode(bk, &bel, &mut labels);

            // Score with the shared hood energy and certify under the
            // SAME pre-update parameters: the bound was computed from
            // `prm`'s unaries, so `lower <= total` by weak duality
            // plus the scorer's rounding allowance.
            let (_, total) =
                mrf::config_energy(model, &labels, &prm);
            lower = run.best - scorer_slack(model, &prm);

            // Flight-recorder hook (DESIGN.md §13): replay this EM
            // iteration's ascent trajectory into the journal. Samples
            // carry the *running best* bound (minus the same scorer
            // slack as the certificate) — the raw per-iteration bound
            // is monotone only up to f64 accumulation noise, the
            // certificate is monotone by construction.
            if crate::obs::live() {
                if crate::obs::armed() {
                    let slack = run.best - lower;
                    let mut best = f64::NEG_INFINITY;
                    for (k, &b) in run.history.iter().enumerate() {
                        best = best.max(b);
                        let lb = best - slack;
                        crate::obs::dual_sample(
                            em_iters - 1,
                            k,
                            lb,
                            total,
                            (total - lb).max(0.0),
                        );
                    }
                } else {
                    crate::obs::tick();
                }
            }

            let mut stats = params::Stats::default();
            for (e, &v) in model.hoods.members.iter().enumerate() {
                stats.add(labels[v as usize], y_elem[e]);
            }
            prm = params::update(&stats, cfg.beta as f32);

            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }
        self.ws.publish_timing();

        EmResult {
            labels,
            em_iters,
            map_iters: total_iters,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: Some(lower),
            pmp: None,
            bp: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn dual_engine_deterministic_across_backends_and_runs() {
        let model = crate::bp::test_model(81);
        let cfg = MrfConfig::default();
        let dual = DualConfig::default();
        let a = DualEngine::new(Backend::Serial, dual)
            .run(&model, &cfg);
        let b = DualEngine::new(Backend::Serial, dual)
            .run(&model, &cfg);
        assert_eq!(a, b, "rerun identical");
        let c = DualEngine::new(
            Backend::threaded_with_grain(Pool::new(4), 64),
            dual,
        )
        .run(&model, &cfg);
        assert_eq!(a, c, "backend independent");
    }

    #[test]
    fn certifies_a_nonnegative_gap() {
        let model = crate::bp::test_model(82);
        let cfg = MrfConfig::default();
        let res = DualEngine::new(Backend::Serial, DualConfig::default())
            .run(&model, &cfg);
        let lb = res.lower_bound.expect("dual engine certifies");
        assert!(lb.is_finite());
        assert!(
            lb <= res.energy,
            "lower bound {lb} vs energy {}",
            res.energy
        );
        assert!(res.labels.iter().all(|&l| l <= 1));
    }

    #[test]
    fn energy_close_to_serial_map_engine() {
        let model = crate::bp::test_model(83);
        let cfg = MrfConfig::default();
        let map = crate::mrf::serial::SerialEngine.run(&model, &cfg);
        let dual =
            DualEngine::new(Backend::Serial, DualConfig::default())
                .run(&model, &cfg);
        let rel = (dual.energy - map.energy).abs()
            / map.energy.abs().max(1.0);
        assert!(rel < 0.05, "dual {} vs map {} (rel {rel})",
                dual.energy, map.energy);
        // And the certificate bounds the MAP engine's energy too,
        // under the dual's own final-iteration parameters semantics:
        // both energies sit above the certified bound.
        let lb = dual.lower_bound.unwrap();
        assert!(lb <= dual.energy);
    }

    #[test]
    fn fixed_iters_runs_exact_em_count() {
        let model = crate::bp::test_model(84);
        let cfg = MrfConfig {
            em_iters: 3,
            fixed_iters: true,
            ..Default::default()
        };
        let dual = DualConfig { iters: 7, ..Default::default() };
        let res =
            DualEngine::new(Backend::Serial, dual).run(&model, &cfg);
        assert_eq!(res.em_iters, 3);
        assert_eq!(res.map_iters, 21, "3 EM x 7 ascent iterations");
    }
}
