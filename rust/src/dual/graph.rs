//! Pairwise co-membership graph for the dual engine.
//!
//! The hood energy (DESIGN.md §5) decomposes exactly (in real
//! arithmetic) into a pairwise binary Potts model:
//!
//! ```text
//! E(x) = sum_v mult_v * data_v(x_v)
//!      + sum_{u<v} 2 * beta * cooc(u, v) * [x_u != x_v]
//! ```
//!
//! where `mult_v` is the number of hood-member instances of vertex `v`
//! and `cooc(u, v)` counts the hoods containing both endpoints: each
//! hood contributes `beta * disagree` per member instance, and a
//! disagreeing pair `{u, v}` inside one hood is counted once from each
//! side, hence the factor 2. This is the form the MPLP-style dual
//! ascent ([`super::ascent`]) operates on.
//!
//! [`PairGraph::build`] derives the structure from [`Hoods`] with the
//! usual two-pass DPP recipe (map degrees, scan offsets, map fill);
//! every pass writes by vertex index, so the result is
//! bitwise-identical on every [`Device`] at any thread count. On top
//! of the CSR it caches:
//!
//! * a [`SegmentPlan`] over the per-vertex message slots, driving the
//!   belief-refresh segmented reductions;
//! * the canonical (`u < v`) edge list with both directed slot
//!   positions, so an edge update can address "the message into `u`"
//!   and "the message into `v`" directly;
//! * a greedy edge coloring (smallest color unused at either
//!   endpoint, in canonical edge order): color classes are
//!   node-disjoint, which is what makes the parallel Gauss-Seidel
//!   sweep in [`super::ascent`] exact and deterministic.

use crate::dpp::{Device, DeviceExt, SegmentPlan, SharedSlice};
use crate::mrf::{Hoods, MrfModel};

/// Static pairwise structure + edge coloring, built once per model.
#[derive(Debug, Clone, PartialEq)]
pub struct PairGraph {
    pub num_vertices: usize,
    /// Directed message-slot ranges per vertex (`nv + 1` entries).
    pub offsets: Vec<u32>,
    /// Slot -> neighbor vertex, ascending within each row.
    pub neighbors: Vec<u32>,
    /// Slot -> number of hoods containing both endpoints (symmetric).
    pub cooc: Vec<u32>,
    /// Vertex -> number of hood-member instances (unary multiplicity;
    /// genuinely 0 for vertices outside every hood).
    pub mult: Vec<u32>,
    /// Cached segmented-reduction plan over the slot CSR: segment `v`
    /// is exactly the messages into vertex `v`.
    pub plan: SegmentPlan,
    /// Canonical edges (`eu[k] < ev[k]`), in row-major slot order.
    pub eu: Vec<u32>,
    pub ev: Vec<u32>,
    /// Directed slot of edge `k` in `eu[k]`'s row (message into `u`).
    pub epos_u: Vec<u32>,
    /// Directed slot of edge `k` in `ev[k]`'s row (message into `v`).
    pub epos_v: Vec<u32>,
    /// Edge weight `2 * beta * cooc`, promoted to f64 once.
    pub ew: Vec<f64>,
    /// Edge ranges per color class (`num_colors + 1` entries).
    pub color_offsets: Vec<u32>,
    /// Canonical edge ids grouped by color, stable in edge order.
    pub color_edges: Vec<u32>,
}

/// Sorted (with repeats) co-members of `v`: every other vertex of
/// every hood that contains an instance of `v`. Runs of equal ids
/// encode the co-occurrence count.
fn gather_comembers(h: &Hoods, v: usize, buf: &mut Vec<u32>) {
    buf.clear();
    let (s, e) =
        (h.vert_offsets[v] as usize, h.vert_offsets[v + 1] as usize);
    for &el in &h.vert_elems[s..e] {
        let hd = h.hood_id[el as usize] as usize;
        for &w in h.hood_members(hd) {
            if w != v as u32 {
                buf.push(w);
            }
        }
    }
    buf.sort_unstable();
}

impl PairGraph {
    /// Build from a model's hoods. Deterministic across devices and
    /// thread counts: both parallel passes write only by vertex index.
    pub fn build(bk: &dyn Device, model: &MrfModel, beta: f32)
        -> PairGraph {
        let h = &model.hoods;
        let nv = model.num_vertices();

        let mult: Vec<u32> = (0..nv)
            .map(|v| h.vert_offsets[v + 1] - h.vert_offsets[v])
            .collect();

        // Pass 1 (map): distinct co-member count per vertex.
        let mut degree = vec![0u32; nv];
        {
            let wd = SharedSlice::new(&mut degree[..]);
            bk.for_chunks(nv, |s, e| {
                let mut buf = Vec::new();
                for v in s..e {
                    gather_comembers(h, v, &mut buf);
                    let mut deg = 0u32;
                    let mut i = 0;
                    while i < buf.len() {
                        let mut j = i + 1;
                        while j < buf.len() && buf[j] == buf[i] {
                            j += 1;
                        }
                        deg += 1;
                        i = j;
                    }
                    unsafe { wd.write(v, deg) };
                }
            });
        }

        // Scan: slot offsets.
        let mut offsets = vec![0u32; nv + 1];
        for v in 0..nv {
            offsets[v + 1] = offsets[v] + degree[v];
        }
        let slots = offsets[nv] as usize;

        // Pass 2 (map): fill neighbor ids + co-occurrence counts.
        let mut neighbors = vec![0u32; slots];
        let mut cooc = vec![0u32; slots];
        {
            let wn = SharedSlice::new(&mut neighbors[..]);
            let wc = SharedSlice::new(&mut cooc[..]);
            bk.for_chunks(nv, |s, e| {
                let mut buf = Vec::new();
                for v in s..e {
                    gather_comembers(h, v, &mut buf);
                    let mut cursor = offsets[v] as usize;
                    let mut i = 0;
                    while i < buf.len() {
                        let mut j = i + 1;
                        while j < buf.len() && buf[j] == buf[i] {
                            j += 1;
                        }
                        unsafe {
                            wn.write(cursor, buf[i]);
                            wc.write(cursor, (j - i) as u32);
                        }
                        cursor += 1;
                        i = j;
                    }
                }
            });
        }

        // Canonical edge extraction (serial, row-major slot order).
        // Rows are sorted, so the reverse slot is a binary search.
        let two_beta = 2.0 * beta as f64;
        let mut eu = Vec::new();
        let mut ev = Vec::new();
        let mut epos_u = Vec::new();
        let mut epos_v = Vec::new();
        let mut ew = Vec::new();
        for u in 0..nv {
            for s in offsets[u] as usize..offsets[u + 1] as usize {
                let v = neighbors[s] as usize;
                if u < v {
                    let row = &neighbors[offsets[v] as usize
                        ..offsets[v + 1] as usize];
                    let p = row
                        .binary_search(&(u as u32))
                        .expect("co-membership is symmetric");
                    eu.push(u as u32);
                    ev.push(v as u32);
                    epos_u.push(s as u32);
                    epos_v.push(offsets[v] + p as u32);
                    ew.push(two_beta * cooc[s] as f64);
                }
            }
        }

        // Greedy edge coloring: smallest color unused at either
        // endpoint, canonical edge order. Classes are node-disjoint.
        let nce = eu.len();
        let mut vert_used: Vec<Vec<u32>> = vec![Vec::new(); nv];
        let mut color = vec![0u32; nce];
        let mut ncolors = 0u32;
        for k in 0..nce {
            let (u, v) = (eu[k] as usize, ev[k] as usize);
            let mut c = 0u32;
            while vert_used[u].contains(&c) || vert_used[v].contains(&c)
            {
                c += 1;
            }
            color[k] = c;
            vert_used[u].push(c);
            vert_used[v].push(c);
            ncolors = ncolors.max(c + 1);
        }
        let nc = ncolors as usize;
        let mut color_offsets = vec![0u32; nc + 1];
        for &c in &color {
            color_offsets[c as usize + 1] += 1;
        }
        for c in 0..nc {
            color_offsets[c + 1] += color_offsets[c];
        }
        let mut cursor = color_offsets.clone();
        let mut color_edges = vec![0u32; nce];
        for (k, &c) in color.iter().enumerate() {
            color_edges[cursor[c as usize] as usize] = k as u32;
            cursor[c as usize] += 1;
        }

        let plan = SegmentPlan::from_csr_offsets(&offsets);
        PairGraph {
            num_vertices: nv,
            offsets,
            neighbors,
            cooc,
            mult,
            plan,
            eu,
            ev,
            epos_u,
            epos_v,
            ew,
            color_offsets,
            color_edges,
        }
    }

    /// Directed message-slot count (2 per canonical edge).
    pub fn num_slots(&self) -> usize {
        self.neighbors.len()
    }

    /// Canonical (undirected) edge count.
    pub fn num_edges(&self) -> usize {
        self.eu.len()
    }

    /// Color-class count of the cached edge coloring.
    pub fn num_colors(&self) -> usize {
        self.color_offsets.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::{PoolDevice, SerialDevice};

    fn model() -> MrfModel {
        crate::bp::test_model(91)
    }

    #[test]
    fn slots_are_symmetric_and_canonical_edges_consistent() {
        let m = model();
        let g = PairGraph::build(&SerialDevice, &m, 0.5);
        assert_eq!(g.num_slots(), 2 * g.num_edges());
        for k in 0..g.num_edges() {
            let (u, v) = (g.eu[k], g.ev[k]);
            assert!(u < v);
            let (su, sv) =
                (g.epos_u[k] as usize, g.epos_v[k] as usize);
            assert_eq!(g.neighbors[su], v, "slot into u names v");
            assert_eq!(g.neighbors[sv], u, "slot into v names u");
            assert_eq!(g.cooc[su], g.cooc[sv], "cooc symmetric");
            assert!(g.ew[k] > 0.0);
        }
    }

    #[test]
    fn color_classes_are_node_disjoint_and_cover_all_edges() {
        let m = model();
        let g = PairGraph::build(&SerialDevice, &m, 0.5);
        let mut seen = vec![false; g.num_edges()];
        for c in 0..g.num_colors() {
            let (s, e) = (
                g.color_offsets[c] as usize,
                g.color_offsets[c + 1] as usize,
            );
            let mut touched = vec![false; g.num_vertices];
            for &k in &g.color_edges[s..e] {
                let k = k as usize;
                assert!(!seen[k], "edge in one class only");
                seen[k] = true;
                for v in [g.eu[k] as usize, g.ev[k] as usize] {
                    assert!(!touched[v], "class is node-disjoint");
                    touched[v] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "coloring covers every edge");
    }

    #[test]
    fn build_is_device_independent() {
        let m = model();
        let a = PairGraph::build(&SerialDevice, &m, 0.5);
        for threads in [2, 4] {
            let b = PairGraph::build(
                &PoolDevice::new(threads, 64),
                &m,
                0.5,
            );
            assert_eq!(a, b, "threads={threads}");
        }
    }

    #[test]
    fn multiplicity_counts_hood_instances() {
        let m = model();
        let g = PairGraph::build(&SerialDevice, &m, 0.5);
        let total: u32 = g.mult.iter().sum();
        assert_eq!(total as usize, m.hoods.num_elements());
    }
}
