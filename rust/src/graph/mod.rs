//! Region adjacency graph (RAG) in CSR form (paper §3.1/§3.2.1).
//!
//! Vertices are oversegmentation regions; an edge connects two regions
//! whose pixels touch (4-connectivity). Two builders:
//!
//! * [`build_rag_serial`] — HashSet-based reference.
//! * [`build_rag_dpp`] — the paper's data-parallel construction: Map
//!   pixel pairs to packed edge keys, SortByKey, Unique, then CSR
//!   offsets via ReduceByKey/Scan.

use std::collections::BTreeSet;

use crate::dpp::{self, Device};
use crate::overseg::Overseg;

/// Compressed-sparse-row undirected graph. Neighbor lists are sorted
/// ascending; every edge appears in both endpoints' lists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    pub offsets: Vec<u32>,
    pub neighbors: Vec<u32>,
}

impl Csr {
    pub fn num_vertices(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    pub fn neighbors_of(&self, v: u32) -> &[u32] {
        &self.neighbors
            [self.offsets[v as usize] as usize
                ..self.offsets[v as usize + 1] as usize]
    }

    pub fn degree(&self, v: u32) -> usize {
        self.neighbors_of(v).len()
    }

    /// Binary adjacency test (lists are sorted).
    #[inline]
    pub fn adjacent(&self, a: u32, b: u32) -> bool {
        self.neighbors_of(a).binary_search(&b).is_ok()
    }

    /// Build from a deduplicated, sorted directed-edge list
    /// (both directions present).
    fn from_directed_sorted(n: usize, src: &[u32], dst: &[u32]) -> Csr {
        let mut offsets = vec![0u32; n + 1];
        for &s in src {
            offsets[s as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        Csr { offsets, neighbors: dst.to_vec() }
    }
}

/// Serial RAG builder (reference for tests).
pub fn build_rag_serial(seg: &Overseg) -> Csr {
    let (w, h) = (seg.width, seg.height);
    let mut edges: BTreeSet<(u32, u32)> = BTreeSet::new();
    for y in 0..h {
        for x in 0..w {
            let a = seg.labels[y * w + x];
            if x + 1 < w {
                let b = seg.labels[y * w + x + 1];
                if a != b {
                    edges.insert((a.min(b), a.max(b)));
                }
            }
            if y + 1 < h {
                let b = seg.labels[(y + 1) * w + x];
                if a != b {
                    edges.insert((a.min(b), a.max(b)));
                }
            }
        }
    }
    let mut src = Vec::with_capacity(edges.len() * 2);
    let mut dst = Vec::with_capacity(edges.len() * 2);
    let mut directed: Vec<(u32, u32)> = Vec::with_capacity(edges.len() * 2);
    for &(a, b) in &edges {
        directed.push((a, b));
        directed.push((b, a));
    }
    directed.sort_unstable();
    for (a, b) in directed {
        src.push(a);
        dst.push(b);
    }
    Csr::from_directed_sorted(seg.num_regions, &src, &dst)
}

/// Data-parallel RAG builder (paper's initialization, §3.2.1).
pub fn build_rag_dpp(bk: &dyn Device, seg: &Overseg) -> Csr {
    let (w, h) = (seg.width, seg.height);
    let n_px = w * h;
    let labels = &seg.labels;

    // Map: each pixel emits up to 2 directed boundary-crossing pairs
    // (right + down), canonicalized (min, max); non-edges emit a
    // sentinel that sorts last and is trimmed after Unique.
    const SENTINEL: u64 = u64::MAX;
    let mk = |a: u32, b: u32| -> u64 {
        if a == b {
            SENTINEL
        } else {
            dpp::pack_pair(a.min(b), a.max(b))
        }
    };
    let right: Vec<u64> = dpp::map_indexed(bk, n_px, |p| {
        let (x, y) = (p % w, p / w);
        if x + 1 < w { mk(labels[p], labels[y * w + x + 1]) } else { SENTINEL }
    });
    let down: Vec<u64> = dpp::map_indexed(bk, n_px, |p| {
        let (x, y) = (p % w, p / w);
        if y + 1 < h { mk(labels[p], labels[(y + 1) * w + x]) } else {
            SENTINEL
        }
    });

    // Concatenate, SortByKey, Unique, trim sentinels.
    let mut keys = right;
    keys.extend_from_slice(&down);
    dpp::sort_keys(bk, &mut keys);
    let uniq = dpp::unique(bk, &keys);
    let m = uniq.partition_point(|&k| k != SENTINEL);
    let undirected = &uniq[..m];

    // Mirror to directed edges and sort again for CSR grouping.
    let mut directed: Vec<u64> = Vec::with_capacity(m * 2);
    directed.extend_from_slice(undirected);
    directed.extend(undirected.iter().map(|&k| {
        let (a, b) = dpp::unpack_pair(k);
        dpp::pack_pair(b, a)
    }));
    dpp::sort_keys(bk, &mut directed);

    let src: Vec<u32> = dpp::map(bk, &directed, |&k| dpp::unpack_pair(k).0);
    let dst: Vec<u32> = dpp::map(bk, &directed, |&k| dpp::unpack_pair(k).1);
    Csr::from_directed_sorted(seg.num_regions, &src, &dst)
}

/// 3D region adjacency graph over a volume oversegmentation
/// ([`crate::overseg::oversegment_3d`]): 6-connectivity voxel pairs
/// (x+1, y+1, z+1) through the same DPP Sort/Unique pipeline. Part of
/// the paper's §5 future-work extension.
pub fn build_rag_3d(
    bk: &dyn Device,
    seg: &Overseg,
    width: usize,
    height: usize,
    depth: usize,
) -> Csr {
    assert_eq!(seg.labels.len(), width * height * depth);
    let labels = &seg.labels;
    let plane = width * height;
    const SENTINEL: u64 = u64::MAX;
    let mk = |a: u32, b: u32| -> u64 {
        if a == b { SENTINEL } else { dpp::pack_pair(a.min(b), a.max(b)) }
    };
    let n_vx = labels.len();
    let right: Vec<u64> = dpp::map_indexed(bk, n_vx, |p| {
        if (p % width) + 1 < width { mk(labels[p], labels[p + 1]) } else {
            SENTINEL
        }
    });
    let down: Vec<u64> = dpp::map_indexed(bk, n_vx, |p| {
        if (p % plane) / width + 1 < height {
            mk(labels[p], labels[p + width])
        } else {
            SENTINEL
        }
    });
    let deep: Vec<u64> = dpp::map_indexed(bk, n_vx, |p| {
        if p / plane + 1 < depth { mk(labels[p], labels[p + plane]) } else {
            SENTINEL
        }
    });

    let mut keys = right;
    keys.extend_from_slice(&down);
    keys.extend_from_slice(&deep);
    dpp::sort_keys(bk, &mut keys);
    let uniq = dpp::unique(bk, &keys);
    let m = uniq.partition_point(|&k| k != SENTINEL);
    let undirected = &uniq[..m];

    let mut directed: Vec<u64> = Vec::with_capacity(m * 2);
    directed.extend_from_slice(undirected);
    directed.extend(undirected.iter().map(|&k| {
        let (a, b) = dpp::unpack_pair(k);
        dpp::pack_pair(b, a)
    }));
    dpp::sort_keys(bk, &mut directed);
    let src: Vec<u32> = dpp::map(bk, &directed, |&k| dpp::unpack_pair(k).0);
    let dst: Vec<u32> = dpp::map(bk, &directed, |&k| dpp::unpack_pair(k).1);
    Csr::from_directed_sorted(seg.num_regions, &src, &dst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::config::OversegConfig;
    use crate::image::synth;
    use crate::overseg::oversegment;
    use crate::pool::Pool;

    fn seg_of(seed: u64) -> Overseg {
        let v = synth::experimental_volume(48, 48, 1, seed);
        oversegment(
            &Backend::Serial,
            &v.slice(0),
            &OversegConfig { scale: 48.0, min_region: 4 },
        )
    }

    #[test]
    fn dpp_matches_serial() {
        for seed in [1, 2, 3] {
            let seg = seg_of(seed);
            let a = build_rag_serial(&seg);
            let b = build_rag_dpp(&Backend::Serial, &seg);
            let c = build_rag_dpp(
                &Backend::threaded_with_grain(Pool::new(4), 128),
                &seg,
            );
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(a, c, "seed {seed}");
        }
    }

    #[test]
    fn csr_invariants() {
        let seg = seg_of(4);
        let g = build_rag_serial(&seg);
        assert_eq!(g.num_vertices(), seg.num_regions);
        assert_eq!(*g.offsets.last().unwrap() as usize, g.neighbors.len());
        for v in 0..g.num_vertices() as u32 {
            let ns = g.neighbors_of(v);
            assert!(ns.windows(2).all(|w| w[0] < w[1]), "sorted, no dups");
            assert!(!ns.contains(&v), "no self loops");
            for &u in ns {
                assert!(g.adjacent(u, v), "symmetry {u}<->{v}");
            }
        }
    }

    #[test]
    fn rag_3d_connects_across_planes() {
        use crate::image::Volume;
        // Two flat slabs stacked in z: slab A (z=0), slab B (z=1) with
        // different intensity -> 2 regions, adjacent only through z.
        let mut v = Volume::new(4, 4, 2);
        for y in 0..4 {
            for x in 0..4 {
                v.set(x, y, 1, 200);
            }
        }
        let seg = crate::overseg::oversegment_3d(
            &Backend::Serial,
            &v,
            &OversegConfig { scale: 32.0, min_region: 1 },
        );
        assert_eq!(seg.num_regions, 2);
        let g = build_rag_3d(&Backend::Serial, &seg, 4, 4, 2);
        assert_eq!(g.num_edges(), 1);
        assert!(g.adjacent(0, 1));
    }

    #[test]
    fn rag_3d_on_one_slice_matches_2d() {
        let v = synth::experimental_volume(32, 32, 1, 8);
        let seg2 = oversegment(
            &Backend::Serial,
            &v.slice(0),
            &OversegConfig { scale: 48.0, min_region: 4 },
        );
        let seg3 = crate::overseg::oversegment_3d(
            &Backend::Serial,
            &v,
            &OversegConfig { scale: 48.0, min_region: 4 },
        );
        assert_eq!(seg2.labels, seg3.labels, "single-slice equivalence");
        let g2 = build_rag_serial(&seg2);
        let g3 = build_rag_3d(&Backend::Serial, &seg3, 32, 32, 1);
        assert_eq!(g2, g3);
    }

    #[test]
    fn two_region_graph() {
        use crate::image::Volume;
        let mut img = Volume::new(8, 8, 1);
        for y in 0..8 {
            for x in 4..8 {
                img.set(x, y, 0, 200);
            }
        }
        let seg = oversegment(
            &Backend::Serial,
            &img.slice(0),
            &OversegConfig { scale: 32.0, min_region: 1 },
        );
        assert_eq!(seg.num_regions, 2);
        let g = build_rag_serial(&seg);
        assert_eq!(g.num_edges(), 1);
        assert!(g.adjacent(0, 1));
    }
}
