//! Observability layer (DESIGN.md §13) — sits on top of
//! [`crate::telemetry`] and makes engines and the serving front end
//! inspectable *while running*. Three pillars:
//!
//! - **[`recorder`]** — the convergence flight recorder: a
//!   fixed-capacity ring journal every engine feeds per iteration
//!   (MAP: energy + labels changed; BP: max residual + damping; dual:
//!   bound/primal/gap per ascent iteration; PMP: continuous energy +
//!   particle/acceptance counts per round). Armed explicitly with
//!   [`arm`]; drained into [`ConvergenceLog`] by the scheduler and
//!   surfaced as the `convergence` section of
//!   [`crate::coordinator::RunReport::to_json`] (downsampled to ≤256
//!   points) or in full via the CLI's `--convergence-out` JSONL dump.
//! - **[`health`]** — serving health: [`SloConfig`] thresholds that
//!   mark violating jobs and feed `Service::health()`, plus the
//!   per-lane [`Heartbeat`] watchdog that reports stalled lanes
//!   instead of hanging silently.
//! - **[`prometheus`]** — text-format (exposition 0.0.4) rendering of
//!   [`crate::telemetry::MetricsSnapshot`] tables and service
//!   counters, reachable as `Service::metrics_text()` and the CLI's
//!   `--metrics-out`.
//!
//! Overhead contract (same bar as telemetry, asserted by
//! `benches/alloc_churn.rs`): with nothing armed every hook below is
//! one relaxed atomic load — no clock read, no float work, no
//! allocation — so default-off runs stay bitwise-identical. Armed
//! runs reuse the `Instant` clock discipline of
//! [`crate::telemetry::span`] / [`crate::dpp::timing`]; no second
//! timing source is introduced.

pub mod health;
pub mod prometheus;
pub mod recorder;

pub use health::{
    current_heartbeat, install_heartbeat, Heartbeat, HeartbeatScope,
    SloConfig, SloFlags,
};
pub use recorder::{
    arm, armed, disarm, drain, ConvPoint, ConvSample, ConvergenceLog,
    LabelDelta, DEFAULT_CAPACITY, MIN_CAPACITY,
};

use std::sync::atomic::{AtomicUsize, Ordering};

/// Count of live observers: an armed recorder contributes one, every
/// installed [`HeartbeatScope`] contributes one. The engine hooks gate
/// on this single relaxed load, so a fully-off process pays nothing
/// else.
static LIVE: AtomicUsize = AtomicUsize::new(0);

/// True when any observer (recorder or heartbeat) is live — the only
/// check a disarmed engine iteration performs.
#[inline]
pub fn live() -> bool {
    LIVE.load(Ordering::Relaxed) != 0
}

pub(crate) fn observer_added() {
    LIVE.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn observer_removed() {
    LIVE.fetch_sub(1, Ordering::Relaxed);
}

/// Progress heartbeat without a sample: engines call this when the
/// recorder is disarmed but a serving watchdog may be listening.
/// No-op (one relaxed load) when nothing observes.
#[inline]
pub fn tick() {
    if !live() {
        return;
    }
    health::beat();
}

/// Record one MAP iteration: total energy and the number of vertices
/// whose label changed. Callers gate on [`armed`] because both inputs
/// cost work to compute.
pub fn map_sample(em: usize, iter: usize, energy: f64, labels_changed: u64) {
    if !live() {
        return;
    }
    health::beat();
    recorder::push(
        em,
        iter,
        ConvPoint::Map { energy, labels_changed },
    );
}

/// Record one BP sweep: the frontier's max residual, the damping in
/// effect, how many messages were updated, which frontier policy ran
/// the sweep (`BpSchedule::name`), and the fraction of directed
/// messages it committed (DESIGN.md §15).
#[allow(clippy::too_many_arguments)]
pub fn bp_sample(
    em: usize,
    sweep: usize,
    max_residual: f64,
    damping: f64,
    updated: u64,
    policy: &'static str,
    committed_frac: f64,
) {
    if !live() {
        return;
    }
    health::beat();
    recorder::push(
        em,
        sweep,
        ConvPoint::Bp {
            max_residual,
            damping,
            updated,
            policy,
            committed_frac,
        },
    );
}

/// Record one dual ascent iteration: certified lower bound, the primal
/// energy of the decoded labeling, and the gap between them.
pub fn dual_sample(
    em: usize,
    iter: usize,
    lower_bound: f64,
    primal: f64,
    gap: f64,
) {
    if !live() {
        return;
    }
    health::beat();
    recorder::push(
        em,
        iter,
        ConvPoint::Dual { lower_bound, primal, gap },
    );
}

/// Record one particle max-product round: the decoded labeling's
/// continuous energy, the live particle count, and how many fresh
/// proposals survived the round's select-and-prune.
pub fn pmp_sample(
    em: usize,
    round: usize,
    energy: f64,
    particles: u64,
    accepted: u64,
) {
    if !live() {
        return;
    }
    health::beat();
    recorder::push(
        em,
        round,
        ConvPoint::Pmp { energy, particles, accepted },
    );
}

/// Serializes tests that arm the process-global recorder (same
/// convention as [`crate::telemetry::trace_test_lock`] /
/// `timing::test_lock`). Not part of the public API.
#[doc(hidden)]
pub fn obs_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_hooks_are_inert() {
        let _g = obs_test_lock();
        assert!(!armed());
        // None of these may panic, observe, or arm anything.
        tick();
        map_sample(0, 0, 1.0, 2);
        bp_sample(0, 1, 0.5, 0.5, 3, "residual", 0.3);
        dual_sample(0, 2, 1.0, 2.0, 1.0);
        pmp_sample(0, 3, 1.0, 12, 4);
        assert!(drain().is_none());
    }

    #[test]
    fn armed_recorder_collects_all_four_kinds() {
        let _g = obs_test_lock();
        arm(16);
        assert!(armed() && live());
        map_sample(0, 0, -10.0, 7);
        bp_sample(1, 3, 0.25, 0.5, 11, "bucketed", 0.5);
        dual_sample(2, 5, -20.0, -18.5, 1.5);
        pmp_sample(3, 7, -31.5, 24, 9);
        let log = drain().expect("armed recorder drains Some");
        assert_eq!(log.samples.len(), 4);
        assert_eq!(log.dropped, 0);
        match log.samples[0].point {
            ConvPoint::Map { energy, labels_changed } => {
                assert_eq!(energy, -10.0);
                assert_eq!(labels_changed, 7);
            }
            ref p => panic!("expected Map point, got {p:?}"),
        }
        assert_eq!((log.samples[1].em, log.samples[1].iter), (1, 3));
        match log.samples[2].point {
            ConvPoint::Dual { lower_bound, primal, gap } => {
                assert_eq!(lower_bound, -20.0);
                assert_eq!(primal, -18.5);
                assert_eq!(gap, 1.5);
            }
            ref p => panic!("expected Dual point, got {p:?}"),
        }
        match log.samples[3].point {
            ConvPoint::Pmp { energy, particles, accepted } => {
                assert_eq!(energy, -31.5);
                assert_eq!(particles, 24);
                assert_eq!(accepted, 9);
            }
            ref p => panic!("expected Pmp point, got {p:?}"),
        }
        let j = log.samples[3].to_json();
        assert_eq!(
            j.get("kind").and_then(crate::json::Value::as_str),
            Some("pmp")
        );
        assert_eq!(
            j.get("accepted").and_then(crate::json::Value::as_usize),
            Some(9)
        );
        disarm();
        assert!(!armed());
    }
}
