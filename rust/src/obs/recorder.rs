//! Convergence flight recorder: a process-global, fixed-capacity ring
//! journal of per-iteration engine samples.
//!
//! Design (DESIGN.md §13): the ring is preallocated at [`arm`] time,
//! so pushing a sample in the steady state is one mutex lock and one
//! slot write — no allocation ever after arming. When the ring fills,
//! the oldest samples are overwritten (flight-recorder semantics: the
//! tail of a long run is always retained) and `dropped` counts what
//! was lost. [`drain`] empties the ring into a [`ConvergenceLog`]
//! without disarming, so a serving process can journal run after run.
//!
//! The recorder is process-global on purpose — engines are driven
//! deep inside scheduler lanes and cannot thread a handle through the
//! `Engine` trait without changing every implementation's signature.
//! The cost is that concurrent runs interleave their samples; callers
//! that need per-run isolation run one job at a time while armed (the
//! CLI does) or drain between jobs.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Value;

/// Default ring capacity in samples (~3 MB armed): enough for every
/// iteration of a multi-slice run at the default iteration caps.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Smallest ring the recorder will arm. A 1-slot ring cannot retain
/// both endpoints of a run, which the report downsampler relies on,
/// so [`arm`] clamps to this and the CLI rejects `--convergence-cap`
/// values below it outright.
pub const MIN_CAPACITY: usize = 2;

/// Per-kind payload of one journal sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConvPoint {
    /// One MAP (Jacobi) iteration of a primal engine.
    Map { energy: f64, labels_changed: u64 },
    /// One BP sweep under a frontier policy (DESIGN.md §15): `policy`
    /// is the schedule family name and `committed_frac` the fraction
    /// of directed messages committed this sweep.
    Bp {
        max_residual: f64,
        damping: f64,
        updated: u64,
        policy: &'static str,
        committed_frac: f64,
    },
    /// One dual block-coordinate ascent iteration.
    Dual { lower_bound: f64, primal: f64, gap: f64 },
    /// One particle max-product round: decoded continuous energy,
    /// the live particle count, and proposals that survived pruning.
    Pmp { energy: f64, particles: u64, accepted: u64 },
}

impl ConvPoint {
    /// The `kind` discriminator used in JSON output.
    pub fn kind(&self) -> &'static str {
        match self {
            ConvPoint::Map { .. } => "map",
            ConvPoint::Bp { .. } => "bp",
            ConvPoint::Dual { .. } => "dual",
            ConvPoint::Pmp { .. } => "pmp",
        }
    }
}

/// One journal entry: when (nanos since arming), where in the run
/// (EM iteration, inner iteration), and the kind-specific payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvSample {
    pub t_nanos: u64,
    pub em: u32,
    pub iter: u32,
    pub point: ConvPoint,
}

impl ConvSample {
    /// Flat JSON object — the JSONL line format of `--convergence-out`
    /// and the element format of the report's `convergence.points`.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("kind", Value::str(self.point.kind())),
            ("t_nanos", (self.t_nanos as usize).into()),
            ("em", (self.em as usize).into()),
            ("iter", (self.iter as usize).into()),
        ];
        match self.point {
            ConvPoint::Map { energy, labels_changed } => {
                fields.push(("energy", energy.into()));
                fields.push(("labels_changed",
                             (labels_changed as usize).into()));
            }
            ConvPoint::Bp {
                max_residual,
                damping,
                updated,
                policy,
                committed_frac,
            } => {
                fields.push(("max_residual", max_residual.into()));
                fields.push(("damping", damping.into()));
                fields.push(("updated", (updated as usize).into()));
                fields.push(("policy", Value::str(policy)));
                fields.push(("committed_frac", committed_frac.into()));
            }
            ConvPoint::Dual { lower_bound, primal, gap } => {
                fields.push(("lower_bound", lower_bound.into()));
                fields.push(("primal", primal.into()));
                fields.push(("gap", gap.into()));
            }
            ConvPoint::Pmp { energy, particles, accepted } => {
                fields.push(("energy", energy.into()));
                fields.push(("particles", (particles as usize).into()));
                fields.push(("accepted", (accepted as usize).into()));
            }
        }
        Value::object(fields)
    }
}

/// A drained journal: samples in chronological order plus how many
/// older samples the ring overwrote.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ConvergenceLog {
    pub samples: Vec<ConvSample>,
    pub dropped: u64,
}

/// Downsampling bound for the report's `convergence.points` section.
const MAX_REPORT_POINTS: usize = 256;

impl ConvergenceLog {
    /// Total samples ever recorded into this journal window.
    pub fn total(&self) -> u64 {
        self.dropped + self.samples.len() as u64
    }

    /// Full-fidelity dump: one JSON object per line (`--convergence-out`).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for s in &self.samples {
            out.push_str(&s.to_json().to_string());
            out.push('\n');
        }
        out
    }

    /// Report section: retained/dropped counts plus at most 256
    /// points. Downsampling is strided with the first and last sample
    /// always kept exactly (DESIGN.md §13), so endpoints of the JSONL
    /// dump and of the report agree.
    pub fn to_json(&self) -> Value {
        let n = self.samples.len();
        let mut points = Vec::with_capacity(n.min(MAX_REPORT_POINTS));
        if n <= MAX_REPORT_POINTS {
            points.extend(self.samples.iter().map(ConvSample::to_json));
        } else {
            // Stride k covers indices 0, k, 2k, ... with at most 255
            // strided picks; the exact last sample is appended.
            let k = (n - 1).div_ceil(MAX_REPORT_POINTS - 1);
            let mut i = 0;
            while i < n - 1 {
                points.push(self.samples[i].to_json());
                i += k;
            }
            points.push(self.samples[n - 1].to_json());
        }
        Value::object(vec![
            ("samples", self.samples.len().into()),
            ("dropped", (self.dropped as usize).into()),
            ("points", Value::Array(points)),
        ])
    }
}

/// The armed ring. Preallocated to capacity; circular once full.
struct Ring {
    t0: Instant,
    buf: Vec<ConvSample>,
    cap: usize,
    /// Overwrite cursor, meaningful once `buf.len() == cap`.
    next: usize,
    dropped: u64,
}

impl Ring {
    fn new(capacity: usize) -> Ring {
        let cap = capacity.max(MIN_CAPACITY);
        Ring {
            t0: Instant::now(),
            buf: Vec::with_capacity(cap),
            cap,
            next: 0,
            dropped: 0,
        }
    }

    fn push(&mut self, mut s: ConvSample) {
        s.t_nanos = self.t0.elapsed().as_nanos() as u64;
        if self.buf.len() < self.cap {
            self.buf.push(s);
        } else {
            self.buf[self.next] = s;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn drain(&mut self) -> ConvergenceLog {
        let mut samples = Vec::with_capacity(self.buf.len());
        // Chronological order: the overwrite cursor points at the
        // oldest retained sample once the ring has wrapped.
        samples.extend_from_slice(&self.buf[self.next..]);
        samples.extend_from_slice(&self.buf[..self.next]);
        let dropped = self.dropped;
        self.buf.clear();
        self.next = 0;
        self.dropped = 0;
        ConvergenceLog { samples, dropped }
    }
}

static ARMED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<Option<Ring>> = Mutex::new(None);

/// Arm the flight recorder with a ring of `capacity` samples
/// (preallocated now; pushes never allocate). Re-arming while armed
/// replaces the ring and discards its contents.
pub fn arm(capacity: usize) {
    let mut ring = RING.lock().unwrap();
    if ring.is_none() {
        super::observer_added();
    }
    *ring = Some(Ring::new(capacity));
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm and discard any unread samples.
pub fn disarm() {
    let mut ring = RING.lock().unwrap();
    ARMED.store(false, Ordering::Relaxed);
    if ring.take().is_some() {
        super::observer_removed();
    }
}

/// True when the ring is armed — engines gate sample *computation*
/// (energy sums, label diffs) on this.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Empty the ring into a log without disarming. `None` when disarmed.
pub fn drain() -> Option<ConvergenceLog> {
    RING.lock().unwrap().as_mut().map(Ring::drain)
}

pub(crate) fn push(em: usize, iter: usize, point: ConvPoint) {
    if !armed() {
        return;
    }
    let mut ring = RING.lock().unwrap();
    if let Some(r) = ring.as_mut() {
        r.push(ConvSample {
            t_nanos: 0, // stamped by Ring::push from the arm clock
            em: em as u32,
            iter: iter as u32,
            point,
        });
    }
}

/// Cross-iteration state for the MAP engines' labels-changed counter:
/// keeps the previous iteration's labels (as `u8`) and counts diffs.
/// The first call after a size change only seeds the buffer and
/// reports 0 — callers seed once before their iteration loop so every
/// in-loop call reports a true delta. Only used on armed runs; the
/// seed call is the single (warmup) allocation.
#[derive(Debug, Default)]
pub struct LabelDelta {
    prev: Vec<u8>,
}

impl LabelDelta {
    pub fn new() -> LabelDelta {
        LabelDelta { prev: Vec::new() }
    }

    /// Count label changes vs. the previous call, then remember
    /// `labels` for the next one.
    pub fn update_u8(&mut self, labels: &[u8]) -> u64 {
        if self.prev.len() != labels.len() {
            self.prev.clear();
            self.prev.extend_from_slice(labels);
            return 0;
        }
        let mut changed = 0u64;
        for (p, &l) in self.prev.iter_mut().zip(labels) {
            changed += u64::from(*p != l);
            *p = l;
        }
        changed
    }

    /// Same, for the Paper-mode step whose label state is `f32`
    /// (binary values stored as floats).
    pub fn update_f32(&mut self, labels: &[f32]) -> u64 {
        if self.prev.len() != labels.len() {
            self.prev.clear();
            self.prev.extend(labels.iter().map(|&l| l as u8));
            return 0;
        }
        let mut changed = 0u64;
        for (p, &l) in self.prev.iter_mut().zip(labels) {
            let l = l as u8;
            changed += u64::from(*p != l);
            *p = l;
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_reports_drops() {
        let mut r = Ring::new(4);
        for i in 0..7u64 {
            r.push(ConvSample {
                t_nanos: 0,
                em: 0,
                iter: i as u32,
                point: ConvPoint::Map { energy: i as f64,
                                        labels_changed: 0 },
            });
        }
        let log = r.drain();
        assert_eq!(log.dropped, 3);
        assert_eq!(log.total(), 7);
        let iters: Vec<u32> =
            log.samples.iter().map(|s| s.iter).collect();
        assert_eq!(iters, [3, 4, 5, 6], "oldest retained first");
        // Drained ring is empty but still usable.
        let log2 = r.drain();
        assert!(log2.samples.is_empty());
        assert_eq!(log2.dropped, 0);
    }

    fn map_sample_at(iter: u32) -> ConvSample {
        ConvSample {
            t_nanos: 0,
            em: 0,
            iter,
            point: ConvPoint::Map { energy: iter as f64,
                                    labels_changed: 0 },
        }
    }

    #[test]
    fn capacity_two_ring_keeps_newest_two_in_order() {
        let mut r = Ring::new(2);
        for i in 0..5 {
            r.push(map_sample_at(i));
        }
        let log = r.drain();
        assert_eq!(log.dropped, 3, "5 pushes into 2 slots drop 3");
        assert_eq!(log.total(), 5);
        let iters: Vec<u32> =
            log.samples.iter().map(|s| s.iter).collect();
        assert_eq!(iters, [3, 4], "oldest retained first");
    }

    #[test]
    fn exactly_full_ring_drops_nothing() {
        let mut r = Ring::new(2);
        r.push(map_sample_at(0));
        r.push(map_sample_at(1));
        let log = r.drain();
        assert_eq!(log.dropped, 0, "fill-to-capacity is lossless");
        let iters: Vec<u32> =
            log.samples.iter().map(|s| s.iter).collect();
        assert_eq!(iters, [0, 1]);
    }

    #[test]
    fn zero_capacity_arms_as_min_capacity() {
        let mut r = Ring::new(0);
        assert_eq!(r.cap, MIN_CAPACITY);
        r.push(map_sample_at(0));
        r.push(map_sample_at(1));
        assert_eq!(r.drain().dropped, 0);
    }

    #[test]
    fn drain_leaves_the_ring_armed_and_recording() {
        let _g = crate::obs::obs_test_lock();
        arm(4);
        push(0, 0, ConvPoint::Map { energy: 1.0, labels_changed: 0 });
        let first = drain().expect("armed recorder drains Some");
        assert_eq!(first.samples.len(), 1);
        // Still armed: the next push lands in the same ring and a
        // second drain sees it with counters reset.
        assert!(armed());
        push(0, 1, ConvPoint::Pmp { energy: -2.0, particles: 8,
                                    accepted: 3 });
        let second = drain().expect("ring survives drain");
        assert_eq!(second.samples.len(), 1);
        assert_eq!(second.dropped, 0);
        assert_eq!(second.samples[0].iter, 1);
        disarm();
    }

    #[test]
    fn timestamps_are_monotone() {
        let mut r = Ring::new(8);
        for i in 0..5 {
            r.push(ConvSample {
                t_nanos: 0,
                em: 0,
                iter: i,
                point: ConvPoint::Dual { lower_bound: 0.0, primal: 0.0,
                                         gap: 0.0 },
            });
        }
        let log = r.drain();
        for w in log.samples.windows(2) {
            assert!(w[0].t_nanos <= w[1].t_nanos);
        }
    }

    #[test]
    fn downsampling_keeps_exact_endpoints_under_256_points() {
        let samples: Vec<ConvSample> = (0..1000u32)
            .map(|i| ConvSample {
                t_nanos: i as u64,
                em: 0,
                iter: i,
                point: ConvPoint::Map { energy: i as f64,
                                        labels_changed: 0 },
            })
            .collect();
        let log = ConvergenceLog { samples, dropped: 5 };
        let j = log.to_json();
        assert_eq!(j.get("samples").and_then(Value::as_usize), Some(1000));
        assert_eq!(j.get("dropped").and_then(Value::as_usize), Some(5));
        let points = j.get("points").and_then(Value::as_array).unwrap();
        assert!(points.len() <= 256, "{} points", points.len());
        assert_eq!(points[0].get("iter").and_then(Value::as_usize), Some(0));
        assert_eq!(
            points[points.len() - 1].get("iter").and_then(Value::as_usize),
            Some(999)
        );
        // Small logs pass through exactly.
        let small = ConvergenceLog {
            samples: log.samples[..10].to_vec(),
            dropped: 0,
        };
        let pj = small.to_json();
        assert_eq!(
            pj.get("points").and_then(Value::as_array).unwrap().len(),
            10
        );
    }

    #[test]
    fn jsonl_lines_parse_and_carry_kind_fields() {
        let log = ConvergenceLog {
            samples: vec![
                ConvSample {
                    t_nanos: 1,
                    em: 0,
                    iter: 0,
                    point: ConvPoint::Bp { max_residual: 0.5,
                                           damping: 0.5, updated: 9,
                                           policy: "stale",
                                           committed_frac: 0.75 },
                },
                ConvSample {
                    t_nanos: 2,
                    em: 0,
                    iter: 1,
                    point: ConvPoint::Dual { lower_bound: -3.0,
                                             primal: -1.0, gap: 2.0 },
                },
            ],
            dropped: 0,
        };
        let text = log.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v0 = crate::json::parse(lines[0]).unwrap();
        assert_eq!(v0.get("kind").and_then(Value::as_str), Some("bp"));
        assert_eq!(v0.get("updated").and_then(Value::as_usize), Some(9));
        assert_eq!(v0.get("policy").and_then(Value::as_str),
                   Some("stale"));
        assert_eq!(v0.get("committed_frac").and_then(Value::as_f64),
                   Some(0.75));
        let v1 = crate::json::parse(lines[1]).unwrap();
        assert_eq!(v1.get("kind").and_then(Value::as_str), Some("dual"));
        assert_eq!(v1.get("gap").and_then(Value::as_f64), Some(2.0));
    }

    #[test]
    fn label_delta_counts_changes() {
        let mut d = LabelDelta::new();
        assert_eq!(d.update_u8(&[0, 1, 0, 1]), 0, "seed call");
        assert_eq!(d.update_u8(&[0, 1, 1, 1]), 1);
        assert_eq!(d.update_u8(&[1, 0, 0, 0]), 4);
        assert_eq!(d.update_u8(&[1, 0, 0, 0]), 0);
        let mut f = LabelDelta::new();
        assert_eq!(f.update_f32(&[0.0, 1.0]), 0, "seed call");
        assert_eq!(f.update_f32(&[1.0, 1.0]), 1);
    }
}
