//! Prometheus text-format rendering (exposition format 0.0.4).
//!
//! A deliberately small writer: `# HELP` / `# TYPE` family headers
//! plus `name{labels} value` sample lines, with label-value escaping
//! per the spec. Metric *families* are fixed names; dynamic row names
//! (primitive names, counter names, lane indices) go into labels, so
//! every emitted name is a valid Prometheus identifier by
//! construction.
//!
//! Log2-histogram translation (DESIGN.md §13): bucket `b >= 1` of a
//! [`Log2Histogram`] holds values in `[2^(b-1), 2^b - 1]` of the
//! recorded unit, so it maps to a cumulative Prometheus bucket with
//! `le = (2^b - 1) * scale` (bucket 0, exact zeros, maps to
//! `le = 0`). Buckets above the highest non-empty one collapse into
//! `+Inf`, which always carries the total count; `_sum` is scaled the
//! same way.

use crate::telemetry::{Log2Histogram, MetricsSnapshot};

/// Incremental exposition writer. Declare each family once with
/// [`family`](TextWriter::family), then emit its samples.
#[derive(Debug, Default)]
pub struct TextWriter {
    out: String,
}

/// Format a sample value: integers without a fraction, `+Inf`/`-Inf`
/// spelled the Prometheus way.
fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".into() } else { "-Inf".into() };
    }
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn push_label_value(out: &mut String, v: &str) {
    out.push('"');
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl TextWriter {
    pub fn new() -> TextWriter {
        TextWriter::default()
    }

    /// Emit the `# HELP` / `# TYPE` header for a metric family.
    /// `kind` is one of `counter`, `gauge`, `histogram`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// Emit one `name{labels} value` sample line.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, val)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push('=');
                push_label_value(&mut self.out, val);
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(v));
        self.out.push('\n');
    }

    /// Emit a full histogram family body (`_bucket`/`_sum`/`_count`)
    /// from a log2 histogram whose samples are in `1/scale` units
    /// (e.g. `scale = 1e-9` renders nanosecond samples as seconds).
    /// The `histogram`-typed family header must already be declared.
    pub fn log2_hist(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        h: &Log2Histogram,
        scale: f64,
    ) {
        let bucket_name = format!("{name}_bucket");
        let counts = h.bucket_counts();
        let last = counts
            .iter()
            .rposition(|&c| c != 0)
            .unwrap_or(0);
        let mut cum = 0u64;
        let with_le = |w: &mut TextWriter, le: &str, cum: u64| {
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            ls.push(("le", le));
            w.sample(&bucket_name, &ls, cum as f64);
        };
        for (b, &c) in counts.iter().enumerate().take(last + 1) {
            cum += c;
            // Upper bound of log2 bucket b (inclusive): 0 for the
            // zero bucket, 2^b - 1 otherwise.
            let ub = if b == 0 {
                0.0
            } else {
                (2f64.powi(b as i32) - 1.0) * scale
            };
            with_le(self, &fmt_value(ub), cum);
        }
        with_le(self, "+Inf", h.total());
        self.sample(
            &format!("{name}_sum"),
            labels,
            h.sum() as f64 * scale,
        );
        self.sample(&format!("{name}_count"), labels, h.total() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Render a [`MetricsSnapshot`]'s four tables as exposition families:
/// time rows become `dpp_op_seconds_total` / `dpp_op_calls_total`
/// (labelled by `op`), counters `dpp_counter_total`, gauges
/// `dpp_gauge`, and histograms `dpp_hist_seconds` (nanosecond samples
/// rendered as seconds).
pub fn render_snapshot(w: &mut TextWriter, snap: &MetricsSnapshot) {
    if !snap.time_rows.is_empty() {
        w.family("dpp_op_seconds_total", "counter",
                 "Cumulative wall time per primitive/stage.");
        for (name, row) in &snap.time_rows {
            w.sample("dpp_op_seconds_total", &[("op", name)], row.secs());
        }
        w.family("dpp_op_calls_total", "counter",
                 "Cumulative invocations per primitive/stage.");
        for (name, row) in &snap.time_rows {
            w.sample("dpp_op_calls_total", &[("op", name)],
                     row.calls as f64);
        }
    }
    if !snap.counters.is_empty() {
        w.family("dpp_counter_total", "counter",
                 "Telemetry counters (bytes, hits...).");
        for (name, v) in &snap.counters {
            w.sample("dpp_counter_total", &[("name", name)], *v as f64);
        }
    }
    if !snap.gauges.is_empty() {
        w.family("dpp_gauge", "gauge",
                 "Telemetry gauges (high-water marks).");
        for (name, v) in &snap.gauges {
            w.sample("dpp_gauge", &[("name", name)], *v as f64);
        }
    }
    if !snap.hists.is_empty() {
        w.family("dpp_hist_seconds", "histogram",
                 "Telemetry latency distributions.");
        for (name, h) in &snap.hists {
            w.log2_hist("dpp_hist_seconds", &[("name", name)], h, 1e-9);
        }
    }
}

/// The global `dpp::timing` registry as a [`MetricsSnapshot`]: rows
/// under [`crate::dpp::timing::COUNTER_PREFIX`] are counters (their
/// value lives in the nanos column by the legacy convention), the rest
/// are time rows. This is what the CLI's `--metrics-out` renders —
/// the scoped [`crate::telemetry::Recorder`] is thread-local and
/// cannot observe sharded lanes, the global registry can.
pub fn timing_snapshot() -> MetricsSnapshot {
    use crate::dpp::timing::COUNTER_PREFIX;
    let mut snap = MetricsSnapshot::default();
    for (name, st) in crate::dpp::timing::snapshot() {
        if name.starts_with(COUNTER_PREFIX) {
            snap.counters.insert(name, st.nanos);
        } else {
            snap.time_rows.insert(
                name,
                crate::telemetry::TimeRow { calls: st.calls,
                                            nanos: st.nanos },
            );
        }
    }
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn families_and_samples_format() {
        let mut w = TextWriter::new();
        w.family("dpp_jobs_total", "counter", "Jobs by state.");
        w.sample("dpp_jobs_total", &[("state", "completed")], 3.0);
        w.sample("dpp_queue_depth", &[], 0.0);
        let text = w.finish();
        assert!(text.contains("# HELP dpp_jobs_total Jobs by state.\n"));
        assert!(text.contains("# TYPE dpp_jobs_total counter\n"));
        assert!(text.contains("dpp_jobs_total{state=\"completed\"} 3\n"));
        assert!(text.contains("dpp_queue_depth 0\n"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut w = TextWriter::new();
        w.sample("m", &[("name", "a\"b\\c\nd")], 1.0);
        assert_eq!(w.finish(), "m{name=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn value_formatting() {
        assert_eq!(fmt_value(3.0), "3");
        assert_eq!(fmt_value(3.5), "3.5");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
    }

    #[test]
    fn log2_hist_buckets_are_cumulative_and_end_at_inf() {
        let mut h = Log2Histogram::new();
        h.record(0); // bucket 0
        h.record(1); // bucket 1: [1, 1]
        h.record(3); // bucket 2: [2, 3]
        h.record(3);
        let mut w = TextWriter::new();
        w.family("lat", "histogram", "test");
        w.log2_hist("lat", &[], &h, 1.0);
        let text = w.finish();
        assert!(text.contains("lat_bucket{le=\"0\"} 1\n"), "{text}");
        assert!(text.contains("lat_bucket{le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("lat_bucket{le=\"3\"} 4\n"), "{text}");
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 4\n"), "{text}");
        assert!(text.contains("lat_sum 7\n"), "{text}");
        assert!(text.contains("lat_count 4\n"), "{text}");
    }

    #[test]
    fn snapshot_renders_all_tables() {
        let mut snap = MetricsSnapshot::default();
        snap.time_rows.insert(
            "SortByKey",
            crate::telemetry::TimeRow { calls: 4, nanos: 2_000_000_000 },
        );
        snap.counters.insert("Workspace::hit", 1024);
        snap.gauges.insert("Workspace::high_water_bytes", 99);
        let mut h = Log2Histogram::new();
        h.record(1_000_000_000);
        snap.hists.insert("wait", h);
        let mut w = TextWriter::new();
        render_snapshot(&mut w, &snap);
        let text = w.finish();
        assert!(text
            .contains("dpp_op_seconds_total{op=\"SortByKey\"} 2\n"));
        assert!(text.contains("dpp_op_calls_total{op=\"SortByKey\"} 4\n"));
        assert!(text
            .contains("dpp_counter_total{name=\"Workspace::hit\"} 1024\n"));
        assert!(text.contains(
            "dpp_gauge{name=\"Workspace::high_water_bytes\"} 99\n"
        ));
        assert!(text.contains("dpp_hist_seconds_count{name=\"wait\"} 1\n"));
    }
}
