//! Serving health: SLO thresholds and the stalled-lane watchdog.
//!
//! The watchdog is deliberately passive — no background thread. Every
//! service worker owns a [`Heartbeat`] (shared atomics) that engine
//! iteration hooks mark through a thread-local: [`install_heartbeat`]
//! binds the current thread to a lane's heartbeat, and the scheduler
//! propagates that binding into the lane threads it spawns (see
//! `sched::run_slices`). `Service::health()` then *computes*
//! stalledness on demand: a lane that is busy but has not marked
//! progress within the stall window is reported stalled instead of
//! hanging the caller.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Serving SLO thresholds (`None` = not enforced). Violations are
/// marked on the job's `JobStats` and counted by `Service::health()`.
///
/// * `max_gap` — certified optimality gap (energy units) of the job's
///   report. Only certifying engines (dual) produce a gap; jobs
///   without one can never violate this SLO.
/// * `max_queue_wait` — seconds between admission and execution start.
/// * `max_job_latency` — seconds between admission and completion
///   (queue wait + execution).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SloConfig {
    pub max_gap: Option<f64>,
    pub max_queue_wait: Option<f64>,
    pub max_job_latency: Option<f64>,
}

impl SloConfig {
    /// True when no threshold is set (the default-off fast path).
    pub fn is_disabled(&self) -> bool {
        self.max_gap.is_none()
            && self.max_queue_wait.is_none()
            && self.max_job_latency.is_none()
    }
}

/// Which SLOs a finished job violated (all false when no [`SloConfig`]
/// threshold was set or none tripped).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SloFlags {
    pub gap: bool,
    pub queue_wait: bool,
    pub job_latency: bool,
}

impl SloFlags {
    pub fn any(&self) -> bool {
        self.gap || self.queue_wait || self.job_latency
    }
}

/// Lane progress clock: `mark` stamps "now", `secs_since` reads the
/// age of the last stamp. Lock-free (one atomic each way); shared
/// between a service worker, the lane threads the scheduler spawns on
/// its behalf, and the `health()` reader.
#[derive(Debug)]
pub struct Heartbeat {
    t0: Instant,
    last_nanos: AtomicU64,
}

impl Default for Heartbeat {
    fn default() -> Heartbeat {
        Heartbeat::new()
    }
}

impl Heartbeat {
    pub fn new() -> Heartbeat {
        Heartbeat { t0: Instant::now(), last_nanos: AtomicU64::new(0) }
    }

    /// Stamp a progress event.
    pub fn mark(&self) {
        self.last_nanos
            .store(self.t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Seconds since the last [`mark`](Heartbeat::mark) (or since
    /// creation, if never marked).
    pub fn secs_since(&self) -> f64 {
        let now = self.t0.elapsed().as_nanos() as u64;
        let last = self.last_nanos.load(Ordering::Relaxed);
        now.saturating_sub(last) as f64 / 1e9
    }
}

thread_local! {
    static CURRENT: RefCell<Vec<Arc<Heartbeat>>> =
        const { RefCell::new(Vec::new()) };
}

/// Bind `hb` as the current thread's progress heartbeat until the
/// returned guard drops. Engine hooks ([`super::tick`] and the sample
/// functions) mark it on every iteration. Scopes nest; the innermost
/// binding wins.
#[must_use = "the heartbeat only receives marks while the scope lives"]
pub fn install_heartbeat(hb: Arc<Heartbeat>) -> HeartbeatScope {
    super::observer_added();
    CURRENT.with(|c| c.borrow_mut().push(hb));
    HeartbeatScope { _not_send: std::marker::PhantomData }
}

/// The current thread's heartbeat binding, if any — used by the
/// scheduler to propagate a service worker's heartbeat into the lane
/// threads it spawns.
pub fn current_heartbeat() -> Option<Arc<Heartbeat>> {
    CURRENT.with(|c| c.borrow().last().cloned())
}

/// RAII guard from [`install_heartbeat`]. `!Send`: must drop on the
/// installing thread.
pub struct HeartbeatScope {
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Drop for HeartbeatScope {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            c.borrow_mut().pop();
        });
        super::observer_removed();
    }
}

/// Mark the current thread's heartbeat, if one is installed. Callers
/// gate on [`super::live`] so unobserved threads never touch the TLS.
pub(crate) fn beat() {
    CURRENT.with(|c| {
        if let Some(hb) = c.borrow().last() {
            hb.mark();
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_config_default_is_disabled() {
        assert!(SloConfig::default().is_disabled());
        assert!(!SloConfig { max_gap: Some(1.0), ..Default::default() }
            .is_disabled());
        assert!(!SloFlags::default().any());
        assert!(SloFlags { queue_wait: true, ..Default::default() }.any());
    }

    #[test]
    fn heartbeat_mark_resets_age() {
        let hb = Arc::new(Heartbeat::new());
        std::thread::sleep(std::time::Duration::from_millis(5));
        let before = hb.secs_since();
        assert!(before >= 0.004, "unmarked age grows: {before}");
        hb.mark();
        assert!(hb.secs_since() < before);
    }

    #[test]
    fn installed_heartbeat_receives_engine_ticks() {
        let hb = Arc::new(Heartbeat::new());
        {
            let _scope = install_heartbeat(hb.clone());
            assert!(super::super::live());
            assert!(current_heartbeat().is_some());
            std::thread::sleep(std::time::Duration::from_millis(5));
            super::super::tick();
            assert!(hb.secs_since() < 0.004, "tick marked the heartbeat");
        }
        assert!(current_heartbeat().is_none());
    }

    #[test]
    fn heartbeat_propagates_to_spawned_threads_by_hand() {
        // The sched propagation pattern: capture on the parent,
        // install inside the child.
        let hb = Arc::new(Heartbeat::new());
        let _scope = install_heartbeat(hb.clone());
        let captured = current_heartbeat();
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(current_heartbeat().is_none(), "TLS not inherited");
                let _inner = captured.clone().map(install_heartbeat);
                super::super::tick();
            });
        });
        assert!(hb.secs_since() < 0.5, "child tick reached the heartbeat");
    }
}
