//! Deterministic PRNGs (PCG32 + SplitMix64).
//!
//! The offline registry has no `rand` crate, so the repo carries its own
//! generators. Everything randomized in the library (label init, noise
//! models, synthetic volumes, property tests) goes through [`Pcg32`] so
//! runs are reproducible from a single seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary state/stream pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single value (stream derived via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, splitmix64(seed ^ 0x9e3779b97f4a7c15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below(hi - lo)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; no caching to
    /// keep reseeding semantics trivial).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// SplitMix64 — used for seed expansion and as a cheap hash.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::seeded(7);
        for bound in [1u32, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f32_unit_interval_and_mean() {
        let mut r = Pcg32::seeded(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
