//! Small integer histogram used for neighborhood-demographics reports
//! (§4.3.3 of the paper attributes scaling differences to the
//! neighborhood-size distribution) and for benchmark summaries.

/// Histogram over u32 values with fixed-width bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub bin_width: u32,
    pub counts: Vec<u64>,
    pub total: u64,
    pub min: u32,
    pub max: u32,
    pub sum: u64,
}

impl Histogram {
    pub fn new(bin_width: u32) -> Self {
        assert!(bin_width > 0);
        Histogram {
            bin_width,
            counts: Vec::new(),
            total: 0,
            min: u32::MAX,
            max: 0,
            sum: 0,
        }
    }

    pub fn add(&mut self, v: u32) {
        let bin = (v / self.bin_width) as usize;
        if bin >= self.counts.len() {
            self.counts.resize(bin + 1, 0);
        }
        self.counts[bin] += 1;
        self.total += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u64;
    }

    pub fn from_values(values: impl IntoIterator<Item = u32>, bin_width: u32)
        -> Self {
        let mut h = Histogram::new(bin_width);
        for v in values {
            h.add(v);
        }
        h
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum as f64 / self.total as f64 }
    }

    /// Coefficient of variation of the *bin counts* — a cheap "how
    /// irregular is this distribution" number used in reports.
    pub fn irregularity(&self) -> f64 {
        let nz: Vec<f64> = self
            .counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| c as f64)
            .collect();
        if nz.len() < 2 {
            return 0.0;
        }
        let mean = nz.iter().sum::<f64>() / nz.len() as f64;
        let var = nz.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / nz.len() as f64;
        var.sqrt() / mean
    }

    /// ASCII rendering for log output / bench reports.
    pub fn render(&self, width: usize) -> String {
        let peak = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let bar = "#".repeat(((c as f64 / peak as f64) * width as f64)
                .round() as usize);
            out.push_str(&format!(
                "{:>6}-{:<6} | {:<width$} {}\n",
                i as u32 * self.bin_width,
                (i as u32 + 1) * self.bin_width - 1,
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_stats() {
        let h = Histogram::from_values([1, 2, 3, 10, 11, 25], 10);
        assert_eq!(h.counts, vec![3, 2, 1]);
        assert_eq!(h.total, 6);
        assert_eq!(h.min, 1);
        assert_eq!(h.max, 25);
        assert!((h.mean() - 52.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn render_skips_empty_bins() {
        let h = Histogram::from_values([1, 100], 10);
        let r = h.render(20);
        assert_eq!(r.lines().count(), 2);
    }

    #[test]
    fn irregularity_zero_for_uniform() {
        let h = Histogram::from_values([1, 11, 21, 31], 10);
        assert_eq!(h.irregularity(), 0.0);
    }
}
