//! Small shared substrates: PRNG, timers, histograms, logging.

pub mod histogram;
pub mod logging;
pub mod prng;
pub mod timer;

pub use histogram::Histogram;
pub use prng::{splitmix64, Pcg32};
pub use timer::{fmt_secs, measure, Stats, Timer};
