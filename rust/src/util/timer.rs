//! Timing helpers: scoped wall-clock timers and robust summary stats.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over repeated timing samples (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var =
            s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            s[n / 2]
        } else {
            0.5 * (s[n / 2 - 1] + s[n / 2])
        };
        Stats { n, min: s[0], max: s[n - 1], mean, median, stddev: var.sqrt() }
    }
}

/// Time `f` with `warmup` discarded runs and `reps` measured runs.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_secs());
    }
    Stats::from_samples(&samples)
}

/// Human-readable duration.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_odd_even_median() {
        let s = Stats::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        let s = Stats::from_samples(&[4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.mean, 2.5);
    }

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let st = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(st.n, 5);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-5).ends_with("us"));
    }
}
