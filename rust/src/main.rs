//! `dpp-pmrf` launcher.
//!
//! Subcommands:
//!   generate  — build a synthetic/experimental dataset and save it
//!   segment   — run the full segmentation pipeline on a dataset
//!   inspect   — dataset/graph demographics (paper §4.3.3 analysis)
//!   engines   — list available engines and artifact buckets
//!
//! Benchmarks live in `rust/benches/` (`cargo bench`); examples in
//! `examples/` (`cargo run --release --example quickstart`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use dpp_pmrf::bp::BpSchedule;
use dpp_pmrf::cli::Spec;
use dpp_pmrf::config::{DatasetKind, DeviceKind, EngineKind, RunConfig};
use dpp_pmrf::coordinator::Coordinator;
use dpp_pmrf::image::{self, Dataset, Volume};
use dpp_pmrf::util::logging::{self, Level};
use dpp_pmrf::{eval as metrics, log_info};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        bail!(top_usage());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "generate" => cmd_generate(rest),
        "segment" => cmd_segment(rest),
        "inspect" => cmd_inspect(rest),
        "engines" => cmd_engines(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{}", top_usage()),
    }
}

fn top_usage() -> String {
    "dpp-pmrf — DPP-based parallel MRF image segmentation \
     (Lessley et al. 2018 reproduction)\n\nUSAGE:\n  dpp-pmrf \
     <generate|segment|inspect|engines> [options]\n\nRun a subcommand \
     with --help for details."
        .to_string()
}

/// Shared dataset/config options.
fn common_spec(spec: Spec) -> Spec {
    spec.opt("config", "JSON config file (flags override)", None)
        .opt("dataset", "synthetic|experimental", Some("synthetic"))
        .opt("width", "slice width", Some("128"))
        .opt("height", "slice height", Some("128"))
        .opt("slices", "number of slices", Some("4"))
        .opt("seed", "dataset seed", Some("24414"))
        .flag("verbose", "debug logging")
}

fn load_cfg(m: &dpp_pmrf::cli::Matches) -> Result<RunConfig> {
    let mut cfg = match m.get("config") {
        Some(path) => RunConfig::from_json_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(d) = m.get("dataset") {
        cfg.dataset.kind = DatasetKind::parse(d)?;
    }
    if let Some(w) = m.get_parse::<usize>("width")? {
        cfg.dataset.width = w;
    }
    if let Some(h) = m.get_parse::<usize>("height")? {
        cfg.dataset.height = h;
    }
    if let Some(s) = m.get_parse::<usize>("slices")? {
        cfg.dataset.slices = s;
    }
    if let Some(s) = m.get_parse::<u64>("seed")? {
        cfg.dataset.seed = s;
    }
    if m.flag("verbose") {
        logging::set_level(Level::Debug);
    }
    Ok(cfg)
}

fn load_or_generate(m: &dpp_pmrf::cli::Matches, cfg: &RunConfig)
    -> Result<Dataset> {
    if let Some(path) = m.get("input") {
        let input = Volume::read_raw(Path::new(path))?;
        log_info!("loaded {}: {}x{}x{}", path, input.width, input.height,
                  input.depth);
        Ok(Dataset { input, ground_truth: None, name: "file" })
    } else {
        log_info!("generating {} dataset ({}x{}x{}, seed {})",
                  cfg.dataset.kind.name(), cfg.dataset.width,
                  cfg.dataset.height, cfg.dataset.slices, cfg.dataset.seed);
        Ok(image::generate(&cfg.dataset))
    }
}

fn cmd_generate(args: &[String]) -> Result<()> {
    let spec = common_spec(Spec::new("dpp-pmrf generate",
                                     "generate a dataset to disk"))
        .opt("out", "output raw volume path", Some("dataset.raw"));
    let m = spec.parse(args)?;
    let cfg = load_cfg(&m)?;
    let ds = image::generate(&cfg.dataset);
    let out = PathBuf::from(m.get("out").unwrap());
    ds.input.write_raw(&out)?;
    if let Some(t) = &ds.ground_truth {
        let mut truth_path = out.as_os_str().to_owned();
        truth_path.push(".truth");
        t.write_raw(Path::new(&truth_path))?;
        log_info!("ground truth porosity {:.3}", metrics::porosity(t));
    }
    log_info!("wrote {}", out.display());
    Ok(())
}

fn cmd_segment(args: &[String]) -> Result<()> {
    let spec = common_spec(Spec::new("dpp-pmrf segment",
                                     "run the segmentation pipeline"))
        .opt("engine", EngineKind::USAGE, Some("dpp"))
        .opt("device",
             "execution device for the DPP primitives \
              (auto|serial|pool|accel; default: config file value, \
              else auto)",
             None)
        .opt("threads", "worker threads (default: all cores)", None)
        .opt("lanes",
             "slice scheduler lanes (1 = serial slice order)", None)
        .opt("inflight",
             "max initialized slices in flight between scheduler stages",
             None)
        .opt("input", "raw volume to segment instead of generating", None)
        .opt("out", "write segmented raw volume here", None)
        .opt("figures", "write PGM figure panels to this directory", None)
        .opt("report", "write a JSON run report here", None)
        .opt("artifacts", "XLA artifacts dir", Some("artifacts"))
        .opt("bp-schedule",
             "bp engine: message frontier policy \
              (sync|residual|stale|bucketed[:bins]|random[:p[:seed]])",
             None)
        .opt("bp-damping", "bp engine: fraction of old message kept",
             None)
        .opt("bp-sweeps", "bp engine: max sweeps per EM iteration", None)
        .opt("bp-tol", "bp engine: residual convergence threshold", None)
        .opt("bp-frontier",
             "bp engine: commit messages with residual >= ratio * max",
             None)
        .opt("dual-iters",
             "dual engine: max ascent iterations per EM iteration", None)
        .opt("dual-tol",
             "dual engine: relative bound-improvement stop threshold",
             None)
        .opt("pmp-particles",
             "pmp engine: particles kept per vertex after pruning",
             None)
        .opt("pmp-iters",
             "pmp engine: max propose/prune rounds per EM iteration",
             None)
        .opt("pmp-sweeps",
             "pmp engine: message-passing sweeps per round", None)
        .opt("pmp-walk-sigma",
             "pmp engine: random-walk proposal step (intensity units)",
             None)
        .flag("profile",
              "record primitive wall time + workspace counters and \
               print the timing table")
        .opt("trace-out",
             "write a Chrome trace-event JSON file of the run \
              (open in Perfetto / chrome://tracing)",
             None)
        .opt("convergence-out",
             "arm the convergence flight recorder and write its full \
              journal as JSONL here (the JSON report embeds a \
              downsampled view)",
             None)
        .opt("convergence-cap",
             "flight recorder ring capacity in samples (default 65536)",
             None)
        .opt("metrics-out",
             "write a Prometheus text-format metrics exposition here \
              at the end of the run (implies --profile)",
             None);
    let m = spec.parse(args)?;
    let mut cfg = load_cfg(&m)?;
    cfg.engine = EngineKind::parse(m.get("engine").unwrap())?;
    if let Some(d) = m.get("device") {
        cfg.device = DeviceKind::parse(d)?;
    }
    if let Some(t) = m.get_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(l) = m.get_parse::<usize>("lanes")? {
        cfg.sched.lanes = l;
    }
    if let Some(i) = m.get_parse::<usize>("inflight")? {
        cfg.sched.inflight = i;
    }
    cfg.artifacts_dir = PathBuf::from(m.get("artifacts").unwrap());
    if let Some(s) = m.get("bp-schedule") {
        // Hard argument errors, not deferred config failures: a bad
        // frontier policy should name the flag that carried it.
        cfg.bp.schedule = BpSchedule::parse(s).map_err(|e| {
            anyhow::anyhow!(
                "--bp-schedule {s} is invalid: {e}. Valid forms: sync, \
                 residual, stale, bucketed[:bins] (bins in [2, 63]), \
                 random[:p[:seed]] (p in (0, 1])."
            )
        })?;
    }
    if let Some(d) = m.get_parse::<f32>("bp-damping")? {
        if !(0.0..1.0).contains(&d) {
            bail!("--bp-damping {d} is invalid: damping is the \
                   fraction of the old message kept and must be in \
                   [0, 1). Pass a value like 0.5, or drop the flag \
                   for the default.");
        }
        cfg.bp.damping = d;
    }
    if let Some(s) = m.get_parse::<usize>("bp-sweeps")? {
        if s == 0 {
            bail!("--bp-sweeps 0 is invalid: the bp engine needs at \
                   least one sweep per EM iteration. Pass \
                   --bp-sweeps 1 or higher, or drop the flag for the \
                   default.");
        }
        cfg.bp.max_sweeps = s;
    }
    if let Some(t) = m.get_parse::<f32>("bp-tol")? {
        cfg.bp.tol = t;
    }
    if let Some(f) = m.get_parse::<f32>("bp-frontier")? {
        if !(0.0..=1.0).contains(&f) {
            bail!("--bp-frontier {f} is invalid: the frontier ratio \
                   scales the sweep's max residual and must be in \
                   [0, 1]. Pass a value like 0.1, or drop the flag \
                   for the default.");
        }
        cfg.bp.frontier = f;
    }
    if let Some(i) = m.get_parse::<usize>("dual-iters")? {
        // Hard argument error, not a silent clamp: zero ascent
        // iterations would leave every EM iteration uncertified.
        if i == 0 {
            bail!("--dual-iters 0 is invalid: the dual engine needs \
                   at least one ascent iteration per EM iteration. \
                   Pass --dual-iters 1 or higher, or drop the flag \
                   for the default.");
        }
        cfg.dual.iters = i;
    }
    if let Some(t) = m.get_parse::<f64>("dual-tol")? {
        cfg.dual.tol = t;
    }
    if let Some(p) = m.get_parse::<usize>("pmp-particles")? {
        cfg.pmp.particles = p;
    }
    if let Some(i) = m.get_parse::<usize>("pmp-iters")? {
        cfg.pmp.iters = i;
    }
    if let Some(s) = m.get_parse::<usize>("pmp-sweeps")? {
        cfg.pmp.sweeps = s;
    }
    if let Some(w) = m.get_parse::<f32>("pmp-walk-sigma")? {
        cfg.pmp.walk_sigma = w;
    }
    if m.flag("profile") {
        cfg.telemetry.profile = true;
    }
    if let Some(p) = m.get("trace-out") {
        cfg.telemetry.trace_out = Some(PathBuf::from(p));
    }
    if let Some(p) = m.get("convergence-out") {
        cfg.obs.convergence_out = Some(PathBuf::from(p));
    }
    if let Some(c) = m.get_parse::<usize>("convergence-cap")? {
        // The recorder would clamp this up to its minimum anyway;
        // reject it here so the user learns the real capacity instead
        // of silently journaling more samples than they asked for.
        if c < dpp_pmrf::obs::MIN_CAPACITY {
            bail!("--convergence-cap {c} is below the flight \
                   recorder's minimum ring capacity of {}. Pass \
                   --convergence-cap {} or higher, or drop the flag \
                   for the default (65536).",
                  dpp_pmrf::obs::MIN_CAPACITY,
                  dpp_pmrf::obs::MIN_CAPACITY);
        }
        cfg.obs.convergence_cap = c;
    }
    if let Some(p) = m.get("metrics-out") {
        cfg.obs.metrics_out = Some(PathBuf::from(p));
    }
    cfg.validate()?;

    // Arm telemetry before the run so init-phase spans are captured;
    // everything defaults off, keeping the hot path bitwise-identical.
    if cfg.telemetry.profile || cfg.obs.metrics_out.is_some() {
        dpp_pmrf::dpp::timing::set_enabled(true);
    }
    if cfg.obs.convergence_out.is_some() {
        dpp_pmrf::obs::arm(cfg.obs.convergence_cap);
    }
    let tracer = cfg
        .telemetry
        .trace_out
        .as_ref()
        .map(|_| dpp_pmrf::telemetry::Tracer::start());

    let ds = load_or_generate(&m, &cfg)?;
    let coord = Coordinator::new(cfg.clone())?;
    log_info!("engine {} / device {} / {} threads / {} lane(s), \
               inflight {}",
              cfg.engine.name(), cfg.device.name(), cfg.threads,
              cfg.sched.lanes, cfg.sched.inflight);
    let report = coord.run(&ds)?;

    if let (Some(tracer), Some(path)) =
        (tracer, cfg.telemetry.trace_out.as_ref()) {
        let trace = tracer.finish();
        std::fs::write(path, trace.to_chrome_json().to_pretty())?;
        log_info!("wrote trace ({} events) to {}", trace.num_events(),
                  path.display());
    }
    if cfg.telemetry.profile {
        println!("{}", dpp_pmrf::dpp::timing::report());
    }
    if let Some(path) = cfg.obs.convergence_out.as_ref() {
        // The run driver drained the ring into the report; the file
        // gets the full journal, the JSON report a ≤256-point view.
        let log = report.convergence.clone().unwrap_or_default();
        std::fs::write(path, log.to_jsonl())?;
        log_info!("wrote convergence journal ({} samples, {} dropped) \
                   to {}",
                  log.samples.len(), log.dropped, path.display());
        dpp_pmrf::obs::disarm();
    }
    if let Some(path) = cfg.obs.metrics_out.as_ref() {
        let mut w = dpp_pmrf::obs::prometheus::TextWriter::new();
        dpp_pmrf::obs::prometheus::render_snapshot(
            &mut w,
            &dpp_pmrf::obs::prometheus::timing_snapshot(),
        );
        std::fs::write(path, w.finish())?;
        log_info!("wrote metrics exposition to {}", path.display());
    }

    log_info!(
        "mean per-slice: init {:.3}s, optimization {:.3}s",
        report.mean_init_secs(),
        report.mean_opt_secs()
    );
    log_info!(
        "whole run: {:.3}s, {:.2} slices/s, lane occupancy {:.0}%",
        report.total_secs,
        report.slices_per_sec(),
        100.0 * report.lane_occupancy()
    );
    if let Some(c) = &report.confusion {
        log_info!("{}", metrics::summary(c));
    }
    log_info!("porosity {:.3}", report.porosity);
    if let (Some(lb), Some(gap)) =
        (report.lower_bound(), report.optimality_gap()) {
        log_info!("certified lower bound {lb:.3} (optimality gap {gap:.3e})");
    }

    if let Some(out) = m.get("out") {
        report.output.write_raw(Path::new(out))?;
        log_info!("wrote {}", out);
    }
    if let Some(dir) = m.get("figures") {
        coord.save_figure(&ds, &report, 0, Path::new(dir))?;
        log_info!("wrote figure panels to {}", dir);
    }
    if let Some(path) = m.get("report") {
        std::fs::write(path, report.to_json().to_pretty())?;
        log_info!("wrote {}", path);
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let spec = common_spec(Spec::new(
        "dpp-pmrf inspect",
        "dataset / graph / neighborhood demographics",
    ));
    let m = spec.parse(args)?;
    let cfg = load_cfg(&m)?;
    let ds = image::generate(&cfg.dataset);
    let coord = Coordinator::new(cfg)?;
    let (seg, model) = coord.build_slice_model(&ds.input, 0);
    println!("slice 0 of {}:", ds.name);
    println!("  regions      {}", seg.num_regions);
    println!("  edges        {}", model.graph.num_edges());
    println!("  hoods        {}", model.hoods.num_hoods());
    println!("  elements     {}", model.hoods.num_elements());
    let hist = model.hoods.size_histogram(4);
    println!(
        "  hood size    mean {:.1}, max {}, irregularity {:.2}",
        hist.mean(),
        hist.max,
        hist.irregularity()
    );
    println!("{}", hist.render(40));
    Ok(())
}

fn cmd_engines(args: &[String]) -> Result<()> {
    let spec = Spec::new("dpp-pmrf engines",
                         "list engines and XLA artifact buckets")
        .opt("artifacts", "XLA artifacts dir", Some("artifacts"));
    let m = spec.parse(args)?;
    println!("engines:");
    for kind in EngineKind::all() {
        println!("  {:<10} {}", kind.name(), kind.about());
    }
    println!("devices (--device):");
    for kind in DeviceKind::all() {
        println!("  {}", kind.name());
    }
    let dir = PathBuf::from(m.get("artifacts").unwrap());
    match dpp_pmrf::runtime::EmRuntime::load(&dir) {
        Ok(rt) => {
            println!("artifact buckets in {}:", dir.display());
            for (n, h) in rt.buckets() {
                println!("  elems {n:>8}  hoods {h:>8}");
            }
        }
        Err(e) => println!("xla runtime unavailable: {e}"),
    }
    let _ = Arc::new(());
    Ok(())
}

#[cfg(test)]
mod tests {
    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn segment_rejects_convergence_cap_below_minimum() {
        // Both invalid values error out during argument handling —
        // before any dataset is generated — with the flag named and
        // the fix spelled out.
        for bad in ["0", "1"] {
            let e = super::cmd_segment(&args(&["--convergence-cap", bad]))
                .expect_err("sub-minimum cap must be rejected");
            let msg = e.to_string();
            assert!(msg.contains("--convergence-cap"), "{msg}");
            assert!(msg.contains("minimum"), "{msg}");
        }
        // The minimum itself is accepted past argument validation
        // (the run then fails later only if the config is otherwise
        // unusable — not the case here, so keep it cheap: 8x8x1).
        super::cmd_segment(&args(&[
            "--convergence-cap", "2", "--width", "8", "--height", "8",
            "--slices", "1", "--engine", "serial",
        ]))
        .expect("minimum capacity is valid");
    }

    #[test]
    fn segment_rejects_zero_dual_iters() {
        let e = super::cmd_segment(&args(&["--dual-iters", "0"]))
            .expect_err("zero ascent iterations must be rejected");
        let msg = e.to_string();
        assert!(msg.contains("--dual-iters"), "{msg}");
        assert!(msg.contains("--dual-iters 1"), "{msg}");
    }

    #[test]
    fn segment_rejects_invalid_bp_knobs() {
        // Every bad BP knob dies during argument handling with the
        // flag named — no dataset generation, no deferred config
        // error that loses the flag's identity.
        let table: &[(&str, &str, &str)] = &[
            ("--bp-frontier", "-0.1", "--bp-frontier"),
            ("--bp-frontier", "1.5", "--bp-frontier"),
            ("--bp-damping", "1.0", "--bp-damping"),
            ("--bp-damping", "-0.2", "--bp-damping"),
            ("--bp-sweeps", "0", "--bp-sweeps"),
            ("--bp-schedule", "bucketed:1", "--bp-schedule"),
            ("--bp-schedule", "bucketed:64", "--bp-schedule"),
            ("--bp-schedule", "random:1.5", "--bp-schedule"),
            ("--bp-schedule", "random:0", "--bp-schedule"),
            ("--bp-schedule", "chaotic", "--bp-schedule"),
        ];
        for (flag, value, needle) in table {
            let e = super::cmd_segment(&args(&[flag, value]))
                .expect_err("invalid bp knob must be rejected");
            let msg = e.to_string();
            assert!(
                msg.contains(needle),
                "{flag} {value}: error must name the flag: {msg}"
            );
        }
    }

    #[test]
    fn segment_accepts_parameterized_bp_schedules() {
        // Each relaxed frontier policy drives a real (tiny) run end
        // to end through the CLI surface.
        for spec in ["stale", "bucketed:4", "random:0.5:7"] {
            super::cmd_segment(&args(&[
                "--width", "16", "--height", "16", "--slices", "1",
                "--engine", "bp", "--bp-schedule", spec,
                "--bp-sweeps", "8",
            ]))
            .unwrap_or_else(|e| {
                panic!("--bp-schedule {spec} should run: {e}")
            });
        }
    }
}
