//! Scoped metric recorders: per-engine / per-lane registries that
//! replace reaching for the global [`crate::dpp::timing`] map.
//!
//! A [`Recorder`] is a cheap `Arc`-shared bundle of metric tables
//! (wall-time rows, counters, gauges, log2 histograms). Installing it
//! with [`Recorder::install`] pushes it onto a **thread-local** sink
//! stack: every `timing::record` / [`crate::telemetry::counter`] call
//! made on that thread while the returned [`RecorderScope`] guard is
//! alive lands in the recorder instead of the global registry. Lanes
//! install their own recorder, record with a plain uncontended mutex
//! (never the global lock), and the driver merges snapshots into one
//! run-level [`MetricsSnapshot`] afterwards.
//!
//! Overhead contract: when no scope is installed anywhere in the
//! process, the sink check is a single relaxed atomic load — the
//! telemetry-off hot path stays allocation-free and branch-predictable
//! (asserted by `benches/alloc_churn.rs`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::Log2Histogram;

/// One wall-time row: same shape as `timing::PrimStat`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TimeRow {
    pub calls: u64,
    pub nanos: u64,
}

impl TimeRow {
    pub fn secs(&self) -> f64 {
        self.nanos as f64 / 1e9
    }
}

/// Point-in-time copy of a recorder's tables; merge several (one per
/// lane) into a run-level view with [`MetricsSnapshot::merge`].
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Wall-time rows keyed by primitive name (`"SortByKey"`, ...).
    pub time_rows: BTreeMap<&'static str, TimeRow>,
    /// Monotonic counters (e.g. `"Workspace::hit"` bytes served).
    pub counters: BTreeMap<&'static str, u64>,
    /// Gauges with max-merge semantics (e.g. high-water bytes).
    pub gauges: BTreeMap<&'static str, u64>,
    /// Log2-bucketed sample distributions.
    pub hists: BTreeMap<&'static str, Log2Histogram>,
}

impl MetricsSnapshot {
    /// Fold `other` into `self`: time rows and counters add, gauges
    /// take the max, histograms merge bucket-wise.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (name, row) in &other.time_rows {
            let e = self.time_rows.entry(name).or_default();
            e.calls += row.calls;
            e.nanos += row.nanos;
        }
        for (name, v) in &other.counters {
            *self.counters.entry(name).or_insert(0) += v;
        }
        for (name, v) in &other.gauges {
            let e = self.gauges.entry(name).or_insert(0);
            *e = (*e).max(*v);
        }
        for (name, h) in &other.hists {
            self.hists.entry(name).or_insert_with(Log2Histogram::new)
                .merge(h);
        }
    }

    /// Sum of all time-row nanos (counters and gauges excluded — they
    /// are not time).
    pub fn total_nanos(&self) -> u64 {
        self.time_rows.values().map(|r| r.nanos).sum()
    }
}

#[derive(Debug, Default)]
struct Inner {
    time_rows: Mutex<BTreeMap<&'static str, TimeRow>>,
    counters: Mutex<BTreeMap<&'static str, u64>>,
    gauges: Mutex<BTreeMap<&'static str, u64>>,
    hists: Mutex<BTreeMap<&'static str, Log2Histogram>>,
}

/// Scoped metric registry (see module docs). Clones share storage, so
/// a lane can keep a handle while the driver holds another.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    inner: Arc<Inner>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Add one wall-time observation to `name`'s row.
    pub fn record_time(&self, name: &'static str, nanos: u64) {
        let mut rows = self.inner.time_rows.lock().unwrap();
        let e = rows.entry(name).or_default();
        e.calls += 1;
        e.nanos += nanos;
    }

    /// Bump counter `name` by `delta`.
    pub fn add_counter(&self, name: &'static str, delta: u64) {
        *self.inner.counters.lock().unwrap().entry(name).or_insert(0) +=
            delta;
    }

    /// Raise gauge `name` to at least `value` (max semantics — gauges
    /// here track high-water marks).
    pub fn gauge_max(&self, name: &'static str, value: u64) {
        let mut g = self.inner.gauges.lock().unwrap();
        let e = g.entry(name).or_insert(0);
        *e = (*e).max(value);
    }

    /// Record one sample into histogram `name`.
    pub fn record_hist(&self, name: &'static str, value: u64) {
        self.inner.hists.lock().unwrap()
            .entry(name).or_insert_with(Log2Histogram::new)
            .record(value);
    }

    /// Copy the current tables out.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            time_rows: self.inner.time_rows.lock().unwrap().clone(),
            counters: self.inner.counters.lock().unwrap().clone(),
            gauges: self.inner.gauges.lock().unwrap().clone(),
            hists: self.inner.hists.lock().unwrap().clone(),
        }
    }

    /// Install this recorder as the metric sink for the **current
    /// thread** until the returned guard drops. Scopes nest; the
    /// innermost wins. The guard is `!Send` — it must drop on the
    /// thread that created it.
    #[must_use = "metrics only route here while the scope guard lives"]
    pub fn install(&self) -> RecorderScope {
        SCOPES_LIVE.fetch_add(1, Ordering::Relaxed);
        STACK.with(|s| s.borrow_mut().push(self.clone()));
        RecorderScope { _not_send: PhantomData }
    }
}

/// RAII guard from [`Recorder::install`]; pops the thread's sink
/// stack on drop.
pub struct RecorderScope {
    _not_send: PhantomData<*const ()>,
}

impl Drop for RecorderScope {
    fn drop(&mut self) {
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
        SCOPES_LIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Process-wide count of live scopes: the fast-path filter that keeps
/// the telemetry-off cost to one relaxed load before any TLS access.
static SCOPES_LIVE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static STACK: RefCell<Vec<Recorder>> = const { RefCell::new(Vec::new()) };
}

/// True when a recorder scope is installed on **this** thread.
#[inline]
pub fn scope_active() -> bool {
    SCOPES_LIVE.load(Ordering::Relaxed) > 0
        && STACK.with(|s| !s.borrow().is_empty())
}

/// Offer a time row to the innermost scoped recorder. Returns `true`
/// if consumed (callers then skip the global registry).
#[inline]
pub(crate) fn sink_time(name: &'static str, nanos: u64) -> bool {
    if SCOPES_LIVE.load(Ordering::Relaxed) == 0 {
        return false;
    }
    STACK.with(|s| {
        let st = s.borrow();
        match st.last() {
            Some(r) => {
                r.record_time(name, nanos);
                true
            }
            None => false,
        }
    })
}

/// Offer a counter bump to the innermost scoped recorder.
#[inline]
pub(crate) fn sink_counter(name: &'static str, delta: u64) -> bool {
    if SCOPES_LIVE.load(Ordering::Relaxed) == 0 {
        return false;
    }
    STACK.with(|s| {
        let st = s.borrow();
        match st.last() {
            Some(r) => {
                r.add_counter(name, delta);
                true
            }
            None => false,
        }
    })
}

/// Offer a gauge max-update to the innermost scoped recorder.
#[inline]
pub(crate) fn sink_gauge(name: &'static str, value: u64) -> bool {
    if SCOPES_LIVE.load(Ordering::Relaxed) == 0 {
        return false;
    }
    STACK.with(|s| {
        let st = s.borrow();
        match st.last() {
            Some(r) => {
                r.gauge_max(name, value);
                true
            }
            None => false,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::timing;

    #[test]
    fn scoped_recorder_captures_without_global_registry() {
        let rec = Recorder::new();
        {
            let _scope = rec.install();
            assert!(scope_active());
            timing::record("Map", 1_000);
            timing::record("Map", 2_000);
            timing::timed("Gather", || std::hint::black_box(7));
        }
        assert!(!scope_active());
        let snap = rec.snapshot();
        assert_eq!(snap.time_rows["Map"], TimeRow { calls: 2, nanos: 3_000 });
        assert_eq!(snap.time_rows["Gather"].calls, 1);
        assert_eq!(snap.total_nanos(), 3_000 + snap.time_rows["Gather"].nanos);
    }

    #[test]
    fn scopes_nest_innermost_wins() {
        let outer = Recorder::new();
        let inner = Recorder::new();
        let _o = outer.install();
        {
            let _i = inner.install();
            timing::record("Scan", 5);
        }
        timing::record("Scan", 7);
        assert_eq!(inner.snapshot().time_rows["Scan"].nanos, 5);
        assert_eq!(outer.snapshot().time_rows["Scan"].nanos, 7);
    }

    #[test]
    fn counters_gauges_hists_and_merge() {
        let a = Recorder::new();
        a.add_counter("Workspace::hit", 100);
        a.add_counter("Workspace::hit", 50);
        a.gauge_max("Workspace::high_water_bytes", 10);
        a.gauge_max("Workspace::high_water_bytes", 4);
        a.record_hist("wait", 8);
        let b = Recorder::new();
        b.add_counter("Workspace::hit", 1);
        b.gauge_max("Workspace::high_water_bytes", 99);
        b.record_hist("wait", 32);
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.counters["Workspace::hit"], 151);
        assert_eq!(merged.gauges["Workspace::high_water_bytes"], 99);
        assert_eq!(merged.hists["wait"].total(), 2);
        assert_eq!(merged.total_nanos(), 0, "non-time metrics are not time");
    }

    #[test]
    fn sink_is_per_thread() {
        let rec = Recorder::new();
        let _scope = rec.install();
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(!scope_active(), "scope must not leak across threads");
            });
        });
        timing::record("Reduce", 9);
        assert_eq!(rec.snapshot().time_rows["Reduce"].calls, 1);
    }
}
