//! Latency aggregation: log2-bucketed histograms for unbounded
//! streams (serving jobs) and exact percentiles for small sample sets
//! (per-slice reports).
//!
//! The histogram is fixed-size — 65 buckets, one per power of two of
//! a `u64` nanosecond value — so recording never allocates and the
//! serving layer can aggregate per-job latency forever without
//! growing. Quantiles interpolate linearly inside the winning bucket,
//! which bounds the relative error by 2x; for the per-slice case,
//! where every sample is already in memory, [`percentiles`] sorts and
//! reads exact ranks instead.

use crate::json::Value;

/// Number of buckets: one per possible `leading_zeros` outcome of a
/// `u64`, plus a dedicated zero bucket.
const BUCKETS: usize = 65;

/// Fixed-size log2-bucketed histogram over `u64` samples
/// (conventionally nanoseconds). Bucket 0 holds exact zeros; bucket
/// `b >= 1` holds values in `[2^(b-1), 2^b - 1]`.
#[derive(Debug, Clone)]
pub struct Log2Histogram {
    counts: [u64; BUCKETS],
    total: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Log2Histogram {
    pub const fn new() -> Log2Histogram {
        Log2Histogram {
            counts: [0; BUCKETS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Record one sample. No allocation, O(1).
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self`.
    pub fn merge(&mut self, other: &Log2Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile `q` in `[0, 1]`: nearest-rank bucket walk
    /// with linear interpolation across the bucket's value range. The
    /// result is clamped to the observed `[min, max]`, so degenerate
    /// histograms (one sample) return that sample exactly.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let (lo, hi) = if b == 0 {
                    (0.0, 0.0)
                } else {
                    (2f64.powi(b as i32 - 1), 2f64.powi(b as i32))
                };
                let frac = (target - seen) as f64 / c as f64;
                let est = lo + frac * (hi - lo);
                return est.clamp(self.min as f64, self.max as f64);
            }
            seen += c;
        }
        self.max as f64
    }

    /// p50/p90/p99 in the recorded unit.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            samples: self.total,
        }
    }

    /// Raw per-bucket counts (65 entries — see the bucket layout in
    /// the type docs). Exposed for the Prometheus translation in
    /// [`crate::obs::prometheus`].
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Saturating sum of all recorded samples (recorded unit).
    pub fn sum(&self) -> u64 {
        self.sum
    }
}

/// The three serving percentiles every report surfaces, plus the
/// sample count they summarize. Unit follows whatever was recorded
/// (seconds for report JSON, nanoseconds inside [`Log2Histogram`]).
///
/// `samples == 0` is meaningful, not degenerate: [`to_json`]
/// serializes the percentiles as `null` so dashboards can distinguish
/// "no traffic" from "instant jobs" (ISSUE 8; pinned by
/// `tests/report_schema.rs`).
///
/// [`to_json`]: LatencySummary::to_json
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// How many samples the percentiles were computed from.
    pub samples: u64,
}

impl LatencySummary {
    /// Divide all three percentiles by `d` (e.g. 1e9 for ns -> s).
    pub fn scaled(self, d: f64) -> LatencySummary {
        LatencySummary {
            p50: self.p50 / d,
            p90: self.p90 / d,
            p99: self.p99 / d,
            samples: self.samples,
        }
    }

    pub fn to_json(self) -> Value {
        if self.samples == 0 {
            return Value::object(vec![
                ("p50", Value::Null),
                ("p90", Value::Null),
                ("p99", Value::Null),
            ]);
        }
        Value::object(vec![
            ("p50", self.p50.into()),
            ("p90", self.p90.into()),
            ("p99", self.p99.into()),
        ])
    }
}

/// Exact nearest-rank percentiles over an in-memory sample set (the
/// per-slice path — a run has few enough slices to sort). Empty input
/// yields the `samples == 0` summary, which serializes as `null`
/// percentiles.
pub fn percentiles(samples: &[f64]) -> LatencySummary {
    if samples.is_empty() {
        return LatencySummary::default();
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = |q: f64| -> f64 {
        let n = s.len() as f64;
        let idx = ((q * n).ceil() as usize).max(1) - 1;
        s[idx.min(s.len() - 1)]
    };
    LatencySummary {
        p50: rank(0.50),
        p90: rank(0.90),
        p99: rank(0.99),
        samples: samples.len() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_track_exact_ranks_within_a_bucket_factor() {
        let mut h = Log2Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.total(), 1000);
        let p50 = h.quantile(0.5);
        assert!(p50 >= 250.0 && p50 <= 1000.0, "p50 = {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 >= 500.0 && p99 <= 1024.0, "p99 = {p99}");
        assert!(h.quantile(1.0) <= h.quantile(1.0).max(1000.0));
    }

    #[test]
    fn histogram_single_sample_is_exact() {
        let mut h = Log2Histogram::new();
        h.record(777);
        assert_eq!(h.quantile(0.5), 777.0);
        assert_eq!(h.quantile(0.99), 777.0);
        assert_eq!(h.mean(), 777.0);
    }

    #[test]
    fn histogram_empty_and_zero() {
        let h = Log2Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        let mut h = Log2Histogram::new();
        h.record(0);
        assert_eq!(h.quantile(0.9), 0.0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Log2Histogram::new();
        let mut b = Log2Histogram::new();
        let mut both = Log2Histogram::new();
        for v in [1u64, 5, 9, 100, 1000] {
            a.record(v);
            both.record(v);
        }
        for v in [2u64, 8, 64, 5000] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.total(), both.total());
        assert_eq!(a.quantile(0.5), both.quantile(0.5));
        assert_eq!(a.mean(), both.mean());
    }

    #[test]
    fn exact_percentiles_nearest_rank() {
        let s: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let p = percentiles(&s);
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p90, 90.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.samples, 100);
        let one = percentiles(&[3.5]);
        assert_eq!((one.p50, one.p90, one.p99), (3.5, 3.5, 3.5));
        assert_eq!(one.samples, 1);
    }

    #[test]
    fn empty_percentiles_serialize_as_null() {
        // "No traffic" must be distinguishable from "instant jobs"
        // (ISSUE 8): zero samples -> null percentiles, a genuine
        // 0-valued sample set -> numeric zeros.
        let empty = percentiles(&[]);
        assert_eq!(empty.samples, 0);
        let j = empty.to_json();
        for q in ["p50", "p90", "p99"] {
            assert_eq!(j.get(q), Some(&Value::Null), "{q}");
        }
        assert_eq!(Log2Histogram::new().summary().to_json().get("p50"),
                   Some(&Value::Null));
        let zeros = percentiles(&[0.0, 0.0]);
        assert_eq!(zeros.to_json().get("p50").and_then(Value::as_f64),
                   Some(0.0));
    }

    #[test]
    fn summary_scales_and_serializes() {
        let mut h = Log2Histogram::new();
        h.record(2_000_000_000);
        let s = h.summary().scaled(1e9);
        assert_eq!(s.p50, 2.0);
        let j = s.to_json();
        assert_eq!(j.get("p50").and_then(Value::as_f64), Some(2.0));
        assert!(j.get("p99").is_some());
    }
}
