//! Unified telemetry: scoped metric recorders, hierarchical span
//! tracing, and latency histograms (DESIGN.md §11).
//!
//! Three pillars, all off by default and all routed through the same
//! cheap gates so the disabled hot path stays bitwise-identical and
//! allocation-free:
//!
//! - **[`Recorder`]** — per-engine / per-lane metric registries
//!   (wall-time rows, counters, gauges, log2 histograms). Install one
//!   on a thread and every `timing::record` / [`counter`] call there
//!   lands in it instead of the global `dpp::timing` map; merge lane
//!   snapshots with [`MetricsSnapshot::merge`]. The global registry
//!   remains the default sink for backward compatibility.
//! - **[`span`] / [`Tracer`]** — RAII spans (run → slice → EM iter →
//!   MAP iter → primitive/stage) recorded into per-thread buffers and
//!   exported as Chrome trace-event JSON via `--trace-out` (load in
//!   Perfetto).
//! - **[`Log2Histogram`] / [`percentiles`]** — the p50/p90/p99 job
//!   latency numbers `sched::Service` and `RunReport::to_json`
//!   surface.
//!
//! ```
//! use dpp_pmrf::telemetry::Recorder;
//! let rec = Recorder::new();
//! {
//!     let _scope = rec.install();
//!     dpp_pmrf::dpp::timing::timed("Map", || ());
//! }
//! assert_eq!(rec.snapshot().time_rows["Map"].calls, 1);
//! ```

pub mod latency;
pub mod metrics;
pub mod span;

pub use latency::{percentiles, LatencySummary, Log2Histogram};
pub use metrics::{MetricsSnapshot, Recorder, RecorderScope, TimeRow};
pub use span::{
    emit_span, name_thread, span, span_arg, tracing, Span, Trace, Tracer,
};

#[doc(hidden)]
pub use span::trace_test_lock;

/// True when a scoped recorder is installed on this thread (fast
/// path: one relaxed atomic load when none is installed anywhere).
#[inline]
pub fn metrics_scope_active() -> bool {
    metrics::scope_active()
}

/// Bump counter `name` by `delta` (bytes, hits...). Routing order:
/// the thread's scoped recorder if one is installed; otherwise, when
/// global profiling is enabled, a legacy `dpp::timing` counter row
/// (value accumulated in the nanos column, calls = bump count) so
/// `timing::report` keeps rendering it outside the time total;
/// otherwise nothing.
#[inline]
pub fn counter(name: &'static str, delta: u64) {
    if metrics::sink_counter(name, delta) {
        return;
    }
    if crate::dpp::timing::enabled() {
        crate::dpp::timing::record(name, delta);
    }
}

/// Raise gauge `name` to at least `value` (high-water marks). Same
/// routing as [`counter`].
#[inline]
pub fn gauge_max(name: &'static str, value: u64) {
    if metrics::sink_gauge(name, value) {
        return;
    }
    if crate::dpp::timing::enabled() {
        crate::dpp::timing::record(name, value);
    }
}
