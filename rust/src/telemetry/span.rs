//! Hierarchical span tracing with Chrome trace-event export.
//!
//! [`Tracer::start`] arms a process-global tracer; while armed,
//! [`span`] returns a cheap RAII guard that records `(name, category,
//! start, duration)` on drop into a **per-thread** event buffer — the
//! hot path never touches a shared lock, so lanes trace independently
//! and the run nests cleanly: run → slice → EM iter → MAP iter →
//! primitive / pipeline stage.
//!
//! While the tracer is off, `span` is two relaxed atomic loads and
//! returns an inert guard: no clock read, no allocation — the
//! telemetry-off path stays bitwise-identical and zero-alloc
//! (asserted by `benches/alloc_churn.rs`).
//!
//! [`Tracer::finish`] disarms the tracer and drains every thread's
//! buffer into a [`Trace`], exported as Chrome trace-event JSON
//! (`{"traceEvents": [...]}` with `"ph": "X"` complete events and
//! `"ph": "M"` thread-name metadata) — load the file in Perfetto or
//! `chrome://tracing`.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::json::Value;

/// One completed span, recorded at guard drop.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: &'static str,
    pub cat: &'static str,
    pub start: Instant,
    pub dur_nanos: u64,
    /// Optional single integer argument (slice z, iteration index...).
    pub arg: Option<(&'static str, u64)>,
}

/// Per-thread event buffer. Only its owning thread pushes, so the
/// mutexes are uncontended until [`Tracer::finish`] drains them.
#[derive(Debug)]
struct ThreadBuf {
    tid: u64,
    label: Mutex<Option<String>>,
    events: Mutex<Vec<SpanEvent>>,
}

#[derive(Debug)]
struct TracerShared {
    epoch: u64,
    t0: Instant,
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
    next_tid: AtomicU64,
}

/// Fast-path switch: checked (relaxed) before any other tracing work.
static TRACING: AtomicBool = AtomicBool::new(false);
/// Bumped per [`Tracer::start`]; thread-local buffer caches carry the
/// epoch they registered under and re-register when it moves on.
static EPOCH: AtomicU64 = AtomicU64::new(0);
static CURRENT: Mutex<Option<Arc<TracerShared>>> = Mutex::new(None);

thread_local! {
    static TBUF: RefCell<Option<(u64, Arc<ThreadBuf>)>> =
        const { RefCell::new(None) };
}

/// True while a tracer is armed.
#[inline]
pub fn tracing() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Armed tracing session; [`Tracer::finish`] yields the [`Trace`].
/// One session at a time: starting a second one while the first is
/// armed replaces it (the first then finishes empty-handed).
#[must_use = "finish() the tracer to export the trace"]
pub struct Tracer {
    shared: Arc<TracerShared>,
}

impl Tracer {
    /// Arm the process-global tracer.
    pub fn start() -> Tracer {
        let epoch = EPOCH.fetch_add(1, Ordering::AcqRel) + 1;
        let shared = Arc::new(TracerShared {
            epoch,
            t0: Instant::now(),
            bufs: Mutex::new(Vec::new()),
            next_tid: AtomicU64::new(1),
        });
        *CURRENT.lock().unwrap() = Some(Arc::clone(&shared));
        TRACING.store(true, Ordering::Release);
        Tracer { shared }
    }

    /// Disarm and drain all thread buffers into a [`Trace`].
    pub fn finish(self) -> Trace {
        {
            let mut cur = CURRENT.lock().unwrap();
            if cur.as_ref().is_some_and(|c| Arc::ptr_eq(c, &self.shared)) {
                *cur = None;
                TRACING.store(false, Ordering::Release);
            }
        }
        let bufs = std::mem::take(&mut *self.shared.bufs.lock().unwrap());
        let mut threads: Vec<ThreadTrace> = bufs
            .iter()
            .map(|b| ThreadTrace {
                tid: b.tid,
                label: b.label.lock().unwrap().clone(),
                events: std::mem::take(&mut *b.events.lock().unwrap()),
            })
            .collect();
        threads.sort_by_key(|t| t.tid);
        Trace { t0: self.shared.t0, threads }
    }
}

/// One thread's worth of drained trace data.
#[derive(Debug)]
pub struct ThreadTrace {
    pub tid: u64,
    pub label: Option<String>,
    pub events: Vec<SpanEvent>,
}

/// Drained trace, ready for export.
#[derive(Debug)]
pub struct Trace {
    t0: Instant,
    pub threads: Vec<ThreadTrace>,
}

impl Trace {
    pub fn num_events(&self) -> usize {
        self.threads.iter().map(|t| t.events.len()).sum()
    }

    /// Chrome trace-event JSON (object form). Timestamps are
    /// microseconds relative to [`Tracer::start`].
    pub fn to_chrome_json(&self) -> Value {
        let mut events = Vec::new();
        for th in &self.threads {
            let tid = th.tid as usize;
            if let Some(lbl) = &th.label {
                events.push(Value::object(vec![
                    ("name", "thread_name".into()),
                    ("ph", "M".into()),
                    ("pid", 1usize.into()),
                    ("tid", tid.into()),
                    ("args",
                     Value::object(vec![("name", lbl.as_str().into())])),
                ]));
            }
            for ev in &th.events {
                let ts =
                    ev.start.saturating_duration_since(self.t0).as_nanos()
                        as f64
                        / 1e3;
                let mut fields = vec![
                    ("name", ev.name.into()),
                    ("cat", ev.cat.into()),
                    ("ph", "X".into()),
                    ("ts", ts.into()),
                    ("dur", (ev.dur_nanos as f64 / 1e3).into()),
                    ("pid", 1usize.into()),
                    ("tid", tid.into()),
                ];
                if let Some((k, v)) = ev.arg {
                    fields.push((
                        "args",
                        Value::object(vec![(k, (v as f64).into())]),
                    ));
                }
                events.push(Value::object(fields));
            }
        }
        Value::object(vec![("traceEvents", Value::Array(events))])
    }
}

/// RAII span guard; inert (`None`) while tracing is off.
pub struct Span(Option<SpanStart>);

struct SpanStart {
    cat: &'static str,
    name: &'static str,
    arg: Option<(&'static str, u64)>,
    start: Instant,
}

/// Open a span under `cat`/`name`; closes (and records) on drop.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !tracing() {
        return Span(None);
    }
    Span(Some(SpanStart { cat, name, arg: None, start: Instant::now() }))
}

/// [`span`] with one integer argument (slice index, iteration...).
#[inline]
pub fn span_arg(
    cat: &'static str,
    name: &'static str,
    key: &'static str,
    val: u64,
) -> Span {
    if !tracing() {
        return Span(None);
    }
    Span(Some(SpanStart {
        cat,
        name,
        arg: Some((key, val)),
        start: Instant::now(),
    }))
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            let dur = s.start.elapsed().as_nanos() as u64;
            push_event(SpanEvent {
                name: s.name,
                cat: s.cat,
                start: s.start,
                dur_nanos: dur,
                arg: s.arg,
            });
        }
    }
}

/// Record an already-measured interval as a span (used by
/// `timing::timed` and the pipeline region so one clock read serves
/// both the metric row and the trace).
#[inline]
pub fn emit_span(
    cat: &'static str,
    name: &'static str,
    start: Instant,
    dur_nanos: u64,
) {
    if !tracing() {
        return;
    }
    push_event(SpanEvent { name, cat, start, dur_nanos, arg: None });
}

/// Label the current thread in the exported trace (`"opt-lane-1"`...).
/// Free when tracing is off — the arguments are only formatted after
/// the armed check.
pub fn name_thread(label: std::fmt::Arguments<'_>) {
    if !tracing() {
        return;
    }
    let text = std::fmt::format(label);
    with_thread_buf(|buf| {
        *buf.label.lock().unwrap() = Some(text);
    });
}

fn push_event(ev: SpanEvent) {
    with_thread_buf(|buf| buf.events.lock().unwrap().push(ev));
}

/// Run `f` on this thread's registered buffer for the current epoch,
/// registering a fresh buffer with the armed tracer if needed. No-op
/// when the tracer disarmed since the caller's check.
fn with_thread_buf(f: impl FnOnce(&ThreadBuf)) {
    TBUF.with(|tb| {
        let mut tb = tb.borrow_mut();
        let epoch = EPOCH.load(Ordering::Acquire);
        let stale = !matches!(&*tb, Some((e, _)) if *e == epoch);
        if stale {
            let Some(tr) = CURRENT.lock().unwrap().clone() else {
                *tb = None;
                return;
            };
            let buf = Arc::new(ThreadBuf {
                tid: tr.next_tid.fetch_add(1, Ordering::Relaxed),
                label: Mutex::new(None),
                events: Mutex::new(Vec::new()),
            });
            tr.bufs.lock().unwrap().push(Arc::clone(&buf));
            *tb = Some((tr.epoch, buf));
        }
        if let Some((_, buf)) = &*tb {
            f(buf);
        }
    });
}

/// Serialize tests (and anything else) that arm the process-global
/// tracer — the span half of telemetry is inherently global, unlike
/// the scoped metric recorders.
#[doc(hidden)]
pub fn trace_test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        let _guard = trace_test_lock();
        assert!(!tracing());
        let s = span("prim", "Map");
        assert!(s.0.is_none(), "no clock read while disarmed");
        drop(s);
    }

    #[test]
    fn spans_nest_and_export_chrome_events() {
        let _guard = trace_test_lock();
        let tracer = Tracer::start();
        {
            let _run = span("run", "run");
            {
                let _slice = span_arg("slice", "opt", "z", 3);
                let _prim = span("prim", "Map");
            }
            std::thread::scope(|s| {
                s.spawn(|| {
                    name_thread(format_args!("opt-lane-{}", 1));
                    let _sp = span_arg("slice", "opt", "z", 4);
                });
            });
        }
        let trace = tracer.finish();
        assert!(!tracing());
        assert_eq!(trace.num_events(), 4);
        assert_eq!(trace.threads.len(), 2);

        let j = trace.to_chrome_json();
        let events = j.get("traceEvents").and_then(Value::as_array).unwrap();
        // 4 X events + 1 thread_name metadata record.
        assert_eq!(events.len(), 5);
        let xs: Vec<&Value> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 4);
        for e in &xs {
            assert!(e.get("ts").and_then(Value::as_f64).unwrap() >= 0.0);
            assert!(e.get("dur").and_then(Value::as_f64).unwrap() >= 0.0);
            assert!(e.get("name").is_some() && e.get("cat").is_some());
        }
        // The run span encloses the same-thread children.
        let run = xs
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("run"))
            .unwrap();
        let run_end = run.get("ts").and_then(Value::as_f64).unwrap()
            + run.get("dur").and_then(Value::as_f64).unwrap();
        let run_tid = run.get("tid").and_then(Value::as_f64).unwrap();
        for e in &xs {
            if e.get("tid").and_then(Value::as_f64) == Some(run_tid) {
                let end = e.get("ts").and_then(Value::as_f64).unwrap()
                    + e.get("dur").and_then(Value::as_f64).unwrap();
                assert!(end <= run_end + 1e-3);
            }
        }
        // Lane attribution: the named lane thread owns the z=4 span.
        let meta = events
            .iter()
            .find(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
            .unwrap();
        assert_eq!(
            meta.get("args").and_then(|a| a.get("name"))
                .and_then(Value::as_str),
            Some("opt-lane-1")
        );
    }

    #[test]
    fn empty_trace_exports_valid_chrome_json() {
        let _guard = trace_test_lock();
        let trace = Tracer::start().finish();
        assert_eq!(trace.num_events(), 0);
        assert!(trace.threads.is_empty());
        let j = trace.to_chrome_json();
        let events =
            j.get("traceEvents").and_then(Value::as_array).unwrap();
        assert!(events.is_empty());
        // And the export round-trips through the JSON parser.
        let back = crate::json::parse(&j.to_pretty()).unwrap();
        assert!(back
            .get("traceEvents")
            .and_then(Value::as_array)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn spans_open_at_export_are_dropped_not_corrupted() {
        let _guard = trace_test_lock();
        let tracer = Tracer::start();
        let closed = span("prim", "Map");
        drop(closed);
        let open = span("prim", "Scan"); // still open at finish()
        let trace = tracer.finish();
        // The open span's guard drops after disarm: its event is
        // discarded, never half-recorded.
        assert_eq!(trace.num_events(), 1);
        drop(open);
        assert_eq!(trace.num_events(), 1, "late drop adds nothing");
        let j = trace.to_chrome_json();
        let events =
            j.get("traceEvents").and_then(Value::as_array).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(
            events[0].get("name").and_then(Value::as_str),
            Some("Map")
        );
    }

    #[test]
    fn two_threads_with_the_same_name_stay_distinct() {
        let _guard = trace_test_lock();
        let tracer = Tracer::start();
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    name_thread(format_args!("opt-lane-{}", 7));
                    drop(span("slice", "opt"));
                });
            }
        });
        let trace = tracer.finish();
        assert_eq!(trace.threads.len(), 2);
        let j = tracer_export(&trace);
        let metas: Vec<&Value> = j
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
            .collect();
        assert_eq!(metas.len(), 2, "one metadata record per thread");
        let tids: Vec<f64> = metas
            .iter()
            .map(|m| m.get("tid").and_then(Value::as_f64).unwrap())
            .collect();
        assert_ne!(tids[0], tids[1], "same label, distinct tids");
        for m in &metas {
            assert_eq!(
                m.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(Value::as_str),
                Some("opt-lane-7")
            );
        }
    }

    /// Export helper: the flat traceEvents array (owned clone).
    fn tracer_export(trace: &Trace) -> Vec<Value> {
        match trace.to_chrome_json().get("traceEvents") {
            Some(Value::Array(v)) => v.clone(),
            _ => panic!("missing traceEvents"),
        }
    }

    #[test]
    fn events_after_finish_are_dropped() {
        let _guard = trace_test_lock();
        let tracer = Tracer::start();
        drop(span("prim", "Map"));
        let trace = tracer.finish();
        assert_eq!(trace.num_events(), 1);
        drop(span("prim", "Map"));
        let tracer2 = Tracer::start();
        drop(span("prim", "Scan"));
        let t2 = tracer2.finish();
        assert_eq!(t2.num_events(), 1, "old-epoch events must not bleed in");
    }
}
