//! Image substrate: volumes, procedural datasets, corruption models,
//! threshold baselines, PGM/raw IO.

pub mod noise;
pub mod synth;
pub mod threshold;
pub mod volume;

pub use volume::{ImageSlice, Volume};

use crate::config::{DatasetConfig, DatasetKind};

/// A generated dataset: the corrupted input plus (for synthetic data)
/// the clean ground truth.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub input: Volume,
    pub ground_truth: Option<Volume>,
    pub name: &'static str,
}

/// Generate the dataset a config describes (corruption included).
pub fn generate(cfg: &DatasetConfig) -> Dataset {
    match cfg.kind {
        DatasetKind::Synthetic => {
            let truth = synth::porous_ground_truth(
                cfg.width, cfg.height, cfg.slices, 0.42, cfg.seed,
            );
            let mut input = truth.clone();
            noise::corrupt(
                &mut input,
                cfg.salt_pepper,
                cfg.gaussian_sigma,
                cfg.ringing,
                cfg.seed,
            );
            Dataset { input, ground_truth: Some(truth), name: "synthetic" }
        }
        DatasetKind::Experimental => {
            let mut input = synth::experimental_volume(
                cfg.width, cfg.height, cfg.slices, cfg.seed,
            );
            noise::corrupt(
                &mut input,
                cfg.salt_pepper * 0.5,
                cfg.gaussian_sigma * 0.35,
                cfg.ringing,
                cfg.seed,
            );
            Dataset { input, ground_truth: None, name: "experimental" }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetConfig;

    #[test]
    fn generate_synthetic_has_truth() {
        let cfg = DatasetConfig {
            width: 32,
            height: 32,
            slices: 2,
            ..Default::default()
        };
        let ds = generate(&cfg);
        assert!(ds.ground_truth.is_some());
        assert_eq!(ds.input.voxels(), 32 * 32 * 2);
        // corruption actually changed the data
        assert_ne!(ds.input, *ds.ground_truth.as_ref().unwrap());
    }

    #[test]
    fn generate_experimental_no_truth() {
        let cfg = DatasetConfig {
            kind: DatasetKind::Experimental,
            width: 32,
            height: 32,
            slices: 1,
            ..Default::default()
        };
        let ds = generate(&cfg);
        assert!(ds.ground_truth.is_none());
        assert_eq!(ds.name, "experimental");
    }
}
