//! Threshold baselines (the "simple threshold" comparison of Figs. 1d
//! and 2d): fixed-level and Otsu's method.

use super::volume::Volume;

/// Global histogram of an 8-bit volume.
pub fn histogram(vol: &Volume) -> [u64; 256] {
    let mut h = [0u64; 256];
    for &v in &vol.data {
        h[v as usize] += 1;
    }
    h
}

/// Otsu's threshold: maximizes between-class variance.
pub fn otsu_level(vol: &Volume) -> u8 {
    let hist = histogram(vol);
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 127;
    }
    let sum_all: f64 =
        hist.iter().enumerate().map(|(i, &c)| i as f64 * c as f64).sum();
    let mut w0 = 0u64;
    let mut sum0 = 0.0f64;
    let mut best = (0.0f64, 127u8);
    for t in 0..256 {
        w0 += hist[t];
        if w0 == 0 {
            continue;
        }
        let w1 = total - w0;
        if w1 == 0 {
            break;
        }
        sum0 += t as f64 * hist[t] as f64;
        let m0 = sum0 / w0 as f64;
        let m1 = (sum_all - sum0) / w1 as f64;
        let between = w0 as f64 * w1 as f64 * (m0 - m1) * (m0 - m1);
        if between > best.0 {
            best = (between, t as u8);
        }
    }
    best.1
}

/// Binarize: `v > level` -> 255 else 0.
pub fn apply(vol: &Volume, level: u8) -> Volume {
    let data =
        vol.data.iter().map(|&v| if v > level { 255u8 } else { 0 }).collect();
    Volume::from_data(vol.width, vol.height, vol.depth, data)
}

/// Otsu-thresholded copy (the paper's "simple threshold" baseline).
pub fn otsu(vol: &Volume) -> Volume {
    apply(vol, otsu_level(vol))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn otsu_separates_bimodal() {
        // Two tight modes at 50 and 200 -> threshold between them.
        let mut data = vec![50u8; 500];
        data.extend(vec![200u8; 500]);
        let v = Volume::from_data(10, 10, 10, data);
        let t = otsu_level(&v);
        assert!((50..200).contains(&t), "t={t}");
        let b = otsu(&v);
        assert_eq!(b.data.iter().filter(|&&x| x == 255).count(), 500);
    }

    #[test]
    fn apply_level_boundary() {
        let v = Volume::from_data(1, 1, 3, vec![10, 11, 12]);
        let b = apply(&v, 11);
        assert_eq!(b.data, vec![0, 0, 255]);
    }

    #[test]
    fn histogram_counts() {
        let v = Volume::from_data(1, 1, 4, vec![3, 3, 7, 255]);
        let h = histogram(&v);
        assert_eq!(h[3], 2);
        assert_eq!(h[7], 1);
        assert_eq!(h[255], 1);
    }
}
