//! Grayscale image volumes (8-bit), the unit the whole pipeline
//! consumes. 3D stacks are processed as independent 2D slices, exactly
//! as the paper does (§4.3.1, §5).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// An 8-bit grayscale 3D volume stored slice-major (z, then y, then x).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Volume {
    pub width: usize,
    pub height: usize,
    pub depth: usize,
    pub data: Vec<u8>,
}

impl Volume {
    pub fn new(width: usize, height: usize, depth: usize) -> Volume {
        Volume { width, height, depth, data: vec![0; width * height * depth] }
    }

    pub fn from_data(width: usize, height: usize, depth: usize,
                     data: Vec<u8>) -> Volume {
        assert_eq!(data.len(), width * height * depth);
        Volume { width, height, depth, data }
    }

    #[inline]
    pub fn slice_len(&self) -> usize {
        self.width * self.height
    }

    #[inline]
    pub fn voxels(&self) -> usize {
        self.data.len()
    }

    /// Borrow slice `z` as a 2D image view.
    pub fn slice(&self, z: usize) -> ImageSlice<'_> {
        let n = self.slice_len();
        ImageSlice {
            width: self.width,
            height: self.height,
            pixels: &self.data[z * n..(z + 1) * n],
        }
    }

    pub fn slice_mut(&mut self, z: usize) -> &mut [u8] {
        let n = self.slice_len();
        &mut self.data[z * n..(z + 1) * n]
    }

    #[inline]
    pub fn at(&self, x: usize, y: usize, z: usize) -> u8 {
        self.data[(z * self.height + y) * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, z: usize, v: u8) {
        self.data[(z * self.height + y) * self.width + x] = v;
    }

    /// Fraction of voxels equal to 0 — the porosity metric's raw input
    /// when 0 encodes void space.
    pub fn zero_fraction(&self) -> f64 {
        let zeros = self.data.iter().filter(|&&v| v == 0).count();
        zeros as f64 / self.voxels() as f64
    }

    /// Write slice `z` as a binary PGM (P5) file.
    pub fn write_pgm(&self, z: usize, path: &Path) -> Result<()> {
        let img = self.slice(z);
        let mut out = format!("P5\n{} {}\n255\n", img.width, img.height)
            .into_bytes();
        out.extend_from_slice(img.pixels);
        std::fs::write(path, out)
            .with_context(|| format!("write {}", path.display()))
    }

    /// Read a single-slice volume from a binary PGM (P5) file.
    pub fn read_pgm(path: &Path) -> Result<Volume> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        parse_pgm(&bytes)
    }

    /// Raw dump: u8 voxels, slice-major, with a tiny JSON sidecar for
    /// dimensions (`<path>.meta.json`).
    pub fn write_raw(&self, path: &Path) -> Result<()> {
        std::fs::write(path, &self.data)
            .with_context(|| format!("write {}", path.display()))?;
        let meta = crate::json::Value::object(vec![
            ("width", self.width.into()),
            ("height", self.height.into()),
            ("depth", self.depth.into()),
        ]);
        std::fs::write(sidecar(path), meta.to_pretty())
            .with_context(|| format!("write {}", sidecar(path).display()))
    }

    pub fn read_raw(path: &Path) -> Result<Volume> {
        let meta = crate::json::from_file(&sidecar(path))?;
        let (w, h, d) = (
            meta.get("width").and_then(|v| v.as_usize()),
            meta.get("height").and_then(|v| v.as_usize()),
            meta.get("depth").and_then(|v| v.as_usize()),
        );
        let (Some(w), Some(h), Some(d)) = (w, h, d) else {
            bail!("bad sidecar {}", sidecar(path).display());
        };
        let data = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        if data.len() != w * h * d {
            bail!("raw size {} != {}x{}x{}", data.len(), w, h, d);
        }
        Ok(Volume::from_data(w, h, d, data))
    }
}

fn sidecar(path: &Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_owned();
    s.push(".meta.json");
    std::path::PathBuf::from(s)
}

fn parse_pgm(bytes: &[u8]) -> Result<Volume> {
    // Header: "P5" <ws> width <ws> height <ws> maxval <single ws> data
    let mut pos = 0usize;
    let mut token = || -> Result<String> {
        // skip whitespace + comments
        loop {
            while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < bytes.len() && bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
            } else {
                break;
            }
        }
        let start = pos;
        while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if start == pos {
            bail!("truncated PGM header");
        }
        Ok(String::from_utf8_lossy(&bytes[start..pos]).into_owned())
    };
    let magic = token()?;
    if magic != "P5" {
        bail!("not a binary PGM (magic {magic})");
    }
    let w: usize = token()?.parse().context("PGM width")?;
    let h: usize = token()?.parse().context("PGM height")?;
    let maxval: usize = token()?.parse().context("PGM maxval")?;
    if maxval != 255 {
        bail!("only maxval 255 supported (got {maxval})");
    }
    pos += 1; // single whitespace after maxval
    if bytes.len() < pos + w * h {
        bail!("PGM data truncated");
    }
    Ok(Volume::from_data(w, h, 1, bytes[pos..pos + w * h].to_vec()))
}

/// Borrowed 2D view of one slice.
#[derive(Debug, Clone, Copy)]
pub struct ImageSlice<'a> {
    pub width: usize,
    pub height: usize,
    pub pixels: &'a [u8],
}

impl<'a> ImageSlice<'a> {
    #[inline]
    pub fn at(&self, x: usize, y: usize) -> u8 {
        self.pixels[y * self.width + x]
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.pixels.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pixels.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_math() {
        let mut v = Volume::new(3, 2, 2);
        v.set(2, 1, 1, 9);
        assert_eq!(v.at(2, 1, 1), 9);
        assert_eq!(v.data[(1 * 2 + 1) * 3 + 2], 9);
        assert_eq!(v.slice(1).at(2, 1), 9);
    }

    #[test]
    fn pgm_round_trip() {
        let dir = std::env::temp_dir().join("dpp_pmrf_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        let mut v = Volume::new(4, 3, 1);
        for (i, p) in v.data.iter_mut().enumerate() {
            *p = (i * 7 % 256) as u8;
        }
        v.write_pgm(0, &path).unwrap();
        let back = Volume::read_pgm(&path).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn raw_round_trip() {
        let dir = std::env::temp_dir().join("dpp_pmrf_raw_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.raw");
        let mut v = Volume::new(5, 4, 3);
        for (i, p) in v.data.iter_mut().enumerate() {
            *p = (i % 251) as u8;
        }
        v.write_raw(&path).unwrap();
        let back = Volume::read_raw(&path).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn pgm_rejects_bad() {
        assert!(parse_pgm(b"P6\n1 1\n255\n\x00").is_err());
        assert!(parse_pgm(b"P5\n4 4\n255\n\x00").is_err()); // truncated
    }

    #[test]
    fn zero_fraction() {
        let v = Volume::from_data(2, 2, 1, vec![0, 255, 0, 255]);
        assert_eq!(v.zero_fraction(), 0.5);
    }
}
