//! Procedural dataset generators (paper §4.1.1 substitutes).
//!
//! * [`porous_ground_truth`] — stands in for the NGCF Mt. Gambier
//!   limestone benchmark: a binary (pore/solid) volume from thresholded
//!   multi-octave value noise. Homogeneous texture => the region graph
//!   has many small neighborhoods with a bell-shaped size distribution,
//!   the property §4.3.3 ties to the synthetic dataset's behaviour.
//! * [`experimental_volume`] — stands in for the ALS beamline 8.3.2
//!   geological micro-CT scan: layered strata, fractures, and bright
//!   inclusions => a denser region graph with an irregular
//!   neighborhood-complexity distribution.
//!
//! Both are deterministic in the seed. See DESIGN.md §Substitutions.

use crate::util::{splitmix64, Pcg32};

use super::volume::Volume;

/// Hash lattice point -> f64 in [0,1).
#[inline]
fn lattice(seed: u64, x: i64, y: i64, z: i64) -> f64 {
    let h = splitmix64(
        seed ^ (x as u64).wrapping_mul(0x9E3779B185EBCA87)
            ^ (y as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
            ^ (z as u64).wrapping_mul(0x165667B19E3779F9),
    );
    (h >> 11) as f64 / (1u64 << 53) as f64
}

fn smooth(t: f64) -> f64 {
    t * t * (3.0 - 2.0 * t)
}

/// Trilinear value noise at a continuous point.
fn value_noise(seed: u64, x: f64, y: f64, z: f64) -> f64 {
    let (xi, yi, zi) = (x.floor() as i64, y.floor() as i64, z.floor() as i64);
    let (fx, fy, fz) =
        (smooth(x - xi as f64), smooth(y - yi as f64), smooth(z - zi as f64));
    let mut acc = 0.0;
    for (dz, wz) in [(0i64, 1.0 - fz), (1, fz)] {
        for (dy, wy) in [(0i64, 1.0 - fy), (1, fy)] {
            for (dx, wx) in [(0i64, 1.0 - fx), (1, fx)] {
                acc += wx * wy * wz
                    * lattice(seed, xi + dx, yi + dy, zi + dz);
            }
        }
    }
    acc
}

/// Multi-octave fractal value noise in [0,1] (approximately).
fn fbm(seed: u64, x: f64, y: f64, z: f64, octaves: u32) -> f64 {
    let mut acc = 0.0;
    let mut amp = 0.5;
    let mut freq = 1.0;
    let mut norm = 0.0;
    for o in 0..octaves {
        acc += amp
            * value_noise(seed.wrapping_add(o as u64), x * freq, y * freq,
                          z * freq);
        norm += amp;
        amp *= 0.5;
        freq *= 2.0;
    }
    acc / norm
}

/// Binary porous-media ground truth: 0 = void (pore), 255 = solid.
///
/// `porosity` sets the target void fraction; the threshold is chosen
/// from the data's own quantile, so the achieved porosity is within a
/// percent of the target.
pub fn porous_ground_truth(
    width: usize,
    height: usize,
    depth: usize,
    porosity: f64,
    seed: u64,
) -> Volume {
    let feature = 12.0; // lattice cells across the short axis
    let scale = feature / width.min(height).max(1) as f64;
    let mut field = Vec::with_capacity(width * height * depth);
    for z in 0..depth {
        for y in 0..height {
            for x in 0..width {
                field.push(fbm(
                    seed,
                    x as f64 * scale,
                    y as f64 * scale,
                    z as f64 * scale * 2.0,
                    4,
                ));
            }
        }
    }
    // Quantile threshold for the requested porosity.
    let mut sorted = field.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = ((sorted.len() as f64 - 1.0) * porosity.clamp(0.0, 1.0)) as usize;
    let thresh = sorted[q];
    let data =
        field.iter().map(|&v| if v <= thresh { 0u8 } else { 255 }).collect();
    Volume::from_data(width, height, depth, data)
}

/// Grayscale "experimental" geological volume (no clean ground truth):
/// depth-warped strata + dark fractures + bright inclusions + a gentle
/// illumination gradient.
pub fn experimental_volume(
    width: usize,
    height: usize,
    depth: usize,
    seed: u64,
) -> Volume {
    let mut vol = Volume::new(width, height, depth);
    let mut rng = Pcg32::seeded(seed);

    // Strata intensity bands.
    let bands = [60.0f64, 120.0, 90.0, 170.0, 140.0, 200.0];
    let band_h = (height as f64 / bands.len() as f64).max(1.0);

    for z in 0..depth {
        for y in 0..height {
            for x in 0..width {
                // Warp the band boundary with low-frequency noise.
                let warp = 18.0
                    * (fbm(seed ^ 0xA11CE, x as f64 / 48.0, z as f64 / 8.0,
                           0.0, 3)
                        - 0.5);
                let fy = (y as f64 + warp).clamp(0.0, height as f64 - 1.0);
                let band = ((fy / band_h) as usize).min(bands.len() - 1);
                let base = bands[band];
                // Fine texture within a band.
                let tex = 22.0
                    * (fbm(seed ^ 0xBEEF, x as f64 / 6.0, y as f64 / 6.0,
                           z as f64 / 3.0, 3)
                        - 0.5);
                // Illumination gradient (common in beamline scans).
                let grad = 14.0 * (x as f64 / width.max(1) as f64 - 0.5);
                let v = (base + tex + grad).clamp(0.0, 255.0);
                vol.set(x, y, z, v as u8);
            }
        }
    }

    // Fractures: dark polylines meandering downward.
    let n_cracks = (width / 24).max(2);
    for _ in 0..n_cracks {
        let mut x = rng.below(width as u32) as f64;
        let z0 = rng.below(depth as u32) as usize;
        let z1 = (z0 + 1 + rng.below(depth as u32) as usize).min(depth);
        for y in 0..height {
            x += rng.normal() * 0.9;
            let xi = x.round();
            if xi < 0.0 || xi >= width as f64 {
                break;
            }
            for z in z0..z1 {
                let xi = xi as usize;
                vol.set(xi, y, z, 15);
                if xi + 1 < width {
                    vol.set(xi + 1, y, z, 25);
                }
            }
        }
    }

    // Bright mineral inclusions: small ellipsoids.
    let n_inc = (width * height / 900).max(4);
    for _ in 0..n_inc {
        let cx = rng.below(width as u32) as f64;
        let cy = rng.below(height as u32) as f64;
        let cz = rng.below(depth as u32) as f64;
        let rx = 1.5 + rng.f64() * 4.0;
        let ry = 1.5 + rng.f64() * 4.0;
        let rz = 1.0 + rng.f64() * 2.0;
        let lo_x = (cx - rx).max(0.0) as usize;
        let hi_x = ((cx + rx) as usize + 1).min(width);
        let lo_y = (cy - ry).max(0.0) as usize;
        let hi_y = ((cy + ry) as usize + 1).min(height);
        let lo_z = (cz - rz).max(0.0) as usize;
        let hi_z = ((cz + rz) as usize + 1).min(depth);
        for z in lo_z..hi_z {
            for y in lo_y..hi_y {
                for x in lo_x..hi_x {
                    let d = ((x as f64 - cx) / rx).powi(2)
                        + ((y as f64 - cy) / ry).powi(2)
                        + ((z as f64 - cz) / rz).powi(2);
                    if d <= 1.0 {
                        vol.set(x, y, z, 235);
                    }
                }
            }
        }
    }

    vol
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn porous_hits_target_porosity() {
        let v = porous_ground_truth(64, 64, 2, 0.4, 7);
        let p = v.zero_fraction();
        assert!((p - 0.4).abs() < 0.02, "porosity {p}");
        // binary output
        assert!(v.data.iter().all(|&x| x == 0 || x == 255));
    }

    #[test]
    fn porous_deterministic_and_seed_sensitive() {
        let a = porous_ground_truth(32, 32, 2, 0.4, 1);
        let b = porous_ground_truth(32, 32, 2, 0.4, 1);
        let c = porous_ground_truth(32, 32, 2, 0.4, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn porous_has_structure_not_salt() {
        // Neighboring voxels should agree far more often than 50%:
        // the field is spatially correlated, not pixel noise.
        let v = porous_ground_truth(64, 64, 1, 0.4, 3);
        let mut agree = 0usize;
        let mut total = 0usize;
        for y in 0..64 {
            for x in 0..63 {
                agree += usize::from(v.at(x, y, 0) == v.at(x + 1, y, 0));
                total += 1;
            }
        }
        assert!(agree as f64 / total as f64 > 0.85);
    }

    #[test]
    fn experimental_is_multimodal_grayscale() {
        let v = experimental_volume(96, 96, 2, 11);
        let mut hist = [0usize; 256];
        for &p in &v.data {
            hist[p as usize] += 1;
        }
        // spread across the range, not binary
        let nonzero_bins = hist.iter().filter(|&&c| c > 0).count();
        assert!(nonzero_bins > 40, "bins={nonzero_bins}");
        // contains dark fractures and bright inclusions
        assert!(hist[15] + hist[25] > 0, "fractures missing");
        assert!(hist[235] > 0, "inclusions missing");
    }

    #[test]
    fn experimental_deterministic() {
        assert_eq!(experimental_volume(32, 32, 2, 5),
                   experimental_volume(32, 32, 2, 5));
    }
}
