//! Corruption models applied to the clean volumes (paper §4.1.1):
//! salt-and-pepper, additive Gaussian (σ = 100 on the 8-bit scale in
//! the paper), and simulated CT ringing artifacts (concentric
//! modulation around the slice center, cf. Perciano et al. 2017).

use crate::util::Pcg32;

use super::volume::Volume;

/// Flip a `fraction` of voxels to 0 or 255 (half each, in expectation).
pub fn salt_and_pepper(vol: &mut Volume, fraction: f64, seed: u64) {
    let mut rng = Pcg32::seeded(seed ^ 0x5a17);
    for v in vol.data.iter_mut() {
        if (rng.f64()) < fraction {
            *v = if rng.f32() < 0.5 { 0 } else { 255 };
        }
    }
}

/// Additive Gaussian noise with standard deviation `sigma` (8-bit
/// scale), clamped to [0, 255].
pub fn additive_gaussian(vol: &mut Volume, sigma: f64, seed: u64) {
    if sigma <= 0.0 {
        return;
    }
    let mut rng = Pcg32::seeded(seed ^ 0x9a55);
    for v in vol.data.iter_mut() {
        let nv = *v as f64 + rng.normal() * sigma;
        *v = nv.clamp(0.0, 255.0) as u8;
    }
}

/// Ring artifacts: concentric sinusoidal intensity modulation around
/// the slice center, with mild per-ring phase jitter. `amplitude` is in
/// 8-bit intensity units (0 disables).
pub fn ringing(vol: &mut Volume, amplitude: f64, seed: u64) {
    if amplitude <= 0.0 {
        return;
    }
    let mut rng = Pcg32::seeded(seed ^ 0x2177);
    let cx = vol.width as f64 / 2.0;
    let cy = vol.height as f64 / 2.0;
    let wavelength = 7.0;
    for z in 0..vol.depth {
        let phase = rng.f64() * std::f64::consts::TAU;
        for y in 0..vol.height {
            for x in 0..vol.width {
                let r = ((x as f64 - cx).powi(2) + (y as f64 - cy).powi(2))
                    .sqrt();
                let m = amplitude
                    * (r / wavelength * std::f64::consts::TAU + phase).sin();
                let idx = (z * vol.height + y) * vol.width + x;
                let nv = vol.data[idx] as f64 + m;
                vol.data[idx] = nv.clamp(0.0, 255.0) as u8;
            }
        }
    }
}

/// Apply the full corruption stack from a dataset config.
pub fn corrupt(
    vol: &mut Volume,
    salt_pepper: f64,
    gaussian_sigma: f64,
    ringing_amp: f64,
    seed: u64,
) {
    ringing(vol, ringing_amp, seed);
    additive_gaussian(vol, gaussian_sigma, seed);
    salt_and_pepper(vol, salt_pepper, seed);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(v: u8) -> Volume {
        Volume::from_data(32, 32, 2, vec![v; 32 * 32 * 2])
    }

    #[test]
    fn salt_pepper_flips_expected_fraction() {
        let mut vol = flat(128);
        salt_and_pepper(&mut vol, 0.1, 1);
        let flipped =
            vol.data.iter().filter(|&&v| v == 0 || v == 255).count();
        let frac = flipped as f64 / vol.voxels() as f64;
        assert!((frac - 0.1).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn gaussian_preserves_mean_spreads_values() {
        let mut vol = flat(128);
        additive_gaussian(&mut vol, 30.0, 2);
        let mean: f64 = vol.data.iter().map(|&v| v as f64).sum::<f64>()
            / vol.voxels() as f64;
        assert!((mean - 128.0).abs() < 3.0, "mean={mean}");
        let distinct: std::collections::BTreeSet<u8> =
            vol.data.iter().copied().collect();
        assert!(distinct.len() > 30);
    }

    #[test]
    fn gaussian_zero_sigma_noop() {
        let mut vol = flat(100);
        additive_gaussian(&mut vol, 0.0, 3);
        assert!(vol.data.iter().all(|&v| v == 100));
    }

    #[test]
    fn ringing_produces_radial_bands() {
        let mut vol = flat(128);
        ringing(&mut vol, 20.0, 4);
        // center row should oscillate around 128
        let row: Vec<i32> = (0..32)
            .map(|x| vol.at(x, 16, 0) as i32 - 128)
            .collect();
        assert!(row.iter().any(|&d| d > 5));
        assert!(row.iter().any(|&d| d < -5));
    }

    #[test]
    fn corrupt_is_deterministic() {
        let mut a = flat(90);
        let mut b = flat(90);
        corrupt(&mut a, 0.05, 50.0, 10.0, 9);
        corrupt(&mut b, 0.05, 50.0, 10.0, 9);
        assert_eq!(a, b);
    }
}
