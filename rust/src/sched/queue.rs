//! Bounded hand-off queue between the init and optimize stages.
//!
//! A Mutex + two-Condvar MPMC ring: producers block once `cap` items
//! are waiting (the scheduler's backpressure contract — initialization
//! can run at most `cap` slices ahead of optimization, bounding peak
//! model memory), consumers block until an item or close arrives. The
//! observed high-water mark is recorded so tests — and
//! `RunReport::sched` — can assert the cap was honored.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Multi-producer multi-consumer queue holding at most `cap` items.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    peak: AtomicUsize,
}

impl<T> BoundedQueue<T> {
    /// Queue admitting at most `cap` (>= 1) waiting items.
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
            peak: AtomicUsize::new(0),
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Highest occupancy ever observed (the in-flight cap audit).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Acquire)
    }

    /// Enqueue `v`, blocking while the queue is full. Returns `false`
    /// (dropping `v`) if the queue was closed underneath the producer
    /// — that only happens when the consumer side poisoned the queue
    /// via [`BoundedQueue::close`] after a panic, and tells the
    /// producer to stop instead of blocking forever on a full queue
    /// nobody will drain.
    pub fn push(&self, v: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.q.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.q.push_back(v);
        self.peak.fetch_max(st.q.len(), Ordering::AcqRel);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Dequeue the next item, blocking while the queue is empty and
    /// open. Returns `None` only once the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.q.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Mark the producer side done: consumers drain what is queued,
    /// then observe `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_then_none_after_close() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None);
        // Push after close is refused, not blocked (panic poisoning).
        assert!(!q.push(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_releases_a_blocked_producer() {
        // A producer stuck on a full queue must observe a close (the
        // consumer-panic poison path) instead of blocking forever.
        let q = BoundedQueue::new(1);
        assert!(q.push(0));
        std::thread::scope(|s| {
            let qr = &q;
            let h = s.spawn(move || qr.push(1)); // blocks: queue full
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.close();
            assert!(!h.join().unwrap(), "push must report the close");
        });
    }

    #[test]
    fn producers_block_at_cap_and_peak_respects_it() {
        // Property sweep: for every cap, a fast producer against a
        // slow consumer never exceeds the cap — the high-water mark
        // proves the backpressure held.
        for cap in [1, 2, 3, 7] {
            let q = BoundedQueue::new(cap);
            let n = 50;
            std::thread::scope(|s| {
                let qp = &q;
                s.spawn(move || {
                    for i in 0..n {
                        qp.push(i);
                    }
                    qp.close();
                });
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                    std::thread::yield_now();
                }
                assert_eq!(got, (0..n).collect::<Vec<_>>());
            });
            assert!(q.peak() <= cap, "cap {cap}, peak {}", q.peak());
            assert!(q.peak() >= 1);
        }
    }

    #[test]
    fn many_producers_many_consumers_deliver_everything() {
        let q = BoundedQueue::new(2);
        let producers = 3;
        let per = 40;
        let total: usize = std::thread::scope(|s| {
            let done = AtomicUsize::new(producers);
            let doner = &done;
            let qr = &q;
            for p in 0..producers {
                s.spawn(move || {
                    for i in 0..per {
                        qr.push(p * per + i);
                    }
                    if doner.fetch_sub(1, Ordering::AcqRel) == 1 {
                        qr.close();
                    }
                });
            }
            let consumers: Vec<_> = (0..2)
                .map(|_| {
                    s.spawn(move || {
                        let mut sum = 0usize;
                        while let Some(v) = qr.pop() {
                            sum += v;
                        }
                        sum
                    })
                })
                .collect();
            consumers.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let n = producers * per;
        assert_eq!(total, n * (n - 1) / 2);
        assert!(q.peak() <= 2);
    }
}
