//! Work-stealing shard of the slice index space.
//!
//! The same range-stealing discipline as [`crate::pool`], lifted from
//! elements to *slices*: every lane owns a contiguous range of slice
//! indices packed into one atomic (`start:u32 | end:u32`), pops single
//! indices from the front, and — when its range drains — steals the
//! back half of the largest victim range. The contiguous split keeps
//! each lane walking neighboring slices (locality for the per-lane
//! engine state) while guaranteeing no idle lane waits on a loaded one.

use std::sync::atomic::{AtomicU64, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Packed(u64);

impl Packed {
    #[inline]
    fn new(start: u32, end: u32) -> Self {
        Packed(((start as u64) << 32) | end as u64)
    }
    #[inline]
    fn start(self) -> u32 {
        (self.0 >> 32) as u32
    }
    #[inline]
    fn end(self) -> u32 {
        self.0 as u32
    }
    #[inline]
    fn len(self) -> u32 {
        self.end().saturating_sub(self.start())
    }
}

enum Steal {
    /// Loot installed as the thief's new range.
    Won,
    /// Lost a CAS race; worth retrying.
    Lost,
    /// No victim holds 2+ slices — every remaining slice will be
    /// drained by its owner, so the thief is done.
    Empty,
}

/// The slice index space `0..n` sharded across `lanes` owners.
///
/// Guarantee: across all lanes, [`SliceShard::claim`] yields every
/// index in `0..n` exactly once (in some order), then `None` forever.
pub struct SliceShard {
    ranges: Vec<AtomicU64>,
}

impl SliceShard {
    /// Evenly partition `0..n` into one contiguous range per lane
    /// (front lanes get the remainder, like the pool's initial split).
    pub fn new(n: usize, lanes: usize) -> SliceShard {
        assert!(n <= u32::MAX as usize, "slice count exceeds packed range");
        let lanes = lanes.max(1);
        let per = n / lanes;
        let rem = n % lanes;
        let mut ranges = Vec::with_capacity(lanes);
        let mut at = 0usize;
        for lane in 0..lanes {
            let len = per + usize::from(lane < rem);
            ranges.push(AtomicU64::new(
                Packed::new(at as u32, (at + len) as u32).0,
            ));
            at += len;
        }
        SliceShard { ranges }
    }

    pub fn lanes(&self) -> usize {
        self.ranges.len()
    }

    /// Slices not yet claimed (racy snapshot; exact once quiescent).
    pub fn remaining(&self) -> usize {
        self.ranges
            .iter()
            .map(|r| Packed(r.load(Ordering::Acquire)).len() as usize)
            .sum()
    }

    /// Pop the next slice for `lane`: front of its own range first,
    /// stealing from the most-loaded victim once the range drains.
    pub fn claim(&self, lane: usize) -> Option<usize> {
        loop {
            if let Some(z) = self.pop_front(lane) {
                return Some(z);
            }
            match self.steal(lane) {
                Steal::Won | Steal::Lost => continue,
                Steal::Empty => return None,
            }
        }
    }

    fn pop_front(&self, lane: usize) -> Option<usize> {
        let slot = &self.ranges[lane];
        loop {
            let cur = Packed(slot.load(Ordering::Acquire));
            let (s, e) = (cur.start(), cur.end());
            if s >= e {
                return None;
            }
            let new = Packed::new(s + 1, e);
            if slot
                .compare_exchange_weak(cur.0, new.0, Ordering::AcqRel,
                                       Ordering::Relaxed)
                .is_ok()
            {
                return Some(s as usize);
            }
        }
    }

    fn steal(&self, lane: usize) -> Steal {
        // Victim with the most remaining slices; a single remaining
        // slice is left to its owner (halving it would steal nothing).
        let mut best: Option<(usize, Packed)> = None;
        for (v, slot) in self.ranges.iter().enumerate() {
            if v == lane {
                continue;
            }
            let cur = Packed(slot.load(Ordering::Acquire));
            if cur.len() >= 2 {
                match best {
                    Some((_, b)) if b.len() >= cur.len() => {}
                    _ => best = Some((v, cur)),
                }
            }
        }
        let (v, cur) = match best {
            Some(x) => x,
            None => return Steal::Empty,
        };
        let (s, e) = (cur.start(), cur.end());
        let mid = e - (e - s) / 2;
        let shrunk = Packed::new(s, mid);
        if self.ranges[v]
            .compare_exchange(cur.0, shrunk.0, Ordering::AcqRel,
                              Ordering::Relaxed)
            .is_ok()
        {
            self.ranges[lane]
                .store(Packed::new(mid, e).0, Ordering::Release);
            Steal::Won
        } else {
            Steal::Lost
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn single_lane_claims_in_order() {
        let shard = SliceShard::new(5, 1);
        let got: Vec<usize> =
            std::iter::from_fn(|| shard.claim(0)).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(shard.claim(0), None);
    }

    #[test]
    fn every_index_claimed_exactly_once_concurrently() {
        for lanes in [2, 3, 4, 8] {
            let n = 503;
            let shard = SliceShard::new(n, lanes);
            let hits: Vec<AtomicU32> =
                (0..n).map(|_| AtomicU32::new(0)).collect();
            std::thread::scope(|s| {
                for lane in 0..lanes {
                    let shard = &shard;
                    let hits = &hits;
                    s.spawn(move || {
                        while let Some(z) = shard.claim(lane) {
                            hits[z].fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "lanes={lanes}"
            );
        }
    }

    #[test]
    fn idle_lane_steals_from_loaded_one() {
        // Lane 1's initial range is empty (2 lanes, all work in lane
        // 0's half after lane 0 never pops): a claim from lane 1 must
        // still find work.
        let shard = SliceShard::new(8, 2);
        // Lane 1 starts with 4..8; drain those, then steal from lane 0.
        let mut seen = Vec::new();
        while let Some(z) = shard.claim(1) {
            seen.push(z);
        }
        assert_eq!(seen.len(), 7, "lane 1 drains all but the last \
                                   owner-reserved slice: {seen:?}");
        assert_eq!(shard.claim(0), Some(0));
        assert_eq!(shard.claim(0), None);
    }

    #[test]
    fn empty_and_more_lanes_than_slices() {
        let shard = SliceShard::new(0, 4);
        for lane in 0..4 {
            assert_eq!(shard.claim(lane), None);
        }
        let shard = SliceShard::new(2, 4);
        let mut got: Vec<usize> =
            (0..4).filter_map(|lane| shard.claim(lane)).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }
}
