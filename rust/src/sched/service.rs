//! Batch serving front end over the slice scheduler.
//!
//! A [`Service`] owns a small crew of job workers. Callers
//! [`Service::submit`] jobs — a dataset plus the [`RunConfig`] to run
//! it under — and block only when the configured number of jobs is
//! already in flight (admission backpressure, same contract as the
//! slice queue one layer down). Each job runs the full coordinator
//! pipeline (which itself shards slices across `cfg.sched.lanes`
//! lanes), so a deployment has two independent concurrency knobs:
//! jobs in flight × lanes per job.
//!
//! Results come back through [`Ticket`]s; [`Service::run_batch`]
//! returns reports in **submission order** regardless of completion
//! order — the determinism contract callers script against. Per-job
//! wall clock is recorded under `Service::job` in
//! [`crate::dpp::timing`] when a metric sink is listening.
//!
//! Independent of profiling, the service **always** measures each
//! job's queue wait (submit → dequeue) and execute time (dequeue →
//! finish): two `Instant::now` calls per job, explicitly exempt from
//! the zero-alloc contract (DESIGN.md §11 — serving jobs are seconds
//! long; two clock reads are noise). Per-job numbers ride back on the
//! ticket ([`Ticket::wait_stats`]); service-lifetime aggregates live
//! in log2 histograms, summarized by [`Service::latency`].
//!
//! Observability (ISSUE 8, DESIGN.md §13): [`Service::health`] is a
//! lock-light live snapshot — queue depth and in-flight from one
//! brief state lock, everything else (job/SLO counters, per-lane
//! busy/progress) from relaxed atomics. A service built with
//! [`Service::with_options`] additionally enforces
//! [`SloConfig`](crate::obs::SloConfig) thresholds — violations are
//! marked on the job's [`JobStats`] and counted in health — and arms
//! the per-lane [`Heartbeat`](crate::obs::Heartbeat) watchdog: engine
//! iteration hooks mark progress, so a busy lane that stops marking
//! for longer than the stall window is *reported* stalled by
//! `health()` instead of hanging its callers silently.
//! [`Service::metrics_text`] renders the same state (plus the global
//! timing registry) in Prometheus text format.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{Coordinator, RunReport};
use crate::dpp::timing;
use crate::image::Dataset;
use crate::obs::{self, Heartbeat, SloConfig, SloFlags};
use crate::telemetry::{LatencySummary, Log2Histogram};
use crate::util::Timer;

/// One unit of serving work: segment `dataset` under `cfg`.
pub struct Job {
    pub dataset: Dataset,
    pub cfg: RunConfig,
}

/// Per-job serving latency, measured for **every** job — profiling
/// on or off (see the module docs for the zero-alloc exemption).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobStats {
    /// Submit → dequeue: time spent waiting for a worker.
    pub queue_wait_secs: f64,
    /// Dequeue → finish: time inside the coordinator run.
    pub exec_secs: f64,
    /// Which serving SLOs this job violated (all false unless the
    /// service was built with thresholds — [`Service::with_options`]).
    pub slo: SloFlags,
    /// The job ran to completion but the service withheld its report
    /// because [`ServiceOptions::enforce_slo`] was set and the run's
    /// certified optimality gap tripped `SloConfig.max_gap`. Distinct
    /// from a run error: the engine succeeded, the certificate failed.
    pub rejected: bool,
}

/// Completion slot one job's result is published through.
struct Slot {
    cell: Mutex<Option<(Result<RunReport>, JobStats)>>,
    done: Condvar,
}

/// Handle to one submitted job; [`Ticket::wait`] blocks until the
/// job's report (or error) is available.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> Result<RunReport> {
        self.wait_stats().0
    }

    /// [`Ticket::wait`] plus the job's serving latency (recorded even
    /// for failed jobs — a panicked run still waited and executed).
    pub fn wait_stats(self) -> (Result<RunReport>, JobStats) {
        let mut cell = self.slot.cell.lock().unwrap();
        loop {
            if let Some(res) = cell.take() {
                return res;
            }
            cell = self.slot.done.wait(cell).unwrap();
        }
    }
}

struct Queued {
    job: Job,
    slot: Arc<Slot>,
    /// Stamped at submit; the worker derives queue wait from it.
    submitted: Instant,
}

/// Service-lifetime latency aggregates (nanosecond histograms).
#[derive(Debug, Default)]
struct LatencyAgg {
    wait: Log2Histogram,
    exec: Log2Histogram,
}

/// Snapshot of the service's job-latency distributions
/// ([`Service::latency`]); percentiles are in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceLatency {
    /// Jobs completed (success or failure) since the service started.
    pub jobs: u64,
    /// Queue-wait percentiles (submit → dequeue), seconds.
    pub wait: LatencySummary,
    /// Execute percentiles (dequeue → finish), seconds.
    pub exec: LatencySummary,
}

/// Construction-time observability knobs ([`Service::with_options`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceOptions {
    /// Serving SLO thresholds; the default enforces none.
    pub slo: SloConfig,
    /// Seconds a **busy** lane may go without a heartbeat mark before
    /// [`Service::health`] reports it stalled. Idle lanes never stall.
    pub stall_window_secs: f64,
    /// Act on the gap SLO instead of only counting it: a job whose
    /// certified optimality gap exceeds `slo.max_gap` comes back as an
    /// error (the report is withheld) and is counted under
    /// `jobs_rejected` / `dpp_jobs_total{state="rejected"}`. Latency
    /// SLOs stay observe-only — by the time they trip, the caller has
    /// already paid the wall clock, so withholding the result would
    /// only add insult.
    pub enforce_slo: bool,
}

impl Default for ServiceOptions {
    fn default() -> ServiceOptions {
        ServiceOptions {
            slo: SloConfig::default(),
            stall_window_secs: 30.0,
            enforce_slo: false,
        }
    }
}

/// Live per-lane view inside [`ServiceHealth`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaneHealth {
    pub lane: usize,
    /// Currently executing a job.
    pub busy: bool,
    /// Jobs this lane has finished (success, error, or panic).
    pub jobs_done: u64,
    /// Seconds since the lane last reported progress (job start/end or
    /// an engine iteration hook).
    pub idle_secs: f64,
    /// Busy and silent past the stall window — the watchdog verdict.
    pub stalled: bool,
}

/// Lock-light service snapshot ([`Service::health`]): queue depth and
/// in-flight from one brief state lock, everything else from relaxed
/// atomics. Safe to poll from a monitoring thread at any frequency.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceHealth {
    /// Jobs admitted but not yet picked up by a worker.
    pub queue_depth: usize,
    /// Jobs admitted and not yet completed (queued + running).
    pub inflight: usize,
    pub inflight_cap: usize,
    /// Jobs ever admitted past backpressure.
    pub jobs_admitted: u64,
    /// Jobs that finished and published a result (success or error,
    /// panics included — a panicked job still completes its ticket).
    pub jobs_completed: u64,
    /// Subset of completed jobs that panicked inside the run.
    pub jobs_panicked: u64,
    /// Subset of completed jobs whose report was withheld because the
    /// certified gap tripped an **enforced** SLO
    /// ([`ServiceOptions::enforce_slo`]).
    pub jobs_rejected: u64,
    /// Per-SLO violation totals (jobs may violate several at once).
    pub slo_gap_violations: u64,
    pub slo_queue_wait_violations: u64,
    pub slo_job_latency_violations: u64,
    pub lanes: Vec<LaneHealth>,
}

impl ServiceHealth {
    /// Sum of all SLO violation counters.
    pub fn slo_violations(&self) -> u64 {
        self.slo_gap_violations
            + self.slo_queue_wait_violations
            + self.slo_job_latency_violations
    }

    /// Indices of lanes the watchdog considers stalled.
    pub fn stalled_lanes(&self) -> Vec<usize> {
        self.lanes
            .iter()
            .filter(|l| l.stalled)
            .map(|l| l.lane)
            .collect()
    }
}

struct ServiceState {
    queue: VecDeque<Queued>,
    /// Jobs submitted and not yet completed (queued + running).
    inflight: usize,
    open: bool,
}

/// Per-worker-lane observability state (all relaxed atomics — read by
/// `health()` without stopping the lane).
struct LaneState {
    busy: AtomicBool,
    jobs_done: AtomicU64,
    heartbeat: Arc<Heartbeat>,
}

impl LaneState {
    fn new() -> LaneState {
        LaneState {
            busy: AtomicBool::new(false),
            jobs_done: AtomicU64::new(0),
            heartbeat: Arc::new(Heartbeat::new()),
        }
    }
}

/// Service-lifetime job/SLO counters (relaxed — monotone totals, no
/// cross-counter consistency promised).
#[derive(Default)]
struct Counters {
    admitted: AtomicU64,
    completed: AtomicU64,
    panicked: AtomicU64,
    rejected: AtomicU64,
    slo_gap: AtomicU64,
    slo_queue_wait: AtomicU64,
    slo_job_latency: AtomicU64,
}

struct Shared {
    state: Mutex<ServiceState>,
    /// Workers wait here for jobs.
    jobs: Condvar,
    /// Submitters wait here for in-flight capacity.
    space: Condvar,
    inflight_cap: usize,
    /// Always-on per-job latency aggregates (locked once per job
    /// completion — uncontended next to a seconds-long run).
    latency: Mutex<LatencyAgg>,
    opts: ServiceOptions,
    counters: Counters,
    /// One entry per worker, indexed by worker id.
    lanes: Vec<LaneState>,
}

/// Multi-job segmentation service (see module docs).
pub struct Service {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Service with `workers` job threads admitting at most
    /// `inflight_cap` concurrent jobs (both clamped to >= 1;
    /// `inflight_cap` below `workers` leaves workers idle). No SLOs
    /// enforced; see [`Service::with_options`].
    pub fn new(workers: usize, inflight_cap: usize) -> Service {
        Service::with_options(workers, inflight_cap, ServiceOptions::default())
    }

    /// [`Service::new`] plus SLO thresholds and the watchdog stall
    /// window ([`ServiceOptions`]).
    pub fn with_options(
        workers: usize,
        inflight_cap: usize,
        opts: ServiceOptions,
    ) -> Service {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(ServiceState {
                queue: VecDeque::new(),
                inflight: 0,
                open: true,
            }),
            jobs: Condvar::new(),
            space: Condvar::new(),
            inflight_cap: inflight_cap.max(1),
            latency: Mutex::new(LatencyAgg::default()),
            opts,
            counters: Counters::default(),
            lanes: (0..workers).map(|_| LaneState::new()).collect(),
        });
        let workers = (0..workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sched-serve-{w}"))
                    .spawn(move || worker_loop(&sh, w))
                    .expect("spawn service worker")
            })
            .collect();
        Service { shared, workers }
    }

    pub fn inflight_cap(&self) -> usize {
        self.shared.inflight_cap
    }

    /// Live health snapshot (see [`ServiceHealth`]). One brief state
    /// lock for queue depth / in-flight; counters and lane state are
    /// relaxed atomic reads. A lane is `stalled` when it is busy and
    /// its heartbeat has been silent longer than
    /// [`ServiceOptions::stall_window_secs`] — the watchdog reports
    /// the hang here instead of letting callers block blind.
    pub fn health(&self) -> ServiceHealth {
        let (queue_depth, inflight) = {
            let st = self.shared.state.lock().unwrap();
            (st.queue.len(), st.inflight)
        };
        let c = &self.shared.counters;
        let stall = self.shared.opts.stall_window_secs;
        let lanes = self
            .shared
            .lanes
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let busy = l.busy.load(Ordering::Relaxed);
                let idle_secs = l.heartbeat.secs_since();
                LaneHealth {
                    lane: i,
                    busy,
                    jobs_done: l.jobs_done.load(Ordering::Relaxed),
                    idle_secs,
                    stalled: busy && idle_secs > stall,
                }
            })
            .collect();
        ServiceHealth {
            queue_depth,
            inflight,
            inflight_cap: self.shared.inflight_cap,
            jobs_admitted: c.admitted.load(Ordering::Relaxed),
            jobs_completed: c.completed.load(Ordering::Relaxed),
            jobs_panicked: c.panicked.load(Ordering::Relaxed),
            jobs_rejected: c.rejected.load(Ordering::Relaxed),
            slo_gap_violations: c.slo_gap.load(Ordering::Relaxed),
            slo_queue_wait_violations: c.slo_queue_wait.load(Ordering::Relaxed),
            slo_job_latency_violations: c
                .slo_job_latency
                .load(Ordering::Relaxed),
            lanes,
        }
    }

    /// Prometheus text-format (exposition 0.0.4) rendering of the
    /// service's health counters, latency histograms, and the global
    /// [`crate::dpp::timing`] registry. One page, scrape-ready; see
    /// DESIGN.md §13 for the log2-bucket translation.
    pub fn metrics_text(&self) -> String {
        use crate::obs::prometheus::{
            render_snapshot, timing_snapshot, TextWriter,
        };
        let h = self.health();
        let mut w = TextWriter::new();
        w.family("dpp_jobs_total", "counter",
                 "Service jobs by lifecycle state.");
        w.sample("dpp_jobs_total", &[("state", "admitted")],
                 h.jobs_admitted as f64);
        w.sample("dpp_jobs_total", &[("state", "completed")],
                 h.jobs_completed as f64);
        w.sample("dpp_jobs_total", &[("state", "panicked")],
                 h.jobs_panicked as f64);
        w.sample("dpp_jobs_total", &[("state", "rejected")],
                 h.jobs_rejected as f64);
        w.family("dpp_slo_violations_total", "counter",
                 "Jobs that violated a serving SLO, by threshold.");
        w.sample("dpp_slo_violations_total", &[("slo", "gap")],
                 h.slo_gap_violations as f64);
        w.sample("dpp_slo_violations_total", &[("slo", "queue_wait")],
                 h.slo_queue_wait_violations as f64);
        w.sample("dpp_slo_violations_total", &[("slo", "job_latency")],
                 h.slo_job_latency_violations as f64);
        w.family("dpp_queue_depth", "gauge",
                 "Jobs admitted but not yet picked up.");
        w.sample("dpp_queue_depth", &[], h.queue_depth as f64);
        w.family("dpp_inflight", "gauge",
                 "Jobs admitted and not yet completed.");
        w.sample("dpp_inflight", &[], h.inflight as f64);
        w.family("dpp_lane_busy", "gauge",
                 "1 while the lane is executing a job.");
        for l in &h.lanes {
            let lane = l.lane.to_string();
            w.sample("dpp_lane_busy", &[("lane", &lane)],
                     if l.busy { 1.0 } else { 0.0 });
        }
        w.family("dpp_lane_jobs_total", "counter",
                 "Jobs finished per lane.");
        for l in &h.lanes {
            let lane = l.lane.to_string();
            w.sample("dpp_lane_jobs_total", &[("lane", &lane)],
                     l.jobs_done as f64);
        }
        {
            let agg = self.shared.latency.lock().unwrap();
            w.family("dpp_job_queue_wait_seconds", "histogram",
                     "Submit -> dequeue wait per job.");
            w.log2_hist("dpp_job_queue_wait_seconds", &[], &agg.wait, 1e-9);
            w.family("dpp_job_exec_seconds", "histogram",
                     "Dequeue -> finish execution per job.");
            w.log2_hist("dpp_job_exec_seconds", &[], &agg.exec, 1e-9);
        }
        render_snapshot(&mut w, &timing_snapshot());
        w.finish()
    }

    /// p50/p90/p99 of queue wait and execute time over every job this
    /// service has completed, in seconds. Available with telemetry
    /// off — the underlying timestamps are always recorded.
    pub fn latency(&self) -> ServiceLatency {
        let agg = self.shared.latency.lock().unwrap();
        ServiceLatency {
            jobs: agg.exec.total(),
            wait: agg.wait.summary().scaled(1e9),
            exec: agg.exec.summary().scaled(1e9),
        }
    }

    /// Submit one job, blocking while `inflight_cap` jobs are already
    /// in flight (admission backpressure).
    pub fn submit(&self, job: Job) -> Ticket {
        let slot = Arc::new(Slot {
            cell: Mutex::new(None),
            done: Condvar::new(),
        });
        let mut st = self.shared.state.lock().unwrap();
        while st.inflight >= self.shared.inflight_cap {
            st = self.shared.space.wait(st).unwrap();
        }
        st.inflight += 1;
        self.shared.counters.admitted.fetch_add(1, Ordering::Relaxed);
        st.queue.push_back(Queued {
            job,
            slot: Arc::clone(&slot),
            // Stamped after backpressure admission: queue wait
            // measures time in OUR queue, not time blocked at the cap
            // (the submitter observes that directly).
            submitted: Instant::now(),
        });
        drop(st);
        self.shared.jobs.notify_one();
        Ticket { slot }
    }

    /// Submit every job and wait for all of them; reports come back in
    /// **submission order** regardless of completion order.
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<Result<RunReport>> {
        // Submission interleaves with completion once the in-flight
        // cap is hit; tickets keep the order either way.
        let tickets: Vec<Ticket> =
            jobs.into_iter().map(|j| self.submit(j)).collect();
        tickets.into_iter().map(Ticket::wait).collect()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.jobs.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, w: usize) {
    loop {
        let queued = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(q) = st.queue.pop_front() {
                    break q;
                }
                if !st.open {
                    return;
                }
                st = shared.jobs.wait(st).unwrap();
            }
        };
        // The two always-on clock reads of the per-job timing bugfix:
        // `started` closes the queue-wait interval, `elapsed` below
        // closes the execute interval. Exempt from the zero-alloc
        // contract (module docs).
        let started = Instant::now();
        let wait = started.duration_since(queued.submitted);
        let lane = &shared.lanes[w];
        lane.busy.store(true, Ordering::Relaxed);
        lane.heartbeat.mark();
        let t = Timer::start();
        // Contain panics to the job: an unwinding run would otherwise
        // leave the ticket's condvar waiting forever and leak one unit
        // of in-flight capacity — per-job failures must never be fatal
        // to the service.
        let mut panicked = false;
        let res = {
            let _span = crate::telemetry::span("job", "Service::job");
            crate::telemetry::name_thread(format_args!("serve-{w}"));
            // Bound only for the job's duration: engine iteration
            // hooks mark it, and the scheduler re-installs it inside
            // the lane threads it spawns (watchdog progress signal).
            let _hb = obs::install_heartbeat(Arc::clone(&lane.heartbeat));
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || run_job(&queued.job),
            ))
            .unwrap_or_else(|p| {
                panicked = true;
                Err(anyhow::anyhow!(
                    "job panicked: {}", panic_message(p.as_ref())
                ))
            })
        };
        let exec = t.elapsed();
        if timing::recording() {
            timing::record("Service::job", exec.as_nanos() as u64);
        }
        let slo = slo_flags(
            &shared.opts.slo,
            &res,
            wait.as_secs_f64(),
            exec.as_secs_f64(),
        );
        // SLO follow-through (DESIGN.md §13): an enforcing service
        // withholds reports whose certificate tripped max_gap. Only the
        // gap SLO rejects — it judges answer quality, not elapsed time.
        let rejected = shared.opts.enforce_slo && slo.gap;
        let res = if rejected {
            let gap = res
                .as_ref()
                .ok()
                .and_then(RunReport::optimality_gap)
                .unwrap_or(f64::NAN);
            let max = shared.opts.slo.max_gap.unwrap_or(f64::NAN);
            Err(anyhow::anyhow!(
                "job rejected: certified optimality gap {gap:.6e} \
                 exceeds the enforced SLO max_gap {max:.6e}; relax the \
                 threshold, raise the engine's iteration budget, or \
                 disable ServiceOptions::enforce_slo to receive \
                 best-effort reports"
            ))
        } else {
            res
        };
        let c = &shared.counters;
        if rejected {
            c.rejected.fetch_add(1, Ordering::Relaxed);
        }
        if slo.gap {
            c.slo_gap.fetch_add(1, Ordering::Relaxed);
        }
        if slo.queue_wait {
            c.slo_queue_wait.fetch_add(1, Ordering::Relaxed);
        }
        if slo.job_latency {
            c.slo_job_latency.fetch_add(1, Ordering::Relaxed);
        }
        if panicked {
            c.panicked.fetch_add(1, Ordering::Relaxed);
        }
        c.completed.fetch_add(1, Ordering::Relaxed);
        lane.jobs_done.fetch_add(1, Ordering::Relaxed);
        lane.heartbeat.mark();
        lane.busy.store(false, Ordering::Relaxed);
        let stats = JobStats {
            queue_wait_secs: wait.as_secs_f64(),
            exec_secs: exec.as_secs_f64(),
            slo,
            rejected,
        };
        {
            let mut agg = shared.latency.lock().unwrap();
            agg.wait.record(wait.as_nanos() as u64);
            agg.exec.record(exec.as_nanos() as u64);
        }
        *queued.slot.cell.lock().unwrap() = Some((res, stats));
        queued.slot.done.notify_all();
        {
            let mut st = shared.state.lock().unwrap();
            st.inflight -= 1;
        }
        shared.space.notify_one();
    }
}

/// Evaluate the configured SLO thresholds against one finished job.
/// The gap SLO only applies to successful reports from certifying
/// engines — a job without a certificate cannot violate it.
fn slo_flags(
    slo: &SloConfig,
    res: &Result<RunReport>,
    wait_secs: f64,
    exec_secs: f64,
) -> SloFlags {
    if slo.is_disabled() {
        return SloFlags::default();
    }
    let gap = match (slo.max_gap, res) {
        (Some(max), Ok(report)) => {
            report.optimality_gap().is_some_and(|g| g > max)
        }
        _ => false,
    };
    SloFlags {
        gap,
        queue_wait: slo.max_queue_wait.is_some_and(|m| wait_secs > m),
        job_latency: slo
            .max_job_latency
            .is_some_and(|m| wait_secs + exec_secs > m),
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>().copied().unwrap_or_else(|| {
        p.downcast_ref::<String>().map(String::as_str)
            .unwrap_or("<non-string payload>")
    })
}

fn run_job(job: &Job) -> Result<RunReport> {
    let coord = Coordinator::new(job.cfg.clone())?;
    coord.run(&job.dataset)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, EngineKind};
    use crate::image;

    fn job(seed: u64, lanes: usize) -> Job {
        let mut cfg = RunConfig {
            dataset: DatasetConfig {
                width: 48,
                height: 48,
                slices: 2,
                seed,
                ..Default::default()
            },
            engine: EngineKind::Dpp,
            threads: 1,
            ..Default::default()
        };
        cfg.sched.lanes = lanes;
        let dataset = image::generate(&cfg.dataset);
        Job { dataset, cfg }
    }

    #[test]
    fn batch_returns_reports_in_submission_order() {
        let service = Service::new(2, 2);
        let jobs = vec![job(11, 1), job(22, 2), job(33, 1)];
        let seeds = [11u64, 22, 33];
        let reports = service.run_batch(jobs);
        assert_eq!(reports.len(), 3);
        for (i, r) in reports.iter().enumerate() {
            let r = r.as_ref().expect("job succeeded");
            assert_eq!(r.slices.len(), 2, "job {i} (seed {})", seeds[i]);
            assert!(r.total_secs > 0.0);
        }
        // Same seed => same output, independent of which worker ran it.
        let again = service.run_batch(vec![job(11, 1)]);
        assert_eq!(
            again[0].as_ref().unwrap().output.data,
            reports[0].as_ref().unwrap().output.data
        );
    }

    #[test]
    fn backpressure_caps_inflight_jobs() {
        // cap 1 on a 2-worker service: submissions serialize, results
        // still come back and in order.
        let service = Service::new(2, 1);
        let reports =
            service.run_batch(vec![job(1, 1), job(2, 1), job(3, 1)]);
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn service_records_job_latency_without_profiling() {
        // The bugfix under test: per-job timing exists even with every
        // telemetry sink off (no profiling, no tracer, no recorder).
        let service = Service::new(1, 2);
        let tickets: Vec<Ticket> =
            (0..3).map(|i| service.submit(job(40 + i, 1))).collect();
        for t in tickets {
            let (res, stats) = t.wait_stats();
            assert!(res.is_ok());
            assert!(stats.exec_secs > 0.0, "job executed for nonzero time");
            assert!(stats.queue_wait_secs >= 0.0);
        }
        let lat = service.latency();
        assert_eq!(lat.jobs, 3);
        assert!(lat.exec.p50 > 0.0, "exec p50 {:?}", lat.exec);
        assert!(lat.exec.p50 <= lat.exec.p99);
        assert!(lat.wait.p50 >= 0.0);
    }

    #[test]
    fn errors_are_per_job_not_fatal() {
        let service = Service::new(1, 2);
        let mut bad = job(5, 1);
        bad.cfg.engine = EngineKind::Xla; // no artifacts loaded => error
        let reports = service.run_batch(vec![bad, job(6, 1)]);
        assert!(reports[0].is_err());
        assert!(reports[1].is_ok());
    }

    #[test]
    fn health_counts_jobs_and_lanes() {
        let service = Service::new(2, 2);
        let fresh = service.health();
        assert_eq!(fresh.jobs_admitted, 0);
        assert_eq!(fresh.lanes.len(), 2);
        assert!(fresh.lanes.iter().all(|l| !l.busy && !l.stalled));
        let reports = service.run_batch(vec![job(7, 1), job(8, 1)]);
        assert!(reports.iter().all(|r| r.is_ok()));
        let h = service.health();
        assert_eq!(h.jobs_admitted, 2);
        assert_eq!(h.jobs_completed, 2);
        assert_eq!(h.jobs_panicked, 0);
        assert_eq!(h.inflight, 0);
        assert_eq!(h.queue_depth, 0);
        assert_eq!(h.inflight_cap, 2);
        assert_eq!(h.slo_violations(), 0, "no SLOs configured");
        assert_eq!(
            h.lanes.iter().map(|l| l.jobs_done).sum::<u64>(),
            2,
            "every finished job lands on some lane"
        );
        assert!(h.stalled_lanes().is_empty());
    }

    #[test]
    fn impossible_latency_slo_marks_jobs_and_counts_violations() {
        // max_job_latency = 0 is unsatisfiable (every run takes > 0 s),
        // so each job must come back flagged and counted.
        let opts = ServiceOptions {
            slo: SloConfig {
                max_job_latency: Some(0.0),
                ..Default::default()
            },
            ..Default::default()
        };
        let service = Service::with_options(1, 2, opts);
        let (res, stats) = service.submit(job(9, 1)).wait_stats();
        assert!(res.is_ok());
        assert!(stats.slo.job_latency, "0-second latency SLO must trip");
        assert!(!stats.slo.gap, "no gap threshold configured");
        let h = service.health();
        assert_eq!(h.slo_job_latency_violations, 1);
        assert_eq!(h.slo_gap_violations, 0);
        assert_eq!(h.slo_violations(), 1);
    }

    #[test]
    fn enforced_gap_slo_rejects_certified_jobs() {
        // max_gap = -1 is unsatisfiable for the dual engine: its
        // certified gap is always >= 0, so enforcement must withhold
        // the report deterministically (no timing dependence).
        let opts = ServiceOptions {
            slo: SloConfig { max_gap: Some(-1.0), ..Default::default() },
            enforce_slo: true,
            ..Default::default()
        };
        let service = Service::with_options(1, 2, opts);
        let mut j = job(12, 1);
        j.cfg.engine = EngineKind::Dual;
        let (res, stats) = service.submit(j).wait_stats();
        let msg = res.expect_err("enforced gap SLO must reject").to_string();
        assert!(msg.contains("rejected"), "{msg}");
        assert!(msg.contains("max_gap"), "{msg}");
        assert!(stats.rejected);
        assert!(stats.slo.gap);
        let h = service.health();
        assert_eq!(h.jobs_rejected, 1);
        assert_eq!(h.jobs_completed, 1);
        assert_eq!(h.slo_gap_violations, 1);
        let text = service.metrics_text();
        assert!(
            text.contains("dpp_jobs_total{state=\"rejected\"} 1\n"),
            "{text}"
        );
        // Same thresholds without enforcement: the violation is
        // counted but the report comes back — observe-only default.
        let observe = ServiceOptions {
            slo: SloConfig { max_gap: Some(-1.0), ..Default::default() },
            enforce_slo: false,
            ..Default::default()
        };
        let service = Service::with_options(1, 2, observe);
        let mut j = job(12, 1);
        j.cfg.engine = EngineKind::Dual;
        let (res, stats) = service.submit(j).wait_stats();
        assert!(res.is_ok(), "observe-only SLO must not withhold");
        assert!(stats.slo.gap && !stats.rejected);
        assert_eq!(service.health().jobs_rejected, 0);
    }

    #[test]
    fn metrics_text_exposes_service_families() {
        let service = Service::new(1, 1);
        let reports = service.run_batch(vec![job(10, 1)]);
        assert!(reports[0].is_ok());
        let text = service.metrics_text();
        assert!(text.contains("# TYPE dpp_jobs_total counter"), "{text}");
        assert!(
            text.contains("dpp_jobs_total{state=\"completed\"} 1\n"),
            "{text}"
        );
        assert!(text.contains("dpp_queue_depth 0\n"));
        assert!(text.contains("dpp_lane_busy{lane=\"0\"} 0\n"));
        assert!(text.contains("dpp_job_exec_seconds_count 1\n"));
        assert!(
            text.contains("dpp_job_exec_seconds_bucket{le=\"+Inf\"} 1\n")
        );
    }
}
