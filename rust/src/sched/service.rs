//! Batch serving front end over the slice scheduler.
//!
//! A [`Service`] owns a small crew of job workers. Callers
//! [`Service::submit`] jobs — a dataset plus the [`RunConfig`] to run
//! it under — and block only when the configured number of jobs is
//! already in flight (admission backpressure, same contract as the
//! slice queue one layer down). Each job runs the full coordinator
//! pipeline (which itself shards slices across `cfg.sched.lanes`
//! lanes), so a deployment has two independent concurrency knobs:
//! jobs in flight × lanes per job.
//!
//! Results come back through [`Ticket`]s; [`Service::run_batch`]
//! returns reports in **submission order** regardless of completion
//! order — the determinism contract callers script against. Per-job
//! wall clock is recorded under `Service::job` in
//! [`crate::dpp::timing`] when a metric sink is listening.
//!
//! Independent of profiling, the service **always** measures each
//! job's queue wait (submit → dequeue) and execute time (dequeue →
//! finish): two `Instant::now` calls per job, explicitly exempt from
//! the zero-alloc contract (DESIGN.md §11 — serving jobs are seconds
//! long; two clock reads are noise). Per-job numbers ride back on the
//! ticket ([`Ticket::wait_stats`]); service-lifetime aggregates live
//! in log2 histograms, summarized by [`Service::latency`].

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{Coordinator, RunReport};
use crate::dpp::timing;
use crate::image::Dataset;
use crate::telemetry::{LatencySummary, Log2Histogram};
use crate::util::Timer;

/// One unit of serving work: segment `dataset` under `cfg`.
pub struct Job {
    pub dataset: Dataset,
    pub cfg: RunConfig,
}

/// Per-job serving latency, measured for **every** job — profiling
/// on or off (see the module docs for the zero-alloc exemption).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobStats {
    /// Submit → dequeue: time spent waiting for a worker.
    pub queue_wait_secs: f64,
    /// Dequeue → finish: time inside the coordinator run.
    pub exec_secs: f64,
}

/// Completion slot one job's result is published through.
struct Slot {
    cell: Mutex<Option<(Result<RunReport>, JobStats)>>,
    done: Condvar,
}

/// Handle to one submitted job; [`Ticket::wait`] blocks until the
/// job's report (or error) is available.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> Result<RunReport> {
        self.wait_stats().0
    }

    /// [`Ticket::wait`] plus the job's serving latency (recorded even
    /// for failed jobs — a panicked run still waited and executed).
    pub fn wait_stats(self) -> (Result<RunReport>, JobStats) {
        let mut cell = self.slot.cell.lock().unwrap();
        loop {
            if let Some(res) = cell.take() {
                return res;
            }
            cell = self.slot.done.wait(cell).unwrap();
        }
    }
}

struct Queued {
    job: Job,
    slot: Arc<Slot>,
    /// Stamped at submit; the worker derives queue wait from it.
    submitted: Instant,
}

/// Service-lifetime latency aggregates (nanosecond histograms).
#[derive(Debug, Default)]
struct LatencyAgg {
    wait: Log2Histogram,
    exec: Log2Histogram,
}

/// Snapshot of the service's job-latency distributions
/// ([`Service::latency`]); percentiles are in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceLatency {
    /// Jobs completed (success or failure) since the service started.
    pub jobs: u64,
    /// Queue-wait percentiles (submit → dequeue), seconds.
    pub wait: LatencySummary,
    /// Execute percentiles (dequeue → finish), seconds.
    pub exec: LatencySummary,
}

struct ServiceState {
    queue: VecDeque<Queued>,
    /// Jobs submitted and not yet completed (queued + running).
    inflight: usize,
    open: bool,
}

struct Shared {
    state: Mutex<ServiceState>,
    /// Workers wait here for jobs.
    jobs: Condvar,
    /// Submitters wait here for in-flight capacity.
    space: Condvar,
    inflight_cap: usize,
    /// Always-on per-job latency aggregates (locked once per job
    /// completion — uncontended next to a seconds-long run).
    latency: Mutex<LatencyAgg>,
}

/// Multi-job segmentation service (see module docs).
pub struct Service {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Service with `workers` job threads admitting at most
    /// `inflight_cap` concurrent jobs (both clamped to >= 1;
    /// `inflight_cap` below `workers` leaves workers idle).
    pub fn new(workers: usize, inflight_cap: usize) -> Service {
        let shared = Arc::new(Shared {
            state: Mutex::new(ServiceState {
                queue: VecDeque::new(),
                inflight: 0,
                open: true,
            }),
            jobs: Condvar::new(),
            space: Condvar::new(),
            inflight_cap: inflight_cap.max(1),
            latency: Mutex::new(LatencyAgg::default()),
        });
        let workers = (0..workers.max(1))
            .map(|w| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sched-serve-{w}"))
                    .spawn(move || worker_loop(&sh, w))
                    .expect("spawn service worker")
            })
            .collect();
        Service { shared, workers }
    }

    pub fn inflight_cap(&self) -> usize {
        self.shared.inflight_cap
    }

    /// p50/p90/p99 of queue wait and execute time over every job this
    /// service has completed, in seconds. Available with telemetry
    /// off — the underlying timestamps are always recorded.
    pub fn latency(&self) -> ServiceLatency {
        let agg = self.shared.latency.lock().unwrap();
        ServiceLatency {
            jobs: agg.exec.total(),
            wait: agg.wait.summary().scaled(1e9),
            exec: agg.exec.summary().scaled(1e9),
        }
    }

    /// Submit one job, blocking while `inflight_cap` jobs are already
    /// in flight (admission backpressure).
    pub fn submit(&self, job: Job) -> Ticket {
        let slot = Arc::new(Slot {
            cell: Mutex::new(None),
            done: Condvar::new(),
        });
        let mut st = self.shared.state.lock().unwrap();
        while st.inflight >= self.shared.inflight_cap {
            st = self.shared.space.wait(st).unwrap();
        }
        st.inflight += 1;
        st.queue.push_back(Queued {
            job,
            slot: Arc::clone(&slot),
            // Stamped after backpressure admission: queue wait
            // measures time in OUR queue, not time blocked at the cap
            // (the submitter observes that directly).
            submitted: Instant::now(),
        });
        drop(st);
        self.shared.jobs.notify_one();
        Ticket { slot }
    }

    /// Submit every job and wait for all of them; reports come back in
    /// **submission order** regardless of completion order.
    pub fn run_batch(&self, jobs: Vec<Job>) -> Vec<Result<RunReport>> {
        // Submission interleaves with completion once the in-flight
        // cap is hit; tickets keep the order either way.
        let tickets: Vec<Ticket> =
            jobs.into_iter().map(|j| self.submit(j)).collect();
        tickets.into_iter().map(Ticket::wait).collect()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.jobs.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, w: usize) {
    loop {
        let queued = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(q) = st.queue.pop_front() {
                    break q;
                }
                if !st.open {
                    return;
                }
                st = shared.jobs.wait(st).unwrap();
            }
        };
        // The two always-on clock reads of the per-job timing bugfix:
        // `started` closes the queue-wait interval, `elapsed` below
        // closes the execute interval. Exempt from the zero-alloc
        // contract (module docs).
        let started = Instant::now();
        let wait = started.duration_since(queued.submitted);
        let t = Timer::start();
        // Contain panics to the job: an unwinding run would otherwise
        // leave the ticket's condvar waiting forever and leak one unit
        // of in-flight capacity — per-job failures must never be fatal
        // to the service.
        let res = {
            let _span = crate::telemetry::span("job", "Service::job");
            crate::telemetry::name_thread(format_args!("serve-{w}"));
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || run_job(&queued.job),
            ))
            .unwrap_or_else(|p| Err(anyhow::anyhow!(
                "job panicked: {}", panic_message(p.as_ref())
            )))
        };
        let exec = t.elapsed();
        if timing::recording() {
            timing::record("Service::job", exec.as_nanos() as u64);
        }
        let stats = JobStats {
            queue_wait_secs: wait.as_secs_f64(),
            exec_secs: exec.as_secs_f64(),
        };
        {
            let mut agg = shared.latency.lock().unwrap();
            agg.wait.record(wait.as_nanos() as u64);
            agg.exec.record(exec.as_nanos() as u64);
        }
        *queued.slot.cell.lock().unwrap() = Some((res, stats));
        queued.slot.done.notify_all();
        {
            let mut st = shared.state.lock().unwrap();
            st.inflight -= 1;
        }
        shared.space.notify_one();
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>().copied().unwrap_or_else(|| {
        p.downcast_ref::<String>().map(String::as_str)
            .unwrap_or("<non-string payload>")
    })
}

fn run_job(job: &Job) -> Result<RunReport> {
    let coord = Coordinator::new(job.cfg.clone())?;
    coord.run(&job.dataset)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, EngineKind};
    use crate::image;

    fn job(seed: u64, lanes: usize) -> Job {
        let mut cfg = RunConfig {
            dataset: DatasetConfig {
                width: 48,
                height: 48,
                slices: 2,
                seed,
                ..Default::default()
            },
            engine: EngineKind::Dpp,
            threads: 1,
            ..Default::default()
        };
        cfg.sched.lanes = lanes;
        let dataset = image::generate(&cfg.dataset);
        Job { dataset, cfg }
    }

    #[test]
    fn batch_returns_reports_in_submission_order() {
        let service = Service::new(2, 2);
        let jobs = vec![job(11, 1), job(22, 2), job(33, 1)];
        let seeds = [11u64, 22, 33];
        let reports = service.run_batch(jobs);
        assert_eq!(reports.len(), 3);
        for (i, r) in reports.iter().enumerate() {
            let r = r.as_ref().expect("job succeeded");
            assert_eq!(r.slices.len(), 2, "job {i} (seed {})", seeds[i]);
            assert!(r.total_secs > 0.0);
        }
        // Same seed => same output, independent of which worker ran it.
        let again = service.run_batch(vec![job(11, 1)]);
        assert_eq!(
            again[0].as_ref().unwrap().output.data,
            reports[0].as_ref().unwrap().output.data
        );
    }

    #[test]
    fn backpressure_caps_inflight_jobs() {
        // cap 1 on a 2-worker service: submissions serialize, results
        // still come back and in order.
        let service = Service::new(2, 1);
        let reports =
            service.run_batch(vec![job(1, 1), job(2, 1), job(3, 1)]);
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn service_records_job_latency_without_profiling() {
        // The bugfix under test: per-job timing exists even with every
        // telemetry sink off (no profiling, no tracer, no recorder).
        let service = Service::new(1, 2);
        let tickets: Vec<Ticket> =
            (0..3).map(|i| service.submit(job(40 + i, 1))).collect();
        for t in tickets {
            let (res, stats) = t.wait_stats();
            assert!(res.is_ok());
            assert!(stats.exec_secs > 0.0, "job executed for nonzero time");
            assert!(stats.queue_wait_secs >= 0.0);
        }
        let lat = service.latency();
        assert_eq!(lat.jobs, 3);
        assert!(lat.exec.p50 > 0.0, "exec p50 {:?}", lat.exec);
        assert!(lat.exec.p50 <= lat.exec.p99);
        assert!(lat.wait.p50 >= 0.0);
    }

    #[test]
    fn errors_are_per_job_not_fatal() {
        let service = Service::new(1, 2);
        let mut bad = job(5, 1);
        bad.cfg.engine = EngineKind::Xla; // no artifacts loaded => error
        let reports = service.run_batch(vec![bad, job(6, 1)]);
        assert!(reports[0].is_err());
        assert!(reports[1].is_ok());
    }
}
