//! Sharded slice scheduler + batch serving front end (DESIGN.md §8).
//!
//! The paper parallelizes *within* one slice's EM optimization; this
//! layer parallelizes *across* slices, where a many-slice stack leaves
//! throughput on the table: initialization (overseg + graph + MCE +
//! hoods) and optimization never overlapped, and every slice ran
//! strictly after the previous one finished.
//!
//! Architecture (per run):
//!
//! ```text
//!   slices 0..depth ──► SliceShard (work-stealing ranges, one per lane)
//!        │ claim                                   [shard.rs]
//!   init workers ×lanes ──► BoundedQueue(inflight) ──► optimize lanes
//!   (overseg/graph/MCE/      backpressure cap           ×lanes, one
//!    hoods per slice)        [queue.rs]                 Engine each
//!        └──────────── two-stage software pipeline ─────────┘
//! ```
//!
//! * **Lanes** — `cfg.sched.lanes` pairs of init/optimize workers.
//!   Each optimize lane constructs its [`crate::mrf::Engine`] once and
//!   reuses it for every slice the lane claims; since ISSUE 5 the DPP
//!   and BP engines each hold a bucketed [`crate::dpp::Workspace`],
//!   so the lane's scratch buffers amortize across its slices, and
//!   each init worker holds its own workspace for the overseg
//!   scratch — one pool per lane, never contended across lanes
//!   (DESIGN.md §10).
//! * **In-flight cap** — `cfg.sched.inflight` bounds how many
//!   initialized-but-unoptimized slice models wait between the stages;
//!   producers block at the cap (bounded memory), and the observed
//!   high-water mark is reported in [`SchedStats::peak_inflight`].
//! * **Determinism** — every worker runs on a device with the *same*
//!   kind, thread count, and grain as the serial path
//!   ([`crate::dpp::Device::chunk_bounds`] depends on all three), and each
//!   slice is claimed exactly once, so per-slice labels, energies, and
//!   the painted output volume are bitwise identical to the serial
//!   loop for every lane count; `lanes = 1` *is* the pre-scheduler
//!   serial loop, same device, same order
//!   (`rust/tests/sched_determinism.rs`). With `threads > 1` each of
//!   the `2 × lanes` stage workers owns a pool of that size, so a run
//!   oversubscribes to roughly `2 × lanes × threads` workers —
//!   lane-parallel throughput runs want `threads = 1`.
//!
//! On top sits [`Service`]: submit N jobs (dataset + config), get
//! deterministically-ordered [`RunReport`]s back, with backpressure
//! via a bounded in-flight job cap (`service.rs`). Stage and job times
//! flow into [`crate::dpp::timing`] under `Sched::init`, `Sched::opt`,
//! and `Service::job` when any metric sink is listening;
//! `benches/throughput.rs` sweeps lanes × engines and reports
//! slices/sec.
//!
//! Telemetry (DESIGN.md §11): every slice records its queue wait and
//! execute time in its [`SliceReport`] (`p50/p90/p99` surface in
//! `RunReport::to_json`), each optimize lane contributes a busy-
//! interval timeline to [`SchedStats::lane_timeline`], and with a
//! [`crate::telemetry::Tracer`] armed the workers emit `run → slice`
//! spans on threads named `init-lane-N` / `opt-lane-N` — the per-lane
//! attribution in the exported Chrome trace. [`Service`] additionally
//! keeps always-on per-job latency histograms
//! ([`service::ServiceLatency`]).

pub mod queue;
pub mod service;
pub mod shard;

pub use queue::BoundedQueue;
pub use service::{Job, LaneHealth, Service, ServiceHealth,
                  ServiceOptions};
pub use shard::SliceShard;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{EngineKind, RunConfig};
use crate::coordinator::{RunReport, SliceReport};
use crate::dpp::{device_descriptor, device_for, device_is_pool_free,
                 timing, Device, SharedSlice, Workspace};
use crate::image::{Dataset, Volume};
use crate::eval::Confusion;
use crate::mrf::{self, Engine, EngineResources, MrfModel};
use crate::overseg::{oversegment_ws, Overseg};
use crate::pool::Pool;
use crate::util::Timer;

/// Scheduler shape and occupancy actually observed during one run —
/// carried on [`RunReport`] so throughput numbers are reproducible
/// from the report alone.
#[derive(Debug, Clone)]
pub struct SchedStats {
    /// Optimize lanes the run executed with (after clamping to the
    /// slice count).
    pub lanes: usize,
    /// Configured in-flight cap (0 on the serial path, which has no
    /// hand-off queue).
    pub inflight_cap: usize,
    /// Peak number of initialized slices that waited in the hand-off
    /// queue (always `<= inflight_cap` on the sharded path).
    pub peak_inflight: usize,
    /// Seconds each init worker spent building slice models.
    pub init_busy_secs: Vec<f64>,
    /// Seconds each optimize lane spent inside EM runs.
    pub lane_busy_secs: Vec<f64>,
    /// Per-lane busy intervals `(start, end)` in seconds since run
    /// start — the lane-occupancy timeline `RunReport::to_json`
    /// exports (one entry per optimized slice, in the order the lane
    /// executed them).
    pub lane_timeline: Vec<Vec<(f64, f64)>>,
}

impl SchedStats {
    /// Stats for the single-lane serial path (no per-interval
    /// timeline; [`run_slices`]' own serial loop records one).
    pub fn serial(init_secs: f64, opt_secs: f64) -> SchedStats {
        SchedStats {
            lanes: 1,
            inflight_cap: 0,
            peak_inflight: 0,
            init_busy_secs: vec![init_secs],
            lane_busy_secs: vec![opt_secs],
            lane_timeline: vec![Vec::new()],
        }
    }

    /// Mean fraction of the run's wall clock each optimize lane spent
    /// busy — 1.0 means the optimize stage never starved.
    pub fn occupancy(&self, total_secs: f64) -> f64 {
        if total_secs <= 0.0 || self.lane_busy_secs.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.lane_busy_secs.iter().sum();
        (busy / (self.lane_busy_secs.len() as f64 * total_secs)).min(1.0)
    }
}

/// Build the per-slice MRF model (the init stage): oversegment, region
/// graph, maximal cliques, 1-neighborhoods. Shared by the serial path,
/// the init workers, and [`crate::coordinator::Coordinator`]. The
/// workspace carries the oversegmentation's scratch — the serial path
/// holds one per run, the sharded path one per init lane, so a
/// many-slice stack pays those buffers once per lane, not per slice.
pub(crate) fn build_slice_model(
    bk: &dyn Device,
    ws: &Workspace,
    cfg: &RunConfig,
    input: &Volume,
    z: usize,
) -> (Overseg, MrfModel) {
    let seg = oversegment_ws(bk, ws, &input.slice(z), &cfg.overseg);
    let model = if cfg.engine == EngineKind::Serial {
        mrf::build_model_serial(&seg)
    } else {
        mrf::build_model(bk, &seg)
    };
    (seg, model)
}

/// Map one slice's vertex labels back to pixels, into the slice's
/// pixel window. The brighter class (higher estimated mu) renders as
/// 255 so outputs are comparable across seeds and engines regardless
/// of label symmetry. The ONE paint formula — both the serial path
/// and the sharded lanes go through here, which is what keeps the
/// serial-vs-sharded bitwise contract immune to formula drift.
pub(crate) fn paint_pixels(
    px: &mut [u8],
    seg: &Overseg,
    labels: &[u8],
    params: &mrf::Params,
) {
    let bright: u8 = u8::from(params.mu[1] > params.mu[0]);
    for (p, &region) in seg.labels.iter().enumerate() {
        let l = labels[region as usize];
        px[p] = if l == bright { 255 } else { 0 };
    }
}

/// [`paint_pixels`] addressed by slice index.
pub(crate) fn paint_slice(
    out: &mut Volume,
    z: usize,
    seg: &Overseg,
    labels: &[u8],
    params: &mrf::Params,
) {
    paint_pixels(out.slice_mut(z), seg, labels, params);
}

/// Device for one scheduler worker — the same construction rule as
/// the coordinator's own device ([`crate::dpp::device_for`]), which is
/// what makes sharded per-slice results bitwise identical to the
/// serial path ([`Device::chunk_bounds`] depends on exactly the
/// configured kind, threads, and grain).
fn worker_device(cfg: &RunConfig) -> Arc<dyn Device> {
    device_for(cfg.device, cfg.threads, cfg.grain, &cfg.artifacts_dir)
}

/// Pool for engines outside the primitive vocabulary when the device
/// carries none: only the [`EngineKind::Reference`] engine consumes
/// `EngineResources::pool`, so it alone gets a `threads`-sized pool
/// (honoring the configured budget rather than collapsing to one
/// thread); every other engine gets the free serial pool instead of
/// eagerly parked worker threads.
pub(crate) fn fallback_pool(engine: EngineKind, threads: usize)
    -> Arc<Pool> {
    if engine == EngineKind::Reference && threads > 1 {
        Pool::new(threads)
    } else {
        Pool::serial()
    }
}

/// Run the slice pipeline for `dataset` under `cfg` through the
/// scheduler, constructing engines from `res` (one per lane).
/// `cfg.sched.lanes <= 1` reproduces the pre-scheduler serial loop
/// bitwise on `res.device`; more lanes shard the stack.
pub fn run_slices(
    dataset: &Dataset,
    cfg: &RunConfig,
    res: &EngineResources,
) -> Result<RunReport> {
    // Fail fast (and on the caller's thread) if the engine cannot be
    // built — e.g. the XLA engine without loaded artifacts.
    let probe = mrf::make_engine(cfg.engine, res)?;
    if cfg.sched.lanes <= 1 || dataset.input.depth <= 1 {
        return run_serial(dataset, cfg, &res.device, probe);
    }
    let name = probe.name();
    drop(probe);
    let kind = cfg.engine;
    let runtime = res.runtime.clone();
    let bp = res.bp;
    let dual = res.dual;
    let pmp = res.pmp;
    let threads = cfg.threads;
    // Hand the coordinator's own device down so a pool-free device
    // (notably accel with loaded artifacts) is reused instead of
    // reconstructed per run.
    let device = Some(Arc::clone(&res.device));
    run_sharded_with_device(dataset, cfg, name, device, move |_lane, dev| {
        let pool =
            dev.pool().unwrap_or_else(|| fallback_pool(kind, threads));
        let lane_res = EngineResources {
            pool,
            device: Arc::clone(dev),
            runtime: runtime.clone(),
            bp,
            dual,
            pmp,
        };
        mrf::make_engine(kind, &lane_res)
            .expect("engine construction already succeeded in the probe")
    })
}

/// Sharded run with a caller-supplied engine factory (called once per
/// optimize lane, on that lane's thread, with the lane's device) —
/// the hook benches use to drive non-default engine modes (e.g.
/// `PairMode::Planned`) through the scheduler. Falls back to the
/// serial loop when `cfg.sched.lanes <= 1`.
pub fn run_sharded_with<F>(
    dataset: &Dataset,
    cfg: &RunConfig,
    engine_name: &'static str,
    factory: F,
) -> Result<RunReport>
where
    F: Fn(usize, &Arc<dyn Device>) -> Box<dyn Engine> + Sync,
{
    run_sharded_with_device(dataset, cfg, engine_name, None, factory)
}

/// [`run_sharded_with`] with an optional already-constructed device
/// to reuse (the coordinator's): pool-free devices are shared across
/// workers, so passing one here avoids reconstructing it — for the
/// accel seat that means not re-loading the AOT artifact bundle.
fn run_sharded_with_device<F>(
    dataset: &Dataset,
    cfg: &RunConfig,
    engine_name: &'static str,
    device: Option<Arc<dyn Device>>,
    factory: F,
) -> Result<RunReport>
where
    F: Fn(usize, &Arc<dyn Device>) -> Box<dyn Engine> + Sync,
{
    let depth = dataset.input.depth;
    let lanes = cfg.sched.lanes.min(depth.max(1));
    if lanes <= 1 {
        let dev = device.unwrap_or_else(|| worker_device(cfg));
        let engine = factory(0, &dev);
        return run_serial(dataset, cfg, &dev, engine);
    }
    run_sharded_inner(dataset, cfg, lanes, engine_name, device, &factory)
}

/// Initialized slice waiting for an optimize lane.
struct InitJob {
    z: usize,
    seg: Overseg,
    model: MrfModel,
    init_secs: f64,
    /// When the init worker enqueued this job — the consuming lane
    /// derives queue wait from it. Always stamped (one `Instant::now`
    /// per slice, exempt from the zero-alloc contract like the stage
    /// timers around it).
    queued_at: std::time::Instant,
}

/// Poison guard: if a stage worker unwinds, close the hand-off queue
/// so the opposite stage's workers unblock (producers stuck on a full
/// queue, consumers waiting for items) and the panic propagates
/// through the scope joins instead of deadlocking the run.
struct PoisonOnPanic<'a>(&'a BoundedQueue<InitJob>);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.close();
        }
    }
}

/// The pre-scheduler per-slice loop, bit for bit: init, optimize,
/// paint, in ascending slice order on one device.
fn run_serial(
    dataset: &Dataset,
    cfg: &RunConfig,
    dev: &Arc<dyn Device>,
    engine: Box<dyn Engine>,
) -> Result<RunReport> {
    let input = &dataset.input;
    // Root of the span hierarchy: run -> slice -> EM iter -> MAP iter
    // -> primitive/stage. Inert unless a tracer is armed.
    let _run_span = crate::telemetry::span("run", "run");
    let t_total = Timer::start();
    let mut output = Volume::new(input.width, input.height, input.depth);
    let mut reports = Vec::with_capacity(input.depth);
    let (mut init_total, mut opt_total) = (0.0f64, 0.0f64);
    let mut timeline: Vec<(f64, f64)> = Vec::new();
    // One init-stage workspace for the whole run (cross-slice reuse).
    let ws = Workspace::new();

    for z in 0..input.depth {
        let t_init = Timer::start();
        let (seg, model) = {
            let _s = crate::telemetry::span_arg(
                "slice", "init", "z", z as u64,
            );
            build_slice_model(&**dev, &ws, cfg, input, z)
        };
        let init_secs = t_init.elapsed_secs();
        init_total += init_secs;
        if timing::recording() {
            timing::record("Sched::init", t_init.elapsed().as_nanos() as u64);
        }

        let opt_from = t_total.elapsed_secs();
        let t_opt = Timer::start();
        let res = {
            let _s = crate::telemetry::span_arg(
                "slice", "opt", "z", z as u64,
            );
            engine.run(&model, &cfg.mrf)
        };
        let opt_secs = t_opt.elapsed_secs();
        opt_total += opt_secs;
        timeline.push((opt_from, t_total.elapsed_secs()));
        if timing::recording() {
            timing::record("Sched::opt", t_opt.elapsed().as_nanos() as u64);
        }

        paint_slice(&mut output, z, &seg, &res.labels, &res.params);

        reports.push(SliceReport {
            z,
            lane: 0,
            regions: seg.num_regions,
            hoods: model.hoods.num_hoods(),
            elements: model.hoods.num_elements(),
            em_iters: res.em_iters,
            map_iters: res.map_iters,
            init_secs,
            // The serial loop optimizes each slice as soon as it is
            // built: nothing ever waits in a hand-off queue.
            queue_wait_secs: 0.0,
            opt_secs,
            final_energy: res.energy,
            lower_bound: res.lower_bound,
            optimality_gap: res
                .lower_bound
                .map(|lb| (res.energy - lb).max(0.0)),
            pmp_particles: res.pmp.map(|p| p.particles),
            pmp_acceptance: res.pmp.map(|p| p.acceptance),
            pmp_max_marginal_energy: res
                .pmp
                .map(|p| p.max_marginal_energy),
            bp_schedule: res.bp.map(|b| b.schedule.spec()),
            bp_committed_frac: res.bp.map(|b| b.committed_frac),
        });
        crate::log_debug!(
            "slice {z}: {} regions, {} hoods, init {:.3}s opt {:.3}s",
            seg.num_regions,
            model.hoods.num_hoods(),
            init_secs,
            opt_secs
        );
    }

    Ok(finalize(
        engine.name(),
        dev.name().to_string(),
        dev.caps(),
        output,
        reports,
        dataset,
        t_total.elapsed_secs(),
        SchedStats {
            lane_timeline: vec![timeline],
            ..SchedStats::serial(init_total, opt_total)
        },
    ))
}

fn run_sharded_inner<F>(
    dataset: &Dataset,
    cfg: &RunConfig,
    lanes: usize,
    engine_name: &'static str,
    preloaded: Option<Arc<dyn Device>>,
    factory: &F,
) -> Result<RunReport>
where
    F: Fn(usize, &Arc<dyn Device>) -> Box<dyn Engine> + Sync,
{
    let input = &dataset.input;
    let depth = input.depth;
    let slice_len = input.slice_len();
    // Root span: closes after the lanes join, so every slice/iter/
    // primitive span nests inside it. Inert unless a tracer is armed.
    let _run_span = crate::telemetry::span("run", "run");
    let t_total = Timer::start();

    if cfg.threads > 1 {
        // The bitwise contract pins every worker's device to
        // cfg.threads (chunk bounds depend on it), so sharding cannot
        // divide the thread budget — it multiplies it.
        crate::log_info!(
            "sched: {lanes} lanes x {} threads each (~{} workers incl. \
             init stage) oversubscribes; prefer --threads 1 for \
             lane-parallel throughput runs",
            cfg.threads,
            2 * lanes * cfg.threads
        );
    }

    // Pool-free (stateless, serial-execution) devices are built ONCE
    // and shared by every worker, so an accel run loads its AOT
    // artifact bundle once per run instead of once per worker; that
    // one device also stamps the report's identity. Pool devices stay
    // per-worker (sharing one pool would serialize the lanes on its
    // submit lock), and their report identity comes from the cheap
    // descriptor — no throwaway pool is ever spawned.
    let shared_device: Option<Arc<dyn Device>> =
        if device_is_pool_free(cfg.device, cfg.threads) {
            Some(match preloaded {
                // Reuse the caller's device only if it is indeed
                // pool-free (sharing a pool would serialize lanes).
                Some(d) if d.pool().is_none() => d,
                _ => worker_device(cfg),
            })
        } else {
            None
        };
    let (device_name, device_caps) = match &shared_device {
        Some(d) => (d.name().to_string(), d.caps()),
        None => {
            let (n, c) = device_descriptor(cfg.device, cfg.threads,
                                           &cfg.artifacts_dir);
            (n.to_string(), c)
        }
    };

    // Watchdog propagation (DESIGN.md §13): a serving worker's
    // heartbeat binding is thread-local, so capture it here and
    // re-install it inside every stage thread — engine iteration
    // hooks then keep marking lane progress from inside the shards.
    // None (and zero cost) outside a service job.
    let heartbeat = crate::obs::current_heartbeat();

    let shard = SliceShard::new(depth, lanes);
    let queue: BoundedQueue<InitJob> =
        BoundedQueue::new(cfg.sched.inflight);
    let producers = AtomicUsize::new(lanes);
    let reports: Mutex<Vec<Option<SliceReport>>> =
        Mutex::new(vec![None; depth]);
    let mut output = Volume::new(input.width, input.height, depth);
    let out_win = SharedSlice::new(&mut output.data);

    let (init_busy, opt_lanes) = std::thread::scope(|s| {
        let mut init_handles = Vec::with_capacity(lanes);
        let mut opt_handles = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let (shard, queue, producers) = (&shard, &queue, &producers);
            let shared_device = &shared_device;
            let heartbeat = &heartbeat;
            init_handles.push(s.spawn(move || {
                let _poison = PoisonOnPanic(queue);
                let _hb = heartbeat
                    .clone()
                    .map(crate::obs::install_heartbeat);
                crate::telemetry::name_thread(
                    format_args!("init-lane-{lane}"),
                );
                let dev = shared_device
                    .clone()
                    .unwrap_or_else(|| worker_device(cfg));
                // One workspace per init lane: overseg scratch is
                // paid once per lane, reused for every slice the
                // lane claims, and never contended across lanes.
                let ws = Workspace::new();
                let mut busy = 0.0f64;
                while let Some(z) = shard.claim(lane) {
                    let t = Timer::start();
                    let (seg, model) = {
                        let _s = crate::telemetry::span_arg(
                            "slice", "init", "z", z as u64,
                        );
                        build_slice_model(&*dev, &ws, cfg, input, z)
                    };
                    let secs = t.elapsed_secs();
                    busy += secs;
                    if timing::recording() {
                        timing::record("Sched::init",
                                       t.elapsed().as_nanos() as u64);
                    }
                    crate::log_debug!(
                        "init lane {lane}: slice {z}, {} regions, {:.3}s",
                        seg.num_regions, secs
                    );
                    let queued = queue.push(InitJob {
                        z,
                        seg,
                        model,
                        init_secs: secs,
                        queued_at: std::time::Instant::now(),
                    });
                    if !queued {
                        break; // consumer side poisoned the queue
                    }
                }
                if producers.fetch_sub(1, Ordering::AcqRel) == 1 {
                    queue.close();
                }
                busy
            }));
        }
        for lane in 0..lanes {
            let (queue, reports, out_win) = (&queue, &reports, &out_win);
            let shared_device = &shared_device;
            let t_total = &t_total;
            let heartbeat = &heartbeat;
            opt_handles.push(s.spawn(move || {
                let _poison = PoisonOnPanic(queue);
                let _hb = heartbeat
                    .clone()
                    .map(crate::obs::install_heartbeat);
                crate::telemetry::name_thread(
                    format_args!("opt-lane-{lane}"),
                );
                let dev = shared_device
                    .clone()
                    .unwrap_or_else(|| worker_device(cfg));
                let engine = factory(lane, &dev);
                let mut busy = 0.0f64;
                let mut timeline: Vec<(f64, f64)> = Vec::new();
                // Paint scratch, reused across the lane's slices
                // (paint_pixels overwrites every pixel).
                let mut px = vec![0u8; slice_len];
                while let Some(job) = queue.pop() {
                    // Queue wait = enqueue to dequeue, the serving
                    // half of the job's latency (the other half is
                    // opt_secs below).
                    let wait_secs =
                        job.queued_at.elapsed().as_secs_f64();
                    let from = t_total.elapsed_secs();
                    let t = Timer::start();
                    let res = {
                        let _s = crate::telemetry::span_arg(
                            "slice", "opt", "z", job.z as u64,
                        );
                        engine.run(&job.model, &cfg.mrf)
                    };
                    let secs = t.elapsed_secs();
                    busy += secs;
                    timeline.push((from, t_total.elapsed_secs()));
                    if timing::recording() {
                        timing::record("Sched::opt",
                                       t.elapsed().as_nanos() as u64);
                    }
                    // Paint this slice, then publish it into the
                    // shared output volume's disjoint voxel range
                    // (SharedSlice because the volume is shared
                    // across lanes; the scratch buffer keeps the
                    // paint formula in paint_pixels, shared with the
                    // serial path).
                    paint_pixels(&mut px, &job.seg, &res.labels,
                                 &res.params);
                    let base = job.z * slice_len;
                    for (p, &v) in px.iter().enumerate() {
                        unsafe { out_win.write(base + p, v) };
                    }
                    crate::log_debug!(
                        "opt lane {lane}: slice {}, opt {:.3}s", job.z, secs
                    );
                    reports.lock().unwrap()[job.z] = Some(SliceReport {
                        z: job.z,
                        lane,
                        regions: job.seg.num_regions,
                        hoods: job.model.hoods.num_hoods(),
                        elements: job.model.hoods.num_elements(),
                        em_iters: res.em_iters,
                        map_iters: res.map_iters,
                        init_secs: job.init_secs,
                        queue_wait_secs: wait_secs,
                        opt_secs: secs,
                        final_energy: res.energy,
                        lower_bound: res.lower_bound,
                        optimality_gap: res
                            .lower_bound
                            .map(|lb| (res.energy - lb).max(0.0)),
                        pmp_particles: res.pmp.map(|p| p.particles),
                        pmp_acceptance: res.pmp.map(|p| p.acceptance),
                        pmp_max_marginal_energy: res
                            .pmp
                            .map(|p| p.max_marginal_energy),
                        bp_schedule: res
                            .bp
                            .map(|b| b.schedule.spec()),
                        bp_committed_frac: res
                            .bp
                            .map(|b| b.committed_frac),
                    });
                }
                (busy, timeline)
            }));
        }
        (
            init_handles
                .into_iter()
                .map(|h| h.join().expect("init worker panicked"))
                .collect::<Vec<f64>>(),
            opt_handles
                .into_iter()
                .map(|h| h.join().expect("optimize lane panicked"))
                .collect::<Vec<(f64, Vec<(f64, f64)>)>>(),
        )
    });
    let (lane_busy, lane_timeline): (Vec<f64>, Vec<Vec<(f64, f64)>>) =
        opt_lanes.into_iter().unzip();

    let slices: Vec<SliceReport> = reports
        .into_inner()
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(z, r)| {
            r.unwrap_or_else(|| panic!("slice {z} never optimized"))
        })
        .collect();

    Ok(finalize(
        engine_name,
        device_name,
        device_caps,
        output,
        slices,
        dataset,
        t_total.elapsed_secs(),
        SchedStats {
            lanes,
            inflight_cap: queue.cap(),
            peak_inflight: queue.peak(),
            init_busy_secs: init_busy,
            lane_busy_secs: lane_busy,
            lane_timeline,
        },
    ))
}

#[allow(clippy::too_many_arguments)]
fn finalize(
    engine: &'static str,
    device: String,
    device_caps: crate::dpp::DeviceCaps,
    output: Volume,
    slices: Vec<SliceReport>,
    dataset: &Dataset,
    total_secs: f64,
    sched: SchedStats,
) -> RunReport {
    let confusion = dataset
        .ground_truth
        .as_ref()
        .map(|t| Confusion::from_volumes(&output, t));
    let porosity = crate::eval::porosity(&output);
    RunReport {
        engine,
        device,
        device_caps,
        output,
        slices,
        confusion,
        porosity,
        total_secs,
        sched,
        // Armed flight recorder (ISSUE 8): hand this run's journal to
        // the report. Disarmed runs get None for free.
        convergence: crate::obs::drain(),
    }
}
