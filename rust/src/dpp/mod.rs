//! The data-parallel-primitive (DPP) engine.
//!
//! This is the paper's central abstraction (§2.3): a small set of
//! canonical primitives — Map, Reduce, Scan, ReduceByKey, SortByKey,
//! Gather, Scatter, Unique (+ CopyIf, which the others are built on) —
//! from which the whole MRF optimization is composed. The paper gets
//! platform portability by running the same primitives on TBB (CPU) or
//! Thrust (GPU); here the same role is played by the [`Device`] trait
//! ([`device`], DESIGN.md §9): every primitive is generic over
//! `D: Device + ?Sized`, and engines hold an `Arc<dyn Device>`:
//!
//! * [`SerialDevice`] — straight loops; the baseline and conformance
//!   oracle.
//! * [`PoolDevice`] — chunked + work-stealing execution on the
//!   in-tree [`crate::pool::Pool`] (the TBB stand-in).
//! * [`OfflineAcceleratorDevice`] — the accelerator seat, carrying
//!   the XLA/PJRT bucket runtime when AOT artifacts are present and
//!   degrading to host execution when they are not.
//!
//! The accelerator back end of the paper (Thrust) maps to the XLA/PJRT
//! path, which executes whole *fused pipelines* of primitives as one
//! AOT-compiled program (see `rust/src/mrf/xla.rs`) rather than one
//! primitive at a time.
//!
//! The pre-device [`Backend`] enum is the **deprecated** spelling of
//! the same choices, kept for one release: it implements [`Device`],
//! so `&Backend` coerces to `&dyn Device` at every primitive call
//! site (see the migration table in `README.md`).
//!
//! Two layers sit on top of the one-call-per-primitive vocabulary and
//! attack the paper's two measured scalability limiters
//! (§4.3.2–4.3.3):
//!
//! * [`SegmentPlan`] (in [`segmented`]) — amortizes **SortByKey**: the
//!   hot loops reduce over *static* keys (hood membership, vertex
//!   groupings, CSR edges), so the sort is paid once at plan build and
//!   every per-iteration `reduce_segments` runs sort-free,
//!   bitwise-identical to the unfused sort + reduce pair.
//! * [`Pipeline`] (in [`pipeline`]) — amortizes the **fork-join
//!   barrier**: a whole iteration's stages execute inside one
//!   persistent pool region ([`crate::pool::Pool::region`]) with a
//!   lightweight phase barrier between stages.
//! * [`Workspace`] (in [`workspace`]) — amortizes the **allocator**:
//!   a typed, size-bucketed scratch pool held one-per-engine/lane;
//!   the `_into`/`_ws` primitive variants draw every intermediate
//!   buffer from it, so steady-state EM/MAP iterations perform zero
//!   heap allocations (DESIGN.md §10, `benches/alloc_churn.rs`).
//!
//! Every primitive and pipeline stage is instrumented through
//! [`timing`] so benches can reproduce the paper's per-DPP breakdown
//! (SortByKey + ReduceByKey dominating at scale, §4.3.2–4.3.3);
//! `benches/ablation_fusion.rs` quantifies what the plan + pipeline
//! layer saves.
//!
//! [`timing`] is the global sink of the telemetry layer
//! ([`crate::telemetry`], DESIGN.md §11): scoped
//! [`crate::telemetry::Recorder`]s capture the same rows per
//! engine/lane without the global registry, and an armed
//! [`crate::telemetry::Tracer`] additionally emits one `prim` span per
//! timed call into the run's Chrome trace. With every sink off, a
//! timed call costs two relaxed atomic loads — no clock read, no
//! allocation.

pub mod core;
pub mod device;
pub mod pipeline;
pub mod segmented;
pub mod sort;
pub mod timing;
pub mod workspace;

pub use self::core::*;
pub use device::*;
pub use pipeline::*;
pub use segmented::*;
pub use sort::*;
pub use workspace::*;

use std::sync::Arc;

use crate::pool::{Pool, DEFAULT_GRAIN};

/// Execution back end for the primitives — the **deprecated** spelling
/// of the device layer, kept for one release. `Backend` implements
/// [`Device`], so it still works everywhere a device does; new code
/// should construct [`SerialDevice`] / [`PoolDevice`] /
/// [`OfflineAcceleratorDevice`] through [`device_for`] instead (see
/// the migration table in `README.md`).
#[derive(Clone)]
pub enum Backend {
    /// Plain loops on the calling thread.
    Serial,
    /// Chunked/work-stealing execution on a shared pool with the given
    /// grain size (elements per claimed chunk).
    Threaded { pool: Arc<Pool>, grain: usize },
}

impl Backend {
    pub fn threaded(pool: Arc<Pool>) -> Backend {
        Backend::Threaded { pool, grain: DEFAULT_GRAIN }
    }

    pub fn threaded_with_grain(pool: Arc<Pool>, grain: usize) -> Backend {
        Backend::Threaded { pool, grain }
    }

    /// THE construction rule for a run-configured backend: Serial for
    /// one thread, else a fresh pool of `threads` workers at `grain`.
    /// Every site that must produce bitwise-identical results for the
    /// same `(threads, grain)` — the coordinator and every scheduler
    /// worker ([`crate::sched`]) — goes through here, because
    /// [`Backend::chunk_bounds`] (and with it every floating-point
    /// association order) depends on exactly these two values.
    pub fn for_threads(threads: usize, grain: usize) -> Backend {
        if threads == 1 {
            Backend::Serial
        } else {
            Backend::threaded_with_grain(Pool::new(threads), grain)
        }
    }

    /// Worker count (1 for serial).
    pub fn threads(&self) -> usize {
        match self {
            Backend::Serial => 1,
            Backend::Threaded { pool, .. } => pool.threads(),
        }
    }

    pub fn grain(&self) -> usize {
        match self {
            Backend::Serial => usize::MAX,
            Backend::Threaded { grain, .. } => *grain,
        }
    }

    /// Run `f(start, end)` over `0..n` under this backend.
    #[inline]
    pub fn for_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        match self {
            Backend::Serial => {
                if n > 0 {
                    f(0, n)
                }
            }
            Backend::Threaded { pool, grain } => {
                pool.parallel_for(n, *grain, f)
            }
        }
    }

    /// Like [`Backend::for_chunks`] but with an explicit grain — used
    /// when the iteration domain is not elements (e.g. hoods or
    /// vertices, whose per-item cost is a multiple of the element
    /// cost).
    #[inline]
    pub fn for_chunks_with<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        match self {
            Backend::Serial => {
                if n > 0 {
                    f(0, n)
                }
            }
            Backend::Threaded { pool, .. } => pool.parallel_for(n, grain, f),
        }
    }

    /// Deterministic chunk boundaries used by two-pass primitives
    /// (scan, radix sort): enough chunks to load every worker, few
    /// enough that the serial combine step is negligible. Shares the
    /// ONE boundary formula with the device layer (`split_bounds` /
    /// `pool_pieces` in [`device`]), so the legacy enum and
    /// [`PoolDevice`] can never drift apart.
    pub fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)> {
        let pieces = match self {
            Backend::Serial => 1,
            Backend::Threaded { pool, grain } => {
                device::pool_pieces(pool.threads(), *grain, n)
            }
        };
        device::split_bounds(n, pieces)
    }

    /// Run `f(chunk_idx)` for each chunk id in parallel.
    pub fn for_chunk_ids<F>(&self, nchunks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        match self {
            Backend::Serial => (0..nchunks).for_each(f),
            Backend::Threaded { pool, .. } => pool.parallel_tasks(nchunks, f),
        }
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Serial => write!(f, "Serial"),
            Backend::Threaded { pool, grain } => {
                write!(f, "Threaded(threads={}, grain={})", pool.threads(),
                       grain)
            }
        }
    }
}
