//! Per-primitive timing registry.
//!
//! The paper's scaling analysis (§4.3.2–4.3.3) hinges on a per-DPP
//! runtime breakdown: SortByKey and ReduceByKey are identified as the
//! scalability limiters. This registry reproduces that instrumentation:
//! when enabled, every primitive invocation records (calls, nanos) under
//! its canonical name; `benches/per_dpp_breakdown.rs` dumps the table.
//!
//! Disabled by default — the check is a single relaxed atomic load, so
//! the hot path pays nothing measurable.
//!
//! This global registry is the **default sink**, kept for backward
//! compatibility (the CLI's `--profile` report and legacy tests).
//! Scoped sinks layer on top: while a
//! [`crate::telemetry::Recorder`] scope is installed on a thread,
//! [`record`] routes that thread's rows into it instead — per-lane
//! attribution with no global lock — and [`timed`] additionally
//! emits a `"prim"` span when a [`crate::telemetry::Tracer`] is
//! armed. New tests should install a scoped recorder rather than
//! `set_enabled` + [`test_lock`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrimStat {
    pub calls: u64,
    pub nanos: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<BTreeMap<&'static str, PrimStat>> =
    Mutex::new(BTreeMap::new());

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// True when any metric sink would consume a [`record`] call from
/// this thread: global profiling enabled **or** a scoped
/// [`crate::telemetry::Recorder`] installed here. Instrumentation
/// sites that precompute values before recording should gate on this,
/// not on [`enabled`] alone.
#[inline]
pub fn recording() -> bool {
    enabled() || crate::telemetry::metrics_scope_active()
}

pub fn reset() {
    REGISTRY.lock().unwrap().clear();
}

/// Snapshot of all recorded primitive stats.
pub fn snapshot() -> BTreeMap<&'static str, PrimStat> {
    REGISTRY.lock().unwrap().clone()
}

/// Record `nanos` against `name` unconditionally (used by the runtime
/// to report executable dispatch under the same table). If the
/// calling thread has a scoped [`crate::telemetry::Recorder`]
/// installed, the row lands there and the global registry is
/// untouched.
pub fn record(name: &'static str, nanos: u64) {
    if crate::telemetry::metrics::sink_time(name, nanos) {
        return;
    }
    let mut reg = REGISTRY.lock().unwrap();
    let st = reg.entry(name).or_default();
    st.calls += 1;
    st.nanos += nanos;
}

/// Time `f` under `name` if any sink is listening ([`recording`]),
/// and emit a `"prim"` trace span if a tracer is armed — one clock
/// read serves both. Fully off: two relaxed loads, no clock read.
#[inline]
pub fn timed<R>(name: &'static str, f: impl FnOnce() -> R) -> R {
    let rec = recording();
    let trace = crate::telemetry::tracing();
    if !rec && !trace {
        return f();
    }
    let t = Instant::now();
    let r = f();
    let nanos = t.elapsed().as_nanos() as u64;
    if rec {
        record(name, nanos);
    }
    if trace {
        crate::telemetry::emit_span("prim", name, t, nanos);
    }
    r
}

/// Serializes **legacy** tests that enable the global registry: the
/// registry is process-wide, so concurrent test threads that both
/// `set_enabled` would bleed counts into each other. New tests should
/// install a scoped [`crate::telemetry::Recorder`] instead and skip
/// this lock entirely. Not part of the public API.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Rows under this prefix are **counters, not timings**: the
/// [`crate::dpp::Workspace`] records its reuse events with byte
/// volume in the value column. [`report`] renders them separately and
/// excludes them from the time total, so the per-DPP breakdown's
/// `share` column stays a pure compute-time ratio.
pub const COUNTER_PREFIX: &str = "Workspace::";

/// Render the registry as an aligned text table sorted by total time.
/// Counter rows (see [`COUNTER_PREFIX`]) are listed beneath the
/// timed primitives with their value shown as bytes and no share.
pub fn report() -> String {
    let snap = snapshot();
    let total: u64 = snap
        .iter()
        .filter(|(name, _)| !name.starts_with(COUNTER_PREFIX))
        .map(|(_, s)| s.nanos)
        .sum();
    let mut rows: Vec<_> = snap.into_iter().collect();
    rows.sort_by_key(|(_, s)| std::cmp::Reverse(s.nanos));
    let mut out = String::from(
        "primitive            calls        total(ms)    share\n");
    let mut counters = String::new();
    for (name, s) in rows {
        if name.starts_with(COUNTER_PREFIX) {
            counters.push_str(&format!(
                "{:<20} {:>8} {:>13} B        -\n",
                name, s.calls, s.nanos,
            ));
        } else {
            out.push_str(&format!(
                "{:<20} {:>8} {:>15.3} {:>8.1}%\n",
                name,
                s.calls,
                s.nanos as f64 / 1e6,
                if total > 0 {
                    100.0 * s.nanos as f64 / total as f64
                } else {
                    0.0
                }
            ));
        }
    }
    out.push_str(&counters);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_when_enabled() {
        let _guard = test_lock();
        reset();
        set_enabled(true);
        let v = timed("test-prim", || 41 + 1);
        assert_eq!(v, 42);
        timed("test-prim", || ());
        let snap = snapshot();
        assert_eq!(snap["test-prim"].calls, 2);
        set_enabled(false);
        reset();
    }

    #[test]
    fn silent_when_disabled() {
        let _guard = test_lock();
        reset();
        set_enabled(false);
        timed("ghost", || ());
        assert!(snapshot().get("ghost").is_none());
    }

    #[test]
    fn report_formats() {
        let _guard = test_lock();
        reset();
        set_enabled(true);
        timed("alpha", || std::thread::sleep(
            std::time::Duration::from_millis(1)));
        let rep = report();
        assert!(rep.contains("alpha"));
        set_enabled(false);
        reset();
    }

    #[test]
    fn counter_rows_do_not_pollute_the_time_shares() {
        let _guard = test_lock();
        reset();
        set_enabled(true);
        timed("alpha", || std::thread::sleep(
            std::time::Duration::from_millis(2)));
        // A huge byte-volume counter row must not absorb alpha's
        // share: alpha remains 100% of the TIME total.
        record("Workspace::hit", 50_000_000_000);
        let rep = report();
        set_enabled(false);
        reset();
        assert!(rep.contains("alpha"));
        assert!(rep.contains("Workspace::hit"));
        assert!(rep.contains("100.0%"), "time share unpolluted: {rep}");
        assert!(rep.contains("50000000000 B"), "bytes rendered: {rep}");
    }
}
