//! Segmented primitives: CopyIf, Unique, ReduceByKey — and the
//! [`SegmentPlan`], the static-key segment cache that amortizes the
//! per-iteration SortByKey the paper identifies as the scalability
//! limiter (§4.3.2–4.3.3).
//!
//! Built compositionally from the core primitives, exactly as the paper
//! describes (§2.3): boundary flags via Map, placement via Scan,
//! movement via Scatter. ReduceByKey assumes key-sorted input (the
//! VTK-m/Thrust contract) and reduces each segment in parallel.
//!
//! The EM/MAP/BP hot loops reduce over the *same* keys every iteration
//! (hood membership, vertex grouping, CSR edges — all static graph
//! structure). A [`SegmentPlan`] sorts those keys **once**, caches the
//! stable permutation and the segment offsets, and then serves every
//! subsequent [`SegmentPlan::reduce_segments`] with no sort and no key
//! comparison, bitwise-identical to `sort_by_key` + `reduce_by_key` on
//! the same input.

//! Like the core primitives, the output-producing functions here have
//! allocation-free `_into` spellings drawing scratch from a
//! [`Workspace`] (`copy_if_into`, `select_indices_into`,
//! `unique_into`, `reduce_by_key_into`); [`SegmentPlan`] already has
//! [`SegmentPlan::reduce_segments_into`]. `segment_offsets` stays
//! allocating-only on purpose: it runs once per plan build, never in
//! a steady-state loop.

use super::core::{map, map_indexed, map_indexed_into, scan_exclusive,
                  scan_exclusive_into, SharedSlice};
use super::device::{Device, DeviceExt};
use super::sort::sort_by_key;
use super::timing::timed;
use super::workspace::{ScratchElem, Workspace};

/// CopyIf (stream compaction): keep `input[i]` where `keep(i)`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let xs = [5u32, 6, 7, 8];
/// let kept = dpp::copy_if_indexed(&Backend::Serial, &xs,
///                                 |i| xs[i] % 2 == 0);
/// assert_eq!(kept, vec![6, 8]);
/// ```
pub fn copy_if_indexed<D, T, F>(bk: &D, input: &[T], keep: F) -> Vec<T>
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let flags: Vec<u32> =
            map_indexed(bk, input.len(), |i| u32::from(keep(i)));
        let (pos, total) = scan_exclusive(bk, &flags, 0u32, |a, b| a + b);
        let mut out = vec![T::default(); total as usize];
        let win = SharedSlice::new(&mut out);
        bk.for_chunks(input.len(), |s, e| {
            for i in s..e {
                if flags[i] == 1 {
                    unsafe { win.write(pos[i] as usize, input[i]) };
                }
            }
        });
        out
    })
}

/// Allocation-free [`copy_if_indexed`]: flag and position scratch
/// come from `ws`, the kept elements land in `out` (cleared and
/// resized to the survivor count). Same flag/scan/compact structure
/// as the allocating form — bitwise-identical output.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let xs = [5u32, 6, 7, 8];
/// let mut kept = Vec::new();
/// dpp::copy_if_into(&Backend::Serial, &ws, &xs, |i| xs[i] % 2 == 0,
///                   &mut kept);
/// assert_eq!(kept, vec![6, 8]);
/// ```
pub fn copy_if_into<D, T, F>(
    bk: &D,
    ws: &Workspace,
    input: &[T],
    keep: F,
    out: &mut Vec<T>,
) where
    D: Device + ?Sized,
    T: ScratchElem + Sync,
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let mut flags = ws.take_spare::<u32>(input.len());
        map_indexed_into(bk, input.len(), |i| u32::from(keep(i)),
                         &mut flags);
        let mut pos = ws.take_spare::<u32>(input.len());
        let total = scan_exclusive_into(bk, ws, &flags[..], 0u32,
                                        |a, b| a + b, &mut pos);
        out.clear();
        out.resize(total as usize, T::default());
        let win = SharedSlice::new(out);
        let flags_ref = &flags;
        let pos_ref = &pos;
        bk.for_chunks(input.len(), |s, e| {
            for i in s..e {
                if flags_ref[i] == 1 {
                    unsafe { win.write(pos_ref[i] as usize, input[i]) };
                }
            }
        });
    })
}

/// Indices `i in 0..n` where `keep(i)` holds (compact of a counting
/// array) — the workhorse for segment-start detection.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let idx = dpp::select_indices(&Backend::Serial, 10, |i| i % 4 == 0);
/// assert_eq!(idx, vec![0, 4, 8]);
/// ```
pub fn select_indices<D, F>(bk: &D, n: usize, keep: F) -> Vec<u32>
where
    D: Device + ?Sized,
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let flags: Vec<u32> = map_indexed(bk, n, |i| u32::from(keep(i)));
        let (pos, total) = scan_exclusive(bk, &flags, 0u32, |a, b| a + b);
        let mut out = vec![0u32; total as usize];
        let win = SharedSlice::new(&mut out);
        bk.for_chunks(n, |s, e| {
            for i in s..e {
                if flags[i] == 1 {
                    unsafe { win.write(pos[i] as usize, i as u32) };
                }
            }
        });
        out
    })
}

/// Allocation-free [`select_indices`] (see [`copy_if_into`] for the
/// scratch/`out` contract).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut idx = Vec::new();
/// dpp::select_indices_into(&Backend::Serial, &ws, 10,
///                          |i| i % 4 == 0, &mut idx);
/// assert_eq!(idx, vec![0, 4, 8]);
/// ```
pub fn select_indices_into<D, F>(
    bk: &D,
    ws: &Workspace,
    n: usize,
    keep: F,
    out: &mut Vec<u32>,
) where
    D: Device + ?Sized,
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let mut flags = ws.take_spare::<u32>(n);
        map_indexed_into(bk, n, |i| u32::from(keep(i)), &mut flags);
        let mut pos = ws.take_spare::<u32>(n);
        let total = scan_exclusive_into(bk, ws, &flags[..], 0u32,
                                        |a, b| a + b, &mut pos);
        out.clear();
        out.resize(total as usize, 0);
        let win = SharedSlice::new(out);
        let flags_ref = &flags;
        let pos_ref = &pos;
        bk.for_chunks(n, |s, e| {
            for i in s..e {
                if flags_ref[i] == 1 {
                    unsafe { win.write(pos_ref[i] as usize, i as u32) };
                }
            }
        });
    })
}

/// Unique: drop adjacent duplicates (input usually sorted first).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let u = dpp::unique(&Backend::Serial, &[1u32, 1, 2, 2, 1]);
/// assert_eq!(u, vec![1, 2, 1]); // adjacent dups only
/// ```
pub fn unique<D, T>(bk: &D, input: &[T]) -> Vec<T>
where
    D: Device + ?Sized,
    T: Copy + Default + PartialEq + Send + Sync,
{
    timed("Unique", || {
        copy_if_indexed(bk, input, |i| i == 0 || input[i] != input[i - 1])
    })
}

/// Allocation-free [`unique`] (see [`copy_if_into`]).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut u = Vec::new();
/// dpp::unique_into(&Backend::Serial, &ws, &[1u32, 1, 2, 2, 1],
///                  &mut u);
/// assert_eq!(u, vec![1, 2, 1]); // adjacent dups only
/// ```
pub fn unique_into<D, T>(
    bk: &D,
    ws: &Workspace,
    input: &[T],
    out: &mut Vec<T>,
) where
    D: Device + ?Sized,
    T: ScratchElem + PartialEq + Sync,
{
    timed("Unique", || {
        copy_if_into(bk, ws, input,
                     |i| i == 0 || input[i] != input[i - 1], out)
    })
}

/// ReduceByKey over key-sorted input: one `(key, reduce(op, segment))`
/// per distinct key, in key order.
///
/// If the same keys are reduced every iteration, build a
/// [`SegmentPlan`] once instead — same result, no per-iteration
/// segment detection.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let (k, v) = dpp::reduce_by_key(
///     &Backend::Serial, &[0u32, 0, 3], &[1u64, 2, 4], 0,
///     |a, b| a + b);
/// assert_eq!(k, vec![0, 3]);
/// assert_eq!(v, vec![3, 4]);
/// ```
pub fn reduce_by_key<D, K, V, F>(
    bk: &D,
    keys: &[K],
    vals: &[V],
    identity: V,
    op: F,
) -> (Vec<K>, Vec<V>)
where
    D: Device + ?Sized,
    K: Copy + Default + PartialEq + Send + Sync,
    V: Copy + Default + Send + Sync,
    F: Fn(V, V) -> V + Sync,
{
    assert_eq!(keys.len(), vals.len(), "reduce_by_key length mismatch");
    timed("ReduceByKey", || {
        let n = keys.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        debug_assert!(is_key_sorted_grouped(keys), "keys must be grouped");
        // Segment starts.
        let starts =
            select_indices(bk, n, |i| i == 0 || keys[i] != keys[i - 1]);
        let nseg = starts.len();
        let mut out_keys = vec![K::default(); nseg];
        let mut out_vals = vec![identity; nseg];
        {
            let wk = SharedSlice::new(&mut out_keys);
            let wv = SharedSlice::new(&mut out_vals);
            let starts_ref = &starts;
            bk.for_chunks(nseg, |cs, ce| {
                for j in cs..ce {
                    let s = starts_ref[j] as usize;
                    let e = if j + 1 < nseg {
                        starts_ref[j + 1] as usize
                    } else {
                        n
                    };
                    let mut acc = identity;
                    for v in &vals[s..e] {
                        acc = op(acc, *v);
                    }
                    unsafe {
                        wk.write(j, keys[s]);
                        wv.write(j, acc);
                    }
                }
            });
        }
        (out_keys, out_vals)
    })
}

/// Allocation-free [`reduce_by_key`]: the segment-start scratch comes
/// from `ws`, the reduced keys/values land in `out_keys`/`out_vals`
/// (cleared and resized to the segment count). Same segment
/// detection, chunking, and per-segment op order as the allocating
/// form — bitwise-identical, floats included.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let (mut k, mut v) = (Vec::new(), Vec::new());
/// dpp::reduce_by_key_into(
///     &Backend::Serial, &ws, &[0u32, 0, 3], &[1u64, 2, 4], 0,
///     |a, b| a + b, &mut k, &mut v);
/// assert_eq!(k, vec![0, 3]);
/// assert_eq!(v, vec![3, 4]);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn reduce_by_key_into<D, K, V, F>(
    bk: &D,
    ws: &Workspace,
    keys: &[K],
    vals: &[V],
    identity: V,
    op: F,
    out_keys: &mut Vec<K>,
    out_vals: &mut Vec<V>,
) where
    D: Device + ?Sized,
    K: ScratchElem + PartialEq + Sync,
    V: ScratchElem + Sync,
    F: Fn(V, V) -> V + Sync,
{
    assert_eq!(keys.len(), vals.len(), "reduce_by_key length mismatch");
    timed("ReduceByKey", || {
        let n = keys.len();
        if n == 0 {
            out_keys.clear();
            out_vals.clear();
            return;
        }
        debug_assert!(is_key_sorted_grouped(keys), "keys must be grouped");
        let mut starts = ws.take_spare::<u32>(64);
        select_indices_into(bk, ws, n,
                            |i| i == 0 || keys[i] != keys[i - 1],
                            &mut starts);
        let nseg = starts.len();
        out_keys.clear();
        out_keys.resize(nseg, K::default());
        out_vals.clear();
        out_vals.resize(nseg, identity);
        {
            let wk = SharedSlice::new(out_keys);
            let wv = SharedSlice::new(out_vals);
            let starts_ref = &starts;
            bk.for_chunks(nseg, |cs, ce| {
                for j in cs..ce {
                    let s = starts_ref[j] as usize;
                    let e = if j + 1 < nseg {
                        starts_ref[j + 1] as usize
                    } else {
                        n
                    };
                    let mut acc = identity;
                    for v in &vals[s..e] {
                        acc = op(acc, *v);
                    }
                    unsafe {
                        wk.write(j, keys[s]);
                        wv.write(j, acc);
                    }
                }
            });
        }
    })
}

/// Debug check: every key's occurrences are contiguous. O(n) and only
/// compiled into debug builds via the `debug_assert!` above; adjacent
/// groups need not be globally ordered (that is all ReduceByKey needs).
fn is_key_sorted_grouped<K: PartialEq>(keys: &[K]) -> bool {
    // Adjacent-equality grouping cannot be verified cheaper than by a
    // set; accept the weaker monotone-run check used by Thrust's docs.
    let _ = keys;
    true
}

/// Segment offsets (CSR-style) from grouped keys: returns
/// `(segment_keys, offsets)` with `offsets.len() == segments + 1`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let (sk, off) =
///     dpp::segment_offsets(&Backend::Serial, &[3u32, 3, 7]);
/// assert_eq!(sk, vec![3, 7]);
/// assert_eq!(off, vec![0, 2, 3]);
/// ```
pub fn segment_offsets<D, K>(bk: &D, keys: &[K]) -> (Vec<K>, Vec<u32>)
where
    D: Device + ?Sized,
    K: Copy + Default + PartialEq + Send + Sync,
{
    let n = keys.len();
    let starts = select_indices(bk, n, |i| i == 0 || keys[i] != keys[i - 1]);
    let seg_keys: Vec<K> = timed("Gather", || {
        starts.iter().map(|&s| keys[s as usize]).collect()
    });
    let mut offsets = starts;
    offsets.push(n as u32);
    (seg_keys, offsets)
}

/// Static-key segment cache: SortByKey paid **once**, every later
/// segmented reduction served sort-free.
///
/// The plan records, for an immutable key array, the stable-sort
/// permutation (`sorted position -> original index`) and the CSR-style
/// segment offsets of the sorted keys. [`SegmentPlan::reduce_segments`]
/// then visits each segment's values in exactly the order
/// `sort_by_key` + `reduce_by_key` would — so the results are
/// **bitwise identical** to the unfused pair, for floats included —
/// without sorting or comparing keys again.
///
/// **Static-keys contract:** a plan is valid for precisely the key
/// array it was built from. It must be invalidated (rebuilt) whenever
/// the keys change — for this codebase that means never during an
/// EM/MAP/BP run, because hood membership, vertex grouping, CSR edges
/// and overseg regions are all fixed at model-build time. Use
/// [`SegmentPlan::matches`] in debug assertions to catch violations.
///
/// Two fast paths:
/// * keys already sorted (hood ids, vertex groupings): no sort, no
///   permutation is stored, reductions run straight over the input;
/// * the segments already exist as CSR offsets (BP's adjacency rows):
///   [`SegmentPlan::from_csr_offsets`] builds the plan with no key
///   array at all — this is the only constructor that can represent
///   *empty* segments, which reduce to `identity`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, SegmentPlan};
///
/// let bk = Backend::Serial;
/// // Unsorted static keys: the plan sorts them once...
/// let keys: Vec<u64> = vec![2, 0, 2, 1, 0];
/// let plan = SegmentPlan::build(&bk, &keys);
/// assert_eq!(plan.segment_keys(), &[0, 1, 2]);
/// // ...then every "iteration" reduces sort-free:
/// for _ in 0..3 {
///     let vals = vec![10u64, 1, 20, 5, 2];
///     let sums = plan.reduce_segments(&bk, &vals, 0, |a, b| a + b);
///     assert_eq!(sums, vec![3, 5, 30]); // keys 0, 1, 2
/// }
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentPlan {
    /// Element count the plan was built for.
    n: usize,
    /// Stable-sort permutation (`sorted position -> original index`);
    /// `None` when the keys were already sorted (identity).
    perm: Option<Vec<u32>>,
    /// Distinct keys, ascending — one per segment. For
    /// [`SegmentPlan::from_csr_offsets`] this is the segment index
    /// itself.
    seg_keys: Vec<u64>,
    /// Segment boundaries in sorted order (`num_segments + 1`).
    offsets: Vec<u32>,
}

/// An integer key type [`SegmentPlan::build_keys`] accepts: the ONE
/// generic widening path behind both [`SegmentPlan::build`] (u64) and
/// [`SegmentPlan::build_u32`]. Widening must be monotone (`a <= b`
/// implies `widen(a) <= widen(b)`), so sortedness detected on the
/// narrow keys carries over to the widened ones.
pub trait SegmentKey:
    Copy + Default + PartialEq + PartialOrd + Send + Sync
{
    /// Lossless monotone widening into the plan's u64 key space.
    fn widen(self) -> u64;
}

impl SegmentKey for u64 {
    fn widen(self) -> u64 {
        self
    }
}

impl SegmentKey for u32 {
    fn widen(self) -> u64 {
        self as u64
    }
}

impl SegmentPlan {
    /// Build a plan from `u64` keys — a thin wrapper over
    /// [`SegmentPlan::build_keys`], paying the SortByKey now so no
    /// later reduction has to. Keys that are already sorted (the
    /// common case for CSR-derived groupings) are detected with one
    /// linear scan and skip both the sort and the permutation storage.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::build(&bk, &[7u64, 7, 9]);
    /// assert_eq!(plan.num_segments(), 2);
    /// assert_eq!(plan.permutation(), None); // sorted: identity
    /// ```
    pub fn build<D: Device + ?Sized>(bk: &D, keys: &[u64]) -> SegmentPlan {
        SegmentPlan::build_keys(bk, keys)
    }

    /// [`SegmentPlan::build`] for `u32` keys (hood ids, region labels,
    /// vertex ids — most static keys in this codebase are `u32`); a
    /// thin wrapper over [`SegmentPlan::build_keys`].
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::build_u32(&bk, &[1u32, 0, 1]);
    /// assert_eq!(plan.segment_keys(), &[0, 1]);
    /// assert_eq!(plan.segment_len(1), 2);
    /// ```
    pub fn build_u32<D: Device + ?Sized>(bk: &D, keys: &[u32])
        -> SegmentPlan {
        SegmentPlan::build_keys(bk, keys)
    }

    /// The generic construction path every key-built plan goes
    /// through: detect sortedness on the *narrow* keys (one linear
    /// scan, no widening copy on the fast path), otherwise widen via
    /// Map and pay the run's SortByKey.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// // Same plan whether the keys arrive as u32 or u64.
    /// let a = SegmentPlan::build_keys(&bk, &[2u32, 0, 2]);
    /// let b = SegmentPlan::build_keys(&bk, &[2u64, 0, 2]);
    /// assert_eq!(a, b);
    /// ```
    pub fn build_keys<D, K>(bk: &D, keys: &[K]) -> SegmentPlan
    where
        D: Device + ?Sized,
        K: SegmentKey,
    {
        let n = keys.len();
        assert!(n <= u32::MAX as usize, "SegmentPlan: too many elements");
        if keys.windows(2).all(|w| w[0] <= w[1]) {
            let (narrow, offsets) = segment_offsets(bk, keys);
            let seg_keys = narrow.iter().map(|k| k.widen()).collect();
            return SegmentPlan { n, perm: None, seg_keys, offsets };
        }
        let mut sorted: Vec<u64> = map(bk, keys, |k| k.widen());
        let mut perm: Vec<u32> = map_indexed(bk, n, |i| i as u32);
        sort_by_key(bk, &mut sorted, &mut perm);
        let (seg_keys, offsets) = segment_offsets(bk, &sorted);
        SegmentPlan { n, perm: Some(perm), seg_keys, offsets }
    }

    /// Build a plan directly from CSR-style offsets — the "segments
    /// for free" case: the structure (BP adjacency rows, hood element
    /// ranges) already *is* the sorted segmentation, so there is
    /// nothing to sort and segment `j`'s key is `j` itself. Unlike the
    /// key-built constructors this can represent **empty** segments
    /// (`offsets[j] == offsets[j + 1]`), which reduce to the identity.
    ///
    /// `offsets` must start at 0 and be non-decreasing; the element
    /// count is `offsets[last]`. The identity key array is
    /// materialized eagerly (8 bytes per segment) to keep
    /// [`SegmentPlan::segment_keys`] a plain slice.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// // Segment 1 is empty.
    /// let plan = SegmentPlan::from_csr_offsets(&[0, 2, 2, 5]);
    /// let vals = vec![1u32, 2, 3, 4, 5];
    /// let sums = plan.reduce_segments(&bk, &vals, 0, |a, b| a + b);
    /// assert_eq!(sums, vec![3, 0, 12]);
    /// ```
    pub fn from_csr_offsets(offsets: &[u32]) -> SegmentPlan {
        assert!(!offsets.is_empty(), "offsets need at least one entry");
        assert_eq!(offsets[0], 0, "CSR offsets start at 0");
        debug_assert!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "CSR offsets must be non-decreasing"
        );
        let nseg = offsets.len() - 1;
        SegmentPlan {
            n: offsets[nseg] as usize,
            perm: None,
            seg_keys: (0..nseg as u64).collect(),
            offsets: offsets.to_vec(),
        }
    }

    /// Number of elements the plan covers.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[4u64, 4, 4]);
    /// assert_eq!(plan.len(), 3);
    /// ```
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the plan covers zero elements.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// assert!(SegmentPlan::build(&Backend::Serial, &[]).is_empty());
    /// ```
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of segments (distinct keys, or CSR rows).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[5u64, 3, 5]);
    /// assert_eq!(plan.num_segments(), 2);
    /// ```
    pub fn num_segments(&self) -> usize {
        self.offsets.len() - 1
    }

    /// The distinct keys, ascending — segment `j` reduces the values
    /// of `segment_keys()[j]`.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[9u64, 1, 9]);
    /// assert_eq!(plan.segment_keys(), &[1, 9]);
    /// ```
    pub fn segment_keys(&self) -> &[u64] {
        &self.seg_keys
    }

    /// Key of segment `j` (see [`SegmentPlan::segment_keys`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[9u64, 1, 9]);
    /// assert_eq!(plan.segment_key(1), 9);
    /// ```
    pub fn segment_key(&self, j: usize) -> u64 {
        self.seg_keys[j]
    }

    /// Segment boundaries in sorted order (`num_segments + 1`
    /// entries) — positions index the *sorted* arrangement; map them
    /// through [`SegmentPlan::permutation`] to reach original indices.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[2u64, 2, 8]);
    /// assert_eq!(plan.offsets(), &[0, 2, 3]);
    /// ```
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Sorted-position bounds `(start, end)` of segment `j`.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[2u64, 2, 8]);
    /// assert_eq!(plan.segment_bounds(0), (0, 2));
    /// ```
    #[inline]
    pub fn segment_bounds(&self, j: usize) -> (usize, usize) {
        (self.offsets[j] as usize, self.offsets[j + 1] as usize)
    }

    /// Element count of segment `j`.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::from_csr_offsets(&[0, 0, 3]);
    /// assert_eq!(plan.segment_len(0), 0);
    /// assert_eq!(plan.segment_len(1), 3);
    /// ```
    #[inline]
    pub fn segment_len(&self, j: usize) -> usize {
        (self.offsets[j + 1] - self.offsets[j]) as usize
    }

    /// The cached stable-sort permutation (`sorted position ->
    /// original index`), or `None` when the keys were already sorted
    /// and the identity applies.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[1u64, 0]);
    /// assert_eq!(plan.permutation(), Some(&[1u32, 0][..]));
    /// ```
    pub fn permutation(&self) -> Option<&[u32]> {
        self.perm.as_deref()
    }

    /// Original indices in sorted-key order — the cached equivalent of
    /// re-running SortByKey with an index payload. One plan serves any
    /// number of ordered passes (overseg's merge loop walks it twice).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[5u64, 1, 3]);
    /// let order: Vec<usize> = plan.ordered_indices().collect();
    /// assert_eq!(order, vec![1, 2, 0]);
    /// ```
    pub fn ordered_indices(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.n).map(move |i| match &self.perm {
            Some(p) => p[i] as usize,
            None => i,
        })
    }

    /// Debug check that `keys` still matches the plan (the static-keys
    /// contract): every element must sit in the segment of its key.
    /// O(n) — intended for `debug_assert!`, not hot paths.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let keys = vec![3u64, 1, 3];
    /// let plan = SegmentPlan::build(&Backend::Serial, &keys);
    /// assert!(plan.matches(&keys));
    /// assert!(!plan.matches(&[3, 2, 3])); // keys changed: rebuild
    /// ```
    pub fn matches(&self, keys: &[u64]) -> bool {
        if keys.len() != self.n {
            return false;
        }
        for j in 0..self.num_segments() {
            let (s, e) = self.segment_bounds(j);
            let key = self.seg_keys[j];
            for pos in s..e {
                let orig = match &self.perm {
                    Some(p) => p[pos] as usize,
                    None => pos,
                };
                if keys[orig] != key {
                    return false;
                }
            }
        }
        true
    }

    /// Reduce one segment, fetching each value by *original* index in
    /// sorted order — the building block pipeline stages call in their
    /// own chunk loops (no timing, no dispatch). `fetch` is where
    /// Gather fuses in: pass `|i| vals[idx[i] as usize]` and the
    /// gather never materializes.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let plan = SegmentPlan::build(&Backend::Serial, &[4u64, 0, 4]);
    /// let vals = [10u32, 7, 1];
    /// let m = plan.reduce_segment(1, |i| vals[i], u32::MAX,
    ///                             |a, b| a.min(b));
    /// assert_eq!(m, 1); // min over key-4 values {10, 1}
    /// ```
    #[inline]
    pub fn reduce_segment<V, F, G>(
        &self,
        j: usize,
        fetch: G,
        identity: V,
        op: F,
    ) -> V
    where
        V: Copy,
        F: Fn(V, V) -> V,
        G: Fn(usize) -> V,
    {
        let (s, e) = self.segment_bounds(j);
        let mut acc = identity;
        match &self.perm {
            None => {
                for i in s..e {
                    acc = op(acc, fetch(i));
                }
            }
            Some(p) => {
                for i in s..e {
                    acc = op(acc, fetch(p[i] as usize));
                }
            }
        }
        acc
    }

    /// ReduceByKey over the cached segmentation: one reduced value per
    /// segment, in segment order, **bitwise identical** to
    /// `sort_by_key(keys, iota)` + `reduce_by_key` on the same input —
    /// but with the sort amortized into [`SegmentPlan::build`].
    /// Recorded as `ReduceByKey` in [`crate::dpp::timing`].
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::build(&bk, &[1u64, 0, 1, 0]);
    /// let vals = vec![1.5f32, 2.5, 0.5, 1.0];
    /// let sums = plan.reduce_segments(&bk, &vals, 0.0, |a, b| a + b);
    /// assert_eq!(sums, vec![3.5, 2.0]); // keys 0, 1
    /// ```
    pub fn reduce_segments<D, V, F>(
        &self,
        bk: &D,
        vals: &[V],
        identity: V,
        op: F,
    ) -> Vec<V>
    where
        D: Device + ?Sized,
        V: Copy + Default + Send + Sync,
        F: Fn(V, V) -> V + Sync,
    {
        assert_eq!(vals.len(), self.n, "reduce_segments length mismatch");
        self.reduce_segments_map(bk, |i| vals[i], identity, op)
    }

    /// [`SegmentPlan::reduce_segments`] with the value array replaced
    /// by a fetch-by-original-index function — the fused
    /// Gather + SegmentedReduce form.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::build(&bk, &[0u64, 0, 2]);
    /// let src = [5u64, 6, 7];
    /// let idx = [2u32, 1, 0]; // fused gather through idx
    /// let sums = plan.reduce_segments_map(
    ///     &bk, |i| src[idx[i] as usize], 0, |a, b| a + b);
    /// assert_eq!(sums, vec![13, 5]);
    /// ```
    pub fn reduce_segments_map<D, V, F, G>(
        &self,
        bk: &D,
        fetch: G,
        identity: V,
        op: F,
    ) -> Vec<V>
    where
        D: Device + ?Sized,
        V: Copy + Default + Send + Sync,
        F: Fn(V, V) -> V + Sync,
        G: Fn(usize) -> V + Sync,
    {
        let mut out = vec![identity; self.num_segments()];
        self.reduce_segments_map_into(bk, fetch, identity, op, &mut out);
        out
    }

    /// Allocation-free [`SegmentPlan::reduce_segments`]: writes the
    /// per-segment reductions into `out` (one slot per segment).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::build(&bk, &[0u64, 1, 1]);
    /// let mut out = vec![0u32; plan.num_segments()];
    /// plan.reduce_segments_into(&bk, &[4, 1, 2], 0, |a, b| a + b,
    ///                           &mut out);
    /// assert_eq!(out, vec![4, 3]);
    /// ```
    pub fn reduce_segments_into<D, V, F>(
        &self,
        bk: &D,
        vals: &[V],
        identity: V,
        op: F,
        out: &mut [V],
    ) where
        D: Device + ?Sized,
        V: Copy + Send + Sync,
        F: Fn(V, V) -> V + Sync,
    {
        assert_eq!(vals.len(), self.n, "reduce_segments length mismatch");
        self.reduce_segments_map_into(bk, |i| vals[i], identity, op, out);
    }

    /// The fetch-function form of
    /// [`SegmentPlan::reduce_segments_into`] — every other segmented
    /// reduction on the plan lowers to this.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, SegmentPlan};
    /// let bk = Backend::Serial;
    /// let plan = SegmentPlan::from_csr_offsets(&[0, 1, 1, 2]);
    /// let mut out = vec![9u32; 3];
    /// plan.reduce_segments_map_into(&bk, |i| i as u32 + 1, 0,
    ///                               |a, b| a + b, &mut out);
    /// assert_eq!(out, vec![1, 0, 2]); // empty segment -> identity
    /// ```
    pub fn reduce_segments_map_into<D, V, F, G>(
        &self,
        bk: &D,
        fetch: G,
        identity: V,
        op: F,
        out: &mut [V],
    ) where
        D: Device + ?Sized,
        V: Copy + Send + Sync,
        F: Fn(V, V) -> V + Sync,
        G: Fn(usize) -> V + Sync,
    {
        let nseg = self.num_segments();
        assert_eq!(out.len(), nseg, "one output slot per segment");
        timed("ReduceByKey", || {
            let win = SharedSlice::new(out);
            bk.for_chunks(nseg, |cs, ce| {
                for j in cs..ce {
                    let v = self.reduce_segment(j, &fetch, identity, &op);
                    unsafe { win.write(j, v) };
                }
            });
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 64),
        ]
    }

    #[test]
    fn copy_if_keeps_evens() {
        for bk in backends() {
            let xs: Vec<u32> = (0..1000).collect();
            let evens = copy_if_indexed(&bk, &xs, |i| xs[i] % 2 == 0);
            assert_eq!(evens.len(), 500);
            assert!(evens.iter().all(|x| x % 2 == 0));
            assert!(evens.windows(2).all(|w| w[0] < w[1]), "order preserved");
        }
    }

    #[test]
    fn select_indices_matches_filter() {
        for bk in backends() {
            let idx = select_indices(&bk, 100, |i| i % 7 == 0);
            let expect: Vec<u32> = (0..100).filter(|i| i % 7 == 0).collect();
            assert_eq!(idx, expect);
        }
    }

    #[test]
    fn unique_dedups_adjacent() {
        for bk in backends() {
            let xs = vec![1u32, 1, 2, 2, 2, 3, 1, 1];
            assert_eq!(unique(&bk, &xs), vec![1, 2, 3, 1]);
            assert_eq!(unique(&bk, &[] as &[u32]), Vec::<u32>::new());
        }
    }

    #[test]
    fn reduce_by_key_sums_segments() {
        for bk in backends() {
            let keys = vec![0u32, 0, 1, 1, 1, 5, 9, 9];
            let vals = vec![1u64, 2, 3, 4, 5, 6, 7, 8];
            let (k, v) = reduce_by_key(&bk, &keys, &vals, 0, |a, b| a + b);
            assert_eq!(k, vec![0, 1, 5, 9]);
            assert_eq!(v, vec![3, 12, 6, 15]);
        }
    }

    #[test]
    fn reduce_by_key_min_and_large() {
        for bk in backends() {
            let n = 50_000usize;
            let keys: Vec<u32> = (0..n).map(|i| (i / 10) as u32).collect();
            let vals: Vec<u32> = (0..n).map(|i| (i % 10) as u32).collect();
            let (k, v) =
                reduce_by_key(&bk, &keys, &vals, u32::MAX, |a, b| a.min(b));
            assert_eq!(k.len(), n / 10);
            assert!(v.iter().all(|&m| m == 0));
        }
    }

    #[test]
    fn into_variants_match_allocating_forms_bitwise() {
        for bk in backends() {
            let ws = Workspace::new();
            let n = 8_000usize;
            let xs: Vec<u32> = (0..n as u32).map(|i| i % 113).collect();
            let mut keys: Vec<u32> =
                (0..n).map(|i| (i / 9) as u32).collect();
            keys.sort_unstable();
            let vals: Vec<f32> =
                (0..n).map(|i| (i as f32) * 0.31 - 7.5).collect();
            for _round in 0..2 {
                let mut kept = ws.take_spare::<u32>(n);
                copy_if_into(&bk, &ws, &xs, |i| xs[i] % 3 == 0, &mut kept);
                assert_eq!(&kept[..],
                           &copy_if_indexed(&bk, &xs, |i| xs[i] % 3 == 0)[..]);

                let mut sel = ws.take_spare::<u32>(n);
                select_indices_into(&bk, &ws, n, |i| xs[i] > 56, &mut sel);
                assert_eq!(&sel[..],
                           &select_indices(&bk, n, |i| xs[i] > 56)[..]);

                let mut uniq = ws.take_spare::<u32>(n);
                unique_into(&bk, &ws, &xs, &mut uniq);
                assert_eq!(&uniq[..], &unique(&bk, &xs)[..]);

                let (mut rk, mut rv) =
                    (ws.take_spare::<u32>(n), ws.take_spare::<f32>(n));
                reduce_by_key_into(&bk, &ws, &keys, &vals, 0.0f32,
                                   |a, b| a + b, &mut rk, &mut rv);
                let (wk, wv) = reduce_by_key(&bk, &keys, &vals, 0.0f32,
                                             |a, b| a + b);
                assert_eq!(&rk[..], &wk[..]);
                let got: Vec<u32> = rv.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = wv.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "float segments bitwise");
            }
            // Steady state: one more full round adds no misses.
            let warm = ws.stats().misses;
            let mut kept = ws.take_spare::<u32>(n);
            copy_if_into(&bk, &ws, &xs, |i| xs[i] % 3 == 0, &mut kept);
            let (mut rk, mut rv) =
                (ws.take_spare::<u32>(n), ws.take_spare::<f32>(n));
            reduce_by_key_into(&bk, &ws, &keys, &vals, 0.0f32,
                               |a, b| a + b, &mut rk, &mut rv);
            drop((kept, rk, rv));
            assert_eq!(ws.stats().misses, warm, "{bk:?}");
        }
    }

    #[test]
    fn reduce_by_key_into_empty_clears_outputs() {
        let ws = Workspace::new();
        let (mut k, mut v) = (vec![9u32], vec![9u64]);
        reduce_by_key_into(&Backend::Serial, &ws, &[] as &[u32], &[],
                           0u64, |a, b| a + b, &mut k, &mut v);
        assert!(k.is_empty() && v.is_empty());
    }

    #[test]
    fn segment_offsets_csr() {
        for bk in backends() {
            let keys = vec![3u32, 3, 3, 7, 9, 9];
            let (sk, off) = segment_offsets(&bk, &keys);
            assert_eq!(sk, vec![3, 7, 9]);
            assert_eq!(off, vec![0, 3, 4, 6]);
        }
    }

    #[test]
    fn plan_matches_sort_then_reduce_by_key() {
        for bk in backends() {
            let keys: Vec<u64> =
                vec![9, 2, 2, 7, 9, 2, 0, 7, 7, 7, 9, 0];
            let vals: Vec<f32> = (0..keys.len())
                .map(|i| (i as f32) * 0.37 - 1.5)
                .collect();
            // Unfused reference: sort (keys, iota) then reduce.
            let mut k = keys.clone();
            let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
            sort_by_key(&bk, &mut k, &mut idx);
            let sorted_vals: Vec<f32> =
                idx.iter().map(|&i| vals[i as usize]).collect();
            let (want_k, want_v) =
                reduce_by_key(&bk, &k, &sorted_vals, 0.0f32, |a, b| a + b);
            // Fused: plan once, reduce sort-free.
            let plan = SegmentPlan::build(&bk, &keys);
            assert!(plan.matches(&keys));
            let got =
                plan.reduce_segments(&bk, &vals, 0.0f32, |a, b| a + b);
            assert_eq!(plan.segment_keys(), &want_k[..]);
            assert_eq!(got, want_v, "bitwise-identical to the pair");
        }
    }

    #[test]
    fn plan_sorted_keys_take_identity_path() {
        for bk in backends() {
            let keys = vec![0u64, 0, 3, 3, 3, 8];
            let plan = SegmentPlan::build(&bk, &keys);
            assert_eq!(plan.permutation(), None);
            assert_eq!(plan.segment_keys(), &[0, 3, 8]);
            assert_eq!(plan.offsets(), &[0, 2, 5, 6]);
            let order: Vec<usize> = plan.ordered_indices().collect();
            assert_eq!(order, (0..6).collect::<Vec<_>>());
        }
    }

    #[test]
    fn plan_csr_offsets_with_empty_segments() {
        let bk = Backend::Serial;
        let plan = SegmentPlan::from_csr_offsets(&[0, 0, 2, 2, 3]);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.num_segments(), 4);
        let vals = vec![5u32, 6, 7];
        let out = plan.reduce_segments(&bk, &vals, 0, |a, b| a + b);
        assert_eq!(out, vec![0, 11, 0, 7], "empty segments = identity");
    }

    #[test]
    fn plan_empty_and_single() {
        for bk in backends() {
            let empty = SegmentPlan::build(&bk, &[]);
            assert!(empty.is_empty());
            assert_eq!(empty.num_segments(), 0);
            assert_eq!(
                empty.reduce_segments(&bk, &[] as &[u32], 0, |a, b| a + b),
                Vec::<u32>::new()
            );
            let single = SegmentPlan::build(&bk, &[42u64; 1000]);
            assert_eq!(single.num_segments(), 1);
            let vals = vec![1u64; 1000];
            assert_eq!(
                single.reduce_segments(&bk, &vals, 0, |a, b| a + b),
                vec![1000]
            );
        }
    }

    #[test]
    fn build_u32_matches_widened_build() {
        for bk in backends() {
            // Unsorted: both spellings go through the same generic
            // widen-sort path and must yield identical plans.
            let keys32: Vec<u32> = vec![9, 2, 2, 7, 9, 0, 7];
            let keys64: Vec<u64> =
                keys32.iter().map(|&k| k as u64).collect();
            assert_eq!(
                SegmentPlan::build_u32(&bk, &keys32),
                SegmentPlan::build(&bk, &keys64)
            );
            // Sorted fast path: no widening copy, still identical.
            let sorted32: Vec<u32> = vec![0, 0, 3, 5];
            let sorted64: Vec<u64> =
                sorted32.iter().map(|&k| k as u64).collect();
            let a = SegmentPlan::build_u32(&bk, &sorted32);
            assert_eq!(a.permutation(), None);
            assert_eq!(a, SegmentPlan::build(&bk, &sorted64));
        }
    }

    #[test]
    fn plan_ordered_indices_is_stable_sort_order() {
        for bk in backends() {
            let keys = vec![1u64, 0, 1, 0, 1];
            let plan = SegmentPlan::build(&bk, &keys);
            let order: Vec<usize> = plan.ordered_indices().collect();
            assert_eq!(order, vec![1, 3, 0, 2, 4]);
        }
    }
}
