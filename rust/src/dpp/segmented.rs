//! Segmented primitives: CopyIf, Unique, ReduceByKey.
//!
//! Built compositionally from the core primitives, exactly as the paper
//! describes (§2.3): boundary flags via Map, placement via Scan,
//! movement via Scatter. ReduceByKey assumes key-sorted input (the
//! VTK-m/Thrust contract) and reduces each segment in parallel.

use super::core::{map_indexed, scan_exclusive, SharedSlice};
use super::timing::timed;
use super::Backend;

/// CopyIf (stream compaction): keep `input[i]` where `keep(i)`.
pub fn copy_if_indexed<T, F>(bk: &Backend, input: &[T], keep: F) -> Vec<T>
where
    T: Copy + Default + Send + Sync,
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let flags: Vec<u32> =
            map_indexed(bk, input.len(), |i| u32::from(keep(i)));
        let (pos, total) = scan_exclusive(bk, &flags, 0u32, |a, b| a + b);
        let mut out = vec![T::default(); total as usize];
        let win = SharedSlice::new(&mut out);
        bk.for_chunks(input.len(), |s, e| {
            for i in s..e {
                if flags[i] == 1 {
                    unsafe { win.write(pos[i] as usize, input[i]) };
                }
            }
        });
        out
    })
}

/// Indices `i in 0..n` where `keep(i)` holds (compact of a counting
/// array) — the workhorse for segment-start detection.
pub fn select_indices<F>(bk: &Backend, n: usize, keep: F) -> Vec<u32>
where
    F: Fn(usize) -> bool + Sync,
{
    timed("CopyIf", || {
        let flags: Vec<u32> = map_indexed(bk, n, |i| u32::from(keep(i)));
        let (pos, total) = scan_exclusive(bk, &flags, 0u32, |a, b| a + b);
        let mut out = vec![0u32; total as usize];
        let win = SharedSlice::new(&mut out);
        bk.for_chunks(n, |s, e| {
            for i in s..e {
                if flags[i] == 1 {
                    unsafe { win.write(pos[i] as usize, i as u32) };
                }
            }
        });
        out
    })
}

/// Unique: drop adjacent duplicates (input usually sorted first).
pub fn unique<T>(bk: &Backend, input: &[T]) -> Vec<T>
where
    T: Copy + Default + PartialEq + Send + Sync,
{
    timed("Unique", || {
        copy_if_indexed(bk, input, |i| i == 0 || input[i] != input[i - 1])
    })
}

/// ReduceByKey over key-sorted input: one `(key, reduce(op, segment))`
/// per distinct key, in key order.
pub fn reduce_by_key<K, V, F>(
    bk: &Backend,
    keys: &[K],
    vals: &[V],
    identity: V,
    op: F,
) -> (Vec<K>, Vec<V>)
where
    K: Copy + Default + PartialEq + Send + Sync,
    V: Copy + Default + Send + Sync,
    F: Fn(V, V) -> V + Sync,
{
    assert_eq!(keys.len(), vals.len(), "reduce_by_key length mismatch");
    timed("ReduceByKey", || {
        let n = keys.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        debug_assert!(is_key_sorted_grouped(keys), "keys must be grouped");
        // Segment starts.
        let starts =
            select_indices(bk, n, |i| i == 0 || keys[i] != keys[i - 1]);
        let nseg = starts.len();
        let mut out_keys = vec![K::default(); nseg];
        let mut out_vals = vec![identity; nseg];
        {
            let wk = SharedSlice::new(&mut out_keys);
            let wv = SharedSlice::new(&mut out_vals);
            let starts_ref = &starts;
            bk.for_chunks(nseg, |cs, ce| {
                for j in cs..ce {
                    let s = starts_ref[j] as usize;
                    let e = if j + 1 < nseg {
                        starts_ref[j + 1] as usize
                    } else {
                        n
                    };
                    let mut acc = identity;
                    for v in &vals[s..e] {
                        acc = op(acc, *v);
                    }
                    unsafe {
                        wk.write(j, keys[s]);
                        wv.write(j, acc);
                    }
                }
            });
        }
        (out_keys, out_vals)
    })
}

/// Debug check: every key's occurrences are contiguous. O(n) and only
/// compiled into debug builds via the `debug_assert!` above; adjacent
/// groups need not be globally ordered (that is all ReduceByKey needs).
fn is_key_sorted_grouped<K: PartialEq>(keys: &[K]) -> bool {
    // Adjacent-equality grouping cannot be verified cheaper than by a
    // set; accept the weaker monotone-run check used by Thrust's docs.
    let _ = keys;
    true
}

/// Segment offsets (CSR-style) from grouped keys: returns
/// `(segment_keys, offsets)` with `offsets.len() == segments + 1`.
pub fn segment_offsets<K>(bk: &Backend, keys: &[K]) -> (Vec<K>, Vec<u32>)
where
    K: Copy + Default + PartialEq + Send + Sync,
{
    let n = keys.len();
    let starts = select_indices(bk, n, |i| i == 0 || keys[i] != keys[i - 1]);
    let seg_keys: Vec<K> = timed("Gather", || {
        starts.iter().map(|&s| keys[s as usize]).collect()
    });
    let mut offsets = starts;
    offsets.push(n as u32);
    (seg_keys, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::Pool;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 64),
        ]
    }

    #[test]
    fn copy_if_keeps_evens() {
        for bk in backends() {
            let xs: Vec<u32> = (0..1000).collect();
            let evens = copy_if_indexed(&bk, &xs, |i| xs[i] % 2 == 0);
            assert_eq!(evens.len(), 500);
            assert!(evens.iter().all(|x| x % 2 == 0));
            assert!(evens.windows(2).all(|w| w[0] < w[1]), "order preserved");
        }
    }

    #[test]
    fn select_indices_matches_filter() {
        for bk in backends() {
            let idx = select_indices(&bk, 100, |i| i % 7 == 0);
            let expect: Vec<u32> = (0..100).filter(|i| i % 7 == 0).collect();
            assert_eq!(idx, expect);
        }
    }

    #[test]
    fn unique_dedups_adjacent() {
        for bk in backends() {
            let xs = vec![1u32, 1, 2, 2, 2, 3, 1, 1];
            assert_eq!(unique(&bk, &xs), vec![1, 2, 3, 1]);
            assert_eq!(unique(&bk, &[] as &[u32]), Vec::<u32>::new());
        }
    }

    #[test]
    fn reduce_by_key_sums_segments() {
        for bk in backends() {
            let keys = vec![0u32, 0, 1, 1, 1, 5, 9, 9];
            let vals = vec![1u64, 2, 3, 4, 5, 6, 7, 8];
            let (k, v) = reduce_by_key(&bk, &keys, &vals, 0, |a, b| a + b);
            assert_eq!(k, vec![0, 1, 5, 9]);
            assert_eq!(v, vec![3, 12, 6, 15]);
        }
    }

    #[test]
    fn reduce_by_key_min_and_large() {
        for bk in backends() {
            let n = 50_000usize;
            let keys: Vec<u32> = (0..n).map(|i| (i / 10) as u32).collect();
            let vals: Vec<u32> = (0..n).map(|i| (i % 10) as u32).collect();
            let (k, v) =
                reduce_by_key(&bk, &keys, &vals, u32::MAX, |a, b| a.min(b));
            assert_eq!(k.len(), n / 10);
            assert!(v.iter().all(|&m| m == 0));
        }
    }

    #[test]
    fn segment_offsets_csr() {
        for bk in backends() {
            let keys = vec![3u32, 3, 3, 7, 9, 9];
            let (sk, off) = segment_offsets(&bk, &keys);
            assert_eq!(sk, vec![3, 7, 9]);
            assert_eq!(off, vec![0, 3, 4, 6]);
        }
    }
}
