//! Core primitives: Map, Reduce, Scan, Gather, Scatter.
//!
//! All primitives are deterministic for a given input regardless of
//! backend *except* floating-point Reduce/Scan, whose association order
//! differs between Serial and Threaded (documented per function). The
//! MRF engines only compare reductions against convergence thresholds,
//! so this is benign — and it mirrors the paper's situation exactly
//! (TBB reductions are unordered too).

//! Every output-producing primitive has two spellings: the original
//! allocating form (`map`, `gather`, `scan_exclusive`, ...) and an
//! `_into` form writing into a caller-owned `Vec` — typically a
//! [`crate::dpp::ScratchVec`] drawn from a [`Workspace`] — so hot
//! loops can run allocation-free (DESIGN.md §10). The allocating
//! forms are thin wrappers over the `_into` paths: one
//! implementation, bitwise-identical results.

use std::sync::atomic::{AtomicU64, Ordering};

use super::device::{Device, DeviceExt};
use super::timing::timed;
use super::workspace::{ScratchElem, Workspace};

/// Shared mutable window over a slice for disjoint parallel writes —
/// the raw building block every primitive (and every
/// [`crate::dpp::Pipeline`] stage) writes its output through.
///
/// Safety contract: within one parallel pass, every index is written
/// by at most one chunk, and a given index is never read and written
/// concurrently. Reads of an index written in an *earlier* pipeline
/// stage are fine — the phase barrier orders them.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::SharedSlice;
///
/// let mut out = vec![0u32; 4];
/// {
///     let win = SharedSlice::new(&mut out);
///     // Chunks write disjoint indices (here: one "chunk").
///     for i in 0..4 {
///         unsafe { win.write(i, (i * i) as u32) };
///     }
///     assert_eq!(unsafe { win.read(3) }, 9);
/// }
/// assert_eq!(out, vec![0, 1, 4, 9]);
/// ```
pub struct SharedSlice<T>(*mut T, usize);

unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}

impl<T> SharedSlice<T> {
    /// Capture a window over `s`. The window borrows nothing: it is a
    /// raw pointer + length, so the caller is responsible for keeping
    /// the underlying buffer alive and un-moved while the window is
    /// used (trivially true for the scoped passes in this crate).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::SharedSlice;
    /// let mut buf = vec![0u8; 2];
    /// let win = SharedSlice::new(&mut buf);
    /// unsafe { win.write(1, 7) };
    /// assert_eq!(buf[1], 7);
    /// ```
    pub fn new(s: &mut [T]) -> Self {
        SharedSlice(s.as_mut_ptr(), s.len())
    }

    /// Number of elements in the window.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::SharedSlice;
    /// let mut buf = vec![0u32; 5];
    /// assert_eq!(SharedSlice::new(&mut buf).len(), 5);
    /// ```
    pub fn len(&self) -> usize {
        self.1
    }

    /// Whether the window is empty.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::SharedSlice;
    /// let mut buf: Vec<u32> = Vec::new();
    /// assert!(SharedSlice::new(&mut buf).is_empty());
    /// ```
    pub fn is_empty(&self) -> bool {
        self.1 == 0
    }

    /// Write `v` at `i`.
    ///
    /// # Safety
    ///
    /// `i` must be written by at most one chunk of the current pass,
    /// and must not be read concurrently within the same pass.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.1);
        unsafe { *self.0.add(i) = v }
    }

    /// Read index `i`.
    ///
    /// # Safety
    ///
    /// No chunk of the current pass may write `i` concurrently. Used
    /// by pipeline stages to read buffers a *previous* stage wrote
    /// (the phase barrier makes those writes visible).
    #[inline]
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.1);
        unsafe { *self.0.add(i) }
    }
}

/// Map: `out[i] = f(&input[i])`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let ys = dpp::map(&Backend::Serial, &[1u32, 2, 3], |x| x * 10);
/// assert_eq!(ys, vec![10, 20, 30]);
/// ```
pub fn map<D, T, U, F>(bk: &D, input: &[T], f: F) -> Vec<U>
where
    D: Device + ?Sized,
    T: Sync,
    U: Copy + Default + Send,
    F: Fn(&T) -> U + Sync,
{
    let mut out = Vec::new();
    map_into(bk, input, f, &mut out);
    out
}

/// Allocation-free [`map`]: `out` is cleared and resized to
/// `input.len()` (within capacity once warm), then written exactly as
/// the allocating form would — bitwise-identical results.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut out = ws.take_spare::<u32>(3);
/// dpp::map_into(&Backend::Serial, &[1u32, 2, 3], |x| x * 10,
///               &mut out);
/// assert_eq!(&out[..], &[10, 20, 30]);
/// ```
pub fn map_into<D, T, U, F>(bk: &D, input: &[T], f: F, out: &mut Vec<U>)
where
    D: Device + ?Sized,
    T: Sync,
    U: Copy + Default + Send,
    F: Fn(&T) -> U + Sync,
{
    timed("Map", || {
        out.clear();
        out.resize(input.len(), U::default());
        let win = SharedSlice::new(out);
        bk.for_chunks(input.len(), |s, e| {
            for i in s..e {
                unsafe { win.write(i, f(&input[i])) };
            }
        });
    })
}

/// Map with the element index: `out[i] = f(i)`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let ys = dpp::map_indexed(&Backend::Serial, 4, |i| i as u32 * 2);
/// assert_eq!(ys, vec![0, 2, 4, 6]);
/// ```
pub fn map_indexed<D, U, F>(bk: &D, n: usize, f: F) -> Vec<U>
where
    D: Device + ?Sized,
    U: Copy + Default + Send,
    F: Fn(usize) -> U + Sync,
{
    let mut out = Vec::new();
    map_indexed_into(bk, n, f, &mut out);
    out
}

/// Allocation-free [`map_indexed`] (see [`map_into`] for the
/// `out`-buffer contract).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut out = Vec::new();
/// dpp::map_indexed_into(&Backend::Serial, 4, |i| i as u32 * 2,
///                       &mut out);
/// assert_eq!(out, vec![0, 2, 4, 6]);
/// ```
pub fn map_indexed_into<D, U, F>(bk: &D, n: usize, f: F, out: &mut Vec<U>)
where
    D: Device + ?Sized,
    U: Copy + Default + Send,
    F: Fn(usize) -> U + Sync,
{
    timed("Map", || {
        out.clear();
        out.resize(n, U::default());
        let win = SharedSlice::new(out);
        bk.for_chunks(n, |s, e| {
            for i in s..e {
                unsafe { win.write(i, f(i)) };
            }
        });
    })
}

/// In-place Map over a mutable slice: `data[i] = f(i, data[i])`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut xs = vec![5u32, 6, 7];
/// dpp::map_in_place(&Backend::Serial, &mut xs, |i, x| x + i as u32);
/// assert_eq!(xs, vec![5, 7, 9]);
/// ```
pub fn map_in_place<D, T, F>(bk: &D, data: &mut [T], f: F)
where
    D: Device + ?Sized,
    T: Copy + Send + Sync,
    F: Fn(usize, T) -> T + Sync,
{
    timed("Map", || {
        let n = data.len();
        let win = SharedSlice::new(data);
        let src = SharedConst(win.0 as *const T);
        bk.for_chunks(n, |s, e| {
            for i in s..e {
                let v = unsafe { src.read(i) };
                unsafe { win.write(i, f(i, v)) };
            }
        });
    })
}

struct SharedConst<T>(*const T);
unsafe impl<T: Sync> Send for SharedConst<T> {}
unsafe impl<T: Sync> Sync for SharedConst<T> {}

impl<T: Copy> SharedConst<T> {
    /// Read index `i`. Caller guarantees no concurrent write to `i`.
    #[inline]
    unsafe fn read(&self, i: usize) -> T {
        unsafe { *self.0.add(i) }
    }
}

/// Zip-map: `out[i] = f(&a[i], &b[i])`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let s = dpp::zip_map(&Backend::Serial, &[1u32, 2], &[10u32, 20],
///                      |a, b| a + b);
/// assert_eq!(s, vec![11, 22]);
/// ```
pub fn zip_map<D, A, B, U, F>(bk: &D, a: &[A], b: &[B], f: F) -> Vec<U>
where
    D: Device + ?Sized,
    A: Sync,
    B: Sync,
    U: Copy + Default + Send,
    F: Fn(&A, &B) -> U + Sync,
{
    let mut out = Vec::new();
    zip_map_into(bk, a, b, f, &mut out);
    out
}

/// Allocation-free [`zip_map`] (see [`map_into`] for the `out`-buffer
/// contract).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut s = Vec::new();
/// dpp::zip_map_into(&Backend::Serial, &[1u32, 2], &[10u32, 20],
///                   |a, b| a + b, &mut s);
/// assert_eq!(s, vec![11, 22]);
/// ```
pub fn zip_map_into<D, A, B, U, F>(
    bk: &D,
    a: &[A],
    b: &[B],
    f: F,
    out: &mut Vec<U>,
) where
    D: Device + ?Sized,
    A: Sync,
    B: Sync,
    U: Copy + Default + Send,
    F: Fn(&A, &B) -> U + Sync,
{
    assert_eq!(a.len(), b.len(), "zip_map length mismatch");
    timed("Map", || {
        out.clear();
        out.resize(a.len(), U::default());
        let win = SharedSlice::new(out);
        bk.for_chunks(a.len(), |s, e| {
            for i in s..e {
                unsafe { win.write(i, f(&a[i], &b[i])) };
            }
        });
    })
}

/// Counting sequence `0..n` (VTK-m's ArrayHandleCounting materialized).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// assert_eq!(dpp::iota(&Backend::Serial, 3), vec![0, 1, 2]);
/// ```
pub fn iota<D: Device + ?Sized>(bk: &D, n: usize) -> Vec<u32> {
    map_indexed(bk, n, |i| i as u32)
}

/// Allocation-free [`iota`] (see [`map_into`] for the `out`-buffer
/// contract).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut out = Vec::new();
/// dpp::iota_into(&Backend::Serial, 3, &mut out);
/// assert_eq!(out, vec![0, 1, 2]);
/// ```
pub fn iota_into<D: Device + ?Sized>(bk: &D, n: usize, out: &mut Vec<u32>) {
    map_indexed_into(bk, n, |i| i as u32, out);
}

/// Reduce with an associative operation and its identity.
///
/// Floating-point note: association order is chunked under the
/// Threaded backend.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let xs: Vec<u64> = (1..=100).collect();
/// assert_eq!(dpp::reduce(&Backend::Serial, &xs, 0, |a, b| a + b),
///            5050);
/// ```
pub fn reduce<D, T, F>(bk: &D, input: &[T], identity: T, op: F) -> T
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Reduce", || {
        let bounds = bk.chunk_bounds(input.len());
        let mut partials = Vec::new();
        reduce_core(bk, input, identity, &op, &bounds, &mut partials)
    })
}

/// Allocation-free [`reduce`]: chunk bounds and partials come from
/// the workspace, the fold order is unchanged — same result bitwise
/// for a given device configuration.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let xs: Vec<u64> = (1..=100).collect();
/// let s = dpp::reduce_ws(&Backend::Serial, &ws, &xs, 0, |a, b| a + b);
/// assert_eq!(s, 5050);
/// ```
pub fn reduce_ws<D, T, F>(
    bk: &D,
    ws: &Workspace,
    input: &[T],
    identity: T,
    op: F,
) -> T
where
    D: Device + ?Sized,
    T: ScratchElem + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Reduce", || {
        let mut bounds = ws.take_spare::<(usize, usize)>(16);
        bk.chunk_bounds_into(input.len(), &mut bounds);
        let mut partials = ws.take_spare::<T>(bounds.len());
        reduce_core(bk, input, identity, &op, &bounds, &mut partials)
    })
}

/// The one chunked-reduce body behind [`reduce`] and [`reduce_ws`]:
/// per-chunk serial accumulation, then a serial fold of the partials
/// in chunk order.
fn reduce_core<D, T, F>(
    bk: &D,
    input: &[T],
    identity: T,
    op: &F,
    bounds: &[(usize, usize)],
    partials: &mut Vec<T>,
) -> T
where
    D: Device + ?Sized,
    T: Copy + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    partials.clear();
    partials.resize(bounds.len(), identity);
    {
        let win = SharedSlice::new(partials);
        bk.for_chunk_ids(bounds.len(), |c| {
            let (s, e) = bounds[c];
            let mut acc = identity;
            for v in &input[s..e] {
                acc = op(acc, *v);
            }
            unsafe { win.write(c, acc) };
        });
    }
    partials.iter().fold(identity, |a, b| op(a, *b))
}

/// Exclusive scan (prefix "sum" with `op`); returns (scanned, total).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let (ex, total) =
///     dpp::scan_exclusive(&Backend::Serial, &[1u32, 2, 3], 0,
///                         |a, b| a + b);
/// assert_eq!(ex, vec![0, 1, 3]);
/// assert_eq!(total, 6);
/// ```
pub fn scan_exclusive<D, T, F>(
    bk: &D,
    input: &[T],
    identity: T,
    op: F,
) -> (Vec<T>, T)
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Scan", || {
        let bounds = bk.chunk_bounds(input.len());
        let (mut partials, mut offsets, mut out) =
            (Vec::new(), Vec::new(), Vec::new());
        let total = scan_core(bk, input, identity, &op, false, &bounds,
                              &mut partials, &mut offsets, &mut out);
        (out, total)
    })
}

/// Allocation-free [`scan_exclusive`]: the scanned array lands in
/// `out` (cleared and resized), the per-chunk partial/offset scratch
/// comes from the workspace, and the total is returned. Identical
/// chunking and op order to the allocating form — bitwise-identical
/// results for a given device configuration.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut ex = Vec::new();
/// let total = dpp::scan_exclusive_into(
///     &Backend::Serial, &ws, &[1u32, 2, 3], 0, |a, b| a + b, &mut ex);
/// assert_eq!(ex, vec![0, 1, 3]);
/// assert_eq!(total, 6);
/// ```
pub fn scan_exclusive_into<D, T, F>(
    bk: &D,
    ws: &Workspace,
    input: &[T],
    identity: T,
    op: F,
    out: &mut Vec<T>,
) -> T
where
    D: Device + ?Sized,
    T: ScratchElem + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Scan", || {
        let mut bounds = ws.take_spare::<(usize, usize)>(16);
        bk.chunk_bounds_into(input.len(), &mut bounds);
        let mut partials = ws.take_spare::<T>(bounds.len());
        let mut offsets = ws.take_spare::<T>(bounds.len());
        scan_core(bk, input, identity, &op, false, &bounds,
                  &mut partials, &mut offsets, out)
    })
}

/// The one three-pass scan body behind every exclusive/inclusive
/// spelling: per-chunk totals, serial scan of the totals, local scan
/// plus chunk offset. Returns the grand total.
#[allow(clippy::too_many_arguments)]
fn scan_core<D, T, F>(
    bk: &D,
    input: &[T],
    identity: T,
    op: &F,
    inclusive: bool,
    bounds: &[(usize, usize)],
    partials: &mut Vec<T>,
    offsets: &mut Vec<T>,
    out: &mut Vec<T>,
) -> T
where
    D: Device + ?Sized,
    T: Copy + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    let n = input.len();
    // Pass 1: per-chunk totals.
    partials.clear();
    partials.resize(bounds.len(), identity);
    {
        let win = SharedSlice::new(partials);
        bk.for_chunk_ids(bounds.len(), |c| {
            let (s, e) = bounds[c];
            let mut acc = identity;
            for v in &input[s..e] {
                acc = op(acc, *v);
            }
            unsafe { win.write(c, acc) };
        });
    }
    // Serial scan of chunk totals.
    offsets.clear();
    offsets.resize(bounds.len(), identity);
    let mut acc = identity;
    for (c, p) in partials.iter().enumerate() {
        offsets[c] = acc;
        acc = op(acc, *p);
    }
    let total = acc;
    // Pass 2: local scan + chunk offset.
    out.clear();
    out.resize(n, identity);
    {
        let win = SharedSlice::new(out);
        let offsets_ref = &*offsets;
        bk.for_chunk_ids(bounds.len(), |c| {
            let (s, e) = bounds[c];
            let mut acc = offsets_ref[c];
            if inclusive {
                for i in s..e {
                    acc = op(acc, input[i]);
                    unsafe { win.write(i, acc) };
                }
            } else {
                for i in s..e {
                    unsafe { win.write(i, acc) };
                    acc = op(acc, input[i]);
                }
            }
        });
    }
    total
}

/// Inclusive scan; returns the scanned array (last element = total).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let inc = dpp::scan_inclusive(&Backend::Serial, &[1u32, 2, 3], 0,
///                               |a, b| a + b);
/// assert_eq!(inc, vec![1, 3, 6]);
/// ```
pub fn scan_inclusive<D, T, F>(bk: &D, input: &[T], identity: T, op: F)
    -> Vec<T>
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Scan", || {
        let bounds = bk.chunk_bounds(input.len());
        let (mut partials, mut offsets, mut out) =
            (Vec::new(), Vec::new(), Vec::new());
        scan_core(bk, input, identity, &op, true, &bounds,
                  &mut partials, &mut offsets, &mut out);
        out
    })
}

/// Allocation-free [`scan_inclusive`] (see [`scan_exclusive_into`]
/// for the buffer contract); returns the total.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut inc = Vec::new();
/// let total = dpp::scan_inclusive_into(
///     &Backend::Serial, &ws, &[1u32, 2, 3], 0, |a, b| a + b,
///     &mut inc);
/// assert_eq!(inc, vec![1, 3, 6]);
/// assert_eq!(total, 6);
/// ```
pub fn scan_inclusive_into<D, T, F>(
    bk: &D,
    ws: &Workspace,
    input: &[T],
    identity: T,
    op: F,
    out: &mut Vec<T>,
) -> T
where
    D: Device + ?Sized,
    T: ScratchElem + Sync,
    F: Fn(T, T) -> T + Sync,
{
    timed("Scan", || {
        let mut bounds = ws.take_spare::<(usize, usize)>(16);
        bk.chunk_bounds_into(input.len(), &mut bounds);
        let mut partials = ws.take_spare::<T>(bounds.len());
        let mut offsets = ws.take_spare::<T>(bounds.len());
        scan_core(bk, input, identity, &op, true, &bounds,
                  &mut partials, &mut offsets, out)
    })
}

/// Sentinel meaning "no out-of-range index seen" in the cold
/// [`AtomicU64`] Gather/Scatter validity flag.
const NO_BAD_INDEX: u64 = u64::MAX;

/// Raise the pinned out-of-range panic on the calling thread, after
/// the fork-join: workers only *record* the smallest offending index
/// (a cold atomic touched on the failure path alone — no extra pass),
/// because a panic inside a stolen chunk would poison the pool
/// instead of propagating.
fn check_bad_index(bad: &AtomicU64, prim: &str, target: &str, len: usize) {
    let j = bad.load(Ordering::Relaxed);
    assert!(
        j == NO_BAD_INDEX,
        "{prim}: index {j} out of range ({target} len {len})"
    );
}

/// Gather: `out[i] = src[idx[i]]`.
///
/// Contract (pinned by the device conformance suite):
/// `idx.len()` is independent of `src.len()` (an empty `idx` yields
/// an empty output regardless of `src`), and every index must lie in
/// `0..src.len()` — an out-of-range index **panics** on every device.
/// Detection costs no extra pass: chunks record an offending index
/// in a cold atomic and the panic is raised on the calling thread
/// after the fork-join (a panic inside a stolen chunk would poison
/// the pool instead of propagating).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let g = dpp::gather(&Backend::Serial, &[10u32, 20, 30], &[2, 0]);
/// assert_eq!(g, vec![30, 10]);
/// ```
pub fn gather<D, T>(bk: &D, src: &[T], idx: &[u32]) -> Vec<T>
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
{
    let mut out = Vec::new();
    gather_into(bk, src, idx, &mut out);
    out
}

/// Allocation-free [`gather`]: same out-of-range contract, writes
/// into `out` (cleared and resized to `idx.len()`).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut g = ws.take_spare::<u32>(2);
/// dpp::gather_into(&Backend::Serial, &[10u32, 20, 30], &[2, 0],
///                  &mut g);
/// assert_eq!(&g[..], &[30, 10]);
/// ```
pub fn gather_into<D, T>(bk: &D, src: &[T], idx: &[u32], out: &mut Vec<T>)
where
    D: Device + ?Sized,
    T: Copy + Default + Send + Sync,
{
    timed("Gather", || {
        out.clear();
        out.resize(idx.len(), T::default());
        let win = SharedSlice::new(out);
        let bad = AtomicU64::new(NO_BAD_INDEX);
        bk.for_chunks(idx.len(), |s, e| {
            for i in s..e {
                let j = idx[i] as usize;
                if j < src.len() {
                    unsafe { win.write(i, src[j]) };
                } else {
                    bad.fetch_min(j as u64, Ordering::Relaxed);
                }
            }
        });
        check_bad_index(&bad, "gather", "src", src.len());
    })
}

/// Scatter: `out[idx[i]] = src[i]`.
///
/// Contract (same as VTK-m's ScatterPermutation, pinned by the device
/// conformance suite): `idx.len()` must equal `src.len()` (mismatch
/// **panics**), every index must lie in `0..out.len()` (out-of-range
/// **panics** on every device, raised on the calling thread after
/// the fork-join so it never poisons a pool worker), and `idx`
/// contains no duplicates — each output location is written at most
/// once. An empty `idx` is a no-op: `out` is untouched.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut out = vec![0u32; 3];
/// dpp::scatter(&Backend::Serial, &[7u32, 8], &[2, 0], &mut out);
/// assert_eq!(out, vec![8, 0, 7]);
/// ```
pub fn scatter<D, T>(bk: &D, src: &[T], idx: &[u32], out: &mut [T])
where
    D: Device + ?Sized,
    T: Copy + Send + Sync,
{
    assert_eq!(src.len(), idx.len(), "scatter length mismatch");
    timed("Scatter", || {
        let win = SharedSlice::new(out);
        let bad = AtomicU64::new(NO_BAD_INDEX);
        bk.for_chunks(src.len(), |s, e| {
            for i in s..e {
                let j = idx[i] as usize;
                if j < win.len() {
                    unsafe { win.write(j, src[i]) };
                } else {
                    bad.fetch_min(j as u64, Ordering::Relaxed);
                }
            }
        });
        check_bad_index(&bad, "scatter", "out", win.len());
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 64),
        ]
    }

    #[test]
    fn map_square() {
        for bk in backends() {
            let xs: Vec<u32> = (0..10_000).collect();
            let ys = map(&bk, &xs, |x| x * x);
            assert!(ys.iter().enumerate().all(|(i, &y)| y == (i * i) as u32));
        }
    }

    #[test]
    fn map_in_place_matches_map() {
        for bk in backends() {
            let mut xs: Vec<u32> = (0..5_000).collect();
            map_in_place(&bk, &mut xs, |i, x| x + i as u32);
            assert!(xs.iter().enumerate().all(|(i, &x)| x == 2 * i as u32));
        }
    }

    #[test]
    fn reduce_sum_and_min() {
        for bk in backends() {
            let xs: Vec<u64> = (1..=10_000).collect();
            assert_eq!(reduce(&bk, &xs, 0u64, |a, b| a + b), 50_005_000);
            assert_eq!(reduce(&bk, &xs, u64::MAX, |a, b| a.min(b)), 1);
        }
    }

    #[test]
    fn scans_match_serial_oracle() {
        for bk in backends() {
            let xs: Vec<u32> = (0..4_321).map(|i| i % 7).collect();
            let (ex, total) = scan_exclusive(&bk, &xs, 0u32, |a, b| a + b);
            let inc = scan_inclusive(&bk, &xs, 0u32, |a, b| a + b);
            let mut acc = 0;
            for i in 0..xs.len() {
                assert_eq!(ex[i], acc, "exclusive @{i}");
                acc += xs[i];
                assert_eq!(inc[i], acc, "inclusive @{i}");
            }
            assert_eq!(total, acc);
        }
    }

    #[test]
    fn scan_empty() {
        for bk in backends() {
            let (ex, total) = scan_exclusive(&bk, &[] as &[u32], 0, |a, b| {
                a + b
            });
            assert!(ex.is_empty());
            assert_eq!(total, 0);
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        for bk in backends() {
            let src: Vec<u32> = (0..1000).map(|i| i * 3).collect();
            let idx: Vec<u32> = (0..1000).rev().collect();
            let g = gather(&bk, &src, &idx);
            assert_eq!(g[0], 999 * 3);
            let mut out = vec![0u32; 1000];
            scatter(&bk, &g, &idx, &mut out);
            assert_eq!(out, src);
        }
    }

    #[test]
    fn iota_counts() {
        for bk in backends() {
            assert_eq!(iota(&bk, 5), vec![0, 1, 2, 3, 4]);
        }
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        for bk in backends() {
            let ws = Workspace::new();
            let xs: Vec<u32> = (0..9_999).map(|i| i % 321).collect();
            let idx: Vec<u32> = (0..9_999).rev().collect();
            for _round in 0..2 {
                let mut m = ws.take_spare::<u32>(xs.len());
                map_into(&bk, &xs, |x| x.wrapping_mul(7), &mut m);
                assert_eq!(&m[..], &map(&bk, &xs, |x| x.wrapping_mul(7))[..]);

                let mut mi = ws.take_spare::<u32>(xs.len());
                map_indexed_into(&bk, xs.len(), |i| i as u32 ^ 5, &mut mi);
                assert_eq!(&mi[..],
                           &map_indexed(&bk, xs.len(), |i| i as u32 ^ 5)[..]);

                let mut z = ws.take_spare::<u32>(xs.len());
                zip_map_into(&bk, &xs, &idx, |a, b| a + b, &mut z);
                assert_eq!(&z[..], &zip_map(&bk, &xs, &idx, |a, b| a + b)[..]);

                let mut io = ws.take_spare::<u32>(xs.len());
                iota_into(&bk, xs.len(), &mut io);
                assert_eq!(&io[..], &iota(&bk, xs.len())[..]);

                let mut g = ws.take_spare::<u32>(idx.len());
                gather_into(&bk, &xs, &idx, &mut g);
                assert_eq!(&g[..], &gather(&bk, &xs, &idx)[..]);

                let mut ex = ws.take_spare::<u32>(xs.len());
                let t = scan_exclusive_into(&bk, &ws, &xs, 0,
                                            |a, b| a + b, &mut ex);
                let (want_ex, want_t) =
                    scan_exclusive(&bk, &xs, 0, |a, b| a + b);
                assert_eq!((&ex[..], t), (&want_ex[..], want_t));

                let mut inc = ws.take_spare::<u32>(xs.len());
                scan_inclusive_into(&bk, &ws, &xs, 0, |a, b| a + b,
                                    &mut inc);
                assert_eq!(&inc[..],
                           &scan_inclusive(&bk, &xs, 0, |a, b| a + b)[..]);

                assert_eq!(
                    reduce_ws(&bk, &ws, &xs, 0u32, |a, b| a.wrapping_add(b)),
                    reduce(&bk, &xs, 0u32, |a, b| a.wrapping_add(b))
                );
            }
        }
    }

    #[test]
    fn into_variants_reach_steady_state_reuse() {
        for bk in backends() {
            let ws = Workspace::new();
            let xs: Vec<u32> = (0..5_000).collect();
            let one_round = || {
                let mut m = ws.take_spare::<u32>(xs.len());
                map_into(&bk, &xs, |x| x + 1, &mut m);
                let mut ex = ws.take_spare::<u32>(xs.len());
                scan_exclusive_into(&bk, &ws, &xs, 0, |a, b| a + b,
                                    &mut ex);
                reduce_ws(&bk, &ws, &xs, 0u32, |a, b| a.wrapping_add(b));
            };
            one_round();
            let warm = ws.stats();
            for _ in 0..5 {
                one_round();
            }
            let now = ws.stats();
            assert_eq!(now.misses, warm.misses,
                       "steady state allocates nothing ({bk:?})");
            assert!(now.hits > warm.hits);
        }
    }

    // --- gather/scatter edge semantics (pinned for the device
    // conformance contract) ---

    #[test]
    fn gather_empty_idx_yields_empty_for_any_src() {
        for bk in backends() {
            assert_eq!(gather(&bk, &[1u32, 2, 3], &[]), Vec::<u32>::new());
            assert_eq!(gather(&bk, &[] as &[u32], &[]), Vec::<u32>::new());
        }
    }

    #[test]
    fn gather_idx_len_independent_of_src_len() {
        for bk in backends() {
            // More gathers than sources (with repeats) is legal.
            let g = gather(&bk, &[10u32, 20], &[0, 1, 0, 1, 1]);
            assert_eq!(g, vec![10, 20, 10, 20, 20]);
        }
    }

    #[test]
    #[should_panic(expected = "gather: index 3 out of range")]
    fn gather_out_of_range_panics() {
        use crate::dpp::SerialDevice;
        gather(&SerialDevice, &[1u32, 2, 3], &[0, 3]);
    }

    #[test]
    fn scatter_empty_is_a_noop() {
        for bk in backends() {
            let mut out = vec![7u32, 8, 9];
            scatter(&bk, &[] as &[u32], &[], &mut out);
            assert_eq!(out, vec![7, 8, 9]);
        }
    }

    #[test]
    #[should_panic(expected = "scatter length mismatch")]
    fn scatter_length_mismatch_panics() {
        use crate::dpp::SerialDevice;
        let mut out = vec![0u32; 4];
        scatter(&SerialDevice, &[1u32, 2, 3], &[0, 1], &mut out);
    }

    #[test]
    #[should_panic(expected = "scatter: index 4 out of range")]
    fn scatter_out_of_range_panics() {
        use crate::dpp::SerialDevice;
        let mut out = vec![0u32; 4];
        scatter(&SerialDevice, &[1u32, 2], &[0, 4], &mut out);
    }

    // The pinned panic must also hold on pool devices — raised on the
    // calling thread after the fork-join, never inside a worker
    // (which would poison the pool and hang instead of panicking).

    #[test]
    #[should_panic(expected = "gather: index 9 out of range")]
    fn gather_out_of_range_panics_on_pool_device() {
        use crate::dpp::PoolDevice;
        let idx: Vec<u32> =
            (0..1000).map(|i| if i == 777 { 9 } else { 0 }).collect();
        gather(&PoolDevice::new(4, 64), &[1u32, 2, 3], &idx);
    }

    #[test]
    #[should_panic(expected = "scatter: index 2000 out of range")]
    fn scatter_out_of_range_panics_on_pool_device() {
        use crate::dpp::PoolDevice;
        let src = vec![1u32; 1000];
        // Distinct indices (the no-duplicates contract) with one
        // out-of-range entry.
        let idx: Vec<u32> =
            (0..1000).map(|i| if i == 500 { 2000 } else { i }).collect();
        let mut out = vec![0u32; 1000];
        scatter(&PoolDevice::new(4, 64), &src, &idx, &mut out);
    }
}
