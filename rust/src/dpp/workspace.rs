//! The workspace layer: a typed, size-bucketed scratch-buffer pool
//! that makes the steady-state hot loops allocation-free (DESIGN.md
//! §10).
//!
//! The paper's per-DPP breakdown (§4.3.2) already shows SortByKey and
//! ReduceByKey dominating at scale; on top of them this port used to
//! pay a hidden tax: every primitive call returned a fresh `Vec`, so
//! each EM/MAP iteration churned large short-lived heap blocks. GPU BP
//! implementations avoid exactly this by preallocating message and
//! workspace buffers once per run — the [`Workspace`] is the host-side
//! equivalent, and the shape the ROADMAP's GPU `Device` slot will
//! need (device buffer reuse is not optional there).
//!
//! Model:
//!
//! * A [`Workspace`] owns shelves of parked buffers, bucketed by
//!   `(element type, power-of-two capacity)`. [`Workspace::take`]
//!   pops a buffer whose capacity covers the request (scanning larger
//!   shelves before allocating) and hands it out as a
//!   [`ScratchVec<T>`] guard; dropping the guard parks the storage
//!   back on its shelf. After one warm-up pass every take is a
//!   **reuse hit** — the steady state allocates nothing.
//! * One workspace per engine/lane. The pool is internally
//!   synchronized (a small uncontended mutex), so a `Workspace` is
//!   `Send + Sync`, but the intended topology is one per optimize
//!   lane / engine — sharded runs then never contend
//!   ([`crate::sched`]).
//! * Counters — reuse hits, misses, and the high-water byte mark —
//!   are **first-class telemetry counters**: each take routes its
//!   byte volume through [`crate::telemetry::counter`]
//!   (`Workspace::hit` / `Workspace::miss`) and
//!   [`Workspace::publish_timing`] publishes the high-water and
//!   resident marks through [`crate::telemetry::gauge_max`]. With a
//!   scoped [`crate::telemetry::Recorder`] installed they land in its
//!   counter/gauge tables; with only global profiling enabled they
//!   fall back to the legacy `dpp::timing` rows, which
//!   [`crate::dpp::timing::report`] still lists separately as bytes,
//!   excluded from the time total, so the per-DPP breakdown's share
//!   column stays a pure compute-time ratio. They are always
//!   available via [`Workspace::stats`] regardless of telemetry
//!   state.
//!
//! Bitwise identity: a taken buffer is length-set and value-filled
//! exactly like the `vec![fill; n]` the allocating primitives build,
//! so the `_into` code paths in [`crate::dpp`] produce byte-identical
//! results to their allocating wrappers (pinned by
//! `tests/workspace_reuse.rs` and `tests/device_conformance.rs`).

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::timing;

/// Element types a [`Workspace`] can pool: plain copyable data with a
/// default fill value. Blanket-implemented — every scalar and small
/// POD struct in this crate (u8..u64, f32/f64, `(usize, usize)`
/// chunk bounds, parameter `Stats`) qualifies automatically.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::Workspace;
/// // (usize, usize) chunk-bound pairs pool like any scalar.
/// let ws = Workspace::new();
/// let b = ws.take::<(usize, usize)>(4);
/// assert_eq!(b.len(), 4);
/// ```
pub trait ScratchElem: Copy + Default + Send + 'static {}

impl<T: Copy + Default + Send + 'static> ScratchElem for T {}

/// Shelf index a request of `n` elements draws from (capacity
/// `2^shelf >= n`).
fn shelf_up(n: usize) -> u32 {
    n.max(1).next_power_of_two().trailing_zeros()
}

/// Shelf index a buffer of capacity `cap` parks on (`2^shelf <= cap`,
/// so every buffer on shelf `s` serves any request with
/// `shelf_up(n) <= s`).
fn shelf_down(cap: usize) -> u32 {
    usize::BITS - 1 - cap.max(1).leading_zeros()
}

/// The shared pool state behind a [`Workspace`] and every guard it
/// hands out.
struct Shelves {
    /// Parked buffers by `(element type, log2 capacity)`. Boxed as
    /// `dyn Any` so one map holds every element type; the `TypeId`
    /// key makes the downcast on take infallible.
    racks: Mutex<HashMap<(TypeId, u32), Vec<Box<dyn Any + Send>>>>,
    /// Highest shelf index any buffer ever parked on — bounds the
    /// take-side scan.
    max_shelf: AtomicU32,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Bytes currently parked on shelves.
    resident_bytes: AtomicUsize,
    /// Bytes currently out with live guards.
    outstanding_bytes: AtomicUsize,
    /// Max of resident + outstanding ever observed.
    high_water_bytes: AtomicUsize,
}

impl Shelves {
    fn lock(
        &self,
    ) -> std::sync::MutexGuard<'_, HashMap<(TypeId, u32), Vec<Box<dyn Any + Send>>>>
    {
        // A panic while parked buffers were mid-push cannot corrupt
        // the map (push is the last step), so poisoned locks recover.
        self.racks.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn note_high_water(&self) {
        let total = self.resident_bytes.load(Ordering::Relaxed)
            + self.outstanding_bytes.load(Ordering::Relaxed);
        self.high_water_bytes.fetch_max(total, Ordering::Relaxed);
    }

    /// Park `buf` back on its capacity shelf (guard drop path).
    fn park<T: ScratchElem>(&self, mut buf: Box<Vec<T>>, charged: usize) {
        buf.clear();
        let bytes = buf.capacity() * std::mem::size_of::<T>();
        let shelf = shelf_down(buf.capacity());
        self.outstanding_bytes.fetch_sub(charged, Ordering::Relaxed);
        self.resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.max_shelf.fetch_max(shelf, Ordering::Relaxed);
        let mut racks = self.lock();
        racks
            .entry((TypeId::of::<T>(), shelf))
            .or_default()
            // Unsizing coercion Box<Vec<T>> -> Box<dyn Any>: no
            // reallocation, so the steady-state park is free.
            .push(buf as Box<dyn Any + Send>);
    }
}

/// Counter snapshot of a [`Workspace`] ([`Workspace::stats`]).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::Workspace;
/// let ws = Workspace::new();
/// drop(ws.take::<u64>(10));
/// drop(ws.take::<u64>(10)); // second take reuses the first buffer
/// let s = ws.stats();
/// assert_eq!((s.misses, s.hits), (1, 1));
/// assert_eq!(s.hit_rate(), 0.5);
/// assert!(s.high_water_bytes >= 10 * 8);
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Takes served from a parked buffer (no allocation).
    pub hits: u64,
    /// Takes that had to allocate a fresh buffer.
    pub misses: u64,
    /// Max bytes ever held (parked + handed out) at once.
    pub high_water_bytes: usize,
    /// Bytes currently parked on shelves.
    pub resident_bytes: usize,
    /// Bytes currently out with live [`ScratchVec`] guards.
    pub outstanding_bytes: usize,
}

impl WorkspaceStats {
    /// Fraction of takes served without allocating (1.0 once warm).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::WorkspaceStats;
    /// let s = WorkspaceStats { hits: 3, misses: 1,
    ///                          ..Default::default() };
    /// assert_eq!(s.hit_rate(), 0.75);
    /// assert_eq!(WorkspaceStats::default().hit_rate(), 0.0);
    /// ```
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Typed, size-bucketed scratch-buffer pool (see the module docs).
/// Hold one per engine / scheduler lane for the whole run; every
/// steady-state [`Workspace::take`] is then a reuse hit and the hot
/// loops allocate nothing.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::Workspace;
///
/// let ws = Workspace::new();
/// {
///     let mut buf = ws.take::<u32>(100); // miss: fresh allocation
///     buf[0] = 7;
/// } // guard drop parks the storage back on its shelf
/// let again = ws.take::<u32>(100); // hit: same storage, re-zeroed
/// assert_eq!(again.len(), 100);
/// assert_eq!(again[0], 0);
/// assert_eq!(ws.stats().hits, 1);
/// assert_eq!(ws.stats().misses, 1);
/// ```
pub struct Workspace {
    inner: Arc<Shelves>,
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "Workspace(hits={}, misses={}, high_water={}B)",
            s.hits, s.misses, s.high_water_bytes
        )
    }
}

impl Workspace {
    /// Empty pool; buffers accrete on first use (the warm-up pass).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Workspace;
    /// let ws = Workspace::new();
    /// assert_eq!(ws.stats().hits + ws.stats().misses, 0);
    /// ```
    pub fn new() -> Workspace {
        Workspace {
            inner: Arc::new(Shelves {
                racks: Mutex::new(HashMap::new()),
                max_shelf: AtomicU32::new(0),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                resident_bytes: AtomicUsize::new(0),
                outstanding_bytes: AtomicUsize::new(0),
                high_water_bytes: AtomicUsize::new(0),
            }),
        }
    }

    /// A buffer of length `n`, every slot set to `T::default()` —
    /// byte-identical to `vec![T::default(); n]`, served from the
    /// pool when a large-enough buffer is parked.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Workspace;
    /// let ws = Workspace::new();
    /// let zs = ws.take::<f32>(5);
    /// assert_eq!(&zs[..], &[0.0; 5]);
    /// ```
    pub fn take<T: ScratchElem>(&self, n: usize) -> ScratchVec<T> {
        self.take_filled(n, T::default())
    }

    /// [`Workspace::take`] with an explicit fill value — the pooled
    /// spelling of `vec![fill; n]` (reductions seed with their
    /// identity this way).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Workspace;
    /// let ws = Workspace::new();
    /// let ones = ws.take_filled::<u32>(3, u32::MAX);
    /// assert_eq!(&ones[..], &[u32::MAX; 3]);
    /// ```
    pub fn take_filled<T: ScratchElem>(&self, n: usize, fill: T)
        -> ScratchVec<T> {
        let mut sv = self.take_spare::<T>(n);
        sv.resize(n, fill);
        sv
    }

    /// An **empty** buffer (`len == 0`) with capacity at least `cap`
    /// — for callers that size the buffer themselves (`_into`
    /// primitives resize it; `extend`/`push` fills stay within
    /// capacity once warm).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Workspace;
    /// let ws = Workspace::new();
    /// let mut sp = ws.take_spare::<u8>(16);
    /// assert!(sp.is_empty() && sp.capacity() >= 16);
    /// sp.extend_from_slice(b"abc");
    /// assert_eq!(&sp[..], b"abc");
    /// ```
    pub fn take_spare<T: ScratchElem>(&self, cap: usize) -> ScratchVec<T> {
        let (buf, hit) = self.acquire::<T>(cap);
        let charged = buf.capacity() * std::mem::size_of::<T>();
        if timing::recording() {
            crate::telemetry::counter(
                if hit { "Workspace::hit" } else { "Workspace::miss" },
                charged as u64,
            );
        }
        ScratchVec { buf: Some(buf), charged, home: Arc::clone(&self.inner) }
    }

    /// Pop a parked buffer with capacity >= `min_cap` (scanning the
    /// exact shelf and then every larger one), or allocate fresh at
    /// the next power of two. Returns (buffer, was-a-hit).
    fn acquire<T: ScratchElem>(&self, min_cap: usize)
        -> (Box<Vec<T>>, bool) {
        let want = shelf_up(min_cap);
        let top = self.inner.max_shelf.load(Ordering::Relaxed).max(want);
        {
            let mut racks = self.inner.lock();
            for shelf in want..=top {
                let Some(stack) =
                    racks.get_mut(&(TypeId::of::<T>(), shelf))
                else {
                    continue;
                };
                let Some(parked) = stack.pop() else { continue };
                drop(racks);
                let buf = parked
                    .downcast::<Vec<T>>()
                    .expect("shelf keyed by TypeId holds only Vec<T>");
                let bytes = buf.capacity() * std::mem::size_of::<T>();
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                self.inner
                    .resident_bytes
                    .fetch_sub(bytes, Ordering::Relaxed);
                self.inner
                    .outstanding_bytes
                    .fetch_add(bytes, Ordering::Relaxed);
                self.inner.note_high_water();
                return (buf, true);
            }
        }
        let cap = min_cap.max(1).next_power_of_two();
        let buf = Box::new(Vec::with_capacity(cap));
        let bytes = cap * std::mem::size_of::<T>();
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        self.inner.outstanding_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.inner.note_high_water();
        (buf, false)
    }

    /// Snapshot the pool counters.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Workspace;
    /// let ws = Workspace::new();
    /// let g = ws.take::<u16>(8);
    /// assert_eq!(ws.stats().outstanding_bytes, 16);
    /// drop(g);
    /// assert_eq!(ws.stats().outstanding_bytes, 0);
    /// assert_eq!(ws.stats().resident_bytes, 16);
    /// ```
    pub fn stats(&self) -> WorkspaceStats {
        WorkspaceStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            high_water_bytes: self
                .inner
                .high_water_bytes
                .load(Ordering::Relaxed),
            resident_bytes: self
                .inner
                .resident_bytes
                .load(Ordering::Relaxed),
            outstanding_bytes: self
                .inner
                .outstanding_bytes
                .load(Ordering::Relaxed),
        }
    }

    /// Publish the pool's high-water and resident byte marks as
    /// telemetry gauges (`Workspace::high_water_bytes` /
    /// `Workspace::resident_bytes`) — engines call this at the end of
    /// a profiled run so the per-DPP breakdown also shows scratch
    /// memory footprint. Routed through
    /// [`crate::telemetry::gauge_max`]: a scoped recorder takes them
    /// as gauges; plain global profiling gets the legacy byte rows.
    /// No-op when no telemetry sink is active.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{timing, Workspace};
    /// let ws = Workspace::new();
    /// ws.publish_timing(); // telemetry off: records nothing
    /// assert!(timing::snapshot()
    ///     .get("Workspace::high_water_bytes")
    ///     .is_none());
    /// ```
    pub fn publish_timing(&self) {
        if timing::recording() {
            let s = self.stats();
            crate::telemetry::gauge_max(
                "Workspace::high_water_bytes",
                s.high_water_bytes as u64,
            );
            crate::telemetry::gauge_max(
                "Workspace::resident_bytes",
                s.resident_bytes as u64,
            );
        }
    }
}

/// A pooled buffer on loan from a [`Workspace`]: behaves as a
/// `Vec<T>` (through `Deref`/`DerefMut`) and parks its storage back
/// on the pool's shelf when dropped. Growing past the granted
/// capacity is allowed — the enlarged storage simply parks on a
/// higher shelf.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::Workspace;
/// let ws = Workspace::new();
/// let mut v = ws.take::<u64>(4);
/// v[1] = 9;
/// v.push(10); // full Vec API via DerefMut
/// assert_eq!(&v[..], &[0, 9, 0, 0, 10]);
/// ```
pub struct ScratchVec<T: ScratchElem> {
    /// `Some` until the drop path takes it; boxed so the round trip
    /// through the shelf's `Box<dyn Any>` never reallocates.
    buf: Option<Box<Vec<T>>>,
    /// Bytes charged to `outstanding` at take time (credited back on
    /// park even if the buffer was grown meanwhile).
    charged: usize,
    home: Arc<Shelves>,
}

impl<T: ScratchElem> Deref for ScratchVec<T> {
    type Target = Vec<T>;

    fn deref(&self) -> &Vec<T> {
        self.buf.as_ref().expect("buffer present until drop")
    }
}

impl<T: ScratchElem> DerefMut for ScratchVec<T> {
    fn deref_mut(&mut self) -> &mut Vec<T> {
        self.buf.as_mut().expect("buffer present until drop")
    }
}

impl<T: ScratchElem> Drop for ScratchVec<T> {
    fn drop(&mut self) {
        if let Some(buf) = self.buf.take() {
            self.home.park(buf, self.charged);
        }
    }
}

impl<T: ScratchElem + std::fmt::Debug> std::fmt::Debug for ScratchVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ScratchVec({:?})", &self[..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_fills_like_vec_macro() {
        let ws = Workspace::new();
        let a = ws.take::<u32>(1000);
        assert_eq!(&a[..], &vec![0u32; 1000][..]);
        let b = ws.take_filled::<f32>(7, -1.5);
        assert_eq!(&b[..], &vec![-1.5f32; 7][..]);
    }

    #[test]
    fn reuse_hits_after_warmup_across_types_and_sizes() {
        let ws = Workspace::new();
        // Warm-up: one take per (type, size class).
        drop(ws.take::<u32>(100));
        drop(ws.take::<u64>(100));
        drop(ws.take::<f32>(1000));
        let warm = ws.stats();
        assert_eq!(warm.misses, 3);
        // Steady state: every take (same or smaller size) hits.
        for _ in 0..10 {
            drop(ws.take::<u32>(100));
            drop(ws.take::<u64>(64)); // smaller: served by same shelf
            drop(ws.take::<f32>(777));
        }
        let s = ws.stats();
        assert_eq!(s.misses, warm.misses, "no steady-state allocations");
        assert_eq!(s.hits, warm.hits + 30);
    }

    #[test]
    fn larger_shelves_serve_smaller_requests() {
        let ws = Workspace::new();
        drop(ws.take::<u8>(4096));
        let g = ws.take::<u8>(3); // 4096-cap buffer covers it
        assert_eq!(ws.stats().misses, 1);
        assert_eq!(ws.stats().hits, 1);
        assert!(g.capacity() >= 4096);
    }

    #[test]
    fn grown_buffers_park_on_higher_shelf_and_still_hit() {
        let ws = Workspace::new();
        {
            let mut sp = ws.take_spare::<u32>(8);
            sp.resize(5000, 0); // grows well past the granted 8
        }
        // The grown storage is found by the upward shelf scan.
        let g = ws.take::<u32>(8);
        assert!(g.capacity() >= 5000);
        assert_eq!(ws.stats().hits, 1);
        assert_eq!(ws.stats().misses, 1);
    }

    #[test]
    fn byte_accounting_balances() {
        let ws = Workspace::new();
        let a = ws.take::<u64>(100); // cap rounds to 128 -> 1024 B
        let s = ws.stats();
        assert_eq!(s.outstanding_bytes, 1024);
        assert_eq!(s.resident_bytes, 0);
        drop(a);
        let s = ws.stats();
        assert_eq!(s.outstanding_bytes, 0);
        assert_eq!(s.resident_bytes, 1024);
        assert_eq!(s.high_water_bytes, 1024);
        // Two live guards push the high-water mark up.
        let _a = ws.take::<u64>(100);
        let _b = ws.take::<u64>(100);
        assert_eq!(ws.stats().high_water_bytes, 2048);
    }

    #[test]
    fn distinct_types_never_share_buffers() {
        let ws = Workspace::new();
        drop(ws.take::<u32>(64));
        drop(ws.take::<f32>(64)); // same size, different TypeId: miss
        assert_eq!(ws.stats().misses, 2);
    }

    #[test]
    fn workspace_is_send_sync_and_guards_follow_element() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Workspace>();
        assert_send_sync::<ScratchVec<u32>>();
        // Concurrent takes from one pool stay consistent.
        let ws = Workspace::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let ws = &ws;
                s.spawn(move || {
                    for _ in 0..100 {
                        let mut b = ws.take::<u64>(256);
                        b[0] = 1;
                    }
                });
            }
        });
        let st = ws.stats();
        assert_eq!(st.hits + st.misses, 400);
        assert_eq!(st.outstanding_bytes, 0);
    }

    #[test]
    fn zero_length_takes_work() {
        let ws = Workspace::new();
        let a = ws.take::<u32>(0);
        assert!(a.is_empty());
        drop(a);
        assert_eq!(ws.stats().misses, 1);
        drop(ws.take::<u32>(0));
        assert_eq!(ws.stats().hits, 1);
    }

    #[test]
    fn shelf_indices_bracket_capacity() {
        assert_eq!(shelf_up(0), 0);
        assert_eq!(shelf_up(1), 0);
        assert_eq!(shelf_up(2), 1);
        assert_eq!(shelf_up(1000), 10);
        assert_eq!(shelf_down(1), 0);
        assert_eq!(shelf_down(1024), 10);
        assert_eq!(shelf_down(1500), 10);
        for n in [1usize, 2, 3, 100, 1024, 4097] {
            assert!(1usize << shelf_up(n) >= n);
            assert!(1usize << shelf_down(n) <= n);
        }
    }
}
