//! The device layer: ONE portable primitive API over serial / pool /
//! accelerator back ends (DESIGN.md §9).
//!
//! The paper's thesis is that expressing the optimization in
//! data-parallel primitives buys *portable performance over hardware
//! architecture* (§2.3: the same primitives run on TBB or Thrust). The
//! [`Device`] trait is where this crate encodes that portability: it
//! owns every execution decision a primitive makes — how an index
//! domain is chunked ([`Device::chunks_dyn`]), where deterministic
//! chunk boundaries come from ([`Device::chunk_bounds`]), and how a
//! fused multi-stage pipeline executes ([`Device::run_stages`]). Every
//! primitive in [`crate::dpp`] is a generic free function over
//! `D: Device + ?Sized`, so engines hold an `Arc<dyn Device>` and are
//! device-agnostic by construction.
//!
//! Registered devices:
//!
//! * [`SerialDevice`] — plain loops on the calling thread; the oracle
//!   every other device's conformance is measured against
//!   (`rust/tests/device_conformance.rs`).
//! * [`PoolDevice`] — chunked/work-stealing execution on the in-tree
//!   [`crate::pool::Pool`] (the TBB stand-in). Wraps exactly the
//!   chunking rules the old `Backend::Threaded` variant used, so
//!   results are bitwise-identical for the same `(threads, grain)`.
//! * [`OfflineAcceleratorDevice`] — the accelerator seat: carries the
//!   XLA/PJRT bucket runtime ([`crate::runtime::EmRuntime`]) when AOT
//!   artifacts are present and degrades to serial host execution when
//!   they are not (the offline stub in `rust/src/runtime/xla.rs` never
//!   loads, so in this build it always reports `offload: false` and
//!   skips gracefully).
//!
//! # Conformance contract
//!
//! Any device added to the registry must pass the conformance suite:
//! for every primitive, **bitwise-identical** outputs to
//! [`SerialDevice`] on empty / single-element / odd-length / large
//! inputs, at every thread count. Exact ops (integers, min/max) must
//! agree on *all* primitives; the one sanctioned exemption is the
//! association order of floating-point global `reduce`/`scan`, which
//! is chunk-ordered per device (exactly the paper's situation — TBB
//! reductions are unordered too). Segmented float reductions are NOT
//! exempt: a [`crate::dpp::SegmentPlan`] reduces each segment serially
//! in cached stable order, so they must match bitwise on every device.
//!
//! The old [`Backend`] enum still works — it implements [`Device`] —
//! but is the deprecated spelling, kept for one release (see the
//! migration table in `README.md`).

use std::path::Path;
use std::sync::Arc;

use crate::pool::Pool;
use crate::runtime::EmRuntime;

use super::pipeline::{run_stages_region, run_stages_serial};
use super::Backend;

/// What a device can do, surfaced into run reports
/// (`RunReport::to_json`) so results are attributable to a hardware
/// path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceCaps {
    /// Executes chunks on more than one thread.
    pub threaded: bool,
    /// Executes [`Device::run_stages`] in one persistent parallel
    /// region (phase barriers) rather than stage-by-stage.
    pub fused_regions: bool,
    /// Carries a loaded accelerator runtime (AOT artifact offload).
    pub offload: bool,
}

impl DeviceCaps {
    /// Capabilities of a serial-execution device.
    pub const fn serial() -> DeviceCaps {
        DeviceCaps { threaded: false, fused_regions: false, offload: false }
    }

    /// Capabilities of a pool-backed device.
    pub const fn pool() -> DeviceCaps {
        DeviceCaps { threaded: true, fused_regions: true, offload: false }
    }

    /// Capabilities of the accelerator seat (`offload` reflects
    /// whether artifacts actually loaded).
    pub const fn accel(offload: bool) -> DeviceCaps {
        DeviceCaps { threaded: false, fused_regions: false, offload }
    }
}

/// One stage of a fused pipeline, as handed to
/// [`Device::run_stages`]: `f(start, end)` over disjoint chunks
/// covering `0..n`, timed under `name`.
pub struct StageSpec<'a> {
    /// Canonical primitive name for [`crate::dpp::timing`].
    pub name: &'static str,
    /// Iteration-domain size.
    pub n: usize,
    /// Explicit chunk grain; `None` = derived from the device.
    pub grain: Option<usize>,
    /// The stage body.
    pub f: &'a (dyn Fn(usize, usize) + Sync),
}

/// A DPP execution device: the object-safe contract every primitive
/// dispatches through. Implementations decide chunking, parallelism,
/// and pipeline fusion; primitives decide *what* runs. See the module
/// docs for the conformance rules an implementation must satisfy.
///
/// The `*_dyn` methods take `&dyn Fn` so the trait stays
/// object-safe; call them through the generic sugar in [`DeviceExt`]
/// (`for_chunks`, `for_chunks_with`, `for_chunk_ids`), which every
/// `D: Device + ?Sized` gets for free.
pub trait Device: Send + Sync + std::fmt::Debug {
    /// Short device name (`"serial"`, `"pool"`, `"accel"`), surfaced
    /// in run reports.
    fn name(&self) -> &'static str;

    /// Worker count (1 for serial-execution devices).
    fn threads(&self) -> usize;

    /// Configured chunk grain; `usize::MAX` for devices that run one
    /// chunk per domain (serial semantics).
    fn grain(&self) -> usize;

    /// Capability flags for reports and dispatch decisions.
    fn caps(&self) -> DeviceCaps;

    /// Run `f(start, end)` over disjoint chunks covering `0..n`.
    fn chunks_dyn(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync));

    /// [`Device::chunks_dyn`] with an explicit grain — used when the
    /// iteration domain is not elements (hoods, vertices).
    fn chunks_with_dyn(
        &self,
        n: usize,
        grain: usize,
        f: &(dyn Fn(usize, usize) + Sync),
    );

    /// Deterministic chunk boundaries used by two-pass primitives
    /// (scan, radix sort). For a given device configuration the
    /// boundaries are a pure function of `n` — this is what every
    /// floating-point association order hangs off, so two devices
    /// with the same `(threads, grain)` produce bitwise-identical
    /// reductions.
    fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)>;

    /// Allocation-free [`Device::chunk_bounds`]: write the same
    /// boundaries into `out` (cleared first; within capacity once the
    /// caller's scratch buffer is warm). The workspace-backed `_into`
    /// primitives route through this so their steady state allocates
    /// nothing; implementations must keep it exactly equal to
    /// `chunk_bounds` (pinned by a unit test below). The default
    /// collects via `chunk_bounds` (one transient allocation) so
    /// out-of-tree devices stay correct unmodified; every in-tree
    /// device overrides it with the shared `split_bounds_into`.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Device, SerialDevice};
    /// let mut out = Vec::new();
    /// SerialDevice.chunk_bounds_into(7, &mut out);
    /// assert_eq!(out, SerialDevice.chunk_bounds(7));
    /// ```
    fn chunk_bounds_into(&self, n: usize, out: &mut Vec<(usize, usize)>) {
        let bounds = self.chunk_bounds(n);
        out.clear();
        out.extend_from_slice(&bounds);
    }

    /// Run `f(chunk_idx)` for each chunk id in parallel.
    fn chunk_ids_dyn(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync));

    /// Execute a fused stage sequence ([`crate::dpp::Pipeline`]):
    /// stage k+1 must observe stage k's writes. The default executes
    /// stages back-to-back on the calling thread; pool devices
    /// override with one persistent region + phase barriers.
    fn run_stages(&self, stages: &[StageSpec<'_>]) {
        run_stages_serial(stages);
    }

    /// The shared thread pool, for callers that need coarse task
    /// parallelism outside the primitive vocabulary (the reference
    /// engine). `None` for devices without one.
    fn pool(&self) -> Option<Arc<Pool>> {
        None
    }

    /// The loaded accelerator runtime, when this device carries one
    /// ([`OfflineAcceleratorDevice`] with artifacts present).
    fn accelerator_runtime(&self) -> Option<Arc<EmRuntime>> {
        None
    }
}

/// Generic sugar over the object-safe [`Device`] hooks so call sites
/// keep passing closures by value. Blanket-implemented for every
/// `D: Device + ?Sized` (including `dyn Device`).
pub trait DeviceExt: Device {
    /// Run `f(start, end)` over `0..n` on this device.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{DeviceExt, SerialDevice};
    /// // Serial: one chunk covering the whole domain.
    /// SerialDevice.for_chunks(5, |s, e| assert_eq!((s, e), (0, 5)));
    /// ```
    fn for_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.chunks_dyn(n, &f);
    }

    /// [`DeviceExt::for_chunks`] with an explicit grain.
    fn for_chunks_with<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        self.chunks_with_dyn(n, grain, &f);
    }

    /// Run `f(chunk_idx)` for each chunk id in parallel.
    fn for_chunk_ids<F>(&self, nchunks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.chunk_ids_dyn(nchunks, &f);
    }
}

impl<D: Device + ?Sized> DeviceExt for D {}

/// Split `0..n` into at most `pieces` contiguous equal-ish bounds —
/// the ONE boundary formula every device (and the legacy [`Backend`])
/// shares, so chunked association orders can never drift apart.
pub(crate) fn split_bounds(n: usize, pieces: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    split_bounds_into(n, pieces, &mut out);
    out
}

/// [`split_bounds`] into a caller-owned buffer — the allocation-free
/// body behind every in-tree [`Device::chunk_bounds_into`] override.
pub(crate) fn split_bounds_into(
    n: usize,
    pieces: usize,
    out: &mut Vec<(usize, usize)>,
) {
    out.clear();
    let per = n.div_ceil(pieces.max(1));
    out.extend(
        (0..pieces.max(1))
            .map(|i| (i * per, ((i + 1) * per).min(n)))
            .filter(|(s, e)| s < e),
    );
}

/// Piece count for a pool device: enough chunks to load every worker,
/// few enough that the serial combine step is negligible.
pub(crate) fn pool_pieces(threads: usize, grain: usize, n: usize) -> usize {
    let by_threads = threads * 4;
    let by_grain = n.div_ceil(grain.max(1));
    by_threads.min(by_grain).max(1)
}

/// Plain loops on the calling thread: the baseline, the conformance
/// oracle, and the device behind `--device serial`.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, SerialDevice};
/// let ys = dpp::map(&SerialDevice, &[1u32, 2, 3], |x| x * 10);
/// assert_eq!(ys, vec![10, 20, 30]);
/// ```
#[derive(Debug, Clone, Copy, Default)]
pub struct SerialDevice;

impl Device for SerialDevice {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn threads(&self) -> usize {
        1
    }

    fn grain(&self) -> usize {
        usize::MAX
    }

    fn caps(&self) -> DeviceCaps {
        DeviceCaps::serial()
    }

    fn chunks_dyn(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n > 0 {
            f(0, n);
        }
    }

    fn chunks_with_dyn(
        &self,
        n: usize,
        _grain: usize,
        f: &(dyn Fn(usize, usize) + Sync),
    ) {
        if n > 0 {
            f(0, n);
        }
    }

    fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)> {
        split_bounds(n, 1)
    }

    fn chunk_bounds_into(&self, n: usize, out: &mut Vec<(usize, usize)>) {
        split_bounds_into(n, 1, out);
    }

    fn chunk_ids_dyn(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        (0..nchunks).for_each(f);
    }
}

/// Chunked + work-stealing execution on a shared [`crate::pool::Pool`]
/// — the TBB stand-in, and the device behind `--device pool`. Chunking
/// rules are shared verbatim with the old `Backend::Threaded` variant
/// (the crate-internal `split_bounds` / `pool_pieces` formulas), so
/// for the same `(threads, grain)` the results are bitwise-identical
/// — the
/// conformance suite and the scheduler's determinism tests both pin
/// this.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, PoolDevice, SerialDevice};
/// let dev = PoolDevice::new(2, 64);
/// let xs: Vec<u32> = (0..1000).collect();
/// let a = dpp::map(&dev, &xs, |x| x + 1);
/// let b = dpp::map(&SerialDevice, &xs, |x| x + 1);
/// assert_eq!(a, b);
/// ```
#[derive(Clone)]
pub struct PoolDevice {
    pool: Arc<Pool>,
    grain: usize,
}

impl PoolDevice {
    /// Fresh pool of `threads` workers at `grain` elements per chunk.
    pub fn new(threads: usize, grain: usize) -> PoolDevice {
        PoolDevice { pool: Pool::new(threads.max(1)), grain }
    }

    /// Wrap an existing pool (benches share one pool per concurrency
    /// level across runs).
    pub fn from_pool(pool: Arc<Pool>, grain: usize) -> PoolDevice {
        PoolDevice { pool, grain }
    }
}

impl std::fmt::Debug for PoolDevice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PoolDevice(threads={}, grain={})",
            self.pool.threads(),
            self.grain
        )
    }
}

impl Device for PoolDevice {
    fn name(&self) -> &'static str {
        "pool"
    }

    fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn grain(&self) -> usize {
        self.grain
    }

    fn caps(&self) -> DeviceCaps {
        DeviceCaps::pool()
    }

    fn chunks_dyn(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        self.pool.parallel_for(n, self.grain, f);
    }

    fn chunks_with_dyn(
        &self,
        n: usize,
        grain: usize,
        f: &(dyn Fn(usize, usize) + Sync),
    ) {
        self.pool.parallel_for(n, grain, f);
    }

    fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)> {
        split_bounds(n, pool_pieces(self.pool.threads(), self.grain, n))
    }

    fn chunk_bounds_into(&self, n: usize, out: &mut Vec<(usize, usize)>) {
        split_bounds_into(
            n,
            pool_pieces(self.pool.threads(), self.grain, n),
            out,
        );
    }

    fn chunk_ids_dyn(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.pool.parallel_tasks(nchunks, f);
    }

    fn run_stages(&self, stages: &[StageSpec<'_>]) {
        run_stages_region(&self.pool, self.grain, stages);
    }

    fn pool(&self) -> Option<Arc<Pool>> {
        Some(Arc::clone(&self.pool))
    }
}

/// The accelerator seat (`--device accel`): primitives execute
/// serially on the host, and the device carries the XLA/PJRT bucket
/// runtime when AOT artifacts load — the identical dispatch path a
/// real GPU/TPU PJRT plugin would serve. When artifacts are absent
/// (or, in this offline build, always — see `rust/src/runtime/xla.rs`)
/// construction still succeeds and the device degrades gracefully:
/// `caps().offload` is `false` and the engines simply stay on the
/// host path.
pub struct OfflineAcceleratorDevice {
    runtime: Option<Arc<EmRuntime>>,
}

impl OfflineAcceleratorDevice {
    /// Probe `dir` for AOT artifacts; never fails — a missing or
    /// unloadable artifact set just yields a host-only device.
    pub fn load(dir: &Path) -> OfflineAcceleratorDevice {
        let runtime = match EmRuntime::load(dir) {
            Ok(rt) => Some(Arc::new(rt)),
            Err(e) => {
                crate::log_debug!(
                    "accel device: artifacts unavailable, host-only ({e})"
                );
                None
            }
        };
        OfflineAcceleratorDevice { runtime }
    }

    /// Wrap an already-loaded runtime (benches share one).
    pub fn with_runtime(rt: Arc<EmRuntime>) -> OfflineAcceleratorDevice {
        OfflineAcceleratorDevice { runtime: Some(rt) }
    }

    /// Whether the accelerator runtime actually loaded.
    pub fn available(&self) -> bool {
        self.runtime.is_some()
    }
}

impl std::fmt::Debug for OfflineAcceleratorDevice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OfflineAcceleratorDevice(offload={})",
            self.runtime.is_some()
        )
    }
}

impl Device for OfflineAcceleratorDevice {
    fn name(&self) -> &'static str {
        "accel"
    }

    fn threads(&self) -> usize {
        1
    }

    fn grain(&self) -> usize {
        usize::MAX
    }

    fn caps(&self) -> DeviceCaps {
        DeviceCaps::accel(self.runtime.is_some())
    }

    fn chunks_dyn(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        SerialDevice.chunks_dyn(n, f);
    }

    fn chunks_with_dyn(
        &self,
        n: usize,
        grain: usize,
        f: &(dyn Fn(usize, usize) + Sync),
    ) {
        SerialDevice.chunks_with_dyn(n, grain, f);
    }

    fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)> {
        SerialDevice.chunk_bounds(n)
    }

    fn chunk_bounds_into(&self, n: usize, out: &mut Vec<(usize, usize)>) {
        SerialDevice.chunk_bounds_into(n, out);
    }

    fn chunk_ids_dyn(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        SerialDevice.chunk_ids_dyn(nchunks, f);
    }

    fn accelerator_runtime(&self) -> Option<Arc<EmRuntime>> {
        self.runtime.clone()
    }
}

// ---------------------------------------------------------------------
// Legacy bridge: the pre-device `Backend` enum is itself a Device, so
// every existing `&Backend` call site coerces to `&dyn Device` and the
// deprecated names keep working for one release.
// ---------------------------------------------------------------------

impl Device for Backend {
    fn name(&self) -> &'static str {
        match self {
            Backend::Serial => "serial",
            Backend::Threaded { .. } => "pool",
        }
    }

    fn threads(&self) -> usize {
        Backend::threads(self)
    }

    fn grain(&self) -> usize {
        Backend::grain(self)
    }

    fn caps(&self) -> DeviceCaps {
        match self {
            Backend::Serial => DeviceCaps::serial(),
            Backend::Threaded { .. } => DeviceCaps::pool(),
        }
    }

    fn chunks_dyn(&self, n: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        Backend::for_chunks(self, n, f);
    }

    fn chunks_with_dyn(
        &self,
        n: usize,
        grain: usize,
        f: &(dyn Fn(usize, usize) + Sync),
    ) {
        Backend::for_chunks_with(self, n, grain, f);
    }

    fn chunk_bounds(&self, n: usize) -> Vec<(usize, usize)> {
        Backend::chunk_bounds(self, n)
    }

    fn chunk_bounds_into(&self, n: usize, out: &mut Vec<(usize, usize)>) {
        let pieces = match self {
            Backend::Serial => 1,
            Backend::Threaded { pool, grain } => {
                pool_pieces(pool.threads(), *grain, n)
            }
        };
        split_bounds_into(n, pieces, out);
    }

    fn chunk_ids_dyn(&self, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
        Backend::for_chunk_ids(self, nchunks, f);
    }

    fn run_stages(&self, stages: &[StageSpec<'_>]) {
        match self {
            Backend::Serial => run_stages_serial(stages),
            Backend::Threaded { pool, grain } => {
                run_stages_region(pool, *grain, stages)
            }
        }
    }

    fn pool(&self) -> Option<Arc<Pool>> {
        match self {
            Backend::Serial => None,
            Backend::Threaded { pool, .. } => Some(Arc::clone(pool)),
        }
    }
}

/// Anything that can become a shared device handle — lets engine
/// constructors accept a [`Backend`] (deprecated spelling), a concrete
/// device, or an `Arc<dyn Device>` interchangeably during the
/// migration window.
pub trait IntoDevice {
    fn into_device(self) -> Arc<dyn Device>;
}

impl IntoDevice for Arc<dyn Device> {
    fn into_device(self) -> Arc<dyn Device> {
        self
    }
}

impl IntoDevice for SerialDevice {
    fn into_device(self) -> Arc<dyn Device> {
        Arc::new(self)
    }
}

impl IntoDevice for PoolDevice {
    fn into_device(self) -> Arc<dyn Device> {
        Arc::new(self)
    }
}

impl IntoDevice for OfflineAcceleratorDevice {
    fn into_device(self) -> Arc<dyn Device> {
        Arc::new(self)
    }
}

impl IntoDevice for Backend {
    /// The legacy-enum bridge: `Serial` becomes a [`SerialDevice`],
    /// `Threaded` a [`PoolDevice`] over the same pool and grain —
    /// chunking (and therefore every association order) is unchanged.
    fn into_device(self) -> Arc<dyn Device> {
        match self {
            Backend::Serial => Arc::new(SerialDevice),
            Backend::Threaded { pool, grain } => {
                Arc::new(PoolDevice::from_pool(pool, grain))
            }
        }
    }
}

/// Which device a run executes its primitives on (`--device`, JSON
/// `"device"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeviceKind {
    /// The historical rule: serial for one thread, pool otherwise.
    #[default]
    Auto,
    /// [`SerialDevice`] regardless of the thread setting.
    Serial,
    /// [`PoolDevice`] with the configured threads and grain.
    Pool,
    /// [`OfflineAcceleratorDevice`] probing the artifacts dir.
    Accel,
}

impl DeviceKind {
    /// Accepted `--device` values, for help text and error messages.
    pub const USAGE: &'static str = "auto|serial|pool|accel";

    pub fn all() -> [DeviceKind; 4] {
        [
            DeviceKind::Auto,
            DeviceKind::Serial,
            DeviceKind::Pool,
            DeviceKind::Accel,
        ]
    }

    pub fn parse(s: &str) -> anyhow::Result<DeviceKind> {
        match s {
            "auto" => Ok(DeviceKind::Auto),
            "serial" => Ok(DeviceKind::Serial),
            "pool" => Ok(DeviceKind::Pool),
            "accel" => Ok(DeviceKind::Accel),
            _ => anyhow::bail!("unknown device `{s}` ({})", Self::USAGE),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Auto => "auto",
            DeviceKind::Serial => "serial",
            DeviceKind::Pool => "pool",
            DeviceKind::Accel => "accel",
        }
    }
}

/// THE construction rule for a run-configured device — the successor
/// of `Backend::for_threads`. Every site that must produce
/// bitwise-identical results for the same configuration — the
/// coordinator and every scheduler worker ([`crate::sched`]) — goes
/// through here, because [`Device::chunk_bounds`] (and with it every
/// floating-point association order) depends on exactly these values.
pub fn device_for(
    kind: DeviceKind,
    threads: usize,
    grain: usize,
    artifacts_dir: &Path,
) -> Arc<dyn Device> {
    match kind {
        DeviceKind::Auto => {
            if threads == 1 {
                Arc::new(SerialDevice)
            } else {
                Arc::new(PoolDevice::new(threads, grain))
            }
        }
        DeviceKind::Serial => Arc::new(SerialDevice),
        DeviceKind::Pool => Arc::new(PoolDevice::new(threads, grain)),
        DeviceKind::Accel => {
            Arc::new(OfflineAcceleratorDevice::load(artifacts_dir))
        }
    }
}

/// Whether [`device_for`] yields a pool-free (stateless,
/// serial-execution) device for this configuration. Pool-free devices
/// are safe to share across scheduler workers — that is how an accel
/// run loads its AOT artifact bundle once per run instead of once per
/// worker. Kept next to [`device_for`] so the two can never disagree
/// on the `Auto` rule (pinned by a unit test below).
pub fn device_is_pool_free(kind: DeviceKind, threads: usize) -> bool {
    match kind {
        DeviceKind::Serial | DeviceKind::Accel => true,
        DeviceKind::Auto => threads == 1,
        DeviceKind::Pool => false,
    }
}

/// Name + capability flags [`device_for`] would yield for this
/// configuration, without spawning a pool — for callers that need to
/// describe a hardware path (e.g. in a report or a dry-run listing)
/// without paying device construction. Note: for `Accel` this probes
/// the artifacts dir, so prefer describing an already-constructed
/// device when one exists.
pub fn device_descriptor(
    kind: DeviceKind,
    threads: usize,
    artifacts_dir: &Path,
) -> (&'static str, DeviceCaps) {
    match kind {
        DeviceKind::Auto => {
            if threads == 1 {
                ("serial", DeviceCaps::serial())
            } else {
                ("pool", DeviceCaps::pool())
            }
        }
        DeviceKind::Serial => ("serial", DeviceCaps::serial()),
        DeviceKind::Pool => ("pool", DeviceCaps::pool()),
        DeviceKind::Accel => {
            let dev = OfflineAcceleratorDevice::load(artifacts_dir);
            ("accel", dev.caps())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_device_single_chunk() {
        let calls = std::sync::atomic::AtomicUsize::new(0);
        SerialDevice.chunks_dyn(7, &|s, e| {
            assert_eq!((s, e), (0, 7));
            calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(calls.load(std::sync::atomic::Ordering::Relaxed), 1);
        SerialDevice.chunks_dyn(0, &|_, _| panic!("no work expected"));
        assert_eq!(SerialDevice.chunk_bounds(7), vec![(0, 7)]);
        assert!(SerialDevice.chunk_bounds(0).is_empty());
    }

    #[test]
    fn chunk_bounds_into_matches_chunk_bounds_on_every_device() {
        let devices: Vec<Box<dyn Device>> = vec![
            Box::new(SerialDevice),
            Box::new(PoolDevice::new(3, 64)),
            Box::new(OfflineAcceleratorDevice::load(Path::new("nope"))),
            Box::new(Backend::Serial),
            Box::new(Backend::threaded_with_grain(Pool::new(2), 1021)),
        ];
        let mut out = Vec::new();
        for dev in &devices {
            for n in [0usize, 1, 7, 1000, 10_000] {
                dev.chunk_bounds_into(n, &mut out);
                assert_eq!(out, dev.chunk_bounds(n),
                           "{} n={n}", dev.name());
            }
        }
    }

    #[test]
    fn pool_device_chunk_bounds_match_legacy_backend() {
        for (threads, grain, n) in
            [(2, 64, 1000), (4, 128, 10_000), (3, 1021, 4_321), (4, 64, 0)]
        {
            let dev = PoolDevice::new(threads, grain);
            let bk = Backend::threaded_with_grain(Pool::new(threads), grain);
            assert_eq!(
                Device::chunk_bounds(&dev, n),
                Backend::chunk_bounds(&bk, n),
                "threads={threads} grain={grain} n={n}"
            );
        }
    }

    #[test]
    fn backend_bridge_preserves_identity() {
        let dev = Backend::Serial.into_device();
        assert_eq!(dev.name(), "serial");
        assert_eq!(dev.threads(), 1);
        let pool = Pool::new(3);
        let dev =
            Backend::threaded_with_grain(Arc::clone(&pool), 77).into_device();
        assert_eq!(dev.name(), "pool");
        assert_eq!(dev.threads(), 3);
        assert_eq!(dev.grain(), 77);
        assert!(dev.pool().is_some());
    }

    #[test]
    fn accel_device_degrades_gracefully() {
        let dev = OfflineAcceleratorDevice::load(Path::new(
            "definitely/not/artifacts",
        ));
        assert!(!dev.available());
        assert!(!dev.caps().offload);
        assert!(dev.accelerator_runtime().is_none());
        // Host execution still works.
        let ys = crate::dpp::map(&dev, &[1u32, 2], |x| x + 1);
        assert_eq!(ys, vec![2, 3]);
    }

    #[test]
    fn device_kind_parse_round_trip() {
        for k in ["auto", "serial", "pool", "accel"] {
            assert_eq!(DeviceKind::parse(k).unwrap().name(), k);
        }
        assert!(DeviceKind::parse("gpu").is_err());
        assert_eq!(DeviceKind::all().len(), 4);
        assert_eq!(DeviceKind::default(), DeviceKind::Auto);
    }

    #[test]
    fn device_for_honors_the_auto_rule() {
        let dir = Path::new("artifacts");
        assert_eq!(device_for(DeviceKind::Auto, 1, 64, dir).name(), "serial");
        assert_eq!(device_for(DeviceKind::Auto, 4, 64, dir).name(), "pool");
        assert_eq!(
            device_for(DeviceKind::Serial, 4, 64, dir).name(),
            "serial"
        );
        assert_eq!(device_for(DeviceKind::Pool, 1, 64, dir).name(), "pool");
        assert_eq!(device_for(DeviceKind::Accel, 4, 64, dir).name(), "accel");
    }

    #[test]
    fn pool_free_rule_matches_construction() {
        let dir = Path::new("definitely/not/artifacts");
        for kind in DeviceKind::all() {
            for threads in [1, 2, 4] {
                assert_eq!(
                    device_is_pool_free(kind, threads),
                    device_for(kind, threads, 64, dir).pool().is_none(),
                    "{kind:?}/{threads}"
                );
            }
        }
    }

    #[test]
    fn descriptor_matches_construction() {
        let dir = Path::new("definitely/not/artifacts");
        for kind in DeviceKind::all() {
            for threads in [1, 4] {
                let (name, caps) = device_descriptor(kind, threads, dir);
                let dev = device_for(kind, threads, 64, dir);
                assert_eq!(name, dev.name(), "{kind:?}/{threads}");
                assert_eq!(caps, dev.caps(), "{kind:?}/{threads}");
            }
        }
    }

    #[test]
    fn ext_trait_works_on_dyn_device() {
        let dev: Arc<dyn Device> = Arc::new(SerialDevice);
        let counter = std::sync::atomic::AtomicUsize::new(0);
        dev.for_chunks(10, |s, e| {
            counter.fetch_add(e - s, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 10);
        dev.for_chunk_ids(3, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 13);
    }
}
