//! Fused DPP pipelines: a sequence of Map/Gather/SegmentedReduce
//! stages executed inside **one** persistent pool parallel region.
//!
//! The paper pays one full fork-join barrier per primitive (§4.1.3's
//! TBB dispatch; our [`crate::pool::Pool::parallel_for`] is the same
//! shape). For the EM/MAP/BP hot loops — a handful of short passes per
//! iteration over static structure — that dispatch overhead is pure
//! loss. A [`Pipeline`] instead enters the pool's persistent region
//! ([`crate::pool::Pool::region`]) once: every worker spins through
//! the stage list, claiming chunks from a shared atomic cursor, and
//! crosses a lightweight [`crate::pool::PhaseBarrier`] between stages.
//! Stage *k*'s writes are visible to stage *k + 1* through the
//! barrier's release/acquire ordering.
//!
//! Per-stage wall time still flows into [`crate::dpp::timing`] under
//! the stage's canonical primitive name, so
//! `benches/per_dpp_breakdown.rs` keeps reproducing the paper's
//! per-DPP breakdown for pipelined engines.
//!
//! Rules for stage closures:
//!
//! * a stage must write only through [`crate::dpp::SharedSlice`]-style
//!   disjoint windows and read only stage-private inputs or buffers
//!   written by *earlier* stages;
//! * a stage must not submit work to the pool (the region holds the
//!   pool for its whole duration) — plain loops only.
//!
//! Determinism: chunk *assignment* to workers is scheduling-dependent,
//! but the chunk set is fixed (`0, g, 2g, ...` for the stage grain
//! `g`), every index is processed exactly once, and all call sites
//! either write independent slots or combine chunk results with exact
//! operations — so pipelined passes produce bitwise-identical results
//! across backends and thread counts whenever their unfused
//! counterparts do.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

use crate::pool::Pool;

use super::device::{Device, StageSpec};
use super::timing;

/// One stage of a [`Pipeline`].
struct Stage<'p> {
    /// Canonical primitive name for [`crate::dpp::timing`].
    name: &'static str,
    /// Iteration-domain size.
    n: usize,
    /// Explicit chunk grain; `None` = derived from the device.
    grain: Option<usize>,
    f: Box<dyn Fn(usize, usize) + Sync + 'p>,
}

/// A fused sequence of data-parallel stages, executed with one pool
/// entry and one phase barrier per stage boundary instead of one
/// fork-join per primitive.
///
/// Build with the consuming [`Pipeline::stage`] chain, then call
/// [`Pipeline::run`] with any [`Device`]. Under a serial device the
/// stages simply run back-to-back on the calling thread (same
/// results, no threads); execution is whatever
/// [`Device::run_stages`] does.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{Backend, Pipeline, SharedSlice};
///
/// let xs: Vec<u32> = (0..1000).collect();
/// let mut doubled = vec![0u32; 1000];
/// let mut total = vec![0u64; 1];
/// let wd = SharedSlice::new(&mut doubled);
/// let wt = SharedSlice::new(&mut total);
/// Pipeline::new()
///     // Stage 1 (Map): doubled[i] = 2 * xs[i].
///     .stage("Map", xs.len(), |s, e| {
///         for i in s..e {
///             unsafe { wd.write(i, 2 * xs[i]) };
///         }
///     })
///     // Stage 2 (Reduce, serial tail): reads what stage 1 wrote —
///     // the phase barrier between stages makes it visible.
///     .serial_stage("Reduce", || {
///         let mut acc = 0u64;
///         for i in 0..1000 {
///             acc += u64::from(unsafe { wd.read(i) });
///         }
///         unsafe { wt.write(0, acc) };
///     })
///     .run(&Backend::Serial);
/// assert_eq!(total[0], 2 * 999 * 1000 / 2);
/// ```
#[derive(Default)]
pub struct Pipeline<'p> {
    stages: Vec<Stage<'p>>,
}

impl<'p> Pipeline<'p> {
    /// Empty pipeline; add work with [`Pipeline::stage`].
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Pipeline;
    /// assert_eq!(Pipeline::new().num_stages(), 0);
    /// ```
    pub fn new() -> Pipeline<'p> {
        Pipeline { stages: Vec::new() }
    }

    /// Append a stage: `f(start, end)` over disjoint chunks covering
    /// `0..n`, with the chunk grain derived from the backend at run
    /// time. `name` is the canonical primitive name the stage's wall
    /// time is recorded under (`"Map"`, `"Gather"`, `"ReduceByKey"`,
    /// ...), keeping the per-DPP breakdown comparable between fused
    /// and unfused execution.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, Pipeline, SharedSlice};
    /// let mut out = vec![0u32; 8];
    /// let w = SharedSlice::new(&mut out);
    /// Pipeline::new()
    ///     .stage("Map", 8, |s, e| {
    ///         for i in s..e {
    ///             unsafe { w.write(i, i as u32) };
    ///         }
    ///     })
    ///     .run(&Backend::Serial);
    /// assert_eq!(out[7], 7);
    /// ```
    pub fn stage<F>(self, name: &'static str, n: usize, f: F) -> Self
    where
        F: Fn(usize, usize) + Sync + 'p,
    {
        self.push(name, n, None, f)
    }

    /// [`Pipeline::stage`] with an explicit chunk grain. Use when the
    /// stage keeps per-chunk partials: chunk starts are then exactly
    /// the multiples of `grain`, so `start / grain` is a stable slot
    /// index into a `ceil(n / grain)`-sized partial array regardless
    /// of which worker claims the chunk.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, Pipeline, SharedSlice};
    /// let n = 10usize;
    /// let grain = 4usize;
    /// let mut partial = vec![0u32; n.div_ceil(grain)];
    /// let w = SharedSlice::new(&mut partial);
    /// Pipeline::new()
    ///     .stage_with_grain("Reduce", n, grain, |s, e| {
    ///         let sum = (s..e).map(|i| i as u32).sum::<u32>();
    ///         unsafe { w.write(s / grain, sum) };
    ///     })
    ///     .run(&Backend::Serial);
    /// // Serial runs one chunk covering everything: slot 0.
    /// assert_eq!(partial.iter().sum::<u32>(), 45);
    /// ```
    pub fn stage_with_grain<F>(
        self,
        name: &'static str,
        n: usize,
        grain: usize,
        f: F,
    ) -> Self
    where
        F: Fn(usize, usize) + Sync + 'p,
    {
        self.push(name, n, Some(grain.max(1)), f)
    }

    /// Append a single-invocation stage — the serial tail between
    /// parallel stages (fold chunk partials, pick a threshold, ...).
    /// Exactly one worker executes `f`; the barriers on both sides
    /// order it against the neighbouring stages.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Backend, Pipeline, SharedSlice};
    /// let mut flag = vec![0u8; 1];
    /// let w = SharedSlice::new(&mut flag);
    /// Pipeline::new()
    ///     .serial_stage("Reduce", || unsafe { w.write(0, 1) })
    ///     .run(&Backend::Serial);
    /// assert_eq!(flag[0], 1);
    /// ```
    pub fn serial_stage<F>(self, name: &'static str, f: F) -> Self
    where
        F: Fn() + Sync + 'p,
    {
        self.push(name, 1, Some(1), move |_, _| f())
    }

    /// Number of stages added so far.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::Pipeline;
    /// let p = Pipeline::new().stage("Map", 4, |_, _| {});
    /// assert_eq!(p.num_stages(), 1);
    /// ```
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    fn push<F>(
        mut self,
        name: &'static str,
        n: usize,
        grain: Option<usize>,
        f: F,
    ) -> Self
    where
        F: Fn(usize, usize) + Sync + 'p,
    {
        self.stages.push(Stage { name, n, grain, f: Box::new(f) });
        self
    }

    /// Execute all stages in order on `dev` (any [`Device`]).
    ///
    /// Serial-execution devices run the stages back-to-back on the
    /// calling thread. [`crate::dpp::PoolDevice`] (and the legacy
    /// `Backend::Threaded`) enter one persistent pool region; workers
    /// claim grain-sized chunks from a shared cursor per stage and
    /// meet at a phase barrier between stages — no fork-join until
    /// the whole pipeline is done. Per-stage wall time (including
    /// barrier wait) is recorded in [`crate::dpp::timing`] when
    /// profiling is enabled.
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::dpp::{Pipeline, PoolDevice, SharedSlice};
    ///
    /// let mut a = vec![0u32; 100];
    /// let mut b = vec![0u32; 100];
    /// let wa = SharedSlice::new(&mut a);
    /// let wb = SharedSlice::new(&mut b);
    /// let dev = PoolDevice::new(2, 16);
    /// Pipeline::new()
    ///     .stage("Map", 100, |s, e| {
    ///         for i in s..e {
    ///             unsafe { wa.write(i, i as u32) };
    ///         }
    ///     })
    ///     .stage("Map", 100, |s, e| {
    ///         for i in s..e {
    ///             let v = unsafe { wa.read(i) };
    ///             unsafe { wb.write(i, v + 1) };
    ///         }
    ///     })
    ///     .run(&dev);
    /// assert!(b.iter().enumerate().all(|(i, &v)| v == i as u32 + 1));
    /// ```
    pub fn run<D: Device + ?Sized>(&self, dev: &D) {
        if self.stages.is_empty() {
            return;
        }
        let specs: Vec<StageSpec<'_>> = self
            .stages
            .iter()
            .map(|st| StageSpec {
                name: st.name,
                n: st.n,
                grain: st.grain,
                f: &*st.f,
            })
            .collect();
        dev.run_stages(&specs);
    }
}

/// Serial stage executor — the default [`Device::run_stages`] body:
/// stages back-to-back on the calling thread, each timed under its
/// canonical primitive name.
pub(crate) fn run_stages_serial(stages: &[StageSpec<'_>]) {
    for st in stages {
        timing::timed(st.name, || {
            if st.n > 0 {
                (st.f)(0, st.n);
            }
        });
    }
}

/// Pool stage executor — one persistent region, a shared atomic chunk
/// cursor per stage, and a phase barrier at every stage boundary.
/// Used by [`crate::dpp::PoolDevice`] and the legacy
/// `Backend::Threaded` variant.
pub(crate) fn run_stages_region(
    pool: &Pool,
    backend_grain: usize,
    stages: &[StageSpec<'_>],
) {
    let workers = pool.threads();
    let grains: Vec<usize> = stages
        .iter()
        .map(|st| {
            st.grain
                .unwrap_or_else(|| auto_grain(st.n, workers, backend_grain))
        })
        .collect();
    let cursors: Vec<AtomicUsize> =
        stages.iter().map(|_| AtomicUsize::new(0)).collect();
    // `recording()` (not `enabled()`): a scoped telemetry recorder on
    // the calling thread must capture stage rows too — the records
    // below happen after the region, on the caller.
    let profile = timing::recording();
    let tracing = crate::telemetry::tracing();
    let nanos: Vec<AtomicU64> =
        stages.iter().map(|_| AtomicU64::new(0)).collect();
    // Stage start offsets from `t_region`, for trace spans. Worker 0
    // measures; the caller reconstructs the `Instant` afterwards.
    let starts: Vec<AtomicU64> =
        stages.iter().map(|_| AtomicU64::new(0)).collect();
    let t_region = Instant::now();
    pool.region(|w, barrier| {
        for (si, st) in stages.iter().enumerate() {
            let t0 = if (profile || tracing) && w == 0 {
                Some(Instant::now())
            } else {
                None
            };
            let g = grains[si];
            loop {
                let s = cursors[si].fetch_add(g, Ordering::Relaxed);
                if s >= st.n {
                    break;
                }
                (st.f)(s, (s + g).min(st.n));
            }
            barrier.wait();
            if let Some(t) = t0 {
                nanos[si].store(
                    t.elapsed().as_nanos() as u64,
                    Ordering::Relaxed,
                );
                starts[si].store(
                    t.duration_since(t_region).as_nanos() as u64,
                    Ordering::Relaxed,
                );
            }
        }
    });
    if profile {
        for (si, st) in stages.iter().enumerate() {
            timing::record(st.name, nanos[si].load(Ordering::Relaxed));
        }
    }
    if tracing {
        for (si, st) in stages.iter().enumerate() {
            let start = t_region
                + std::time::Duration::from_nanos(
                    starts[si].load(Ordering::Relaxed),
                );
            crate::telemetry::emit_span(
                "stage",
                st.name,
                start,
                nanos[si].load(Ordering::Relaxed),
            );
        }
    }
}

/// Stage grain when the caller did not pin one: enough chunks to load
/// every worker several times over (dynamic balance), capped at the
/// backend's configured grain (cache-friendly chunk cost).
fn auto_grain(n: usize, workers: usize, backend_grain: usize) -> usize {
    n.div_ceil(workers.max(1) * 8).clamp(1, backend_grain.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::core::SharedSlice;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 64),
        ]
    }

    #[test]
    fn stages_chain_with_dependencies() {
        for bk in backends() {
            let n = 10_000usize;
            let mut a = vec![0u64; n];
            let mut b = vec![0u64; n];
            let mut total = vec![0u64; 1];
            let wa = SharedSlice::new(&mut a);
            let wb = SharedSlice::new(&mut b);
            let wt = SharedSlice::new(&mut total);
            Pipeline::new()
                .stage("Map", n, |s, e| {
                    for i in s..e {
                        unsafe { wa.write(i, i as u64) };
                    }
                })
                .stage("Map", n, |s, e| {
                    for i in s..e {
                        let v = unsafe { wa.read(i) };
                        unsafe { wb.write(i, 3 * v) };
                    }
                })
                .serial_stage("Reduce", || {
                    let mut acc = 0u64;
                    for i in 0..n {
                        acc += unsafe { wb.read(i) };
                    }
                    unsafe { wt.write(0, acc) };
                })
                .run(&bk);
            assert_eq!(total[0], 3 * (n as u64 - 1) * n as u64 / 2);
        }
    }

    #[test]
    fn every_index_processed_exactly_once() {
        use std::sync::atomic::AtomicU32;
        for bk in backends() {
            let n = 4_321usize;
            let hits: Vec<AtomicU32> =
                (0..n).map(|_| AtomicU32::new(0)).collect();
            let hits_ref = &hits;
            Pipeline::new()
                .stage("Map", n, move |s, e| {
                    for i in s..e {
                        hits_ref[i].fetch_add(1, Ordering::Relaxed);
                    }
                })
                .run(&bk);
            assert!(hits
                .iter()
                .all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn explicit_grain_slots_are_stable() {
        for bk in backends() {
            let n = 1000usize;
            let grain = 128usize;
            let slots = n.div_ceil(grain);
            let mut partial = vec![0u64; slots];
            let wp = SharedSlice::new(&mut partial);
            Pipeline::new()
                .stage_with_grain("Reduce", n, grain, |s, e| {
                    let mut acc = 0u64;
                    for i in s..e {
                        acc += i as u64;
                    }
                    // Serial runs one chunk (slot 0); threaded runs
                    // per-grain chunks whose starts are multiples of
                    // the grain. Accumulate so both layouts sum right.
                    let slot = s / grain;
                    let old = unsafe { wp.read(slot) };
                    unsafe { wp.write(slot, old + acc) };
                })
                .run(&bk);
            assert_eq!(
                partial.iter().sum::<u64>(),
                (n as u64 - 1) * n as u64 / 2
            );
        }
    }

    #[test]
    fn empty_stages_and_empty_pipeline_are_noops() {
        for bk in backends() {
            Pipeline::new().run(&bk);
            let mut out = vec![7u32; 3];
            let w = SharedSlice::new(&mut out);
            Pipeline::new()
                .stage("Map", 0, |_, _| panic!("no work expected"))
                .stage("Map", 3, |s, e| {
                    for i in s..e {
                        unsafe { w.write(i, 1) };
                    }
                })
                .run(&bk);
            assert_eq!(out, vec![1, 1, 1]);
        }
    }

    #[test]
    fn records_stage_timing_under_primitive_names() {
        // Scoped recorder instead of the global registry: no
        // timing::test_lock(), no cross-test interference — the
        // region records stage rows on the calling thread.
        let rec = crate::telemetry::Recorder::new();
        let bk = Backend::threaded_with_grain(Pool::new(2), 32);
        let mut out = vec![0u32; 64];
        let w = SharedSlice::new(&mut out);
        {
            let _scope = rec.install();
            Pipeline::new()
                .stage("Map", 64, |s, e| {
                    for i in s..e {
                        unsafe { w.write(i, 1) };
                    }
                })
                .stage("ReduceByKey", 64, |_, _| {})
                .run(&bk);
        }
        let snap = rec.snapshot();
        assert!(snap.time_rows.contains_key("Map"));
        assert!(snap.time_rows.contains_key("ReduceByKey"));
    }
}
