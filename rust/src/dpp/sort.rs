//! SortByKey — LSD radix sort on integer keys with a carried payload.
//!
//! The paper's SortByKey sorts (vertexId, cliqueId) *pairs* (§3.2.1) and
//! (vertex, label) energy pairs (§3.2.2); it is one of the two
//! primitives that dominate runtime at scale. Pairs are packed into u64
//! keys (`hi << 32 | lo`), so one sort orders by (hi, lo)
//! lexicographically.
//!
//! Parallel LSD radix, 8-bit digits: per chunk histogram → global
//! (digit-major) exclusive scan → stable scatter per chunk. Passes over
//! digits that are constant across all keys are skipped, so sorting
//! small-domain keys costs proportionally less — this mirrors Thrust's
//! optimization and matters for the per-DPP breakdown bench.
//!
//! Two spellings per sort (DESIGN.md §10): the legacy allocating one
//! (`sort_by_key`, `sort_keys`) and the workspace one (`sort_by_key_ws`,
//! `sort_keys_ws`) whose ping-pong key/payload buffers and digit
//! histogram persist across passes *and* — through the
//! [`Workspace`] — across iterations. Both lower to the same cores
//! (`radix_pairs_core` / `radix_keys_core`), so results are
//! bitwise-identical. The keys-only core carries no payload at all:
//! `sort_keys` no longer allocates (or moves) a dummy zero payload.
//!
//! A comparison sort (`sort_pairs_comparison`) is kept as the ablation
//! baseline (`benches/ablation_sort.rs`).
//!
// deny(hot-loop-alloc): every allocation below carries an alloc-ok
// justification; the steady-state `_ws` paths must not allocate
// (enforced by ci/check_hot_loop_allocs.sh and benches/alloc_churn.rs).

use super::core::{scan_exclusive, scan_exclusive_into, SharedSlice};
use super::device::{Device, DeviceExt};
use super::timing::timed;
use super::workspace::Workspace;

const RADIX_BITS: usize = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Counter-array length (`BUCKETS * nchunks`) at which step 2 of the
/// radix sort — the exclusive scan over per-chunk digit counters —
/// runs as a device [`scan_exclusive`] instead of one serial sweep.
/// Below this the serial sweep stays cache-resident and beats the
/// fork-join it would replace (`pool_pieces` caps `nchunks` at
/// `4 * threads`, so the device scan only engages on very wide
/// machines). Integer addition is exact, so both paths produce
/// identical counters — the threshold is pure policy, never
/// observable in results.
pub const RADIX_PAR_SCAN_MIN: usize = 32 * 1024;

/// Pack a pair into a lexicographic u64 key.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::pack_pair;
/// assert!(pack_pair(1, 0) > pack_pair(0, u32::MAX));
/// assert_eq!(pack_pair(1, 2), (1u64 << 32) | 2);
/// ```
#[inline]
pub fn pack_pair(hi: u32, lo: u32) -> u64 {
    ((hi as u64) << 32) | lo as u64
}

/// Unpack a lexicographic u64 key.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{pack_pair, unpack_pair};
/// assert_eq!(unpack_pair(pack_pair(7, 9)), (7, 9));
/// ```
#[inline]
pub fn unpack_pair(key: u64) -> (u32, u32) {
    ((key >> 32) as u32, key as u32)
}

/// Stable sort of `(keys, vals)` by key, ascending. Radix/LSD.
///
/// When the keys are *static* across iterations, do not re-sort them:
/// build a [`crate::dpp::SegmentPlan`] once instead and reduce
/// sort-free every iteration. When the sort itself recurs (the Paper
/// pairing mode), use [`sort_by_key_ws`] so the scratch recurs too.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut keys = vec![3u64, 1, 3, 2];
/// let mut vals = vec![0u32, 1, 2, 3];
/// dpp::sort_by_key(&Backend::Serial, &mut keys, &mut vals);
/// assert_eq!(keys, vec![1, 2, 3, 3]);
/// assert_eq!(vals, vec![1, 3, 0, 2]); // stable: 0 before 2
/// ```
pub fn sort_by_key<D: Device + ?Sized>(
    bk: &D,
    keys: &mut Vec<u64>,
    vals: &mut Vec<u32>,
) {
    assert_eq!(keys.len(), vals.len(), "sort_by_key length mismatch");
    timed("SortByKey", || {
        // alloc-ok: the legacy allocating spelling by contract.
        let bounds = bk.chunk_bounds(keys.len());
        let (mut tk, mut tv, mut hist) =
            (Vec::new(), Vec::new(), Vec::new()); // alloc-ok: legacy
        radix_pairs_core(bk, keys, vals, &mut tk, &mut tv, &mut hist,
                         &bounds, None);
    })
}

/// Allocation-free [`sort_by_key`]: the ping-pong buffers and the
/// digit histogram come from `ws`, so repeated sorts (one per MAP
/// iteration in Paper mode) reuse the same storage run-long.
/// Bitwise-identical ordering to the allocating form.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut keys = vec![3u64, 1, 3, 2];
/// let mut vals = vec![0u32, 1, 2, 3];
/// dpp::sort_by_key_ws(&Backend::Serial, &ws, &mut keys, &mut vals);
/// assert_eq!(keys, vec![1, 2, 3, 3]);
/// assert_eq!(vals, vec![1, 3, 0, 2]);
/// // A second same-shape sort is served entirely from the pool.
/// let mut k2 = vec![9u64, 7, 8, 6];
/// let mut v2 = vec![0u32, 1, 2, 3];
/// let misses = ws.stats().misses;
/// dpp::sort_by_key_ws(&Backend::Serial, &ws, &mut k2, &mut v2);
/// assert_eq!(ws.stats().misses, misses);
/// ```
pub fn sort_by_key_ws<D: Device + ?Sized>(
    bk: &D,
    ws: &Workspace,
    keys: &mut Vec<u64>,
    vals: &mut Vec<u32>,
) {
    assert_eq!(keys.len(), vals.len(), "sort_by_key length mismatch");
    timed("SortByKey", || {
        let n = keys.len();
        let mut bounds = ws.take_spare::<(usize, usize)>(16);
        bk.chunk_bounds_into(n, &mut bounds);
        let mut tk = ws.take_spare::<u64>(n);
        let mut tv = ws.take_spare::<u32>(n);
        let mut hist = ws.take_spare::<u32>(bounds.len() * BUCKETS);
        radix_pairs_core(bk, keys, vals, &mut tk, &mut tv, &mut hist,
                         &bounds, Some(ws));
    })
}

/// Sort keys only (payload-free variant used by Unique pipelines).
/// Runs the keys-only radix core — no dummy payload is allocated or
/// moved, halving the memory traffic of the old spelling.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut keys = vec![9u64, 4, 7];
/// dpp::sort_keys(&Backend::Serial, &mut keys);
/// assert_eq!(keys, vec![4, 7, 9]);
/// ```
pub fn sort_keys<D: Device + ?Sized>(bk: &D, keys: &mut Vec<u64>) {
    timed("SortByKey", || {
        // alloc-ok: the legacy allocating spelling by contract.
        let bounds = bk.chunk_bounds(keys.len());
        let (mut tk, mut hist) = (Vec::new(), Vec::new()); // alloc-ok: legacy
        radix_keys_core(bk, keys, &mut tk, &mut hist, &bounds, None);
    })
}

/// Allocation-free [`sort_keys`] (see [`sort_by_key_ws`]).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend, Workspace};
/// let ws = Workspace::new();
/// let mut keys = vec![9u64, 4, 7];
/// dpp::sort_keys_ws(&Backend::Serial, &ws, &mut keys);
/// assert_eq!(keys, vec![4, 7, 9]);
/// ```
pub fn sort_keys_ws<D: Device + ?Sized>(
    bk: &D,
    ws: &Workspace,
    keys: &mut Vec<u64>,
) {
    timed("SortByKey", || {
        let n = keys.len();
        let mut bounds = ws.take_spare::<(usize, usize)>(16);
        bk.chunk_bounds_into(n, &mut bounds);
        let mut tk = ws.take_spare::<u64>(n);
        let mut hist = ws.take_spare::<u32>(bounds.len() * BUCKETS);
        radix_keys_core(bk, keys, &mut tk, &mut hist, &bounds, Some(ws));
    })
}

/// Which digit positions actually vary (OR of key diffs vs key[0])?
/// NB: computed with a plain loop — `reduce` would need a separate
/// combine step since `acc | (k ^ first)` is not associative over
/// partial accumulators.
fn varying_digits(keys: &[u64]) -> u64 {
    let first = keys.first().copied().unwrap_or(0);
    let mut varying = 0u64;
    for k in keys {
        varying |= k ^ first;
    }
    varying
}

/// Step 1: per-chunk digit histograms in digit-major layout
/// (`hist[b * nchunks + c]`), built into the persistent `hist` buffer.
fn build_histogram<D: Device + ?Sized>(
    bk: &D,
    keys: &[u64],
    shift: usize,
    bounds: &[(usize, usize)],
    hist: &mut Vec<u32>,
) {
    let nchunks = bounds.len();
    hist.clear();
    hist.resize(nchunks * BUCKETS, 0);
    let win = SharedSlice::new(hist);
    bk.for_chunk_ids(nchunks, |c| {
        let (s, e) = bounds[c];
        let mut local = [0u32; BUCKETS];
        for k in &keys[s..e] {
            local[((k >> shift) as usize) & (BUCKETS - 1)] += 1;
        }
        for (b, &cnt) in local.iter().enumerate() {
            unsafe { win.write(b * nchunks + c, cnt) };
        }
    });
}

/// Step 2: exclusive scan over the `BUCKETS * nchunks` counters —
/// serial below [`RADIX_PAR_SCAN_MIN`], a device scan above it
/// (identical integers either way; see the constant's docs).
fn scan_counters<D: Device + ?Sized>(
    bk: &D,
    hist: &mut Vec<u32>,
    ws: Option<&Workspace>,
) {
    if hist.len() >= RADIX_PAR_SCAN_MIN {
        match ws {
            Some(ws) => {
                let mut scanned = ws.take_spare::<u32>(hist.len());
                scan_exclusive_into(bk, ws, &hist[..], 0u32,
                                    |a, b| a + b, &mut scanned);
                std::mem::swap(hist, &mut *scanned);
            }
            None => {
                // alloc-ok: legacy allocating spelling by contract.
                let (scanned, _) =
                    scan_exclusive(bk, &hist[..], 0u32, |a, b| a + b);
                *hist = scanned;
            }
        }
    } else {
        let mut acc = 0u32;
        for slot in hist.iter_mut() {
            let v = *slot;
            *slot = acc;
            acc += v;
        }
    }
}

/// The pair-sorting radix core both [`sort_by_key`] spellings lower
/// to: skip constant digits, histogram → scan → stable scatter per
/// pass, ping-ponging between the caller's arrays and the `tmp_*`
/// scratch (a `Vec`-level swap per pass, so the sorted data always
/// ends in `keys`/`vals`).
#[allow(clippy::too_many_arguments)]
fn radix_pairs_core<D: Device + ?Sized>(
    bk: &D,
    keys: &mut Vec<u64>,
    vals: &mut Vec<u32>,
    tmp_k: &mut Vec<u64>,
    tmp_v: &mut Vec<u32>,
    hist: &mut Vec<u32>,
    bounds: &[(usize, usize)],
    ws: Option<&Workspace>,
) {
    let n = keys.len();
    if n <= 1 {
        return;
    }
    let varying = varying_digits(keys);
    if varying == 0 {
        return; // all keys equal: already sorted, stability trivial
    }
    tmp_k.clear();
    tmp_k.resize(n, 0);
    tmp_v.clear();
    tmp_v.resize(n, 0);
    let nchunks = bounds.len();
    let mut flips = 0usize;
    for pass in 0..(64 / RADIX_BITS) {
        let shift = pass * RADIX_BITS;
        if (varying >> shift) & (BUCKETS as u64 - 1) == 0 {
            continue; // digit constant across all keys — skip pass
        }
        build_histogram(bk, keys, shift, bounds, hist);
        scan_counters(bk, hist, ws);
        // Step 3: stable scatter per chunk.
        {
            let wk = SharedSlice::new(tmp_k);
            let wv = SharedSlice::new(tmp_v);
            let keys_ref = &*keys;
            let vals_ref = &*vals;
            let hist_ref = &*hist;
            bk.for_chunk_ids(nchunks, |c| {
                let (s, e) = bounds[c];
                let mut offsets = [0u32; BUCKETS];
                for b in 0..BUCKETS {
                    offsets[b] = hist_ref[b * nchunks + c];
                }
                for i in s..e {
                    let k = keys_ref[i];
                    let b = ((k >> shift) as usize) & (BUCKETS - 1);
                    let pos = offsets[b] as usize;
                    offsets[b] += 1;
                    unsafe {
                        wk.write(pos, k);
                        wv.write(pos, vals_ref[i]);
                    }
                }
            });
        }
        std::mem::swap(keys, tmp_k);
        std::mem::swap(vals, tmp_v);
        flips += 1;
    }
    if ws.is_some() && flips % 2 == 1 {
        unswap_after_odd_passes(keys, tmp_k);
        unswap_after_odd_passes(vals, tmp_v);
    }
}

/// After an odd number of ping-pong passes the caller's `Vec` and the
/// scratch `Vec` have exchanged allocations. On the workspace path
/// that exchange must not leak a *sub-power-of-two* capacity into the
/// pool: such a buffer parks on a shelf the upward scan (which starts
/// at the request's rounded-up shelf) never reaches for same-size
/// requests, so every later sort would miss and the pool would grow
/// without bound. One memcpy of the sorted data restores the
/// identities in that case; pow2-capacity exchanges (the pool-backed
/// hot path — all `ScratchVec`s carry pow2 capacities) are harmless
/// and stay zero-copy, as do even pass counts. The legacy allocating
/// wrappers skip this entirely (their scratch is dropped, and
/// pre-workspace `sort_by_key` also returned a swapped allocation).
fn unswap_after_odd_passes<T: Copy>(caller: &mut Vec<T>, tmp: &mut Vec<T>) {
    if tmp.capacity().is_power_of_two() {
        return; // interchangeable with the pool's own buffers
    }
    tmp.copy_from_slice(caller);
    std::mem::swap(caller, tmp);
}

/// The keys-only radix core (`sort_keys*`): identical passes to
/// [`radix_pairs_core`] with no payload array touched at all.
fn radix_keys_core<D: Device + ?Sized>(
    bk: &D,
    keys: &mut Vec<u64>,
    tmp_k: &mut Vec<u64>,
    hist: &mut Vec<u32>,
    bounds: &[(usize, usize)],
    ws: Option<&Workspace>,
) {
    let n = keys.len();
    if n <= 1 {
        return;
    }
    let varying = varying_digits(keys);
    if varying == 0 {
        return;
    }
    tmp_k.clear();
    tmp_k.resize(n, 0);
    let nchunks = bounds.len();
    let mut flips = 0usize;
    for pass in 0..(64 / RADIX_BITS) {
        let shift = pass * RADIX_BITS;
        if (varying >> shift) & (BUCKETS as u64 - 1) == 0 {
            continue;
        }
        build_histogram(bk, keys, shift, bounds, hist);
        scan_counters(bk, hist, ws);
        {
            let wk = SharedSlice::new(tmp_k);
            let keys_ref = &*keys;
            let hist_ref = &*hist;
            bk.for_chunk_ids(nchunks, |c| {
                let (s, e) = bounds[c];
                let mut offsets = [0u32; BUCKETS];
                for b in 0..BUCKETS {
                    offsets[b] = hist_ref[b * nchunks + c];
                }
                for i in s..e {
                    let k = keys_ref[i];
                    let b = ((k >> shift) as usize) & (BUCKETS - 1);
                    let pos = offsets[b] as usize;
                    offsets[b] += 1;
                    unsafe { wk.write(pos, k) };
                }
            });
        }
        std::mem::swap(keys, tmp_k);
        flips += 1;
    }
    if ws.is_some() && flips % 2 == 1 {
        unswap_after_odd_passes(keys, tmp_k);
    }
}

/// Comparison-sort baseline for the ablation bench: pack into tuples,
/// use the standard library's pdqsort-ish unstable sort, unpack.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::sort_pairs_comparison;
/// let mut keys = vec![2u64, 1];
/// let mut vals = vec![10u32, 20];
/// sort_pairs_comparison(&mut keys, &mut vals);
/// assert_eq!(keys, vec![1, 2]);
/// assert_eq!(vals, vec![20, 10]);
/// ```
pub fn sort_pairs_comparison(keys: &mut [u64], vals: &mut [u32]) {
    timed("SortByKey(cmp)", || {
        let mut zipped: Vec<(u64, u32)> = keys
            .iter()
            .copied()
            .zip(vals.iter().copied())
            .collect(); // alloc-ok: ablation baseline, not a hot path
        zipped.sort_by_key(|&(k, _)| k);
        for (i, (k, v)) in zipped.into_iter().enumerate() {
            keys[i] = k;
            vals[i] = v;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;
    use crate::util::Pcg32;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 128),
        ]
    }

    fn random_pairs(n: usize, key_bits: u32, seed: u64) -> (Vec<u64>, Vec<u32>) {
        let mut rng = Pcg32::seeded(seed);
        let mask = if key_bits >= 64 { u64::MAX } else { (1 << key_bits) - 1 };
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let vals: Vec<u32> = (0..n as u32).collect();
        (keys, vals)
    }

    #[test]
    fn sorts_and_is_stable() {
        for bk in backends() {
            // few distinct keys => stability observable via payload order
            let mut keys: Vec<u64> =
                (0..10_000).map(|i| (i % 5) as u64).collect();
            let mut vals: Vec<u32> = (0..10_000).collect();
            sort_by_key(&bk, &mut keys, &mut vals);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]));
            // within equal keys, payloads ascend (stability)
            for w in keys.windows(2).zip(vals.windows(2)) {
                if w.0[0] == w.0[1] {
                    assert!(w.1[0] < w.1[1]);
                }
            }
        }
    }

    #[test]
    fn matches_std_sort_random() {
        for bk in backends() {
            for bits in [8, 20, 40, 64] {
                let (mut keys, mut vals) = random_pairs(7777, bits, 42);
                let mut expect = keys.clone();
                expect.sort_unstable();
                sort_by_key(&bk, &mut keys, &mut vals);
                assert_eq!(keys, expect, "bits={bits}");
                // payload still a permutation
                let mut vs = vals.clone();
                vs.sort_unstable();
                assert_eq!(vs, (0..7777).collect::<Vec<u32>>());
            }
        }
    }

    #[test]
    fn ws_variants_match_legacy_bitwise_and_reuse_scratch() {
        for bk in backends() {
            let ws = Workspace::new();
            for round in 0..3u64 {
                for bits in [8, 40, 64] {
                    let (keys, vals) =
                        random_pairs(4096, bits, 100 + round + bits as u64);
                    let (mut lk, mut lv) = (keys.clone(), vals.clone());
                    sort_by_key(&bk, &mut lk, &mut lv);
                    let (mut wk, mut wv) = (keys.clone(), vals.clone());
                    sort_by_key_ws(&bk, &ws, &mut wk, &mut wv);
                    assert_eq!(wk, lk, "keys bits={bits}");
                    assert_eq!(wv, lv, "vals bits={bits}");

                    let mut lo = keys.clone();
                    sort_keys(&bk, &mut lo);
                    let mut wo = keys.clone();
                    sort_keys_ws(&bk, &ws, &mut wo);
                    assert_eq!(wo, lo, "keys-only bits={bits}");
                }
                if round == 0 {
                    // Everything the sorts need is parked now.
                    let warm = ws.stats().misses;
                    let (mut k, mut v) = random_pairs(4096, 64, 7);
                    sort_by_key_ws(&bk, &ws, &mut k, &mut v);
                    assert_eq!(ws.stats().misses, warm,
                               "steady-state sort allocates nothing");
                }
            }
        }
    }

    #[test]
    fn ws_sort_with_non_pow2_caller_vecs_reaches_steady_state() {
        // Regression: an odd number of radix passes used to swap the
        // caller's allocation into the pool; a non-power-of-two caller
        // capacity then parked on a shelf the upward scan never
        // reaches for same-size requests, so every later sort missed
        // and the pool grew without bound.
        let bk = Backend::Serial;
        let ws = Workspace::new();
        // 1-byte key domain -> exactly one (odd) performed pass.
        let make = |seed: u64| -> (Vec<u64>, Vec<u32>) {
            let mut rng = Pcg32::seeded(seed);
            // collect() sizes the Vecs at exactly 3000 (not pow2).
            let keys: Vec<u64> =
                (0..3000).map(|_| rng.next_u64() & 0xFF).collect();
            let vals: Vec<u32> = (0..3000).collect();
            (keys, vals)
        };
        let (mut k, mut v) = make(1);
        sort_by_key_ws(&bk, &ws, &mut k, &mut v);
        let k_cap = k.capacity();
        let warm = ws.stats();
        for seed in 2..12 {
            let (mut k, mut v) = make(seed);
            sort_by_key_ws(&bk, &ws, &mut k, &mut v);
            assert!(k.windows(2).all(|w| w[0] <= w[1]));
            let mut ko = make(seed).0;
            sort_keys_ws(&bk, &ws, &mut ko);
            assert_eq!(ko, k);
        }
        let now = ws.stats();
        assert_eq!(now.misses, warm.misses,
                   "fresh non-pow2 caller vecs must not strand buffers");
        assert_eq!(now.resident_bytes, warm.resident_bytes,
                   "pool footprint stable across caller-owned sorts");
        // And the caller kept its own (non-pow2) allocation.
        assert_eq!(k_cap, 3000);
    }

    #[test]
    fn keys_only_path_matches_pair_sort_keys() {
        for bk in backends() {
            let (keys, _) = random_pairs(5000, 64, 11);
            let mut with_payload = keys.clone();
            let mut payload: Vec<u32> = (0..5000).collect();
            sort_by_key(&bk, &mut with_payload, &mut payload);
            let mut keys_only = keys.clone();
            sort_keys(&bk, &mut keys_only);
            assert_eq!(keys_only, with_payload);
        }
    }

    #[test]
    fn parallel_counter_scan_matches_serial_sweep() {
        // Force both sides of the RADIX_PAR_SCAN_MIN policy on the
        // same counters: results must be identical integers.
        let bk = Backend::threaded_with_grain(Pool::new(4), 64);
        let mut rng = Pcg32::seeded(99);
        let mut hist: Vec<u32> = (0..RADIX_PAR_SCAN_MIN + 123)
            .map(|_| (rng.next_u64() % 7) as u32)
            .collect();
        let mut serial = hist.clone();
        let mut acc = 0u32;
        for slot in serial.iter_mut() {
            let v = *slot;
            *slot = acc;
            acc += v;
        }
        // Above the threshold with no workspace: device-scan path.
        scan_counters(&bk, &mut hist, None);
        assert_eq!(hist, serial);
        // Same again through a workspace.
        let ws = Workspace::new();
        let mut hist2: Vec<u32> = (0..RADIX_PAR_SCAN_MIN + 123)
            .map(|i| serial.get(i + 1).map_or(0, |_| 1))
            .collect();
        let mut serial2 = hist2.clone();
        let mut acc = 0u32;
        for slot in serial2.iter_mut() {
            let v = *slot;
            *slot = acc;
            acc += v;
        }
        scan_counters(&bk, &mut hist2, Some(&ws));
        assert_eq!(hist2, serial2);
    }

    #[test]
    fn payload_follows_key() {
        for bk in backends() {
            let (mut keys, mut vals) = random_pairs(2048, 64, 7);
            let orig_keys = keys.clone();
            sort_by_key(&bk, &mut keys, &mut vals);
            for (k, v) in keys.iter().zip(vals.iter()) {
                assert_eq!(orig_keys[*v as usize], *k);
            }
        }
    }

    #[test]
    fn pair_packing_orders_lexicographically() {
        assert!(pack_pair(1, 0) > pack_pair(0, u32::MAX));
        assert!(pack_pair(1, 2) < pack_pair(1, 3));
        assert_eq!(unpack_pair(pack_pair(7, 9)), (7, 9));
    }

    #[test]
    fn empty_and_single() {
        for bk in backends() {
            let ws = Workspace::new();
            let mut k: Vec<u64> = vec![];
            let mut v: Vec<u32> = vec![];
            sort_by_key(&bk, &mut k, &mut v);
            sort_by_key_ws(&bk, &ws, &mut k, &mut v);
            let mut k = vec![5u64];
            let mut v = vec![1u32];
            sort_by_key(&bk, &mut k, &mut v);
            sort_by_key_ws(&bk, &ws, &mut k, &mut v);
            assert_eq!(k, vec![5]);
            assert_eq!(v, vec![1]);
            let mut k: Vec<u64> = vec![];
            sort_keys(&bk, &mut k);
            sort_keys_ws(&bk, &ws, &mut k);
            assert!(k.is_empty());
        }
    }

    #[test]
    fn comparison_baseline_agrees() {
        let (mut k1, mut v1) = random_pairs(3000, 64, 3);
        let (mut k2, mut v2) = (k1.clone(), v1.clone());
        sort_by_key(&Backend::Serial, &mut k1, &mut v1);
        sort_pairs_comparison(&mut k2, &mut v2);
        assert_eq!(k1, k2);
        // payloads may differ within equal keys only; keys random u64 so
        // collisions are ~impossible at this size.
        assert_eq!(v1, v2);
    }
}
