//! SortByKey — LSD radix sort on integer keys with a carried payload.
//!
//! The paper's SortByKey sorts (vertexId, cliqueId) *pairs* (§3.2.1) and
//! (vertex, label) energy pairs (§3.2.2); it is one of the two
//! primitives that dominate runtime at scale. Pairs are packed into u64
//! keys (`hi << 32 | lo`), so one sort orders by (hi, lo)
//! lexicographically.
//!
//! Parallel LSD radix, 8-bit digits: per chunk histogram → global
//! (digit-major) exclusive scan → stable scatter per chunk. Passes over
//! digits that are constant across all keys are skipped, so sorting
//! small-domain keys costs proportionally less — this mirrors Thrust's
//! optimization and matters for the per-DPP breakdown bench.
//!
//! A comparison sort (`sort_pairs_comparison`) is kept as the ablation
//! baseline (`benches/ablation_sort.rs`).

use super::core::SharedSlice;
use super::device::{Device, DeviceExt};
use super::timing::timed;

const RADIX_BITS: usize = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Pack a pair into a lexicographic u64 key.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::pack_pair;
/// assert!(pack_pair(1, 0) > pack_pair(0, u32::MAX));
/// assert_eq!(pack_pair(1, 2), (1u64 << 32) | 2);
/// ```
#[inline]
pub fn pack_pair(hi: u32, lo: u32) -> u64 {
    ((hi as u64) << 32) | lo as u64
}

/// Unpack a lexicographic u64 key.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{pack_pair, unpack_pair};
/// assert_eq!(unpack_pair(pack_pair(7, 9)), (7, 9));
/// ```
#[inline]
pub fn unpack_pair(key: u64) -> (u32, u32) {
    ((key >> 32) as u32, key as u32)
}

/// Stable sort of `(keys, vals)` by key, ascending. Radix/LSD.
///
/// When the keys are *static* across iterations, do not re-sort them:
/// build a [`crate::dpp::SegmentPlan`] once instead and reduce
/// sort-free every iteration.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut keys = vec![3u64, 1, 3, 2];
/// let mut vals = vec![0u32, 1, 2, 3];
/// dpp::sort_by_key(&Backend::Serial, &mut keys, &mut vals);
/// assert_eq!(keys, vec![1, 2, 3, 3]);
/// assert_eq!(vals, vec![1, 3, 0, 2]); // stable: 0 before 2
/// ```
pub fn sort_by_key<D: Device + ?Sized>(
    bk: &D,
    keys: &mut Vec<u64>,
    vals: &mut Vec<u32>,
) {
    assert_eq!(keys.len(), vals.len(), "sort_by_key length mismatch");
    timed("SortByKey", || {
        radix_sort(bk, keys, vals);
    })
}

/// Sort keys only (payload-free variant used by Unique pipelines).
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::{self, Backend};
/// let mut keys = vec![9u64, 4, 7];
/// dpp::sort_keys(&Backend::Serial, &mut keys);
/// assert_eq!(keys, vec![4, 7, 9]);
/// ```
pub fn sort_keys<D: Device + ?Sized>(bk: &D, keys: &mut Vec<u64>) {
    timed("SortByKey", || {
        let mut vals = vec![0u32; keys.len()];
        radix_sort(bk, keys, &mut vals);
    })
}

fn radix_sort<D: Device + ?Sized>(
    bk: &D,
    keys: &mut Vec<u64>,
    vals: &mut Vec<u32>,
) {
    let n = keys.len();
    if n <= 1 {
        return;
    }
    // Which digit positions actually vary? (OR of key diffs vs key[0]).
    // NB: computed with a plain loop — `reduce` would need a separate
    // combine step since `acc | (k ^ first)` is not associative over
    // partial accumulators.
    let first = keys[0];
    let mut varying = 0u64;
    for k in keys.iter() {
        varying |= k ^ first;
    }

    let mut src_k = std::mem::take(keys);
    let mut src_v = std::mem::take(vals);
    let mut dst_k = vec![0u64; n];
    let mut dst_v = vec![0u32; n];

    let bounds = bk.chunk_bounds(n);
    let nchunks = bounds.len();

    for pass in 0..(64 / RADIX_BITS) {
        let shift = pass * RADIX_BITS;
        if (varying >> shift) & (BUCKETS as u64 - 1) == 0 {
            continue; // digit constant across all keys — skip pass
        }
        // 1. per-chunk digit histograms
        let mut hist = vec![0u32; nchunks * BUCKETS];
        {
            let win = SharedSlice::new(&mut hist);
            let bounds_ref = &bounds;
            let keys_ref = &src_k;
            bk.for_chunk_ids(nchunks, |c| {
                let (s, e) = bounds_ref[c];
                let mut local = [0u32; BUCKETS];
                for k in &keys_ref[s..e] {
                    local[((k >> shift) as usize) & (BUCKETS - 1)] += 1;
                }
                for (b, &cnt) in local.iter().enumerate() {
                    // digit-major layout: hist[b * nchunks + c]
                    unsafe { win.write(b * nchunks + c, cnt) };
                }
            });
        }
        // 2. serial exclusive scan over (digit, chunk) — 256*nchunks ints
        let mut acc = 0u32;
        for slot in hist.iter_mut() {
            let v = *slot;
            *slot = acc;
            acc += v;
        }
        // 3. stable scatter per chunk
        {
            let wk = SharedSlice::new(&mut dst_k);
            let wv = SharedSlice::new(&mut dst_v);
            let bounds_ref = &bounds;
            let keys_ref = &src_k;
            let vals_ref = &src_v;
            let hist_ref = &hist;
            bk.for_chunk_ids(nchunks, |c| {
                let (s, e) = bounds_ref[c];
                let mut offsets = [0u32; BUCKETS];
                for b in 0..BUCKETS {
                    offsets[b] = hist_ref[b * nchunks + c];
                }
                for i in s..e {
                    let k = keys_ref[i];
                    let b = ((k >> shift) as usize) & (BUCKETS - 1);
                    let pos = offsets[b] as usize;
                    offsets[b] += 1;
                    unsafe {
                        wk.write(pos, k);
                        wv.write(pos, vals_ref[i]);
                    }
                }
            });
        }
        std::mem::swap(&mut src_k, &mut dst_k);
        std::mem::swap(&mut src_v, &mut dst_v);
    }
    *keys = src_k;
    *vals = src_v;
}

/// Comparison-sort baseline for the ablation bench: pack into tuples,
/// use the standard library's pdqsort-ish unstable sort, unpack.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::dpp::sort_pairs_comparison;
/// let mut keys = vec![2u64, 1];
/// let mut vals = vec![10u32, 20];
/// sort_pairs_comparison(&mut keys, &mut vals);
/// assert_eq!(keys, vec![1, 2]);
/// assert_eq!(vals, vec![20, 10]);
/// ```
pub fn sort_pairs_comparison(keys: &mut [u64], vals: &mut [u32]) {
    timed("SortByKey(cmp)", || {
        let mut zipped: Vec<(u64, u32)> =
            keys.iter().copied().zip(vals.iter().copied()).collect();
        zipped.sort_by_key(|&(k, _)| k);
        for (i, (k, v)) in zipped.into_iter().enumerate() {
            keys[i] = k;
            vals[i] = v;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;
    use crate::util::Pcg32;

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 128),
        ]
    }

    fn random_pairs(n: usize, key_bits: u32, seed: u64) -> (Vec<u64>, Vec<u32>) {
        let mut rng = Pcg32::seeded(seed);
        let mask = if key_bits >= 64 { u64::MAX } else { (1 << key_bits) - 1 };
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
        let vals: Vec<u32> = (0..n as u32).collect();
        (keys, vals)
    }

    #[test]
    fn sorts_and_is_stable() {
        for bk in backends() {
            // few distinct keys => stability observable via payload order
            let mut keys: Vec<u64> =
                (0..10_000).map(|i| (i % 5) as u64).collect();
            let mut vals: Vec<u32> = (0..10_000).collect();
            sort_by_key(&bk, &mut keys, &mut vals);
            assert!(keys.windows(2).all(|w| w[0] <= w[1]));
            // within equal keys, payloads ascend (stability)
            for w in keys.windows(2).zip(vals.windows(2)) {
                if w.0[0] == w.0[1] {
                    assert!(w.1[0] < w.1[1]);
                }
            }
        }
    }

    #[test]
    fn matches_std_sort_random() {
        for bk in backends() {
            for bits in [8, 20, 40, 64] {
                let (mut keys, mut vals) = random_pairs(7777, bits, 42);
                let mut expect = keys.clone();
                expect.sort_unstable();
                sort_by_key(&bk, &mut keys, &mut vals);
                assert_eq!(keys, expect, "bits={bits}");
                // payload still a permutation
                let mut vs = vals.clone();
                vs.sort_unstable();
                assert_eq!(vs, (0..7777).collect::<Vec<u32>>());
            }
        }
    }

    #[test]
    fn payload_follows_key() {
        for bk in backends() {
            let (mut keys, mut vals) = random_pairs(2048, 64, 7);
            let orig_keys = keys.clone();
            sort_by_key(&bk, &mut keys, &mut vals);
            for (k, v) in keys.iter().zip(vals.iter()) {
                assert_eq!(orig_keys[*v as usize], *k);
            }
        }
    }

    #[test]
    fn pair_packing_orders_lexicographically() {
        assert!(pack_pair(1, 0) > pack_pair(0, u32::MAX));
        assert!(pack_pair(1, 2) < pack_pair(1, 3));
        assert_eq!(unpack_pair(pack_pair(7, 9)), (7, 9));
    }

    #[test]
    fn empty_and_single() {
        for bk in backends() {
            let mut k: Vec<u64> = vec![];
            let mut v: Vec<u32> = vec![];
            sort_by_key(&bk, &mut k, &mut v);
            let mut k = vec![5u64];
            let mut v = vec![1u32];
            sort_by_key(&bk, &mut k, &mut v);
            assert_eq!(k, vec![5]);
            assert_eq!(v, vec![1]);
        }
    }

    #[test]
    fn comparison_baseline_agrees() {
        let (mut k1, mut v1) = random_pairs(3000, 64, 3);
        let (mut k2, mut v2) = (k1.clone(), v1.clone());
        sort_by_key(&Backend::Serial, &mut k1, &mut v1);
        sort_pairs_comparison(&mut k2, &mut v2);
        assert_eq!(k1, k2);
        // payloads may differ within equal keys only; keys random u64 so
        // collisions are ~impossible at this size.
        assert_eq!(v1, v2);
    }
}
