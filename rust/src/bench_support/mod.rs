//! Shared benchmark scaffolding: standard workloads, concurrency
//! sweeps, and table/series output for the paper-reproduction benches
//! (`rust/benches/`, one per table/figure — see DESIGN.md §4).
//!
//! Scale is controlled by `DPP_PMRF_BENCH_SCALE`:
//!   * `smoke` — tiny, seconds (CI / `make bench` default sanity)
//!   * `paper` — the shapes used for the README's reported numbers
//! or any explicit `<width>x<height>x<slices>` triple.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::config::{DatasetConfig, DatasetKind, RunConfig};
use crate::image::{self, Dataset};
use crate::util::Stats;

/// Benchmark scale selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scale {
    pub width: usize,
    pub height: usize,
    pub slices: usize,
    pub reps: usize,
    pub warmup: usize,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("DPP_PMRF_BENCH_SCALE").as_deref() {
            Ok("paper") => Scale {
                width: 256,
                height: 256,
                slices: 4,
                reps: 3,
                warmup: 1,
            },
            Ok(spec) if spec.contains('x') => {
                let parts: Vec<usize> = spec
                    .split('x')
                    .filter_map(|p| p.parse().ok())
                    .collect();
                assert_eq!(parts.len(), 3,
                           "DPP_PMRF_BENCH_SCALE=WxHxS expected");
                Scale {
                    width: parts[0],
                    height: parts[1],
                    slices: parts[2],
                    reps: 3,
                    warmup: 1,
                }
            }
            _ => Scale {
                width: 96,
                height: 96,
                slices: 2,
                reps: 3,
                warmup: 1,
            },
        }
    }
}

/// The two paper datasets at bench scale.
pub fn workload(kind: DatasetKind, scale: Scale) -> (Dataset, RunConfig) {
    let dataset = DatasetConfig {
        kind,
        width: scale.width,
        height: scale.height,
        slices: scale.slices,
        ..Default::default()
    };
    let cfg = RunConfig {
        dataset: dataset.clone(),
        // Fixed iteration counts so every engine/concurrency does the
        // same work — timings become comparable (the paper also fixes
        // 20 EM iterations, §3.2.2).
        mrf: crate::config::MrfConfig {
            em_iters: 5,
            map_iters: 4,
            fixed_iters: true,
            ..Default::default()
        },
        ..Default::default()
    };
    (image::generate(&dataset), cfg)
}

/// Build the per-slice MRF models once (initialization phase) so
/// benches time exactly what the paper times: the optimization loop.
pub fn prepare_models(ds: &Dataset, cfg: &RunConfig)
    -> Vec<crate::mrf::MrfModel> {
    let pool = crate::pool::Pool::with_default_threads();
    let bk = crate::dpp::Backend::threaded(pool);
    (0..ds.input.depth)
        .map(|z| {
            let seg = crate::overseg::oversegment(
                &bk, &ds.input.slice(z), &cfg.overseg,
            );
            crate::mrf::build_model(&bk, &seg)
        })
        .collect()
}

/// Run `f` under a freshly installed scoped telemetry
/// [`crate::telemetry::Recorder`] and return its result together with
/// the metrics captured while it ran. Benches (and tests) get a
/// per-measurement primitive breakdown without touching the global
/// registry — no `timing::test_lock()`, no cross-bench interference.
pub fn with_recorder<R>(
    f: impl FnOnce() -> R,
) -> (R, crate::telemetry::MetricsSnapshot) {
    let rec = crate::telemetry::Recorder::new();
    let out = {
        let _scope = rec.install();
        f()
    };
    (out, rec.snapshot())
}

/// Thread counts for sweep benches: 1, 2, 4, ... up to the machine.
pub fn thread_sweep() -> Vec<usize> {
    let max = crate::pool::available_threads();
    let mut out = vec![1usize];
    while *out.last().unwrap() * 2 <= max {
        out.push(out.last().unwrap() * 2);
    }
    if *out.last().unwrap() != max {
        out.push(max);
    }
    out
}

/// A recorded bench row, serializable to the results JSON.
#[derive(Debug, Clone)]
pub struct Row {
    pub labels: Vec<(String, String)>,
    pub secs: Stats,
}

/// Collects rows and writes `bench_results/<name>.json` + a text table.
pub struct Report {
    name: &'static str,
    rows: Vec<Row>,
}

impl Report {
    pub fn new(name: &'static str) -> Report {
        Report { name, rows: Vec::new() }
    }

    pub fn add(&mut self, labels: Vec<(&str, String)>, secs: Stats) {
        self.rows.push(Row {
            labels: labels
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
            secs,
        });
    }

    pub fn rows(&self) -> &[Row] {
        &self.rows
    }

    /// Median seconds of the row matching all given labels.
    pub fn median(&self, labels: &[(&str, &str)]) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| {
                labels.iter().all(|(k, v)| {
                    r.labels.iter().any(|(rk, rv)| rk == k && rv == v)
                })
            })
            .map(|r| r.secs.median)
    }

    /// Print an aligned table and persist JSON under `bench_results/`.
    pub fn finish(&self) -> PathBuf {
        let mut table = String::new();
        for row in &self.rows {
            let mut line = String::new();
            for (k, v) in &row.labels {
                line.push_str(&format!("{k}={v:<12} "));
            }
            line.push_str(&format!(
                "median {:>10}  (min {:>10}, n={})",
                crate::util::fmt_secs(row.secs.median),
                crate::util::fmt_secs(row.secs.min),
                row.secs.n
            ));
            table.push_str(&line);
            table.push('\n');
        }
        println!("== {} ==\n{table}", self.name);

        let dir = Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("{}.json", self.name));
        let rows: Vec<crate::json::Value> = self
            .rows
            .iter()
            .map(|r| {
                let mut fields: Vec<(&str, crate::json::Value)> = r
                    .labels
                    .iter()
                    .map(|(k, v)| {
                        (k.as_str(), crate::json::Value::str(v.clone()))
                    })
                    .collect();
                fields.push(("median_secs", r.secs.median.into()));
                fields.push(("min_secs", r.secs.min.into()));
                fields.push(("mean_secs", r.secs.mean.into()));
                crate::json::Value::object(fields)
            })
            .collect();
        let doc = crate::json::Value::object(vec![
            ("bench", crate::json::Value::str(self.name)),
            ("rows", crate::json::Value::Array(rows)),
        ]);
        if let Ok(mut f) = std::fs::File::create(&path) {
            let _ = f.write_all(doc.to_pretty().as_bytes());
        }
        println!("wrote {}\n", path.display());
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_sweep_monotone_and_capped() {
        let sweep = thread_sweep();
        assert_eq!(sweep[0], 1);
        assert!(sweep.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*sweep.last().unwrap(), crate::pool::available_threads());
    }

    #[test]
    fn workload_is_deterministic() {
        let s = Scale { width: 32, height: 32, slices: 1, reps: 1,
                        warmup: 0 };
        let (a, _) = workload(DatasetKind::Synthetic, s);
        let (b, _) = workload(DatasetKind::Synthetic, s);
        assert_eq!(a.input, b.input);
    }

    #[test]
    fn report_median_lookup() {
        let mut r = Report::new("test");
        r.add(
            vec![("engine", "dpp".into()), ("threads", "2".into())],
            Stats::from_samples(&[1.0, 2.0, 3.0]),
        );
        assert_eq!(r.median(&[("engine", "dpp"), ("threads", "2")]),
                   Some(2.0));
        assert_eq!(r.median(&[("engine", "serial")]), None);
    }
}
