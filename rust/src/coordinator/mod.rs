//! L3 coordinator: drives the full segmentation pipeline over a 3D
//! stack of 2D slices, exactly as the paper runs its datasets (§4.3.1):
//! per slice — oversegment, build the region graph, enumerate maximal
//! cliques, construct 1-neighborhoods, run the selected EM engine, and
//! map vertex labels back to pixels. Reports the per-phase timings the
//! paper's evaluation is built on (optimization time only is the
//! headline number).
//!
//! Slice execution is dispatched through the slice scheduler
//! ([`crate::sched`]): `sched.lanes = 1` (the default) runs the
//! classic serial loop bitwise; more lanes shard the stack across
//! work-stealing init/optimize worker pairs with the same per-slice
//! results.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{DeviceKind, EngineKind, RunConfig};
use crate::dpp::{device_for, Device, DeviceCaps, OfflineAcceleratorDevice};
use crate::image::{Dataset, Volume};
use crate::eval::Confusion;
use crate::mrf::{self, Engine, MrfModel};
use crate::overseg::Overseg;
use crate::pool::Pool;
use crate::runtime::EmRuntime;
use crate::sched::SchedStats;
use crate::util::Timer;

/// Timings and statistics for one slice.
#[derive(Debug, Clone)]
pub struct SliceReport {
    pub z: usize,
    pub regions: usize,
    pub hoods: usize,
    pub elements: usize,
    pub em_iters: usize,
    pub map_iters: usize,
    /// Seconds spent in initialization (overseg + graph + MCE + hoods).
    pub init_secs: f64,
    /// Seconds spent in EM optimization (the paper's reported time).
    pub opt_secs: f64,
    /// Optimize lane that ran this slice (0 on the serial path).
    pub lane: usize,
    /// Seconds this slice sat initialized-but-unclaimed in the slice
    /// queue before an optimize lane picked it up (0 on the serial
    /// path, where slices never queue).
    pub queue_wait_secs: f64,
    pub final_energy: f64,
    /// Certified lower bound on the slice's final energy, when the
    /// engine can produce one (the dual engine's ascent objective minus
    /// scorer slack; `None` for engines without certificates).
    pub lower_bound: Option<f64>,
    /// `final_energy - lower_bound`, clamped at zero — the per-slice
    /// optimality gap the certificate guarantees. `None` whenever
    /// `lower_bound` is.
    pub optimality_gap: Option<f64>,
    /// Live particle count of the slice's final particle tensor
    /// (`nv * K`); `None` for every engine but pmp.
    pub pmp_particles: Option<usize>,
    /// Mean fraction of random-walk proposals that survived
    /// select-and-prune across the slice's rounds; `None` unless pmp.
    pub pmp_acceptance: Option<f64>,
    /// Best decoded continuous (max-marginal) energy the particle
    /// solver reached on this slice; `None` unless pmp.
    pub pmp_max_marginal_energy: Option<f64>,
    /// Canonical `--bp-schedule` spec (parameters included) of the
    /// frontier policy that optimized this slice; `None` for every
    /// engine family but BP (DESIGN.md §15).
    pub bp_schedule: Option<String>,
    /// Mean fraction of directed messages the policy committed per
    /// sweep on this slice; `None` unless the BP engine ran it.
    pub bp_committed_frac: Option<f64>,
}

/// Aggregated result of a full run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub engine: &'static str,
    /// Name of the [`Device`] the primitives executed on.
    pub device: String,
    /// Capability flags of that device (threaded / fused regions /
    /// accelerator offload).
    pub device_caps: DeviceCaps,
    pub output: Volume,
    pub slices: Vec<SliceReport>,
    /// Verification vs ground truth, when the dataset has one.
    pub confusion: Option<Confusion>,
    pub porosity: f64,
    /// End-to-end wall clock for the whole run — scheduling and
    /// assembly included, not just per-slice sums — the honest
    /// denominator for throughput numbers.
    pub total_secs: f64,
    /// Scheduler shape + occupancy observed during the run.
    pub sched: SchedStats,
    /// Convergence flight-recorder journal for this run: `Some` when
    /// the recorder was armed ([`crate::obs::arm`]), drained by the
    /// run driver. `None` on default-off runs.
    pub convergence: Option<crate::obs::ConvergenceLog>,
}

impl RunReport {
    /// Mean per-slice optimization time — the paper's headline metric.
    pub fn mean_opt_secs(&self) -> f64 {
        self.slices.iter().map(|s| s.opt_secs).sum::<f64>()
            / self.slices.len().max(1) as f64
    }

    pub fn mean_init_secs(&self) -> f64 {
        self.slices.iter().map(|s| s.init_secs).sum::<f64>()
            / self.slices.len().max(1) as f64
    }

    /// Whole-run throughput: slices per wall-clock second.
    pub fn slices_per_sec(&self) -> f64 {
        if self.total_secs > 0.0 {
            self.slices.len() as f64 / self.total_secs
        } else {
            0.0
        }
    }

    /// Mean fraction of the run each optimize lane spent busy.
    pub fn lane_occupancy(&self) -> f64 {
        self.sched.occupancy(self.total_secs)
    }

    /// Total EM iterations across slices.
    pub fn total_em_iters(&self) -> usize {
        self.slices.iter().map(|s| s.em_iters).sum()
    }

    /// Total inner iterations (MAP iterations or BP sweeps) across
    /// slices.
    pub fn total_map_iters(&self) -> usize {
        self.slices.iter().map(|s| s.map_iters).sum()
    }

    /// Run-level certified lower bound: the sum of per-slice bounds,
    /// present only when *every* slice carries one (energies are
    /// additive across slices, so the sum bounds the summed energy).
    pub fn lower_bound(&self) -> Option<f64> {
        self.slices
            .iter()
            .map(|s| s.lower_bound)
            .sum::<Option<f64>>()
    }

    /// Run-level optimality gap: summed final energy minus the summed
    /// lower bound, clamped at zero. `None` whenever
    /// [`Self::lower_bound`] is.
    pub fn optimality_gap(&self) -> Option<f64> {
        self.lower_bound().map(|lb| {
            let energy: f64 =
                self.slices.iter().map(|s| s.final_energy).sum();
            (energy - lb).max(0.0)
        })
    }

    /// Run-level particle count: the sum across slices, present only
    /// when *every* slice carries one (same contract as
    /// [`Self::lower_bound`] — a mixed-engine report stays null).
    pub fn pmp_particles(&self) -> Option<usize> {
        self.slices
            .iter()
            .map(|s| s.pmp_particles)
            .sum::<Option<usize>>()
    }

    /// Run-level proposal acceptance: mean of the per-slice means,
    /// `None` unless every slice reports one.
    pub fn pmp_acceptance(&self) -> Option<f64> {
        let sum = self
            .slices
            .iter()
            .map(|s| s.pmp_acceptance)
            .sum::<Option<f64>>()?;
        Some(sum / self.slices.len().max(1) as f64)
    }

    /// Run-level continuous max-marginal energy: per-slice energies
    /// are additive, so the sum plays the same role `lower_bound`'s
    /// sum does. `None` unless every slice reports one.
    pub fn pmp_max_marginal_energy(&self) -> Option<f64> {
        self.slices
            .iter()
            .map(|s| s.pmp_max_marginal_energy)
            .sum::<Option<f64>>()
    }

    /// Run-level BP frontier policy: the canonical schedule spec when
    /// every slice ran the same one, else `None` (same
    /// present-only-when-homogeneous contract as
    /// [`Self::lower_bound`]).
    pub fn bp_schedule(&self) -> Option<&str> {
        let first = self.slices.first()?.bp_schedule.as_deref()?;
        self.slices
            .iter()
            .all(|s| s.bp_schedule.as_deref() == Some(first))
            .then_some(first)
    }

    /// Run-level committed fraction: mean of the per-slice means,
    /// `None` unless every slice reports one (same contract as
    /// [`Self::pmp_acceptance`]).
    pub fn bp_committed_frac(&self) -> Option<f64> {
        let sum = self
            .slices
            .iter()
            .map(|s| s.bp_committed_frac)
            .sum::<Option<f64>>()?;
        Some(sum / self.slices.len().max(1) as f64)
    }

    /// JSON rendering for the README's tables / bench reports.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        // Certificate fields are part of the report contract for every
        // engine: present-but-null when the engine cannot certify, so
        // consumers can probe one stable schema (tests/report_schema.rs).
        let opt_f64 = |o: Option<f64>| match o {
            Some(x) => x.into(),
            None => Value::Null,
        };
        let mut fields = vec![
            ("engine", Value::str(self.engine)),
            // Device identity + capability flags: results are only
            // comparable across runs when the hardware path is pinned
            // in the report (device tentpole).
            ("device", Value::str(self.device.as_str())),
            ("device_threaded", self.device_caps.threaded.into()),
            ("device_fused_regions",
             self.device_caps.fused_regions.into()),
            ("device_offload", self.device_caps.offload.into()),
            ("mean_opt_secs", self.mean_opt_secs().into()),
            ("mean_init_secs", self.mean_init_secs().into()),
            // Whole-run wall clock + throughput (sched tentpole): the
            // per-slice means above cannot answer "how fast is the
            // stack done" once slices overlap.
            ("total_secs", self.total_secs.into()),
            ("slices_per_sec", self.slices_per_sec().into()),
            ("lanes", self.sched.lanes.into()),
            ("inflight_cap", self.sched.inflight_cap.into()),
            ("peak_inflight", self.sched.peak_inflight.into()),
            ("lane_occupancy", self.lane_occupancy().into()),
            ("porosity", self.porosity.into()),
            ("slices", self.slices.len().into()),
            ("em_iters", self.total_em_iters().into()),
            ("map_iters", self.total_map_iters().into()),
            ("lower_bound", opt_f64(self.lower_bound())),
            ("optimality_gap", opt_f64(self.optimality_gap())),
            // Particle max-product deliverables (ISSUE 9): same
            // present-but-null contract as the certificate fields.
            ("pmp_particles",
             match self.pmp_particles() {
                 Some(p) => p.into(),
                 None => Value::Null,
             }),
            ("pmp_acceptance", opt_f64(self.pmp_acceptance())),
            ("pmp_max_marginal_energy",
             opt_f64(self.pmp_max_marginal_energy())),
            // BP frontier-policy deliverables (ISSUE 10, DESIGN.md
            // §15): same present-but-null contract again.
            ("bp_schedule",
             match self.bp_schedule() {
                 Some(s) => Value::str(s),
                 None => Value::Null,
             }),
            ("bp_committed_frac", opt_f64(self.bp_committed_frac())),
            // Flight-recorder section (ISSUE 8): null when the
            // recorder was not armed, else counts + <= 256 points with
            // exact endpoints (full fidelity goes to --convergence-out).
            ("convergence",
             self.convergence
                 .as_ref()
                 .map(crate::obs::ConvergenceLog::to_json)
                 .unwrap_or(Value::Null)),
        ];
        if let Some(c) = &self.confusion {
            fields.push(("precision", c.precision().into()));
            fields.push(("recall", c.recall().into()));
            fields.push(("accuracy", c.accuracy().into()));
        }
        // Serving latency (telemetry tentpole): treat each slice as a
        // job — queue wait + optimize time — and report p50/p90/p99 so
        // sharded tail latency is visible without a trace file. Always
        // present: the timestamps feeding it are recorded even with
        // profiling off.
        let waits: Vec<f64> =
            self.slices.iter().map(|s| s.queue_wait_secs).collect();
        let opts: Vec<f64> =
            self.slices.iter().map(|s| s.opt_secs).collect();
        let jobs: Vec<f64> = self
            .slices
            .iter()
            .map(|s| s.queue_wait_secs + s.opt_secs)
            .collect();
        fields.push(("job_latency",
                     crate::telemetry::percentiles(&jobs).to_json()));
        fields.push(("queue_wait",
                     crate::telemetry::percentiles(&waits).to_json()));
        fields.push(("exec",
                     crate::telemetry::percentiles(&opts).to_json()));
        // Lane-occupancy timeline: per optimize lane, the (from, to)
        // run-relative intervals (seconds) it spent executing slices —
        // enough to reconstruct the utilization picture a trace viewer
        // would draw, straight from the report JSON.
        let timeline: Vec<Value> = self
            .sched
            .lane_timeline
            .iter()
            .map(|lane| {
                Value::Array(
                    lane.iter()
                        .map(|&(from, to)| {
                            Value::Array(vec![from.into(), to.into()])
                        })
                        .collect(),
                )
            })
            .collect();
        fields.push(("lane_timeline", Value::Array(timeline)));
        // Per-slice detail: iteration counts were collected in
        // SliceReport all along but dropped from the JSON, which made
        // BP-vs-MAP iteration comparisons impossible in bench reports.
        let slice_reports: Vec<Value> = self
            .slices
            .iter()
            .map(|s| {
                Value::object(vec![
                    ("z", s.z.into()),
                    ("regions", s.regions.into()),
                    ("hoods", s.hoods.into()),
                    ("elements", s.elements.into()),
                    ("em_iters", s.em_iters.into()),
                    ("map_iters", s.map_iters.into()),
                    ("init_secs", s.init_secs.into()),
                    ("opt_secs", s.opt_secs.into()),
                    ("lane", s.lane.into()),
                    ("queue_wait_secs", s.queue_wait_secs.into()),
                    ("final_energy", s.final_energy.into()),
                    ("lower_bound", opt_f64(s.lower_bound)),
                    ("optimality_gap", opt_f64(s.optimality_gap)),
                    ("pmp_particles",
                     match s.pmp_particles {
                         Some(p) => p.into(),
                         None => Value::Null,
                     }),
                    ("pmp_acceptance", opt_f64(s.pmp_acceptance)),
                    ("pmp_max_marginal_energy",
                     opt_f64(s.pmp_max_marginal_energy)),
                    ("bp_schedule",
                     match &s.bp_schedule {
                         Some(spec) => Value::str(spec.as_str()),
                         None => Value::Null,
                     }),
                    ("bp_committed_frac",
                     opt_f64(s.bp_committed_frac)),
                ])
            })
            .collect();
        fields.push(("slice_reports", Value::Array(slice_reports)));
        Value::object(fields)
    }
}

/// Pool + device for a run config, via the one shared construction
/// rule ([`crate::dpp::device_for`]) the scheduler's workers also use
/// — bitwise parity between serial and sharded runs depends on every
/// site constructing devices identically.
fn pool_and_device(cfg: &RunConfig) -> (Arc<Pool>, Arc<dyn Device>) {
    let device =
        device_for(cfg.device, cfg.threads, cfg.grain, &cfg.artifacts_dir);
    // The shared pool also serves engines outside the primitive
    // vocabulary (ReferenceEngine's coarse task parallelism), so it
    // honors `cfg.threads` even when the primitive device is
    // serial-execution (`--device serial|accel`) — but only for the
    // engine that actually consumes it.
    let pool = device.pool().unwrap_or_else(|| {
        crate::sched::fallback_pool(cfg.engine, cfg.threads)
    });
    (pool, device)
}

/// The coordinator owns the pool, the DPP device, and (for the xla
/// engine) the PJRT runtime; it is reused across runs.
pub struct Coordinator {
    pub cfg: RunConfig,
    pool: Arc<Pool>,
    device: Arc<dyn Device>,
    runtime: Option<Arc<EmRuntime>>,
}

impl Coordinator {
    pub fn new(cfg: RunConfig) -> Result<Coordinator> {
        let (pool, device) = pool_and_device(&cfg);
        let runtime = if cfg.engine == EngineKind::Xla {
            // The accel device may already carry the runtime; load
            // separately only when it does not.
            match device.accelerator_runtime() {
                Some(rt) => Some(rt),
                None => Some(Arc::new(
                    EmRuntime::load(&cfg.artifacts_dir)
                        .context("loading XLA artifacts")?,
                )),
            }
        } else {
            None
        };
        Ok(Coordinator { cfg, pool, device, runtime })
    }

    /// Pre-loaded runtime variant (lets benches share one runtime).
    /// With `DeviceKind::Accel` the runtime is routed straight into
    /// the accel seat instead of re-probing the artifacts dir.
    pub fn with_runtime(cfg: RunConfig, runtime: Arc<EmRuntime>)
        -> Coordinator {
        let (pool, device) = if cfg.device == DeviceKind::Accel {
            let device: Arc<dyn Device> = Arc::new(
                OfflineAcceleratorDevice::with_runtime(
                    Arc::clone(&runtime),
                ),
            );
            let pool = device.pool().unwrap_or_else(|| {
                crate::sched::fallback_pool(cfg.engine, cfg.threads)
            });
            (pool, device)
        } else {
            pool_and_device(&cfg)
        };
        Coordinator { cfg, pool, device, runtime: Some(runtime) }
    }

    /// The device this coordinator's primitives execute on.
    pub fn device(&self) -> &Arc<dyn Device> {
        &self.device
    }

    /// The resource bundle [`mrf::make_engine`] dispatches on.
    pub fn engine_resources(&self) -> mrf::EngineResources {
        mrf::EngineResources {
            pool: Arc::clone(&self.pool),
            device: Arc::clone(&self.device),
            runtime: self.runtime.clone(),
            bp: self.cfg.bp,
            dual: self.cfg.dual,
            pmp: self.cfg.pmp,
        }
    }

    /// Instantiate the configured engine (one dispatch site for every
    /// kind: [`mrf::make_engine`]).
    pub fn engine(&self) -> Box<dyn Engine> {
        mrf::make_engine(self.cfg.engine, &self.engine_resources())
            .expect("engine resources prepared in Coordinator::new")
    }

    /// Build the per-slice MRF model (initialization phase).
    pub fn build_slice_model(&self, input: &Volume, z: usize)
        -> (Overseg, MrfModel) {
        crate::sched::build_slice_model(
            &*self.device,
            &crate::dpp::Workspace::new(),
            &self.cfg,
            input,
            z,
        )
    }

    /// Run the full pipeline over every slice of the dataset, through
    /// the slice scheduler: `cfg.sched.lanes = 1` is the classic
    /// serial loop on this coordinator's device (bitwise-identical to
    /// the pre-scheduler path); more lanes shard the stack with the
    /// same per-slice results (DESIGN.md §8).
    pub fn run(&self, dataset: &Dataset) -> Result<RunReport> {
        crate::sched::run_slices(dataset, &self.cfg,
                                 &self.engine_resources())
    }

    /// Save a side-by-side PGM triptych (input / segmentation / truth)
    /// of one slice for qualitative inspection (Figs. 1–2 analog).
    pub fn save_figure(
        &self,
        dataset: &Dataset,
        report: &RunReport,
        z: usize,
        dir: &Path,
    ) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        dataset.input.write_pgm(z, &dir.join(format!("slice{z}_input.pgm")))?;
        report
            .output
            .write_pgm(z, &dir.join(format!("slice{z}_segmented.pgm")))?;
        if let Some(t) = &dataset.ground_truth {
            t.write_pgm(z, &dir.join(format!("slice{z}_truth.pgm")))?;
        }
        let thresh = crate::image::threshold::otsu(&dataset.input);
        thresh.write_pgm(z, &dir.join(format!("slice{z}_threshold.pgm")))?;
        Ok(())
    }
}

impl Coordinator {
    /// Direct-3D pipeline (the paper's §5 future-work mode): one
    /// oversegmentation, one 6-connected region graph, and one EM
    /// optimization over the entire volume instead of per-slice runs —
    /// region context flows across slice boundaries.
    pub fn run_3d(&self, dataset: &Dataset) -> Result<RunReport> {
        let input = &dataset.input;
        let engine = self.engine();
        let t_total = Timer::start();

        let t_init = Timer::start();
        // 6-connectivity gives the merger ~1.5x more edges per voxel
        // than 2D; shrink the scale constant so regions stay as pure
        // as their 2D counterparts.
        let overseg_cfg = crate::config::OversegConfig {
            scale: self.cfg.overseg.scale * 0.25,
            min_region: self.cfg.overseg.min_region,
        };
        let seg = crate::overseg::oversegment_3d(
            &*self.device, input, &overseg_cfg,
        );
        let graph = crate::graph::build_rag_3d(
            &*self.device, &seg, input.width, input.height, input.depth,
        );
        let cliques = crate::mce::enumerate_dpp(&*self.device, &graph);
        let hoods = mrf::hoods::build_dpp(
            &*self.device, &graph, &cliques, graph.num_vertices(),
        );
        let model = MrfModel { y: seg.mean.clone(), graph, hoods };
        let init_secs = t_init.elapsed_secs();

        // 3D region graphs are far denser than 2D ones, so the
        // absolute Potts sum (beta * disagreeing hood members) grows
        // with neighborhood size while the data term does not.
        // Normalize beta to the 2D operating point (mean hood size
        // ~12) so the smoothness/data balance carries over.
        let mean_hood = model.hoods.num_elements() as f64
            / model.hoods.num_hoods().max(1) as f64;
        let mut mrf_cfg = self.cfg.mrf.clone();
        mrf_cfg.beta = (self.cfg.mrf.beta * 12.0 / mean_hood.max(1.0))
            .min(self.cfg.mrf.beta);

        let t_opt = Timer::start();
        let res = engine.run(&model, &mrf_cfg);
        let opt_secs = t_opt.elapsed_secs();

        // Paint the whole volume at once (labels are voxel-linear).
        let mut output = Volume::new(input.width, input.height, input.depth);
        let bright: u8 = u8::from(res.params.mu[1] > res.params.mu[0]);
        for (p, &region) in seg.labels.iter().enumerate() {
            output.data[p] =
                if res.labels[region as usize] == bright { 255 } else { 0 };
        }

        let confusion = dataset
            .ground_truth
            .as_ref()
            .map(|t| Confusion::from_volumes(&output, t));
        let porosity = crate::eval::porosity(&output);
        Ok(RunReport {
            engine: engine.name(),
            device: self.device.name().to_string(),
            device_caps: self.device.caps(),
            output,
            slices: vec![SliceReport {
                z: 0,
                regions: seg.num_regions,
                hoods: model.hoods.num_hoods(),
                elements: model.hoods.num_elements(),
                em_iters: res.em_iters,
                map_iters: res.map_iters,
                init_secs,
                opt_secs,
                lane: 0,
                queue_wait_secs: 0.0,
                final_energy: res.energy,
                lower_bound: res.lower_bound,
                optimality_gap: res
                    .lower_bound
                    .map(|lb| (res.energy - lb).max(0.0)),
                pmp_particles: res.pmp.map(|p| p.particles),
                pmp_acceptance: res.pmp.map(|p| p.acceptance),
                pmp_max_marginal_energy: res
                    .pmp
                    .map(|p| p.max_marginal_energy),
                bp_schedule: res.bp.map(|b| b.schedule.spec()),
                bp_committed_frac: res.bp.map(|b| b.committed_frac),
            }],
            confusion,
            porosity,
            total_secs: t_total.elapsed_secs(),
            sched: SchedStats::serial(init_secs, opt_secs),
            convergence: crate::obs::drain(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetKind};

    fn base_cfg(engine: EngineKind) -> RunConfig {
        // Paper-level corruption (σ=100 Gaussian + salt&pepper +
        // ringing) — the regime Figs. 1–2 evaluate, where MRF
        // segmentation clearly beats thresholding.
        RunConfig {
            dataset: DatasetConfig {
                width: 64,
                height: 64,
                slices: 2,
                ..Default::default()
            },
            engine,
            threads: 2,
            ..Default::default()
        }
    }

    #[test]
    fn pipeline_runs_and_scores_synthetic() {
        let cfg = base_cfg(EngineKind::Dpp);
        let ds = crate::image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg).unwrap();
        let report = coord.run(&ds).unwrap();
        assert_eq!(report.slices.len(), 2);
        let c = report.confusion.expect("synthetic has ground truth");
        assert!(c.accuracy() > 0.85, "accuracy {}", c.accuracy());
        // At paper-level corruption, MRF must beat simple thresholding
        // (Fig. 1c vs 1d).
        let thr = crate::image::threshold::otsu(&ds.input);
        let tc = Confusion::from_volumes(&thr,
                                         ds.ground_truth.as_ref().unwrap());
        assert!(c.accuracy() > tc.accuracy(),
                "mrf {} vs threshold {}", c.accuracy(), tc.accuracy());
    }

    #[test]
    fn all_engines_produce_close_outputs() {
        let ds = crate::image::generate(&base_cfg(EngineKind::Dpp).dataset);
        let mut outputs = Vec::new();
        for engine in [EngineKind::Serial, EngineKind::Reference,
                       EngineKind::Dpp] {
            let coord = Coordinator::new(base_cfg(engine)).unwrap();
            let report = coord.run(&ds).unwrap();
            outputs.push(report.output);
        }
        let n = outputs[0].voxels() as f64;
        for o in &outputs[1..] {
            let agree = o
                .data
                .iter()
                .zip(&outputs[0].data)
                .filter(|(a, b)| a == b)
                .count() as f64;
            assert!(agree / n > 0.995, "agreement {}", agree / n);
        }
    }

    #[test]
    fn experimental_dataset_runs_without_truth() {
        let mut cfg = base_cfg(EngineKind::Reference);
        cfg.dataset.kind = DatasetKind::Experimental;
        let ds = crate::image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg).unwrap();
        let report = coord.run(&ds).unwrap();
        assert!(report.confusion.is_none());
        assert!(report.porosity > 0.0 && report.porosity < 1.0);
    }

    #[test]
    fn direct_3d_mode_matches_or_beats_slicewise() {
        let cfg = base_cfg(EngineKind::Dpp);
        let ds = crate::image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg).unwrap();
        let slicewise = coord.run(&ds).unwrap();
        let direct = coord.run_3d(&ds).unwrap();
        let a2 = slicewise.confusion.unwrap().accuracy();
        let a3 = direct.confusion.unwrap().accuracy();
        // The 3D mode is the paper's *future work* (§5): it must
        // produce a sound segmentation in the same quality regime as
        // the slice-wise protocol (our synthetic field is only mildly
        // z-correlated, so it does not dominate here).
        assert!(a3 > 0.8, "3d accuracy {a3}");
        assert!(a3 >= a2 - 0.08, "3d {a3} vs slicewise {a2}");
        assert_eq!(direct.output.voxels(), ds.input.voxels());
    }

    #[test]
    fn report_json_has_metrics() {
        let cfg = base_cfg(EngineKind::Serial);
        let ds = crate::image::generate(&cfg.dataset);
        let coord = Coordinator::new(cfg).unwrap();
        let report = coord.run(&ds).unwrap();
        let j = report.to_json();
        assert!(j.get("accuracy").is_some());
        // Device identity + capability flags (device tentpole): the
        // base_cfg runs threads=2 under DeviceKind::Auto -> pool.
        assert_eq!(j.get("device").and_then(|v| v.as_str()), Some("pool"));
        assert_eq!(
            j.get("device_threaded").and_then(|v| v.as_bool()),
            Some(true)
        );
        assert_eq!(
            j.get("device_offload").and_then(|v| v.as_bool()),
            Some(false)
        );
        assert!(j.get("mean_opt_secs").and_then(|v| v.as_f64()).unwrap()
                > 0.0);
        // Throughput metrics (sched tentpole): whole-run wall clock
        // and slices/sec must be present and consistent.
        let total = j.get("total_secs").and_then(|v| v.as_f64()).unwrap();
        assert!(total > 0.0);
        let sps =
            j.get("slices_per_sec").and_then(|v| v.as_f64()).unwrap();
        assert!((sps - report.slices.len() as f64 / total).abs() < 1e-9);
        assert_eq!(j.get("lanes").and_then(|v| v.as_f64()), Some(1.0));
        let occ =
            j.get("lane_occupancy").and_then(|v| v.as_f64()).unwrap();
        assert!((0.0..=1.0).contains(&occ));
        // Serving latency percentiles (telemetry tentpole): always in
        // the report, profiling on or off.
        let lat = j.get("job_latency").expect("job_latency object");
        for q in ["p50", "p90", "p99"] {
            let v = lat.get(q).and_then(|v| v.as_f64()).unwrap();
            assert!(v > 0.0, "job_latency.{q} = {v}");
        }
        assert!(lat.get("p50").unwrap().as_f64()
                <= lat.get("p99").unwrap().as_f64());
        assert!(j.get("queue_wait").and_then(|v| v.get("p50")).is_some());
        assert!(j.get("exec").and_then(|v| v.get("p99")).is_some());
        // One timeline per lane; the serial run records every slice's
        // optimize interval on its single lane.
        match j.get("lane_timeline") {
            Some(crate::json::Value::Array(lanes)) => {
                assert_eq!(lanes.len(), 1, "serial run has one lane");
                let spans = lanes[0].as_array().unwrap();
                assert_eq!(spans.len(), report.slices.len());
            }
            other => panic!("lane_timeline missing/not array: {other:?}"),
        }
        // Iteration counts must survive into the JSON, per slice and
        // in total, so engines' inner-loop costs are comparable.
        assert!(j.get("em_iters").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        assert!(j.get("map_iters").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        match j.get("slice_reports") {
            Some(crate::json::Value::Array(rows)) => {
                assert_eq!(rows.len(), report.slices.len());
                for (row, s) in rows.iter().zip(&report.slices) {
                    assert_eq!(
                        row.get("em_iters").and_then(|v| v.as_f64()),
                        Some(s.em_iters as f64)
                    );
                    assert_eq!(
                        row.get("map_iters").and_then(|v| v.as_f64()),
                        Some(s.map_iters as f64)
                    );
                }
            }
            other => panic!("slice_reports missing/not array: {other:?}"),
        }
    }

    #[test]
    fn sharded_run_matches_single_lane() {
        // Smoke-level check of the scheduler dispatch (the full
        // lanes × engines sweep lives in tests/sched_determinism.rs).
        let mut cfg = base_cfg(EngineKind::Dpp);
        cfg.dataset.slices = 4;
        let ds = crate::image::generate(&cfg.dataset);
        let serial =
            Coordinator::new(cfg.clone()).unwrap().run(&ds).unwrap();
        assert_eq!(serial.sched.lanes, 1);
        cfg.sched.lanes = 2;
        let sharded = Coordinator::new(cfg).unwrap().run(&ds).unwrap();
        assert_eq!(sharded.sched.lanes, 2);
        assert_eq!(sharded.output.data, serial.output.data);
        for (a, b) in sharded.slices.iter().zip(&serial.slices) {
            assert_eq!(a.z, b.z);
            assert_eq!(a.final_energy.to_bits(), b.final_energy.to_bits());
        }
    }

    #[test]
    fn bp_engine_runs_end_to_end_and_matches_serial_quality() {
        let ds = crate::image::generate(&base_cfg(EngineKind::Bp).dataset);

        let serial =
            Coordinator::new(base_cfg(EngineKind::Serial)).unwrap()
                .run(&ds).unwrap();
        let bp = Coordinator::new(base_cfg(EngineKind::Bp)).unwrap()
            .run(&ds).unwrap();

        assert_eq!(bp.engine, "bp");
        assert_eq!(bp.slices.len(), serial.slices.len());
        let acc = bp.confusion.expect("synthetic has truth").accuracy();
        assert!(acc > 0.85, "bp accuracy {acc}");
        // Acceptance bar: per-slice final energy within 5% of the
        // serial MAP engine on the same fixture.
        for (b, s) in bp.slices.iter().zip(&serial.slices) {
            let rel = (b.final_energy - s.final_energy).abs()
                / s.final_energy.abs().max(1.0);
            assert!(rel < 0.05,
                    "slice {}: bp {} vs serial {} (rel {rel})",
                    b.z, b.final_energy, s.final_energy);
        }
    }
}
