//! # DPP-PMRF
//!
//! Reproduction of *“DPP-PMRF: Rethinking Optimization for a
//! Probabilistic Graphical Model Using Data-Parallel Primitives”*
//! (Lessley et al., 2018): Markov-Random-Field image segmentation
//! reformulated entirely in terms of data-parallel primitives, with a
//! serial baseline, a coarse-parallel "OpenMP" reference engine, the
//! fine-grained DPP engine, an AOT-compiled XLA/PJRT accelerator
//! path (JAX + Pallas at build time, rust-only at run time), and a
//! data-parallel loopy belief propagation engine ([`bp`]) with
//! residual message scheduling, a dual-decomposition engine
//! ([`dual`]) whose MPLP-style ascent certifies per-run optimality
//! gaps, and a particle max-product engine ([`pmp`]) that carries
//! the same DPP vocabulary into **continuous** label spaces
//! (per-vertex particle sets, seeded random-walk proposals,
//! select-and-prune). Above the engines, a sharded slice
//! scheduler and batch serving front end ([`sched`]) turn the
//! per-slice pipeline into a throughput system, observed end to end
//! by the [`telemetry`] layer (scoped metric recorders, span tracing,
//! latency percentiles) and the [`obs`] layer on top of it
//! (convergence flight recorder, serving health + SLOs, Prometheus
//! exposition).
//!
//! See `README.md` for the front door (quickstart + the bench ->
//! paper-figure map) and `DESIGN.md` for the architecture.

pub mod bench_support;
pub mod bp;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dpp;
pub mod dual;
pub mod eval;
pub mod graph;
pub mod image;
pub mod json;
pub mod mce;
pub mod mrf;
pub mod obs;
pub mod overseg;
pub mod pmp;
pub mod pool;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod util;

/// Convenient re-exports for examples and benches.
pub mod prelude {
    pub use crate::bp::{BpConfig, BpSchedule};
    pub use crate::config::{DatasetKind, EngineKind, RunConfig,
                            SchedConfig};
    // `Backend` is the deprecated device spelling, re-exported for one
    // release; see the migration table in README.md.
    pub use crate::dpp::Backend;
    pub use crate::dpp::{device_for, Device, DeviceCaps, DeviceExt,
                         DeviceKind, IntoDevice,
                         OfflineAcceleratorDevice, PoolDevice,
                         SerialDevice};
    pub use crate::pool::Pool;
    pub use crate::sched::{Job, Service};
    pub use crate::telemetry::{LatencySummary, MetricsSnapshot, Recorder,
                               Tracer};
    pub use crate::util::{Pcg32, Timer};
}
