//! Verification metrics (paper §4.2.1): precision, recall, accuracy
//! from a voxel confusion matrix, plus porosity (void fraction).
//!
//! Named `eval` since ISSUE 8 — the old `crate::metrics` path was one
//! keystroke away from the *performance* metrics in
//! [`crate::telemetry`] and [`crate::obs`], and kept being confused
//! with them. A deprecated `crate::metrics` re-export shim covered
//! the rename for one release and was removed in ISSUE 9; spell it
//! `crate::eval` (see README release notes).

use crate::image::Volume;

/// Voxel-level confusion matrix for binary volumes (0 = negative/void,
/// 255 = positive/solid).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub tn: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl Confusion {
    /// Compare a predicted binary volume against ground truth.
    pub fn from_volumes(pred: &Volume, truth: &Volume) -> Confusion {
        assert_eq!(pred.data.len(), truth.data.len(), "shape mismatch");
        let mut c = Confusion::default();
        for (&p, &t) in pred.data.iter().zip(truth.data.iter()) {
            match (p > 127, t > 127) {
                (true, true) => c.tp += 1,
                (false, false) => c.tn += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn total(&self) -> u64 {
        self.tp + self.tn + self.fp + self.fn_
    }

    /// precision = TP / (TP + FP)
    pub fn precision(&self) -> f64 {
        ratio(self.tp, self.tp + self.fp)
    }

    /// recall = TP / (TP + FN)
    pub fn recall(&self) -> f64 {
        ratio(self.tp, self.tp + self.fn_)
    }

    /// accuracy = (TP + TN) / total
    pub fn accuracy(&self) -> f64 {
        ratio(self.tp + self.tn, self.total())
    }

    /// F1 = harmonic mean of precision and recall.
    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) }
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 { 0.0 } else { num as f64 / den as f64 }
}

/// Porosity ρ = V_void / V_total for a binary volume (0 = void).
pub fn porosity(vol: &Volume) -> f64 {
    vol.zero_fraction()
}

/// Pretty one-line metric summary (percentages, paper style).
pub fn summary(c: &Confusion) -> String {
    format!(
        "precision {:.1}%  recall {:.1}%  accuracy {:.1}%  f1 {:.1}%",
        c.precision() * 100.0,
        c.recall() * 100.0,
        c.accuracy() * 100.0,
        c.f1() * 100.0
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vol(data: Vec<u8>) -> Volume {
        let n = data.len();
        Volume::from_data(n, 1, 1, data)
    }

    #[test]
    fn perfect_prediction() {
        let t = vol(vec![0, 255, 255, 0]);
        let c = Confusion::from_volumes(&t, &t);
        assert_eq!(c.precision(), 1.0);
        assert_eq!(c.recall(), 1.0);
        assert_eq!(c.accuracy(), 1.0);
        assert_eq!(c.f1(), 1.0);
    }

    #[test]
    fn known_confusion_counts() {
        let truth = vol(vec![255, 255, 0, 0]);
        let pred = vol(vec![255, 0, 255, 0]);
        let c = Confusion::from_volumes(&pred, &truth);
        assert_eq!((c.tp, c.fn_, c.fp, c.tn), (1, 1, 1, 1));
        assert_eq!(c.precision(), 0.5);
        assert_eq!(c.recall(), 0.5);
        assert_eq!(c.accuracy(), 0.5);
    }

    #[test]
    fn degenerate_no_positives() {
        let truth = vol(vec![0, 0]);
        let pred = vol(vec![0, 0]);
        let c = Confusion::from_volumes(&pred, &truth);
        assert_eq!(c.precision(), 0.0); // no positive predictions
        assert_eq!(c.accuracy(), 1.0);
    }

    #[test]
    fn porosity_counts_zeros() {
        assert_eq!(porosity(&vol(vec![0, 0, 255, 255])), 0.5);
    }

    #[test]
    fn summary_formats() {
        let c = Confusion { tp: 99, tn: 1, fp: 1, fn_: 1 };
        let s = summary(&c);
        assert!(s.contains("precision 99.0%"));
    }
}
