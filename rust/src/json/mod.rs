//! Minimal JSON substrate (parser + serializer).
//!
//! The offline registry has no `serde`; configs, artifact manifests and
//! benchmark reports all go through this module. It implements the full
//! JSON grammar (RFC 8259) minus `\u` surrogate-pair edge refinements,
//! which none of our documents use.

mod parse;
mod value;

pub use parse::{parse, ParseError};
pub use value::Value;

/// Convenience: parse a file.
pub fn from_file(path: &std::path::Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null},
                      "s": "hi\n\"there\""}"#;
        let v = parse(src).unwrap();
        let text = v.to_string();
        let v2 = parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "xs": [1, 2], "s": "x", "f": false}"#)
            .unwrap();
        assert_eq!(v.get("n").and_then(Value::as_i64), Some(3));
        assert_eq!(v.get("xs").and_then(Value::as_array).map(|a| a.len()),
                   Some(2));
        assert_eq!(v.get("s").and_then(Value::as_str), Some("x"));
        assert_eq!(v.get("f").and_then(Value::as_bool), Some(false));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "\"\\x\"", "1 2"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
