//! Recursive-descent JSON parser.

use std::collections::BTreeMap;
use std::fmt;

use super::Value;

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed,
/// trailing garbage rejected).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("bad number `{text}`")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers() {
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(parse("1e-3").unwrap().as_f64(), Some(0.001));
    }

    #[test]
    fn nested() {
        let v = parse(r#"[{"a":[[]]},[{}]]"#).unwrap();
        assert!(v.idx(0).unwrap().get("a").is_some());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse("{}").unwrap(),
                   Value::Object(Default::default()));
    }

    #[test]
    fn error_positions() {
        let e = parse("[1, x]").unwrap_err();
        assert_eq!(e.pos, 4);
    }
}
