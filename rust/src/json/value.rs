//! JSON value tree + serializer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node. Objects use a BTreeMap so serialization is
/// deterministic (stable diffs in committed reports).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Array(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|f| {
            if f.fract() == 0.0 && f.abs() < 9.0e15 {
                Some(f as i64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Builder helpers.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn num(n: f64) -> Value {
        Value::Number(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::String(s.into())
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..n {
                out.push_str("  ");
            }
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::String(s) => escape(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                write_value(item, out, indent + 1, pretty);
            }
            if !a.is_empty() {
                pad(out, indent);
            }
            out.push(']');
        }
        Value::Object(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(item, out, indent + 1, pretty);
            }
            if !m.is_empty() {
                pad(out, indent);
            }
            out.push('}');
        }
    }
}

impl Value {
    /// Pretty serialization (2-space indent).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, 0, true);
        out.push('\n');
        out
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        write_value(self, &mut out, 0, false);
        f.write_str(&out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_vs_pretty() {
        let v = Value::object(vec![
            ("a", Value::Array(vec![1.0.into(), 2.0.into()])),
            ("b", "x".into()),
        ]);
        assert_eq!(v.to_string(), r#"{"a":[1,2],"b":"x"}"#);
        assert!(v.to_pretty().contains("\n  \"a\": ["));
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::num(3.0).to_string(), "3");
        assert_eq!(Value::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(Value::str("a\u{1}b").to_string(), "\"a\\u0001b\"");
    }
}
