//! Union-find (disjoint set) with union by size and path halving.

/// Disjoint-set forest over `0..n`.
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    sets: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> UnionFind {
        UnionFind {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            sets: n,
        }
    }

    /// Root of `x` (with path halving).
    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp;
            x = gp as usize;
        }
        x
    }

    /// Size of the set containing root `r` (call with a root for O(1)).
    pub fn size(&mut self, x: usize) -> usize {
        let r = self.find(x);
        self.size[r] as usize
    }

    /// Union the sets of `a` and `b`; returns the new root.
    pub fn union(&mut self, a: usize, b: usize) -> usize {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return ra;
        }
        let (big, small) = if self.size[ra] >= self.size[rb] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[small] = big as u32;
        self.size[big] += self.size[small];
        self.sets -= 1;
        big
    }

    /// Number of disjoint sets remaining.
    pub fn num_sets(&self) -> usize {
        self.sets
    }

    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_unions() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.num_sets(), 5);
        uf.union(0, 1);
        uf.union(3, 4);
        assert_eq!(uf.num_sets(), 3);
        assert!(uf.same(0, 1));
        assert!(!uf.same(1, 2));
        uf.union(1, 3);
        assert!(uf.same(0, 4));
        assert_eq!(uf.num_sets(), 2);
    }

    #[test]
    fn sizes_accumulate() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 1);
        uf.union(0, 2);
        assert_eq!(uf.size(1), 3);
        assert_eq!(uf.size(5), 1);
    }

    #[test]
    fn union_idempotent() {
        let mut uf = UnionFind::new(3);
        let r1 = uf.union(0, 1);
        let r2 = uf.union(0, 1);
        assert_eq!(r1, r2);
        assert_eq!(uf.num_sets(), 2);
    }

    #[test]
    fn chain_flattens() {
        let mut uf = UnionFind::new(1000);
        for i in 0..999 {
            uf.union(i, i + 1);
        }
        assert_eq!(uf.num_sets(), 1);
        assert_eq!(uf.size(0), 1000);
        assert!(uf.same(0, 999));
    }
}
