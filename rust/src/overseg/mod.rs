//! Oversegmentation: partition a 2D slice into irregular superpixel
//! regions of statistically similar intensity (paper §3.1).
//!
//! Felzenszwalb–Huttenlocher graph-based merging: 4-connected pixel
//! edges weighted by intensity difference are processed in ascending
//! weight order; two components merge when the edge weight is within
//! each component's internal difference plus a size-scaled tolerance
//! (`scale / |C|`). A final pass absorbs regions smaller than
//! `min_region`. Edge ordering is one stable DPP radix argsort of the
//! weight keys; both merge passes walk the cached permutation (sort
//! paid once, served twice), so the oversegmentation is itself a DPP
//! client, as in the paper.
//!
//! Scratch reuse: [`oversegment_ws`] draws the argsort arrays and the
//! union-find side tables from a caller-held
//! [`crate::dpp::Workspace`]. The scheduler's init lanes hold one
//! workspace per lane ([`crate::sched`]), so a many-slice stack pays
//! the oversegmentation's buffer allocations once per lane instead of
//! once per slice (DESIGN.md §10).

mod unionfind;

pub use unionfind::UnionFind;

use crate::config::OversegConfig;
use crate::dpp::{self, Device, Workspace};
use crate::image::ImageSlice;

/// Result of oversegmenting one slice: a compact region labeling plus
/// per-region statistics (the MRF's `y` observations).
#[derive(Debug, Clone)]
pub struct Overseg {
    /// Per-pixel region id in `0..num_regions`.
    pub labels: Vec<u32>,
    pub num_regions: usize,
    /// Mean intensity per region.
    pub mean: Vec<f32>,
    /// Pixel count per region.
    pub size: Vec<u32>,
    pub width: usize,
    pub height: usize,
}

/// 4-connectivity pixel edges, weight = |ΔI|. The radix sort behind
/// the weight [`crate::dpp::SegmentPlan`] is stable, so equal-weight
/// edges keep build order and the merging is deterministic.
fn build_edges(img: &ImageSlice) -> (Vec<u32>, Vec<u32>, Vec<u8>) {
    let (w, h) = (img.width, img.height);
    let mut a = Vec::with_capacity(2 * w * h);
    let mut b = Vec::with_capacity(2 * w * h);
    let mut wt = Vec::with_capacity(2 * w * h);
    for y in 0..h {
        for x in 0..w {
            let p = (y * w + x) as u32;
            let ip = img.at(x, y);
            if x + 1 < w {
                a.push(p);
                b.push(p + 1);
                wt.push(ip.abs_diff(img.at(x + 1, y)));
            }
            if y + 1 < h {
                a.push(p);
                b.push(p + w as u32);
                wt.push(ip.abs_diff(img.at(x, y + 1)));
            }
        }
    }
    (a, b, wt)
}

/// Oversegment one image slice.
pub fn oversegment(bk: &dyn Device, img: &ImageSlice, cfg: &OversegConfig)
    -> Overseg {
    oversegment_ws(bk, &Workspace::new(), img, cfg)
}

/// [`oversegment`] drawing its scratch (edge-order argsort arrays,
/// union-find side tables) from a caller-held workspace — bitwise
/// the same regions; a lane that segments many slices through one
/// workspace allocates the scratch once instead of per slice.
///
/// # Examples
///
/// ```
/// use dpp_pmrf::config::OversegConfig;
/// use dpp_pmrf::dpp::{SerialDevice, Workspace};
/// use dpp_pmrf::image::synth;
/// use dpp_pmrf::overseg::{oversegment, oversegment_ws};
/// let v = synth::porous_ground_truth(16, 16, 1, 0.4, 7);
/// let cfg = OversegConfig { scale: 64.0, min_region: 2 };
/// let ws = Workspace::new();
/// let a = oversegment_ws(&SerialDevice, &ws, &v.slice(0), &cfg);
/// let b = oversegment(&SerialDevice, &v.slice(0), &cfg);
/// assert_eq!(a.labels, b.labels);
/// ```
pub fn oversegment_ws(
    bk: &dyn Device,
    ws: &Workspace,
    img: &ImageSlice,
    cfg: &OversegConfig,
) -> Overseg {
    let (ea, eb, ew) = build_edges(img);
    segment_core(bk, ws, img.pixels, &ea, &eb, &ew, img.width,
                 img.height, cfg)
}

/// Oversegment a full 3D volume directly (the paper's §5 future-work
/// extension): 6-connectivity voxel edges, one region partition for the
/// whole stack instead of per-slice partitions. The returned
/// [`Overseg`] flattens z into the height axis (`height = h * depth`),
/// which every downstream consumer (RAG, hoods, painting) already
/// handles since they only read `labels` linearly.
pub fn oversegment_3d(bk: &dyn Device, vol: &crate::image::Volume,
                      cfg: &OversegConfig) -> Overseg {
    let (w, h, d) = (vol.width, vol.height, vol.depth);
    let mut a = Vec::with_capacity(3 * vol.voxels());
    let mut b = Vec::with_capacity(3 * vol.voxels());
    let mut wt = Vec::with_capacity(3 * vol.voxels());
    let plane = w * h;
    for z in 0..d {
        for y in 0..h {
            for x in 0..w {
                let p = z * plane + y * w + x;
                let ip = vol.data[p];
                if x + 1 < w {
                    a.push(p as u32);
                    b.push((p + 1) as u32);
                    wt.push(ip.abs_diff(vol.data[p + 1]));
                }
                if y + 1 < h {
                    a.push(p as u32);
                    b.push((p + w) as u32);
                    wt.push(ip.abs_diff(vol.data[p + w]));
                }
                if z + 1 < d {
                    a.push(p as u32);
                    b.push((p + plane) as u32);
                    wt.push(ip.abs_diff(vol.data[p + plane]));
                }
            }
        }
    }
    segment_core(bk, &Workspace::new(), &vol.data, &a, &b, &wt, w,
                 h * d, cfg)
}

/// Shared Felzenszwalb merging core over an explicit edge list.
#[allow(clippy::too_many_arguments)]
fn segment_core(
    bk: &dyn Device,
    ws: &Workspace,
    intensity: &[u8],
    ea: &[u32],
    eb: &[u32],
    ew: &[u8],
    width: usize,
    height: usize,
    cfg: &OversegConfig,
) -> Overseg {
    let n = intensity.len();
    let m = ew.len();

    // Edge ordering: one stable radix argsort of the weight keys
    // through the workspace (SortByKey paid once); both merge passes
    // below walk the cached permutation with no further sort. A
    // stable sort with an iota payload yields exactly the order the
    // old SegmentPlan::ordered_indices produced, minus the plan's
    // unused segment-detection passes.
    let mut keys = ws.take_spare::<u64>(m);
    dpp::map_into(bk, ew, |&w| w as u64, &mut keys);
    let mut order = ws.take_spare::<u32>(m);
    dpp::iota_into(bk, m, &mut order);
    dpp::sort_by_key_ws(bk, ws, &mut keys, &mut order);

    // Sequential merging (union-find is inherently sequential; the
    // paper's pipeline also builds the graph once per slice).
    let mut uf = UnionFind::new(n);
    // Max internal edge weight per component root.
    let mut internal = ws.take::<f64>(n);
    let scale = cfg.scale.max(0.0);
    for &ei in order.iter() {
        let ei = ei as usize;
        let (pa, pb, w) =
            (ea[ei] as usize, eb[ei] as usize, ew[ei] as f64);
        let ra = uf.find(pa);
        let rb = uf.find(pb);
        if ra == rb {
            continue;
        }
        let ta = internal[ra] + scale / uf.size(ra) as f64;
        let tb = internal[rb] + scale / uf.size(rb) as f64;
        if w <= ta && w <= tb {
            let r = uf.union(ra, rb);
            internal[r] = w.max(internal[ra]).max(internal[rb]);
        }
    }

    // Absorb small regions into an arbitrary neighbor (ascending edge
    // order keeps this deterministic and edge-contrast-aware).
    if cfg.min_region > 1 {
        for &ei in order.iter() {
            let ei = ei as usize;
            let ra = uf.find(ea[ei] as usize);
            let rb = uf.find(eb[ei] as usize);
            if ra != rb
                && (uf.size(ra) < cfg.min_region
                    || uf.size(rb) < cfg.min_region)
            {
                uf.union(ra, rb);
            }
        }
    }

    // Compact labels 0..R-1 (first-appearance order: deterministic).
    let mut remap = ws.take_filled::<u32>(n, u32::MAX);
    let mut labels = vec![0u32; n];
    let mut num_regions = 0u32;
    for p in 0..n {
        let r = uf.find(p);
        if remap[r] == u32::MAX {
            remap[r] = num_regions;
            num_regions += 1;
        }
        labels[p] = remap[r];
    }

    // Region statistics: one O(n) accumulation. (A SegmentPlan over
    // the labels would work too, but it is read exactly once here, so
    // its sort could never amortize — the plan layer is for the keys
    // the hot loops reduce over every iteration.)
    let mut sum = ws.take::<u64>(num_regions as usize);
    let mut size = vec![0u32; num_regions as usize];
    for (p, &l) in labels.iter().enumerate() {
        sum[l as usize] += intensity[p] as u64;
        size[l as usize] += 1;
    }
    let mean = sum
        .iter()
        .zip(&size[..])
        .map(|(&s, &c)| s as f32 / c.max(1) as f32)
        .collect();

    Overseg {
        labels,
        num_regions: num_regions as usize,
        mean,
        size,
        width,
        height,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::image::Volume;
    use crate::pool::Pool;

    fn cfg() -> OversegConfig {
        OversegConfig { scale: 64.0, min_region: 4 }
    }

    fn checkerboard_halves() -> Volume {
        // left half 40, right half 200 -> exactly 2 regions expected
        let mut v = Volume::new(16, 16, 1);
        for y in 0..16 {
            for x in 0..16 {
                v.set(x, y, 0, if x < 8 { 40 } else { 200 });
            }
        }
        v
    }

    #[test]
    fn two_flat_halves_two_regions() {
        let v = checkerboard_halves();
        let o = oversegment(&Backend::Serial, &v.slice(0), &cfg());
        assert_eq!(o.num_regions, 2);
        assert_eq!(o.labels[0], 0);
        assert_eq!(o.labels[15], 1);
        assert!((o.mean[0] - 40.0).abs() < 1e-5);
        assert!((o.mean[1] - 200.0).abs() < 1e-5);
        assert_eq!(o.size[0] + o.size[1], 256);
    }

    #[test]
    fn labels_are_compact_and_cover() {
        let v = crate::image::synth::experimental_volume(48, 48, 1, 3);
        let o = oversegment(&Backend::Serial, &v.slice(0), &cfg());
        assert!(o.num_regions > 2);
        let max = *o.labels.iter().max().unwrap() as usize;
        assert_eq!(max + 1, o.num_regions);
        assert_eq!(o.size.iter().sum::<u32>() as usize, 48 * 48);
    }

    #[test]
    fn min_region_enforced() {
        let v = crate::image::synth::experimental_volume(48, 48, 1, 5);
        let o = oversegment(&Backend::Serial, &v.slice(0), &OversegConfig {
            scale: 16.0,
            min_region: 8,
        });
        assert!(o.size.iter().all(|&s| s >= 8),
                "min size {:?}", o.size.iter().min());
    }

    #[test]
    fn serial_and_threaded_agree() {
        let v = crate::image::synth::experimental_volume(40, 40, 1, 9);
        let a = oversegment(&Backend::Serial, &v.slice(0), &cfg());
        let b = oversegment(
            &Backend::threaded_with_grain(Pool::new(4), 256),
            &v.slice(0),
            &cfg(),
        );
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.mean, b.mean);
    }

    #[test]
    fn flat_image_single_region() {
        let v = Volume::from_data(8, 8, 1, vec![77; 64]);
        let o = oversegment(&Backend::Serial, &v.slice(0), &cfg());
        assert_eq!(o.num_regions, 1);
        assert_eq!(o.mean[0], 77.0);
    }
}
