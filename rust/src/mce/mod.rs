//! Maximal clique enumeration (MCE) over the region adjacency graph.
//!
//! The MRF neighborhood structure is built from maximal cliques
//! (paper §3.1–3.2; DPP-based MCE is Lessley et al., LDAV 2017 [23]).
//! Two implementations:
//!
//! * [`enumerate_serial`] — Bron–Kerbosch with pivoting (the classical
//!   reference; also the correctness oracle).
//! * [`enumerate_dpp`] — iterative, breadth-first *ordered expansion*
//!   composed from DPPs: level k holds all k-cliques as a flat array;
//!   Map counts ascending extensions, Scan allocates, Map fills, Map
//!   flags maximality, CopyIf compacts the maximal ones. Every clique
//!   (sorted ascending) is generated exactly once from its prefix, so
//!   no dedup sort is needed.
//!
//! RAGs are near-planar, so cliques are small (≤ 4 in practice) and the
//! level count stays tiny.

use crate::dpp::{self, Device, DeviceExt};
use crate::graph::Csr;

/// A set of cliques in ragged CSR-like storage. Each clique's vertices
/// are sorted ascending.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CliqueSet {
    pub offsets: Vec<u32>,
    pub members: Vec<u32>,
}

impl CliqueSet {
    pub fn num_cliques(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn clique(&self, i: usize) -> &[u32] {
        &self.members[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    pub fn push(&mut self, clique: &[u32]) {
        if self.offsets.is_empty() {
            self.offsets.push(0);
        }
        self.members.extend_from_slice(clique);
        self.offsets.push(self.members.len() as u32);
    }

    /// Canonical form for comparisons: cliques sorted lexicographically.
    pub fn normalized(&self) -> Vec<Vec<u32>> {
        let mut all: Vec<Vec<u32>> = (0..self.num_cliques())
            .map(|i| self.clique(i).to_vec())
            .collect();
        all.sort();
        all
    }

    /// Rebuild in canonical (lexicographic) clique order. Both
    /// enumerators finish with this so hood numbering is identical no
    /// matter which backend built the model.
    fn canonicalize(self) -> CliqueSet {
        let mut out = CliqueSet::default();
        out.offsets.push(0);
        for clique in self.normalized() {
            out.push(&clique);
        }
        out
    }
}

/// Bron–Kerbosch with pivoting. Emits cliques with members ascending.
pub fn enumerate_serial(g: &Csr) -> CliqueSet {
    let n = g.num_vertices();
    let mut out = CliqueSet::default();
    if n == 0 {
        out.offsets.push(0);
        return out;
    }
    let mut r: Vec<u32> = Vec::new();
    let p: Vec<u32> = (0..n as u32).collect();
    let x: Vec<u32> = Vec::new();
    bron_kerbosch(g, &mut r, p, x, &mut out);
    if out.offsets.is_empty() {
        out.offsets.push(0);
    }
    out.canonicalize()
}

fn bron_kerbosch(
    g: &Csr,
    r: &mut Vec<u32>,
    p: Vec<u32>,
    x: Vec<u32>,
    out: &mut CliqueSet,
) {
    if p.is_empty() && x.is_empty() {
        let mut clique = r.clone();
        clique.sort_unstable();
        out.push(&clique);
        return;
    }
    // Pivot: vertex of P ∪ X with most neighbors in P.
    let pivot = p
        .iter()
        .chain(x.iter())
        .copied()
        .max_by_key(|&u| {
            p.iter().filter(|&&v| g.adjacent(u, v)).count()
        })
        .unwrap();
    let candidates: Vec<u32> =
        p.iter().copied().filter(|&v| !g.adjacent(pivot, v)).collect();
    let mut p = p;
    let mut x = x;
    for v in candidates {
        let nv = g.neighbors_of(v);
        let p2: Vec<u32> =
            p.iter().copied().filter(|&u| nv.binary_search(&u).is_ok())
                .collect();
        let x2: Vec<u32> =
            x.iter().copied().filter(|&u| nv.binary_search(&u).is_ok())
                .collect();
        r.push(v);
        bron_kerbosch(g, r, p2, x2, out);
        r.pop();
        p.retain(|&u| u != v);
        x.push(v);
    }
}

/// Is `w` adjacent to every vertex of `clique`?
#[inline]
fn adjacent_to_all(g: &Csr, w: u32, clique: &[u32]) -> bool {
    clique.iter().all(|&u| g.adjacent(w, u))
}

/// Does any vertex extend `clique` (i.e. is it NOT maximal)?
/// Scans the neighbor list of the clique's minimum-degree member.
fn has_extension(g: &Csr, clique: &[u32]) -> bool {
    let probe = *clique
        .iter()
        .min_by_key(|&&v| g.degree(v))
        .expect("non-empty clique");
    g.neighbors_of(probe).iter().any(|&w| {
        !clique.contains(&w) && adjacent_to_all(g, w, clique)
    })
}

/// DPP-based MCE by ordered expansion (see module docs).
pub fn enumerate_dpp(bk: &dyn Device, g: &Csr) -> CliqueSet {
    let n = g.num_vertices();
    let mut out = CliqueSet::default();
    out.offsets.push(0);
    if n == 0 {
        return out;
    }

    // Isolated vertices are maximal 1-cliques.
    let isolated = dpp::select_indices(bk, n, |v| g.degree(v as u32) == 0);
    for v in &isolated {
        out.push(&[*v]);
    }

    // Level 2: every undirected edge (u < v), flattened from CSR by a
    // CopyIf over the directed neighbor array.
    let dir_src: Vec<u32> = {
        // src vertex of each directed CSR entry
        let mut src = vec![0u32; g.neighbors.len()];
        for v in 0..n {
            for i in g.offsets[v] as usize..g.offsets[v + 1] as usize {
                src[i] = v as u32;
            }
        }
        src
    };
    let fwd = dpp::select_indices(bk, g.neighbors.len(), |i| {
        dir_src[i] < g.neighbors[i]
    });
    let mut level: Vec<u32> = Vec::with_capacity(fwd.len() * 2);
    for &i in &fwd {
        level.push(dir_src[i as usize]);
        level.push(g.neighbors[i as usize]);
    }
    let mut k = 2usize;

    while !level.is_empty() {
        let count = level.len() / k;
        let cliques = &level;

        // Maximality flags (Map over cliques).
        let maximal: Vec<u32> = dpp::map_indexed(bk, count, |c| {
            u32::from(!has_extension(g, &cliques[c * k..(c + 1) * k]))
        });
        for c in 0..count {
            if maximal[c] == 1 {
                out.push(&cliques[c * k..(c + 1) * k]);
            }
        }

        // Ascending extensions: w > max(C), adjacent to all of C.
        // Map: count per clique.
        let ext_counts: Vec<u32> = dpp::map_indexed(bk, count, |c| {
            let cl = &cliques[c * k..(c + 1) * k];
            let max = cl[k - 1];
            g.neighbors_of(max)
                .iter()
                .filter(|&&w| w > max && adjacent_to_all(g, w, &cl[..k - 1]))
                .count() as u32
        });
        // Scan: output offsets.
        let (offs, total) =
            dpp::scan_exclusive(bk, &ext_counts, 0u32, |a, b| a + b);
        if total == 0 {
            break;
        }
        // Map: fill the (k+1)-clique array.
        let mut next = vec![0u32; total as usize * (k + 1)];
        {
            let win = crate::dpp::core::SharedSlice::new(&mut next);
            let offs_ref = &offs;
            bk.for_chunks(count, |s, e| {
                for c in s..e {
                    let cl = &cliques[c * k..(c + 1) * k];
                    let max = cl[k - 1];
                    let mut slot = offs_ref[c] as usize;
                    for &w in g.neighbors_of(max) {
                        if w > max && adjacent_to_all(g, w, &cl[..k - 1]) {
                            let base = slot * (k + 1);
                            for (j, &u) in cl.iter().enumerate() {
                                unsafe { win.write(base + j, u) };
                            }
                            unsafe { win.write(base + k, w) };
                            slot += 1;
                        }
                    }
                }
            });
        }
        level = next;
        k += 1;
        assert!(k <= 64, "clique size blew up — not a RAG-like graph?");
    }
    out.canonicalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;
    use crate::util::Pcg32;

    /// Build a CSR from an undirected edge list.
    fn csr(n: usize, edges: &[(u32, u32)]) -> Csr {
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(a, b) in edges {
            adj[a as usize].push(b);
            adj[b as usize].push(a);
        }
        let mut offsets = vec![0u32];
        let mut neighbors = Vec::new();
        for l in adj.iter_mut() {
            l.sort_unstable();
            l.dedup();
            neighbors.extend_from_slice(l);
            offsets.push(neighbors.len() as u32);
        }
        Csr { offsets, neighbors }
    }

    fn backends() -> Vec<Backend> {
        vec![
            Backend::Serial,
            Backend::threaded_with_grain(Pool::new(4), 16),
        ]
    }

    #[test]
    fn triangle_plus_tail() {
        // 0-1-2 triangle, 2-3 tail: maximal cliques {0,1,2}, {2,3}
        let g = csr(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let want = vec![vec![0, 1, 2], vec![2, 3]];
        assert_eq!(enumerate_serial(&g).normalized(), want);
        for bk in backends() {
            assert_eq!(enumerate_dpp(&bk, &g).normalized(), want);
        }
    }

    #[test]
    fn isolated_vertices_are_cliques() {
        let g = csr(3, &[(0, 1)]);
        let want = vec![vec![0, 1], vec![2]];
        assert_eq!(enumerate_serial(&g).normalized(), want);
        for bk in backends() {
            assert_eq!(enumerate_dpp(&bk, &g).normalized(), want);
        }
    }

    #[test]
    fn k4_single_clique() {
        let g = csr(4, &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let want = vec![vec![0, 1, 2, 3]];
        assert_eq!(enumerate_serial(&g).normalized(), want);
        for bk in backends() {
            assert_eq!(enumerate_dpp(&bk, &g).normalized(), want);
        }
    }

    #[test]
    fn moon_graph_overlapping_cliques() {
        // Two triangles sharing an edge: {0,1,2}, {1,2,3}
        let g = csr(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]);
        let want = vec![vec![0, 1, 2], vec![1, 2, 3]];
        assert_eq!(enumerate_serial(&g).normalized(), want);
        for bk in backends() {
            assert_eq!(enumerate_dpp(&bk, &g).normalized(), want);
        }
    }

    #[test]
    fn random_sparse_graphs_agree() {
        let mut rng = Pcg32::seeded(99);
        for trial in 0..10 {
            let n = 30 + (trial * 7) % 40;
            let m = n * 2;
            let mut edges = Vec::new();
            for _ in 0..m {
                let a = rng.below(n as u32);
                let b = rng.below(n as u32);
                if a != b {
                    edges.push((a.min(b), a.max(b)));
                }
            }
            let g = csr(n, &edges);
            let want = enumerate_serial(&g).normalized();
            for bk in backends() {
                assert_eq!(enumerate_dpp(&bk, &g).normalized(), want,
                           "trial {trial}");
            }
        }
    }

    #[test]
    fn cliques_cover_all_vertices() {
        // Every vertex appears in at least one maximal clique.
        let mut rng = Pcg32::seeded(5);
        let n = 50;
        let mut edges = Vec::new();
        for _ in 0..80 {
            let a = rng.below(n as u32);
            let b = rng.below(n as u32);
            if a != b {
                edges.push((a.min(b), a.max(b)));
            }
        }
        let g = csr(n, &edges);
        let cs = enumerate_serial(&g);
        let mut seen = vec![false; n];
        for i in 0..cs.num_cliques() {
            for &v in cs.clique(i) {
                seen[v as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
