//! [`PmpEngine`] — particle max-product as a drop-in
//! [`Engine`](crate::mrf::Engine) in the shared EM outer loop.
//!
//! The solver optimizes the continuous objective; the EM loop needs
//! discrete Potts labels and hood energies. The bridge, per EM
//! iteration:
//!
//! 1. Refresh the [`ContinuousModel`]'s scales from the current
//!    (mu, sigma): `σ` = the class-sigma mean (floored like
//!    [`params::SIGMA_FLOOR`]), truncation = the class separation in
//!    σ units — so the continuous prior adapts as EM sharpens the
//!    classes.
//! 2. Run [`super::solve`], warm-starting the particle tensor from
//!    the previous EM iteration (proposal streams are re-seeded per
//!    EM iteration, so fresh candidates keep arriving).
//! 3. Threshold the decoded continuous labels into classes by
//!    per-class Gaussian energy (ties → class 0, like every engine),
//!    score with the shared hood energy
//!    ([`crate::mrf::config_energy`]) so histories are directly
//!    comparable, and re-estimate (mu, sigma) from the hood-member
//!    instances exactly as the discrete engines do.
//!
//! The extra deliverables over the discrete engines ride in
//! [`EmResult::pmp`](crate::mrf::EmResult::pmp): total particle
//! count, mean proposal acceptance, and the final continuous
//! max-marginal energy.

use std::sync::Arc;

use crate::config::MrfConfig;
use crate::dpp::{Device, IntoDevice, Workspace, WorkspaceStats};
use crate::mrf::continuous::ContinuousModel;
use crate::mrf::{self, params, ConvergenceWindow, Engine, EmResult,
                 MrfModel};
use crate::util::splitmix64;

use super::{solve, PmpConfig, PmpStats};

pub struct PmpEngine {
    device: Arc<dyn Device>,
    pub pmp: PmpConfig,
    /// Scratch pool for the per-round particle tensors; one per
    /// engine, so each scheduler lane amortizes the grown/pruned
    /// buffers across its slices (DESIGN.md §10).
    ws: Workspace,
}

impl PmpEngine {
    /// Engine on any device — accepts a concrete device, an
    /// `Arc<dyn Device>`, or the deprecated `Backend` spelling.
    pub fn new(device: impl IntoDevice, pmp: PmpConfig) -> Self {
        PmpEngine { device: device.into_device(), pmp,
                    ws: Workspace::new() }
    }

    /// The device every solver round of this engine executes on.
    pub fn device(&self) -> &Arc<dyn Device> {
        &self.device
    }

    /// Counters of the engine-held scratch pool (see
    /// [`crate::dpp::Workspace::stats`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use dpp_pmrf::pmp::{PmpConfig, PmpEngine};
    /// use dpp_pmrf::dpp::SerialDevice;
    /// let engine = PmpEngine::new(SerialDevice, PmpConfig::default());
    /// assert_eq!(engine.workspace_stats().misses, 0);
    /// ```
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.ws.stats()
    }
}

/// Class of a continuous label under (mu, sigma): per-class Gaussian
/// energy `((x−μ_l)/σ_l)²/2 + ln σ_l`, ties → class 0 — the same
/// deterministic tie rule every discrete engine uses.
#[inline]
pub(crate) fn classify(x: f32, prm: &crate::mrf::Params) -> u8 {
    let e = |l: usize| {
        let s = prm.sigma[l].max(params::SIGMA_FLOOR);
        let d = (x - prm.mu[l]) / s;
        0.5 * d * d + s.ln()
    };
    u8::from(e(1) < e(0))
}

impl Engine for PmpEngine {
    fn name(&self) -> &'static str {
        "pmp"
    }

    fn run(&self, model: &MrfModel, cfg: &MrfConfig) -> EmResult {
        let bk: &dyn Device = &*self.device;
        let nv = model.num_vertices();
        let y_elem = model.y_elems();

        // Same seeded init as every other engine, so class polarity
        // and first-iteration parameters match across families.
        let (mut prm, mut labels) =
            params::init_random(nv, cfg.beta as f32, cfg.seed);

        // One continuous model per run; only its scalar scales are
        // refreshed per EM iteration (the graph clone happens once).
        let mut cm = ContinuousModel::new(
            model.graph.clone(),
            model.y.clone(),
            25.0,
            (cfg.beta.max(0.0) as f32).max(1e-3),
            4.0,
        );

        let mut em_window =
            ConvergenceWindow::new(cfg.window, cfg.threshold);
        let mut em_iters = 0usize;
        let mut total_rounds = 0usize;
        let k = self.pmp.particles.max(1);
        let mut stats = PmpStats {
            particles: nv * k,
            acceptance: 0.0,
            max_marginal_energy: f64::INFINITY,
        };
        let mut warm: Option<Vec<f32>> = None;

        for em in 0..cfg.em_iters {
            // Inert unless a tracer is armed (telemetry::span).
            let _em_span = crate::telemetry::span_arg(
                "em", "em_iter", "iter", em_iters as u64,
            );
            em_iters += 1;

            cm.sigma = (0.5 * (prm.sigma[0] + prm.sigma[1]))
                .max(params::SIGMA_FLOOR);
            cm.trunc =
                ((prm.mu[1] - prm.mu[0]).abs() / cm.sigma).max(1.0);
            let mut pcfg = self.pmp;
            // Fresh proposal streams each EM iteration; the tensor
            // itself warm-starts from the previous survivors.
            pcfg.seed =
                splitmix64(self.pmp.seed ^ cfg.seed ^ em as u64);

            let run = solve(
                bk, &self.ws, &cm, &pcfg, warm.as_deref(),
                cfg.fixed_iters,
            );
            total_rounds += run.iters;
            for (v, l) in labels.iter_mut().enumerate() {
                *l = classify(run.x_map[v], &prm);
            }
            let (_, total) =
                mrf::config_energy(model, &labels, &prm);

            // Flight-recorder hook (DESIGN.md §13): replay this EM
            // iteration's rounds — decoded continuous energy plus
            // the proposal-acceptance count per round.
            if crate::obs::live() {
                if crate::obs::armed() {
                    for (r, &e) in run.history.iter().enumerate() {
                        crate::obs::pmp_sample(
                            em_iters - 1,
                            r,
                            e,
                            (nv * k) as u64,
                            run.accepted[r],
                        );
                    }
                } else {
                    crate::obs::tick();
                }
            }

            let denom = (run.iters * nv * k) as f64;
            stats.acceptance = if denom > 0.0 {
                run.accepted.iter().sum::<u64>() as f64 / denom
            } else {
                0.0
            };
            stats.max_marginal_energy = run.energy;
            warm = Some(run.particles);

            let mut pstats = params::Stats::default();
            for (e, &v) in model.hoods.members.iter().enumerate() {
                pstats.add(labels[v as usize], y_elem[e]);
            }
            prm = params::update(&pstats, cfg.beta as f32);

            em_window.push(total);
            if em_window.converged() && !cfg.fixed_iters {
                break;
            }
        }
        self.ws.publish_timing();

        EmResult {
            labels,
            em_iters,
            map_iters: total_rounds,
            energy: *em_window.history().last().unwrap_or(&0.0),
            history: em_window.history().to_vec(),
            params: prm,
            lower_bound: None,
            pmp: Some(stats),
            bp: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::Backend;
    use crate::pool::Pool;

    #[test]
    fn pmp_engine_deterministic_across_backends_and_runs() {
        let model = crate::bp::test_model(91);
        let cfg = MrfConfig { em_iters: 3, ..Default::default() };
        let pmp = PmpConfig { iters: 4, ..Default::default() };
        let a = PmpEngine::new(Backend::Serial, pmp).run(&model, &cfg);
        let b = PmpEngine::new(Backend::Serial, pmp).run(&model, &cfg);
        assert_eq!(a, b, "rerun identical");
        let c = PmpEngine::new(
            Backend::threaded_with_grain(Pool::new(4), 64),
            pmp,
        )
        .run(&model, &cfg);
        assert_eq!(a, c, "backend independent");
    }

    #[test]
    fn reports_particle_stats_and_no_certificate() {
        let model = crate::bp::test_model(92);
        let cfg = MrfConfig { em_iters: 2, ..Default::default() };
        let pmp = PmpConfig { iters: 3, ..Default::default() };
        let res = PmpEngine::new(Backend::Serial, pmp).run(&model, &cfg);
        assert_eq!(res.lower_bound, None, "pmp does not certify");
        let s = res.pmp.expect("pmp engine reports particle stats");
        assert_eq!(s.particles, model.num_vertices() * pmp.particles);
        assert!((0.0..=1.0).contains(&s.acceptance), "{}", s.acceptance);
        assert!(s.max_marginal_energy.is_finite());
        assert!(res.labels.iter().all(|&l| l <= 1));
        assert!(res.energy.is_finite());
    }

    #[test]
    fn fixed_iters_runs_exact_round_count() {
        let model = crate::bp::test_model(93);
        let cfg = MrfConfig {
            em_iters: 3,
            fixed_iters: true,
            ..Default::default()
        };
        let pmp = PmpConfig { iters: 5, ..Default::default() };
        let res = PmpEngine::new(Backend::Serial, pmp).run(&model, &cfg);
        assert_eq!(res.em_iters, 3);
        assert_eq!(res.map_iters, 15, "3 EM x 5 pmp rounds");
    }
}
