//! Plain-loop particle max-product oracle.
//!
//! Mirrors [`super::solve`] operation for operation: identical
//! per-item kernels ([`super::propose`], [`super::message_kernel`],
//! [`super::belief_key`], [`super::rank_of`]), identical fold orders
//! (every accumulation starts from the same identity and walks the
//! same ascending index order the `SegmentPlan` folds use), identical
//! f64 scoring. The DPP path only changes *which thread* evaluates
//! each item, never the arithmetic — so this oracle pins it bitwise
//! on every device (`rust/tests/pmp_conformance.rs`).

use crate::mrf::continuous::ContinuousModel;

use super::{
    belief_key, build_edge_index, message_kernel, propose, rank_of,
    PmpConfig, PmpRun,
};

/// Serial reference of [`super::solve`] — same signature minus the
/// device and workspace.
pub fn solve(
    model: &ContinuousModel,
    cfg: &PmpConfig,
    init: Option<&[f32]>,
    fixed_iters: bool,
) -> PmpRun {
    let nv = model.num_vertices();
    let k = cfg.particles.max(1);
    let a = 2 * k;
    let g = &model.graph;
    let nde = g.neighbors.len();
    let edges = build_edge_index(g);

    let mut x = Vec::with_capacity(nv * k);
    match init {
        Some(warm) => {
            assert_eq!(warm.len(), nv * k, "init is nv x K");
            x.extend_from_slice(warm);
        }
        None => {
            for v in 0..nv {
                for s in 0..k {
                    x.push(if s == 0 {
                        model.y[v]
                    } else {
                        propose(
                            cfg.seed, 0, v, s, k, model.y[v],
                            cfg.walk_sigma,
                        )
                    });
                }
            }
        }
    }

    let mut x_best = vec![0.0f32; nv];
    let mut e_best = f64::INFINITY;
    let mut history = Vec::new();
    let mut accepted = Vec::new();
    let mut rounds = 0usize;

    let mut x_aug = vec![0.0f32; nv * a];
    let mut d_aug = vec![0.0f32; nv * a];
    let mut msum = vec![0.0f32; nv * a];
    let mut inc = vec![0.0f32; nv * a];
    let mut msg = vec![0.0f32; nde * a];
    let mut msg_next = vec![0.0f32; nde * a];
    let mut keys = vec![0u64; nv];
    let mut x_dec = vec![0.0f32; nv];
    let mut kept: Vec<u32> = Vec::with_capacity(nv * k);
    let mut x_new = vec![0.0f32; nv * k];

    for round in 0..cfg.iters.max(1) {
        rounds += 1;
        // 1. Propose/augment.
        for t in 0..nv * a {
            let (v, s) = (t / a, t % a);
            x_aug[t] = if s < k {
                x[v * k + s]
            } else {
                propose(
                    cfg.seed,
                    round + 1,
                    v,
                    s - k,
                    k,
                    x[v * k + (s - k)],
                    cfg.walk_sigma,
                )
            };
        }
        for t in 0..nv * a {
            d_aug[t] = model.data_energy(t / a, x_aug[t]);
        }
        msg.fill(0.0);

        // 2. Min-sum sweeps. The belief accumulation walks each CSR
        //    row ascending from 0.0 — the exact `SegmentPlan` fold.
        let beliefs = |msg: &[f32],
                       inc: &mut [f32],
                       msum: &mut [f32]| {
            for j in 0..a {
                for v in 0..nv {
                    let (s, e) = (
                        g.offsets[v] as usize,
                        g.offsets[v + 1] as usize,
                    );
                    let mut acc = 0.0f32;
                    for p in s..e {
                        acc += msg[edges.rev[p] as usize * a + j];
                    }
                    inc[j * nv + v] = acc;
                }
            }
            for t in 0..nv * a {
                msum[t] = d_aug[t] + inc[(t % a) * nv + t / a];
            }
        };
        for _ in 0..cfg.sweeps.max(1) {
            beliefs(&msg, &mut inc, &mut msum);
            for (t, slot) in msg_next.iter_mut().enumerate() {
                *slot = message_kernel(
                    model, &x_aug, &msum, &msg, &edges.src,
                    &g.neighbors, &edges.rev, a, t,
                );
            }
            std::mem::swap(&mut msg, &mut msg_next);
        }
        beliefs(&msg, &mut inc, &mut msum);

        // 3. Decode: per-vertex key min, ascending slots, from the
        //    same u64::MAX identity as the particle-plan fold.
        for v in 0..nv {
            let mut acc = u64::MAX;
            for t in v * a..(v + 1) * a {
                acc = acc.min(belief_key(msum[t], t % a));
            }
            keys[v] = acc;
        }
        for v in 0..nv {
            x_dec[v] = x_aug[v * a + (keys[v] & 0xFFFF_FFFF) as usize];
        }
        let e = model.energy(&x_dec);
        history.push(e);
        if e < e_best {
            e_best = e;
            x_best.copy_from_slice(&x_dec);
        }

        // 4. Select-and-prune, ascending index order like CopyIf.
        kept.clear();
        for t in 0..nv * a {
            if rank_of(&msum, t / a, a, t % a) < k {
                kept.push(t as u32);
            }
        }
        debug_assert_eq!(kept.len(), nv * k);
        for (t, &src) in kept.iter().enumerate() {
            x_new[t] = x_aug[src as usize];
        }
        std::mem::swap(&mut x, &mut x_new);
        accepted.push(
            kept.iter().filter(|&&gg| (gg as usize % a) >= k).count()
                as u64,
        );

        if !fixed_iters && history.len() >= 2 {
            let prev = history[history.len() - 2];
            if (prev - e).abs() <= cfg.tol * e.abs().max(1.0) {
                break;
            }
        }
    }

    PmpRun {
        x_map: x_best,
        energy: e_best,
        history,
        accepted,
        particles: x,
        iters: rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::{SerialDevice, Workspace};
    use crate::mrf::continuous::synthetic_denoise;

    #[test]
    fn serial_matches_dpp_serial_device_bitwise() {
        let (m, _) = synthetic_denoise(7, 5, 9.0, 21);
        let cfg = PmpConfig { iters: 4, ..Default::default() };
        let ws = Workspace::new();
        let oracle = solve(&m, &cfg, None, true);
        let dpp = super::super::solve(
            &SerialDevice, &ws, &m, &cfg, None, true,
        );
        assert_eq!(oracle, dpp, "oracle vs DPP path on SerialDevice");
        let bits_a: Vec<u32> =
            oracle.x_map.iter().map(|f| f.to_bits()).collect();
        let bits_b: Vec<u32> =
            dpp.x_map.iter().map(|f| f.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "bit-exact labels");
        assert_eq!(oracle.energy.to_bits(), dpp.energy.to_bits());
    }
}
