//! Particle max-product (D-PMP) over continuous label spaces — the
//! fourth optimizer family (DESIGN.md §14).
//!
//! The discrete engines (MAP / BP / dual) optimize Potts labels; this
//! subsystem optimizes a [`ContinuousModel`]
//! (`crate::mrf::continuous`) whose labels are real numbers, by
//! maintaining a small **particle set** per vertex and running
//! max-product (min-sum in energy form) message passing over the
//! particle-indexed discretization, following the D-PMP loop
//! (Pacheco et al.; pyDPMP):
//!
//! 1. **Propose/augment** — each of the `K` survivors spawns one
//!    random-walk proposal, growing every vertex's set to `A = 2K`.
//!    Proposals are seeded per `(round, vertex, slot)` through
//!    dedicated [`Pcg32`] streams, so they are identical regardless
//!    of execution order — the device and lane count can never change
//!    the candidate sets.
//! 2. **Message passing** — `sweeps` synchronous min-sum sweeps over
//!    the augmented sets: belief accumulation is a segmented reduce
//!    over the **cached CSR plan** (one fold per particle column),
//!    message minimization is a DPP map over particle pairs.
//! 3. **Decode** — per-vertex argmin of the beliefs via a segmented
//!    min over the particle plan (keys pack the belief's
//!    total-order bits with the slot index, so ties break to the
//!    lowest slot on every device), scored in f64 by
//!    [`ContinuousModel::energy`]; the best decoding over all rounds
//!    is the answer.
//! 4. **Select-and-prune** — keep each vertex's `K` best-belief
//!    particles via [`select_indices`](crate::dpp::select_indices) +
//!    `gather`, shrinking `A → K` for the next round.
//!
//! Per-round tensors repeatedly grow (`nv·A`) and shrink (`nv·K`), so
//! every buffer is drawn from the engine's [`Workspace`] — after the
//! first round the loop allocates nothing.
//!
//! [`serial`] holds the plain-loop oracle; [`solve`] is the DPP path.
//! Both call the same `#[inline]` per-item kernels and fold in the
//! same order from the same identities, so their outputs are
//! **bitwise identical** on every registered device
//! (`rust/tests/pmp_conformance.rs`). [`engine::PmpEngine`] adapts
//! the solver to the discrete [`Engine`](crate::mrf::Engine) EM loop.

pub mod engine;
pub mod serial;

pub use engine::PmpEngine;

use crate::dpp::{self, Device, SegmentPlan, Workspace};
use crate::graph::Csr;
use crate::mrf::continuous::ContinuousModel;
use crate::util::{splitmix64, Pcg32};

/// Knobs of the particle max-product solver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PmpConfig {
    /// Particles kept per vertex after pruning (`K`); the augmented
    /// sets hold `2K`.
    pub particles: usize,
    /// Maximum propose→pass→prune rounds per solve.
    pub iters: usize,
    /// Synchronous min-sum sweeps per round.
    pub sweeps: usize,
    /// Random-walk proposal step, in label units.
    pub walk_sigma: f32,
    /// Relative decoded-energy stall that ends the round loop.
    pub tol: f64,
    /// Proposal-stream seed.
    pub seed: u64,
}

impl Default for PmpConfig {
    fn default() -> PmpConfig {
        PmpConfig {
            particles: 6,
            iters: 10,
            sweeps: 3,
            walk_sigma: 12.0,
            tol: 1e-4,
            seed: 0xD1F0_5EED,
        }
    }
}

/// Per-run statistics the PMP engine surfaces through
/// [`EmResult`](crate::mrf::EmResult) and `RunReport`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PmpStats {
    /// Total particles maintained after pruning (`nv · K`).
    pub particles: usize,
    /// Mean fraction of pruned slots won by fresh proposals.
    pub acceptance: f64,
    /// Final decoded max-marginal energy (continuous objective).
    pub max_marginal_energy: f64,
}

/// Output of one [`solve`] / [`serial::solve`] call.
#[derive(Debug, Clone, PartialEq)]
pub struct PmpRun {
    /// Best decoded labeling over all rounds.
    pub x_map: Vec<f32>,
    /// Its continuous energy (min over `history`).
    pub energy: f64,
    /// Decoded energy per round.
    pub history: Vec<f64>,
    /// Per round: pruned slots won by fresh proposals (of `nv · K`).
    pub accepted: Vec<u64>,
    /// Final pruned particle tensor (`nv · K`), for warm starts.
    pub particles: Vec<f32>,
    /// Rounds executed.
    pub iters: usize,
}

// ---------------------------------------------------------------
// Shared per-item kernels. Every arithmetic expression both solver
// paths evaluate lives here, `#[inline]`, parameterized only by
// plain indices — the foundation of the bitwise-identity contract.
// ---------------------------------------------------------------

/// Random-walk proposal for `(round, vertex, slot)`. Stream-seeded:
/// the draw depends only on the coordinates, never on execution
/// order. `round` 0 is the cold-start init (slot 0 = the observation
/// itself); proposals in round `t` use `round = t + 1`.
#[inline]
pub(crate) fn propose(
    seed: u64,
    round: usize,
    v: usize,
    slot: usize,
    k: usize,
    base: f32,
    walk: f32,
) -> f32 {
    let mut rng = Pcg32::new(
        splitmix64(seed ^ (round as u64).wrapping_mul(0x9E37_79B9)),
        (v * k + slot) as u64,
    );
    base + walk * rng.normal() as f32
}

/// Min-sum message for directed edge `p` (from `src[p]` to
/// `nbrs[p]`) at receiver slot `j`: minimize over the sender's `a`
/// slots, subtracting the reverse message so the sender's belief
/// becomes its "all-but-receiver" max-marginal. Strict `<` keeps the
/// first minimum — deterministic on every device.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn message_kernel(
    model: &ContinuousModel,
    x_aug: &[f32],
    msum: &[f32],
    msg: &[f32],
    src: &[u32],
    nbrs: &[u32],
    rev: &[u32],
    a: usize,
    t: usize,
) -> f32 {
    let (p, j) = (t / a, t % a);
    let u = src[p] as usize;
    let v = nbrs[p] as usize;
    let xj = x_aug[v * a + j];
    let rp = rev[p] as usize;
    let mut best = f32::INFINITY;
    for i in 0..a {
        let c = model.pair_energy(x_aug[u * a + i], xj)
            + (msum[u * a + i] - msg[rp * a + i]);
        if c < best {
            best = c;
        }
    }
    best
}

/// Map a belief onto `u64` so integer `min` is an exact,
/// tie-deterministic argmin: high 32 bits are the f32's total-order
/// bits, low 32 bits the slot index (ties → lowest slot).
#[inline]
pub(crate) fn belief_key(val: f32, slot: usize) -> u64 {
    let b = val.to_bits();
    let ord = if b & 0x8000_0000 != 0 { !b } else { b ^ 0x8000_0000 };
    ((ord as u64) << 32) | slot as u64
}

/// Rank of slot `slot` within vertex `v`'s `a` beliefs (0 = best);
/// counted over the packed keys, so the ordering is total and
/// device-independent.
#[inline]
pub(crate) fn rank_of(bel: &[f32], v: usize, a: usize, slot: usize)
    -> usize {
    let me = belief_key(bel[v * a + slot], slot);
    let mut r = 0usize;
    for b in 0..a {
        if belief_key(bel[v * a + b], b) < me {
            r += 1;
        }
    }
    r
}

// ---------------------------------------------------------------
// Graph preparation, shared by both paths.
// ---------------------------------------------------------------

/// Directed-edge index over a symmetric CSR: `src[p]` = owning
/// vertex of slot `p`, `rev[p]` = slot of the reverse edge.
#[derive(Debug, Clone)]
pub(crate) struct EdgeIndex {
    pub src: Vec<u32>,
    pub rev: Vec<u32>,
}

pub(crate) fn build_edge_index(g: &Csr) -> EdgeIndex {
    let nde = g.neighbors.len();
    let mut src = vec![0u32; nde];
    for v in 0..g.num_vertices() {
        let (s, e) =
            (g.offsets[v] as usize, g.offsets[v + 1] as usize);
        for sp in &mut src[s..e] {
            *sp = v as u32;
        }
    }
    let mut rev = vec![0u32; nde];
    for (p, rp) in rev.iter_mut().enumerate() {
        let u = src[p];
        let v = g.neighbors[p] as usize;
        let (s, e) =
            (g.offsets[v] as usize, g.offsets[v + 1] as usize);
        *rp = (s..e)
            .find(|&q| g.neighbors[q] == u)
            .expect("pmp needs a symmetric CSR") as u32;
    }
    EdgeIndex { src, rev }
}

/// Uniform particle segments (one length-`a` segment per vertex) as
/// CSR offsets — feeds the decode plan.
pub(crate) fn particle_offsets(nv: usize, a: usize) -> Vec<u32> {
    (0..=nv).map(|v| (v * a) as u32).collect()
}

// ---------------------------------------------------------------
// The DPP path.
// ---------------------------------------------------------------

/// Run particle max-product on `model` with the DPP primitives on
/// device `bk`, drawing every per-round tensor from `ws`.
///
/// `init` (length `nv · particles`) warm-starts the particle tensor;
/// `None` seeds from the observations. With `fixed_iters` the round
/// loop always runs `cfg.iters` rounds (tests compare paths exactly).
///
/// Bitwise identical to [`serial::solve`] on every registered device
/// — see the module docs for why.
pub fn solve(
    bk: &dyn Device,
    ws: &Workspace,
    model: &ContinuousModel,
    cfg: &PmpConfig,
    init: Option<&[f32]>,
    fixed_iters: bool,
) -> PmpRun {
    let nv = model.num_vertices();
    let k = cfg.particles.max(1);
    let a = 2 * k;
    let nde = model.graph.neighbors.len();
    assert!(
        nv.checked_mul(a).is_some_and(|n| n < u32::MAX as usize),
        "particle tensor must index in u32"
    );
    let edges = build_edge_index(&model.graph);
    // The cached plans: CSR rows for belief accumulation, uniform
    // particle segments for the decode argmin. Built once per solve,
    // reused every sweep of every round.
    let vertex_plan = SegmentPlan::from_csr_offsets(&model.graph.offsets);
    let poffsets = particle_offsets(nv, a);
    let particle_plan = SegmentPlan::from_csr_offsets(&poffsets);

    let mut x = ws.take_spare::<f32>(nv * k);
    match init {
        Some(warm) => {
            assert_eq!(warm.len(), nv * k, "init is nv x K");
            x.extend_from_slice(warm);
        }
        None => {
            for v in 0..nv {
                for s in 0..k {
                    x.push(if s == 0 {
                        model.y[v]
                    } else {
                        propose(
                            cfg.seed, 0, v, s, k, model.y[v],
                            cfg.walk_sigma,
                        )
                    });
                }
            }
        }
    }

    let mut x_best = vec![0.0f32; nv];
    let mut e_best = f64::INFINITY;
    let mut history = Vec::new();
    let mut accepted = Vec::new();
    let mut rounds = 0usize;

    for round in 0..cfg.iters.max(1) {
        rounds += 1;
        let _span = crate::telemetry::span_arg(
            "map", "pmp_round", "round", round as u64,
        );
        // Per-round scratch: augmented tensors (nv·A / nde·A) are
        // taken here and returned at the end of the round, so the
        // pool alternately serves the grown and pruned shapes.
        let mut x_aug = ws.take_spare::<f32>(nv * a);
        let mut d_aug = ws.take_spare::<f32>(nv * a);
        let mut msum = ws.take_spare::<f32>(nv * a);
        let mut inc = ws.take_filled::<f32>(nv * a, 0.0);
        let mut msg = ws.take_filled::<f32>(nde * a, 0.0);
        let mut msg_next = ws.take_spare::<f32>(nde * a);
        let mut keys = ws.take_filled::<u64>(nv, 0);
        let mut x_dec = ws.take_spare::<f32>(nv);
        let mut kept = ws.take_spare::<u32>(nv * k);
        let mut x_new = ws.take_spare::<f32>(nv * k);

        // 1. Propose/augment: slots 0..K carry the survivors, slots
        //    K..A one walk proposal each.
        {
            let xr: &[f32] = &x;
            dpp::map_indexed_into(
                bk,
                nv * a,
                |t| {
                    let (v, s) = (t / a, t % a);
                    if s < k {
                        xr[v * k + s]
                    } else {
                        propose(
                            cfg.seed,
                            round + 1,
                            v,
                            s - k,
                            k,
                            xr[v * k + (s - k)],
                            cfg.walk_sigma,
                        )
                    }
                },
                &mut x_aug,
            );
        }
        dpp::map_indexed_into(
            bk,
            nv * a,
            |t| model.data_energy(t / a, x_aug[t]),
            &mut d_aug,
        );

        // 2. Min-sum sweeps. Beliefs: one segmented reduce over the
        //    CSR plan per particle column (fold from 0.0 in slot
        //    order); messages: a map over nde·A receiver slots, each
        //    minimizing over the sender's A particles.
        let beliefs = |msg: &[f32], inc: &mut [f32], msum: &mut Vec<f32>| {
            for j in 0..a {
                vertex_plan.reduce_segments_map_into(
                    bk,
                    |p| msg[edges.rev[p] as usize * a + j],
                    0.0f32,
                    |s, m| s + m,
                    &mut inc[j * nv..(j + 1) * nv],
                );
            }
            dpp::map_indexed_into(
                bk,
                nv * a,
                |t| d_aug[t] + inc[(t % a) * nv + t / a],
                msum,
            );
        };
        for _ in 0..cfg.sweeps.max(1) {
            beliefs(&msg, &mut inc, &mut msum);
            dpp::map_indexed_into(
                bk,
                nde * a,
                |t| {
                    message_kernel(
                        model, &x_aug, &msum, &msg, &edges.src,
                        &model.graph.neighbors, &edges.rev, a, t,
                    )
                },
                &mut msg_next,
            );
            std::mem::swap(&mut *msg, &mut *msg_next);
        }
        beliefs(&msg, &mut inc, &mut msum);

        // 3. Decode: segmented argmin over the particle plan.
        particle_plan.reduce_segments_map_into(
            bk,
            |t| belief_key(msum[t], t % a),
            u64::MAX,
            u64::min,
            &mut keys,
        );
        dpp::map_indexed_into(
            bk,
            nv,
            |v| x_aug[v * a + (keys[v] & 0xFFFF_FFFF) as usize],
            &mut x_dec,
        );
        let e = model.energy(&x_dec);
        history.push(e);
        if e < e_best {
            e_best = e;
            x_best.copy_from_slice(&x_dec);
        }

        // 4. Select-and-prune: each vertex keeps its K best-ranked
        //    slots (ranks are distinct, so exactly nv·K survive).
        dpp::select_indices_into(
            bk,
            ws,
            nv * a,
            |t| rank_of(&msum, t / a, a, t % a) < k,
            &mut kept,
        );
        debug_assert_eq!(kept.len(), nv * k);
        dpp::gather_into(bk, &x_aug, &kept, &mut x_new);
        std::mem::swap(&mut *x, &mut *x_new);
        accepted.push(
            kept.iter().filter(|&&g| (g as usize % a) >= k).count()
                as u64,
        );

        if !fixed_iters && history.len() >= 2 {
            let prev = history[history.len() - 2];
            if (prev - e).abs() <= cfg.tol * e.abs().max(1.0) {
                break;
            }
        }
    }

    PmpRun {
        x_map: x_best,
        energy: e_best,
        history,
        accepted,
        particles: x.to_vec(),
        iters: rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::SerialDevice;
    use crate::mrf::continuous::synthetic_denoise;

    #[test]
    fn edge_index_inverts_itself() {
        let (m, _) = synthetic_denoise(4, 3, 5.0, 7);
        let idx = build_edge_index(&m.graph);
        for p in 0..m.graph.neighbors.len() {
            let q = idx.rev[p] as usize;
            assert_eq!(idx.rev[q] as usize, p, "rev is an involution");
            assert_eq!(idx.src[q], m.graph.neighbors[p]);
            assert_eq!(m.graph.neighbors[q], idx.src[p]);
        }
    }

    #[test]
    fn belief_key_orders_like_f32() {
        let vals = [-3.5f32, -0.0, 0.0, 1.0, 7.25, f32::INFINITY];
        for w in vals.windows(2) {
            assert!(
                belief_key(w[0], 0) < belief_key(w[1], 0)
                    || w[0].to_bits() ^ w[1].to_bits()
                        == 0x8000_0000,
                "{} < {}",
                w[0],
                w[1]
            );
        }
        // Equal values tie-break on slot.
        assert!(belief_key(2.0, 1) < belief_key(2.0, 2));
    }

    #[test]
    fn solve_reduces_energy_and_prunes_to_k() {
        let (m, _) = synthetic_denoise(8, 6, 10.0, 11);
        let cfg = PmpConfig { iters: 6, ..Default::default() };
        let ws = Workspace::new();
        let run =
            solve(&SerialDevice, &ws, &m, &cfg, None, false);
        assert_eq!(run.x_map.len(), m.num_vertices());
        assert_eq!(
            run.particles.len(),
            m.num_vertices() * cfg.particles
        );
        assert_eq!(run.history.len(), run.iters);
        assert_eq!(run.energy, run.history.iter().cloned()
            .fold(f64::INFINITY, f64::min));
        // Optimizing must beat the raw noisy observation.
        assert!(run.energy <= m.energy(&m.y), "{} vs obs", run.energy);
    }

    #[test]
    fn warm_start_resumes_from_given_particles() {
        let (m, _) = synthetic_denoise(5, 4, 8.0, 3);
        let cfg = PmpConfig {
            iters: 1,
            walk_sigma: 0.0,
            ..Default::default()
        };
        let ws = Workspace::new();
        let first =
            solve(&SerialDevice, &ws, &m, &cfg, None, true);
        let second = solve(
            &SerialDevice, &ws, &m, &cfg,
            Some(&first.particles), true,
        );
        // Zero walk: proposals duplicate survivors, so the particle
        // set is a fixpoint and the decode can only stay or improve.
        assert!(second.energy <= first.energy);
        assert_eq!(second.particles, first.particles);
    }
}
